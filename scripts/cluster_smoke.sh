#!/usr/bin/env bash
# Cluster smoke test: boot two `lrbi serve --worker` processes and a
# `--router` over them (one output-column shard each, docs/CLUSTER.md),
# then prove the tier behaves the way the docs promise:
#   - INFER traffic routed through the scatter/gather path serves
#     cleanly and the per-worker counters surface on the router's
#     Prometheus page (net_worker_requests grows with shard fan-out);
#   - killing a worker degrades into a *typed* client failure (never a
#     hang), moves net_worker_unavailable, and the supervisor opens the
#     dead replica's circuit breaker (net_breaker_opens);
#   - restarting the worker on its original port reintegrates it with
#     no operator SWAP and no router restart (net_reintegrations), and
#     the re-driven traffic's logits are byte-identical to the pre-kill
#     capture;
#   - the router and both workers still shut down gracefully over the
#     wire.
# Finishes with the cluster test suite (cross-process bit-identity for
# every kernel format × shard count, rolling swap, model-key routing).
# Part of scripts/verify.sh and the CI cluster-smoke job.
set -euo pipefail
cd "$(dirname "$0")/../rust"

LRBI=./target/release/lrbi
[ -x "$LRBI" ] || cargo build --release

w1_log="$(mktemp)"; w2_log="$(mktemp)"; w2b_log="$(mktemp)"; r_log="$(mktemp)"
w1_pid=""; w2_pid=""; w2b_pid=""; r_pid=""
cleanup() {
  for pid in "$r_pid" "$w1_pid" "$w2_pid" "$w2b_pid"; do
    [ -n "$pid" ] && kill "$pid" 2>/dev/null || true
  done
  rm -f "$w1_log" "$w2_log" "$w2b_log" "$r_log"
}
trap cleanup EXIT

# Wait for a server log to print its bound address, then echo it.
wait_addr() { # $1=log $2=pid $3=name
  for _ in $(seq 1 100); do
    grep -q "listening on " "$1" && break
    kill -0 "$2" 2>/dev/null || { echo "$3 died:" >&2; cat "$1" >&2; exit 1; }
    sleep 0.1
  done
  grep -q "listening on " "$1" || { echo "$3 never came up:" >&2; cat "$1" >&2; exit 1; }
  sed -n 's/^listening on \([0-9.:]*\) .*/\1/p' "$1" | head -n1
}

echo "== boot: two workers (synthetic lowrank model, 10 output columns)"
"$LRBI" serve --worker 127.0.0.1:0 --kernel lowrank --threads 2 --max-wait-ms 1 \
  >"$w1_log" 2>&1 &
w1_pid=$!
"$LRBI" serve --worker 127.0.0.1:0 --kernel lowrank --threads 2 --max-wait-ms 1 \
  >"$w2_log" 2>&1 &
w2_pid=$!
w1=$(wait_addr "$w1_log" "$w1_pid" "worker 1")
w2=$(wait_addr "$w2_log" "$w2_pid" "worker 2")
echo "   workers $w1, $w2"

echo "== boot: router over 2 shards (columns split 0..5, 5..10), fast supervision"
"$LRBI" serve --router 127.0.0.1:0 --workers "$w1,$w2" --shards 2 \
  --health-interval-ms 200 --breaker-failures 1 --breaker-cooldown-ms 200 \
  --breaker-successes 1 \
  --metrics-addr 127.0.0.1:0 >"$r_log" 2>&1 &
r_pid=$!
raddr=$(wait_addr "$r_log" "$r_pid" "router")
maddr=$(sed -n 's|^metrics on http://\([0-9.:]*\) .*|\1|p' "$r_log" | head -n1)
[ -n "$maddr" ] || { echo "could not parse router metrics address:"; cat "$r_log"; exit 1; }
grep -q "router over 2 shard(s)" "$r_log" \
  || { echo "router banner missing the shard map:"; cat "$r_log"; exit 1; }
echo "   router $raddr, metrics $maddr"

echo "== traffic: 16 INFERs routed through scatter/gather"
out=$("$LRBI" serve --connect "$raddr" --requests 16 --rows 2)
echo "   $out"

scrape_body() {
  local mhost=${maddr%:*} mport=${maddr##*:}
  exec 3<>"/dev/tcp/${mhost}/${mport}"
  printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3
  cat <&3 | awk 'body{print} /^\r?$/{body=1}'
  exec 3<&- 3>&-
}

counter() { # $1=body $2=name
  printf '%s\n' "$1" | sed -n "s/^lrbi_$2 \([0-9]*\).*/\1/p"
}

# Poll the scrape until a counter reaches a floor (supervision is
# asynchronous: probes tick every ~200ms under the flags above).
wait_counter() { # $1=name $2=floor $3=iterations (x 0.2s)
  local got=""
  for _ in $(seq 1 "$3"); do
    got=$(counter "$(scrape_body)" "$1")
    if [ -n "$got" ] && [ "$got" -ge "$2" ]; then echo "$got"; return 0; fi
    sleep 0.2
  done
  echo "timed out waiting for lrbi_$1 >= $2 (last: '${got:-missing}')" >&2
  return 1
}

echo "== scrape: worker-tier counters surface on the router's metrics page"
body=$(scrape_body)
# 16 requests x 2 shards = 32 scatters minimum.
for want in "net_worker_requests 32" "net_requests 16"; do
  name=${want% *}; floor=${want#* }
  got=$(counter "$body" "$name")
  [ -n "$got" ] && [ "$got" -ge "$floor" ] \
    || { echo "expected lrbi_$name >= $floor, got '${got:-missing}'"; exit 1; }
  echo "   lrbi_$name = $got (>= $floor)"
done
fails=$(counter "$body" "net_worker_failures")
[ "${fails:-0}" -eq 0 ] || { echo "healthy cluster reported $fails worker failures"; exit 1; }

echo "== capture: reference logits before the fault (fixed-seed inputs)"
pre_logits=$("$LRBI" serve --connect "$raddr" --requests 4 --rows 2 --print-logits \
  | grep '^logits')
[ -n "$pre_logits" ] || { echo "no logits captured"; exit 1; }

echo "== worker loss: killing worker 2 must be a typed failure, not a hang"
kill "$w2_pid"; wait "$w2_pid" 2>/dev/null || true; w2_pid=""
if "$LRBI" serve --connect "$raddr" --requests 2 --rows 1 >/dev/null 2>&1; then
  echo "expected a typed 'unavailable' failure after losing a shard"; exit 1
fi
echo "   client failed with a typed error, as documented"
got=$(counter "$(scrape_body)" "net_worker_unavailable")
[ -n "$got" ] && [ "$got" -ge 1 ] \
  || { echo "expected lrbi_net_worker_unavailable >= 1, got '${got:-missing}'"; exit 1; }
echo "   lrbi_net_worker_unavailable = $got (>= 1)"

echo "== supervision: the dead replica's breaker opens (no operator action)"
got=$(wait_counter net_breaker_opens 1 50)
echo "   lrbi_net_breaker_opens = $got (>= 1)"
got=$(wait_counter net_health_probes 1 50)
echo "   lrbi_net_health_probes = $got (>= 1)"

echo "== restart: worker 2 comes back on its original port ($w2)"
"$LRBI" serve --worker "$w2" --kernel lowrank --threads 2 --max-wait-ms 1 \
  >"$w2b_log" 2>&1 &
w2b_pid=$!
wait_addr "$w2b_log" "$w2b_pid" "worker 2 (restarted)" >/dev/null

echo "== supervision: automatic reintegration — no SWAP, no router restart"
got=$(wait_counter net_reintegrations 1 75)
echo "   lrbi_net_reintegrations = $got (>= 1)"
kill -0 "$r_pid" 2>/dev/null || { echo "router died during reintegration"; exit 1; }

echo "== traffic: re-driven logits are byte-identical to the pre-kill capture"
post_logits=$("$LRBI" serve --connect "$raddr" --requests 4 --rows 2 --print-logits \
  | grep '^logits')
[ "$pre_logits" = "$post_logits" ] \
  || { echo "logits changed across kill/reintegration"; exit 1; }
echo "   4 requests, identical bytes through the reintegrated fleet"

echo "== graceful shutdown over the wire (router, then both workers)"
"$LRBI" serve --connect "$raddr" --requests 0 --shutdown >/dev/null
wait "$r_pid"; r_pid=""
"$LRBI" serve --connect "$w1" --requests 0 --shutdown >/dev/null
wait "$w1_pid"; w1_pid=""
"$LRBI" serve --connect "$w2" --requests 0 --shutdown >/dev/null
wait "$w2b_pid"; w2b_pid=""

echo "== cluster suite: cross-process bit-identity, rolling swap, key routing"
cargo test -q --release --test cluster

echo "cluster smoke: OK"
