#!/usr/bin/env bash
# Telemetry smoke test: boot `lrbi serve --listen --metrics-addr`,
# drive traffic through the wire protocol, snapshot `lrbi top`,
# scrape the Prometheus endpoint, and validate the exposition format
# line-by-line. Finishes by running the zero-allocation steady-state
# test, proving the hot path stays allocation-free with the telemetry
# histograms recording. Part of scripts/verify.sh and the CI
# telemetry-smoke job; guide: docs/OBSERVABILITY.md.
set -euo pipefail
cd "$(dirname "$0")/../rust"

LRBI=./target/release/lrbi
[ -x "$LRBI" ] || cargo build --release

log="$(mktemp)"
srv_pid=""
cleanup() {
  [ -n "$srv_pid" ] && kill "$srv_pid" 2>/dev/null || true
  rm -f "$log"
}
trap cleanup EXIT

echo "== boot: serve --listen --metrics-addr (lowrank kernel, 2 plan threads)"
"$LRBI" serve --listen 127.0.0.1:0 --metrics-addr 127.0.0.1:0 \
  --kernel lowrank --threads 2 --max-wait-ms 1 >"$log" 2>&1 &
srv_pid=$!

for _ in $(seq 1 100); do
  grep -q "listening on " "$log" && break
  kill -0 "$srv_pid" 2>/dev/null || { echo "server died:"; cat "$log"; exit 1; }
  sleep 0.1
done
grep -q "listening on " "$log" || { echo "server never came up:"; cat "$log"; exit 1; }

addr=$(sed -n 's/^listening on \([0-9.:]*\) .*/\1/p' "$log" | head -n1)
maddr=$(sed -n 's|^metrics on http://\([0-9.:]*\) .*|\1|p' "$log" | head -n1)
[ -n "$addr" ] || { echo "could not parse server address:"; cat "$log"; exit 1; }
[ -n "$maddr" ] || { echo "could not parse metrics address:"; cat "$log"; exit 1; }
echo "   server $addr, metrics $maddr"

echo "== traffic: 32 INFER frames through the wire client"
"$LRBI" serve --connect "$addr" --requests 32 --rows 2 >/dev/null

echo "== lrbi top --iters 1 shows per-stage and per-kernel series"
top_out=$("$LRBI" top --addr "$addr" --iters 1)
echo "$top_out" | grep -q 'stage_ns{stage=spmm}' \
  || { echo "top output missing spmm stage:"; echo "$top_out"; exit 1; }
echo "$top_out" | grep -q 'spmm_ns{kernel=lowrank}' \
  || { echo "top output missing kernel series:"; echo "$top_out"; exit 1; }

echo "== scrape: ${maddr} answers Prometheus text"
mhost=${maddr%:*}
mport=${maddr##*:}
exec 3<>"/dev/tcp/${mhost}/${mport}"
printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3
scrape=$(cat <&3)
exec 3<&- 3>&-

body=$(printf '%s\n' "$scrape" | awk 'body{print} /^\r?$/{body=1}')
for stage in decode queue batch spmm merge write; do
  printf '%s\n' "$body" | grep -q "lrbi_stage_ns{stage=\"$stage\",quantile=\"0.5\"}" \
    || { echo "scrape missing stage '$stage':"; printf '%s\n' "$body"; exit 1; }
done
printf '%s\n' "$body" | grep -q '# TYPE lrbi_stage_ns summary' \
  || { echo "scrape missing TYPE line"; exit 1; }
spmm_count=$(printf '%s\n' "$body" \
  | sed -n 's/^lrbi_stage_ns_count{stage="spmm"} \([0-9]*\).*/\1/p')
[ -n "$spmm_count" ] && [ "$spmm_count" -gt 0 ] \
  || { echo "scrape reports no spmm samples (got '${spmm_count:-}')"; exit 1; }

# every sample line must parse as `name{labels} value` / `name value`
bad=$(printf '%s\n' "$body" | tr -d '\r' | grep -v '^#' | grep -v '^[[:space:]]*$' \
  | grep -Ev '^lrbi_[A-Za-z0-9_]+(\{[^}]*\})? [0-9]+$' || true)
if [ -n "$bad" ]; then
  echo "malformed exposition lines:"
  printf '%s\n' "$bad"
  exit 1
fi

echo "== graceful shutdown over the wire"
"$LRBI" serve --connect "$addr" --requests 0 --shutdown >/dev/null
wait "$srv_pid"
srv_pid=""

echo "== zero-allocation steady state holds with telemetry recording"
cargo test -q --release --test serving \
  steady_state_serving_allocates_nothing_on_the_spmm_hot_path

echo "telemetry smoke: OK"
