#!/usr/bin/env bash
# Chaos smoke test: boot `lrbi serve --listen` under a deterministic
# LRBI_FAULT plan (docs/ROBUSTNESS.md), then prove the stack degrades
# the way the docs promise:
#   - a client with a retry budget recovers from injected transient
#     overload (and its retries are observed);
#   - already-expired deadlines are shed with typed DEADLINE_EXCEEDED
#     frames (and counted, without running spmm for them);
#   - the shed/overload/fault counters all surface on the Prometheus
#     page, so a live fault plan is one scrape away from discovery;
#   - the server still shuts down gracefully over the wire.
# Finishes with the chaos test suite (every injection point against a
# live in-process server). Part of scripts/verify.sh and the CI
# chaos-smoke job.
set -euo pipefail
cd "$(dirname "$0")/../rust"

LRBI=./target/release/lrbi
[ -x "$LRBI" ] || cargo build --release

log="$(mktemp)"
srv_pid=""
cleanup() {
  [ -n "$srv_pid" ] && kill "$srv_pid" 2>/dev/null || true
  rm -f "$log"
}
trap cleanup EXIT

plan="read_stall=1:20, infer_overload=1+2"
echo "== boot: serve --listen under LRBI_FAULT=\"$plan\""
LRBI_FAULT="$plan" "$LRBI" serve --listen 127.0.0.1:0 \
  --metrics-addr 127.0.0.1:0 --kernel lowrank --threads 2 \
  --max-wait-ms 1 >"$log" 2>&1 &
srv_pid=$!

for _ in $(seq 1 100); do
  grep -q "listening on " "$log" && break
  kill -0 "$srv_pid" 2>/dev/null || { echo "server died:"; cat "$log"; exit 1; }
  sleep 0.1
done
grep -q "listening on " "$log" || { echo "server never came up:"; cat "$log"; exit 1; }

addr=$(sed -n 's/^listening on \([0-9.:]*\) .*/\1/p' "$log" | head -n1)
maddr=$(sed -n 's|^metrics on http://\([0-9.:]*\) .*|\1|p' "$log" | head -n1)
[ -n "$addr" ] || { echo "could not parse server address:"; cat "$log"; exit 1; }
[ -n "$maddr" ] || { echo "could not parse metrics address:"; cat "$log"; exit 1; }
echo "   server $addr, metrics $maddr"

echo "== retry: the first two INFERs are injected 'overloaded'; --retries 3 recovers"
out=$("$LRBI" serve --connect "$addr" --requests 8 --rows 1 \
  --retries 3 --retry-base-ms 5)
echo "   $out"
retries=$(printf '%s\n' "$out" | sed -n 's/.* \([0-9]*\) retries observed.*/\1/p')
[ -n "$retries" ] && [ "$retries" -ge 2 ] \
  || { echo "expected >= 2 retries observed, got '${retries:-}'"; exit 1; }

echo "== deadline: --deadline-ms 0 probes the expired-shed path"
out=$("$LRBI" serve --connect "$addr" --requests 4 --rows 1 --deadline-ms 0)
echo "   $out"
shed=$(printf '%s\n' "$out" | sed -n 's/.* \([0-9]*\) shed by deadline.*/\1/p')
[ "${shed:-0}" -eq 4 ] \
  || { echo "expected all 4 expired requests shed, got '${shed:-}'"; exit 1; }

echo "== scrape: shed/overload/fault counters surface on the metrics page"
mhost=${maddr%:*}
mport=${maddr##*:}
exec 3<>"/dev/tcp/${mhost}/${mport}"
printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3
scrape=$(cat <&3)
exec 3<&- 3>&-
body=$(printf '%s\n' "$scrape" | awk 'body{print} /^\r?$/{body=1}')

counter() {
  printf '%s\n' "$body" | sed -n "s/^lrbi_$1 \([0-9]*\).*/\1/p"
}
for want in "net_deadline_exceeded 4" "net_rejected_overload 2" "faults_injected 3"; do
  name=${want% *}
  floor=${want#* }
  got=$(counter "$name")
  [ -n "$got" ] && [ "$got" -ge "$floor" ] \
    || { echo "expected lrbi_$name >= $floor, got '${got:-missing}'"; exit 1; }
  echo "   lrbi_$name = $got (>= $floor)"
done

echo "== graceful shutdown over the wire (fault plan still installed)"
"$LRBI" serve --connect "$addr" --requests 0 --shutdown >/dev/null
wait "$srv_pid"
srv_pid=""

echo "== chaos suite: every injection point against a live server"
cargo test -q --release --test chaos

echo "chaos smoke: OK"
