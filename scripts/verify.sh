#!/usr/bin/env bash
# Tier-1 verify gate: build + tests (unit, property, integration,
# doctests) + docs with warnings denied + clippy with warnings denied.
# Run from anywhere; operates on the rust/ package.
set -euo pipefail
cd "$(dirname "$0")/../rust"

command -v cargo >/dev/null 2>&1 || {
  echo "verify.sh: cargo not found; install a Rust toolchain (rustup.rs) to run the verify gate" >&2
  exit 1
}

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== spmm determinism suite (thread matrix: 1 and 4; all 7 kernel formats)"
for t in 1 4; do
  LRBI_THREADS="$t" cargo test -q --test kernels
done

echo "== spmm SIMD matrix (dispatched and LRBI_SIMD=off; all 7 kernel formats)"
for s in on off; do
  LRBI_SIMD="$s" cargo test -q --test kernels
done

echo "== pack/inspect smoke over every storable format"
smoke_dir="$(mktemp -d)"
trap 'rm -rf "$smoke_dir"' EXIT
for f in dense csr relative lowrank viterbi dcsr; do
  ./target/release/lrbi pack --format "$f" --out "$smoke_dir/$f.lrbi" --rank 8 --sparsity 0.9 >/dev/null
  ./target/release/lrbi inspect --artifact "$smoke_dir/$f.lrbi" >/dev/null
done
# tiled packs via --tiles regardless of --format
./target/release/lrbi pack --format lowrank --tiles 2 --out "$smoke_dir/tiled.lrbi" --rank 8 --sparsity 0.9 >/dev/null
./target/release/lrbi inspect --artifact "$smoke_dir/tiled.lrbi" >/dev/null

echo "== telemetry smoke (serve --listen --metrics-addr + scrape + top + zero-alloc)"
../scripts/telemetry_smoke.sh

echo "== chaos smoke (LRBI_FAULT plan + retry recovery + deadline shed + chaos suite)"
../scripts/chaos_smoke.sh

echo "== cluster smoke (router + 2 workers: scatter/gather, worker-loss probe, cluster suite)"
../scripts/cluster_smoke.sh

echo "== cargo doc --no-deps (warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== markdown link check (README.md + docs/)"
../scripts/check_links.sh

echo "== cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "verify: OK"
