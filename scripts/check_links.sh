#!/usr/bin/env bash
# Markdown link check for the documentation suite: every relative
# link target in README.md and docs/*.md must exist on disk (http(s)
# and mailto links are skipped; "#anchor" fragments are stripped).
# Part of the CI docs job and scripts/verify.sh, so the docs cannot
# point at files that moved or were renamed.
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0

# The docs the rest of the suite links to by name must exist — the
# glob below only checks files that are present, so a deleted doc
# would otherwise pass silently.
for required in docs/PROTOCOL.md docs/SERVING.md docs/CLUSTER.md \
  docs/OBSERVABILITY.md docs/ROBUSTNESS.md; do
  if [ ! -e "$required" ]; then
    echo "missing required doc: $required"
    fail=1
  fi
done

for md in README.md docs/*.md; do
  dir=$(dirname "$md")
  # extract the (target) of every [text](target) link
  while IFS= read -r target; do
    target=${target%%#*}              # drop anchors
    [ -z "$target" ] && continue      # pure in-page anchor
    case "$target" in
      http://*|https://*|mailto:*) continue ;;
    esac
    if [ ! -e "$dir/$target" ] && [ ! -e "$target" ]; then
      echo "broken link in $md: $target"
      fail=1
    fi
  done < <(grep -oE '\]\([^)]+\)' "$md" | sed -E 's/^\]\(//; s/\)$//')
done

if [ "$fail" -ne 0 ]; then
  echo "link check: FAILED"
  exit 1
fi
echo "link check: OK"
