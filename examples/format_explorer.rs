//! Figure 1 explorer: walk the paper's worked 5x5 example through
//! every index representation, printing each intermediate (Eqs. 1-6),
//! then do the same for an arbitrary matrix from the CLI seed.
//!
//!     cargo run --release --example format_explorer [seed]

use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::formats::binary::BinaryIndex;
use lrbi::formats::csr::Csr16;
use lrbi::formats::relative::Csr5Relative;
use lrbi::formats::viterbi;
use lrbi::pruning::magnitude::{magnitude_mask, paper_example_weights};
use lrbi::tensor::Matrix;
use lrbi::util::bits::BitMatrix;
use lrbi::util::rng::Rng;

fn print_mask(title: &str, m: &BitMatrix) {
    println!("{title}:");
    for i in 0..m.rows() {
        let row: String = (0..m.cols()).map(|j| if m.get(i, j) { '1' } else { '0' }).collect();
        println!("  {row}");
    }
}

fn main() -> lrbi::Result<()> {
    println!("== the paper's worked example (Eqs. 1-6) ==");
    let w = paper_example_weights();
    // Eq. (2): threshold 0.7
    let mask = {
        let data = w.data();
        BitMatrix::from_fn(5, 5, |i, j| data[i * 5 + j].abs() >= 0.7)
    };
    print_mask("I (Eq. 2)", &mask);
    let csr = Csr16::encode(&mask)?;
    println!("CSR: IA={:?} JA={:?}", csr.ia, csr.ja);

    let mut cfg = Algorithm1Config::new(2, mask.sparsity());
    cfg.sp_grid = (1..10).map(|i| i as f64 * 0.1).collect();
    let f = algorithm1(&w, &cfg)?;
    print_mask("I_p (factor)", &f.ip);
    print_mask("I_z (factor)", &f.iz);
    print_mask("I_a = I_p (x) I_z", &f.mask);
    println!(
        "mismatched bits vs I: {} (paper's example: 2)",
        f.mask.hamming(&mask)
    );

    println!("\n== random matrix comparison ==");
    let seed: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let mut rng = Rng::new(seed);
    let w = Matrix::gaussian(64, 80, 0.0, 1.0, &mut rng);
    let s = 0.9;
    let (mask, stats) = magnitude_mask(&w, s);
    println!("64x80 @ S={:.2} (threshold {:.3}):", stats.sparsity, stats.threshold);
    let bin = BinaryIndex::encode(&mask);
    let c16 = Csr16::encode(&mask)?;
    let c5 = Csr5Relative::encode(&mask);
    let vit = viterbi::compress(&w, s)?;
    let f = algorithm1(&w, &Algorithm1Config::new(4, s))?;
    println!("  binary   : {:>6} B (exact)", bin.index_bytes());
    println!("  CSR16    : {:>6} B (exact)", c16.index_bytes());
    println!("  CSR5 rel : {:>6} B (exact, {} entries)", c5.index_bytes(), c5.entry_count());
    println!("  viterbi  : {:>6} B (approx mask, cost {:.2})", vit.index.index_bytes(), vit.cost);
    println!("  low-rank : {:>6} B (approx mask, cost {:.2}, k=4)", f.index_bytes(), f.raw_cost);
    // exact formats must round-trip; approximate ones match their own decode
    assert_eq!(bin.decode(), mask);
    assert_eq!(c16.decode()?, mask);
    assert_eq!(c5.decode(), mask);
    assert_eq!(vit.index.decode(), vit.mask);
    println!("round-trips OK");
    Ok(())
}
