//! Serve a compressed model through the PJRT artifact path with
//! dynamic batching, reporting latency percentiles and throughput —
//! the deployment story the paper motivates (regular, parallel index
//! decompression on the request path).
//!
//!     make artifacts && cargo run --release --example serve_compressed

use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::coordinator::metrics::Metrics;
use lrbi::runtime::artifacts::{ArtifactSet, GEOMETRY};
use lrbi::runtime::client::Runtime;
use lrbi::serve::batcher::BatchPolicy;
use lrbi::serve::engine::{MlpParams, PjrtBackend, ServingEngine};
use lrbi::tensor::Matrix;
use lrbi::util::rng::Rng;
use lrbi::util::stats::percentile;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> lrbi::Result<()> {
    let g = GEOMETRY;
    // 1. Compress FC1's index with Algorithm 1 (k = artifact rank).
    let params = MlpParams::init(5);
    let f = algorithm1(&params.w1, &Algorithm1Config::new(g.rank, 0.95))?;
    println!(
        "compressed FC1 index: {:.1}x ({} bytes), sparsity {:.3}",
        f.compression_ratio(),
        f.index_bytes(),
        f.achieved_sparsity
    );
    let ip = Matrix::from_vec(g.hidden0, g.rank, f.ip.to_f32())?;
    let iz = Matrix::from_vec(g.rank, g.hidden1, f.iz.to_f32())?;

    // 2. Start the serving engine (PJRT backend built in-thread).
    let metrics = Arc::new(Metrics::new());
    let params2 = params.clone();
    let engine = ServingEngine::start_with(
        move || {
            let rt = Runtime::new(ArtifactSet::open_default()?)?;
            PjrtBackend::new(rt, &params2, &ip, &iz)
        },
        BatchPolicy { max_batch: g.batch, max_wait: Duration::from_millis(2) },
        Arc::clone(&metrics),
    );

    // 3. Closed-loop load: 8 clients x N requests, latency tracked.
    let n_clients = 8usize;
    let per_client = if std::env::var("LRBI_QUICK").is_ok() { 32 } else { 128 };
    let client = engine.client();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let cl = client.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(50 + c as u64);
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..GEOMETRY.input_dim).map(|_| rng.next_f32()).collect();
                    let t = Instant::now();
                    cl.call(x).unwrap().unwrap();
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                }
                lat
            })
        })
        .collect();
    let mut lat: Vec<f64> = Vec::new();
    for h in handles {
        lat.extend(h.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = metrics.snapshot();
    println!("\nserved {} requests in {:.2}s = {:.0} req/s", snap.requests, wall, snap.requests as f64 / wall);
    println!("batches: {} (mean size {:.1})", snap.batches, snap.mean_batch_size());
    println!(
        "latency ms: p50 {:.2}  p90 {:.2}  p99 {:.2}",
        percentile(&mut lat.clone(), 0.5),
        percentile(&mut lat.clone(), 0.9),
        percentile(&mut lat, 0.99)
    );
    Ok(())
}
