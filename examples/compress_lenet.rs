//! Reproduce the paper's §2.2 LeNet-5 study: sweep the BMF rank over
//! FC1 and print the compression-ratio / cost / sparsity trade-off
//! (Table 1 left's structure), including tiled variants (Figure 6).
//!
//!     cargo run --release --example compress_lenet

use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::bmf::compression_ratio;
use lrbi::models::lenet::{FC1_COLS, FC1_ROWS};
use lrbi::tensor::Matrix;
use lrbi::tiling::{compress_tiled, equal_budget_rank, RankPlan, TilePlan};
use lrbi::util::rng::Rng;

fn main() -> lrbi::Result<()> {
    let mut rng = Rng::new(2);
    let w = Matrix::gaussian(FC1_ROWS, FC1_COLS, 0.0, 0.05, &mut rng);
    let s = 0.95;

    println!("rank sweep on FC1 ({FC1_ROWS}x{FC1_COLS}), S={s}:");
    println!("{:>5} {:>10} {:>12} {:>10} {:>8}", "k", "ratio", "index bytes", "cost", "S_a");
    for k in [4usize, 8, 16, 32, 64] {
        let f = algorithm1(&w, &Algorithm1Config::new(k, s))?;
        println!(
            "{k:>5} {:>9.1}x {:>12} {:>10.2} {:>8.4}",
            f.compression_ratio(),
            f.index_bytes(),
            f.cost,
            f.achieved_sparsity
        );
    }

    println!("\ntiled factorization at equal index budget (Figure 6):");
    for (plan, label) in [
        (TilePlan::new(1, 1), "1x1"),
        (TilePlan::new(2, 2), "2x2"),
        (TilePlan::new(4, 4), "4x4"),
    ] {
        let k = equal_budget_rank(FC1_ROWS, FC1_COLS, plan, 64)?;
        let base = Algorithm1Config::new(k, s);
        let t = compress_tiled(&w, plan, &RankPlan::Uniform(k), &base)?;
        println!(
            "  {label}: rank {k:>3}, {:>7} index bits ({:.1}x), cost {:.2}",
            t.index_bits(),
            t.compression_ratio(),
            t.cost()
        );
    }
    println!(
        "\n(single-tile k=64 reference ratio: {:.1}x)",
        compression_ratio(FC1_ROWS, FC1_COLS, 64)
    );
    Ok(())
}
