//! Query a running `lrbi serve --listen` frontend over TCP: send one
//! random row batch, print the logits, then fetch the server's
//! `STATS` counters. The client side of the README's end-to-end
//! tutorial (wire spec: docs/PROTOCOL.md).
//!
//!     # terminal A
//!     cargo run --release -- pack --out model.lrbi --format lowrank --rank 16
//!     cargo run --release -- serve --listen 127.0.0.1:4000 --artifact model.lrbi
//!     # terminal B
//!     cargo run --release --example query_server -- 127.0.0.1:4000
//!
//! The address may also come from `LRBI_SERVE_ADDR`; the optional
//! second argument is the model key (default: the server's default
//! model).

use lrbi::runtime::artifacts::GEOMETRY;
use lrbi::serve::protocol::RowBatch;
use lrbi::serve::server::NetClient;
use lrbi::util::rng::Rng;

fn main() {
    let mut args = std::env::args().skip(1);
    let addr = args
        .next()
        .or_else(|| std::env::var("LRBI_SERVE_ADDR").ok())
        .unwrap_or_else(|| "127.0.0.1:4000".to_string());
    let key = args.next().unwrap_or_default();

    let mut client = match NetClient::connect(&addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot connect to {addr}: {e}");
            eprintln!("start a server first: lrbi serve --listen {addr} --artifact model.lrbi");
            std::process::exit(2);
        }
    };
    println!("connected to {addr}");

    // One 3-row batch of synthetic inputs at the artifact geometry.
    let mut rng = Rng::new(7);
    let rows: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..GEOMETRY.input_dim).map(|_| rng.next_f32()).collect())
        .collect();
    let batch = RowBatch::from_rows(&rows).expect("batch");
    match client.infer(&key, batch) {
        Ok(logits) => {
            println!("logits ({}x{}):", logits.rows(), logits.cols());
            for i in 0..logits.rows() {
                let row = logits.row(i);
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap_or(0);
                println!("  row {i}: argmax class {argmax}, logit {:.4}", row[argmax]);
            }
        }
        Err(e) => {
            eprintln!("inference failed: {e}");
            std::process::exit(2);
        }
    }

    match client.stats() {
        Ok(stats) => {
            println!("\nserver counters (STATS frame):");
            for (name, value) in stats.iter().filter(|(_, v)| *v > 0) {
                println!("  {name:<24} {value}");
            }
        }
        Err(e) => eprintln!("stats failed: {e}"),
    }
}
