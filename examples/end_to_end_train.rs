//! END-TO-END DRIVER (the repo's headline validation): pre-train the
//! LeNet-FC classifier on the synthetic digit task *through the AOT
//! PJRT artifacts*, prune FC1 with Algorithm 1, retrain with the
//! decoded low-rank mask, and report the paper's Table-1 quantities.
//! The L1 Pallas decode kernel executes inside every training step —
//! all three layers compose on a real workload.
//!
//!     make artifacts && cargo run --release --example end_to_end_train
//!
//! Results land as CSVs under `reports/`.

use lrbi::bmf::algorithm1::Algorithm1Config;
use lrbi::runtime::artifacts::GEOMETRY;
use lrbi::runtime::client::Runtime;
use lrbi::train::data::SyntheticDigits;
use lrbi::train::loop_::{PjrtTrainer, TrainConfig, TrainLog};

fn main() -> lrbi::Result<()> {
    let quick = std::env::var("LRBI_QUICK").is_ok();
    let rt = Runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());

    let cfg = TrainConfig {
        lr: 0.1,
        pretrain_steps: if quick { 60 } else { 400 },
        retrain_steps: if quick { 120 } else { 800 },
        eval_every: if quick { 30 } else { 100 },
        batch: GEOMETRY.batch,
        seed: 7,
    };
    let train = SyntheticDigits::default().generate(8192);
    let test = SyntheticDigits { seed: 0xE7A1, ..Default::default() }.generate(1024);
    let mut log = TrainLog::default();
    let mut t = PjrtTrainer::new(rt, cfg.clone())?;

    println!("\n== phase 1: pre-training ({} steps, batch {}) ==", cfg.pretrain_steps, cfg.batch);
    t.train(&train, &test, cfg.pretrain_steps, &mut log)?;
    let pre_acc = t.evaluate(&test)?;
    println!("pre-train accuracy: {pre_acc:.4}");

    println!("\n== phase 2: prune FC1 via Algorithm 1 (k=16, S=0.95) ==");
    let mut a1 = Algorithm1Config::new(GEOMETRY.rank, 0.95);
    a1.manip = lrbi::pruning::manip::ManipMethod::AmplifyAboveThreshold;
    let f = t.prune_fc1(&a1)?;
    let post_acc = t.evaluate(&test)?;
    println!(
        "mask: sparsity {:.4}, compression {:.1}x ({} B), cost {:.2}",
        f.achieved_sparsity,
        f.compression_ratio(),
        f.index_bytes(),
        f.cost
    );
    println!("accuracy right after pruning: {post_acc:.4} (paper Table 1: collapses, e.g. 0.30)");

    println!("\n== phase 3: retrain with the low-rank mask ({} steps) ==", cfg.retrain_steps);
    t.train(&train, &test, cfg.retrain_steps, &mut log)?;
    let final_acc = t.evaluate(&test)?;

    println!("\n== loss curve (step, loss) ==");
    for (s, l) in &log.losses {
        if s % (if quick { 60 } else { 200 }) == 0 {
            println!("  {s:>6}  {l:.4}");
        }
    }
    println!("\n== accuracy checkpoints ==");
    for (s, a) in &log.accuracy {
        println!("  step {s:>6}: {a:.4}");
    }
    println!(
        "\nSUMMARY: pre-prune {pre_acc:.4} -> post-prune {post_acc:.4} -> retrained {final_acc:.4}"
    );
    println!(
        "index: 50.0KB (binary) -> {:.1}KB (low-rank k=16): {:.1}x compression",
        f.index_bytes() as f64 / 1000.0,
        f.compression_ratio()
    );
    if final_acc < pre_acc - 0.1 {
        eprintln!("WARNING: retraining did not recover accuracy");
        std::process::exit(1);
    }
    Ok(())
}
