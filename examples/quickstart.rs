//! Quickstart: compress one weight matrix's pruning index with
//! Algorithm 1 and compare against every other index format.
//!
//!     cargo run --release --example quickstart

use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::formats::format_comparison;
use lrbi::formats::lowrank::LowRankIndex;
use lrbi::tensor::Matrix;
use lrbi::util::rng::Rng;

fn main() -> lrbi::Result<()> {
    // A LeNet-5 FC1-shaped layer (800x500) with Gaussian "pretrained"
    // weights — the paper's §2.2 workload.
    let mut rng = Rng::new(1);
    let w = Matrix::gaussian(800, 500, 0.0, 0.05, &mut rng);

    // Algorithm 1: NMF -> threshold -> sweep S_p, binary-search S_z.
    let cfg = Algorithm1Config::new(16, 0.95);
    let f = algorithm1(&w, &cfg)?;
    println!("factorized FC1 index: rank {}  S_p {:.2}  S_z {:.2}", f.rank, f.sp, f.sz);
    println!("  achieved sparsity : {:.4} (target 0.95)", f.achieved_sparsity);
    println!("  compression ratio : {:.1}x (paper: 19.2x)", f.compression_ratio());
    println!("  index size        : {} bytes (paper: 2.6KB)", f.index_bytes());
    println!("  cost (unintended) : {:.2}", f.cost);

    // Round-trip through the storable format.
    let enc = LowRankIndex::encode(&f);
    assert_eq!(enc.decode()?, f.mask);
    println!("  serialize/decode  : OK ({} payload bytes)", enc.index_bytes());

    // Compare against binary / CSR16 / CSR5 / Viterbi (Table 1 right).
    println!("\nTable 1 (right) — FC1 index size by format:");
    for row in format_comparison(&w, 0.95, f.index_bits(), "k=16")? {
        println!("  {:<12} {:>8.1} KB  {}", row.name, row.kb(), row.comment);
    }
    Ok(())
}
