"""AOT path: every entry point lowers to parseable HLO text."""

import jax

from compile import aot


def test_all_entries_lower_to_hlo_text():
    for name, fn, ex_args in aot.entries():
        lowered = jax.jit(fn).lower(*ex_args)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, f"{name}: missing ENTRY computation"
        # jax >= 0.5 serialized protos are rejected by xla_extension 0.5.1;
        # the text path must stay the interchange format.
        assert len(text) > 200, f"{name}: suspiciously small HLO"


def test_entry_names_unique_and_complete():
    names = [e[0] for e in aot.entries()]
    assert len(names) == len(set(names))
    assert {"train_step", "predict", "decode_matmul", "nmf_step"} <= set(names)


def test_shape_str_format():
    args = aot.entries()[2][2]  # decode_matmul
    s = aot.shape_str(args)
    assert s == "800x16;16x500;800x500;64x800"
