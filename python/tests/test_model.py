"""L2 correctness: model shapes, masking semantics, training dynamics."""

import numpy as np
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def small_batch(seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((model.BATCH, model.INPUT_DIM)).astype(np.float32))
    y = np.zeros((model.BATCH, model.NUM_CLASSES), np.float32)
    y[np.arange(model.BATCH), rng.integers(0, model.NUM_CLASSES, model.BATCH)] = 1.0
    return x, jnp.asarray(y)


def test_forward_shapes():
    params = model.init_params(jax.random.PRNGKey(0))
    ip, iz = model.dense_mask_factors()
    x, _ = small_batch()
    logits = model.forward(params, ip, iz, x)
    assert logits.shape == (model.BATCH, model.NUM_CLASSES)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_dense_factors_equal_unmasked():
    """All-ones factors must reproduce the dense (unmasked) model."""
    params = model.init_params(jax.random.PRNGKey(1))
    ip, iz = model.dense_mask_factors()
    x, _ = small_batch(1)
    w0, b0, w1, b1, w2, b2 = params
    h0 = jax.nn.relu(x @ w0 + b0)
    h1 = jax.nn.relu(h0 @ w1 + b1)
    want = h1 @ w2 + b2
    got = model.forward(params, ip, iz, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_train_step_reduces_loss():
    params = model.init_params(jax.random.PRNGKey(2))
    ip, iz = model.dense_mask_factors()
    x, y = small_batch(2)
    lr = jnp.array([0.1], jnp.float32)
    flat = params
    losses = []
    for _ in range(30):
        out = model.train_step(*flat, ip, iz, x, y, lr)
        losses.append(float(out[0]))
        flat = out[1:]
    assert losses[-1] < losses[0] * 0.7, f"no learning: {losses[0]} -> {losses[-1]}"


def test_masked_gradient_respects_mask():
    """dL/dW1 must be zero wherever the decoded mask is zero."""
    params = model.init_params(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    ip = jnp.asarray((rng.random((model.HIDDEN0, model.RANK)) < 0.2).astype(np.float32))
    iz = jnp.asarray((rng.random((model.RANK, model.HIDDEN1)) < 0.2).astype(np.float32))
    x, y = small_batch(3)
    grads = jax.grad(model.loss_fn)(params, ip, iz, x, y)
    g_w1 = np.asarray(grads[2])
    mask = np.asarray(ref.mask_ref(ip, iz))
    assert np.all(g_w1[mask == 0.0] == 0.0)
    # and some gradient does flow where the mask is 1
    assert np.any(g_w1[mask == 1.0] != 0.0)


def test_masked_forward_ignores_pruned_weights():
    """Perturbing W1 where mask==0 must not change the logits."""
    params = model.init_params(jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    ip = jnp.asarray((rng.random((model.HIDDEN0, model.RANK)) < 0.3).astype(np.float32))
    iz = jnp.asarray((rng.random((model.RANK, model.HIDDEN1)) < 0.3).astype(np.float32))
    x, _ = small_batch(4)
    base = np.asarray(model.forward(params, ip, iz, x))
    mask = np.asarray(ref.mask_ref(ip, iz))
    w0, b0, w1, b1, w2, b2 = params
    noise = jnp.asarray(rng.standard_normal(w1.shape).astype(np.float32)) * (1.0 - mask)
    pert = (w0, b0, w1 + noise, b1, w2, b2)
    got = np.asarray(model.forward(pert, ip, iz, x))
    assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_predict_entry_matches_forward():
    params = model.init_params(jax.random.PRNGKey(5))
    ip, iz = model.dense_mask_factors()
    x, _ = small_batch(5)
    got = model.predict(*params, ip, iz, x)[0]
    want = model.forward(params, ip, iz, x)
    assert_allclose(np.asarray(got), np.asarray(want))
