"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py.
This is the CORE correctness signal for the compiled artifacts.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile.kernels import binary_decode, nmf_update, ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand_binary(rng, shape, density=0.4):
    return (rng.random(shape) < density).astype(np.float32)


def rand_f32(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- mask decode


@settings(**SETTINGS)
@given(
    m=st.integers(2, 48),
    k=st.integers(1, 16),
    n=st.integers(2, 48),
    density=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_reconstruct_mask_matches_ref(m, k, n, density, seed):
    rng = np.random.default_rng(seed)
    ip = rand_binary(rng, (m, k), density)
    iz = rand_binary(rng, (k, n), density)
    got = np.asarray(binary_decode.reconstruct_mask(jnp.asarray(ip), jnp.asarray(iz)))
    want = np.asarray(ref.mask_ref(jnp.asarray(ip), jnp.asarray(iz)))
    assert_allclose(got, want, rtol=0, atol=0)


def test_reconstruct_mask_is_binary():
    rng = np.random.default_rng(0)
    ip = rand_binary(rng, (40, 8))
    iz = rand_binary(rng, (8, 30))
    mask = np.asarray(binary_decode.reconstruct_mask(jnp.asarray(ip), jnp.asarray(iz)))
    assert set(np.unique(mask)).issubset({0.0, 1.0})


def test_reconstruct_mask_paper_example():
    """Eq. (5) -> Eq. (6) of the paper, verbatim."""
    ip = jnp.array([[0, 1], [1, 0], [0, 1], [0, 1], [1, 0]], jnp.float32)
    iz = jnp.array([[1, 0, 1, 1, 0], [0, 1, 1, 0, 1]], jnp.float32)
    want = np.array(
        [
            [0, 1, 1, 0, 1],
            [1, 0, 1, 1, 0],
            [0, 1, 1, 0, 1],
            [0, 1, 1, 0, 1],
            [1, 0, 1, 1, 0],
        ],
        np.float32,
    )
    got = np.asarray(binary_decode.reconstruct_mask(ip, iz))
    assert_allclose(got, want)


def test_reconstruct_mask_rank_overlap_clamps():
    # Two overlapping rank-1 terms must still give a {0,1} mask.
    ip = jnp.ones((4, 3), jnp.float32)
    iz = jnp.ones((3, 5), jnp.float32)
    got = np.asarray(binary_decode.reconstruct_mask(ip, iz))
    assert_allclose(got, np.ones((4, 5), np.float32))


@pytest.mark.parametrize("block_n", [1, 2, 5, 10])
def test_reconstruct_mask_block_size_invariance(block_n):
    rng = np.random.default_rng(1)
    ip = rand_binary(rng, (16, 4))
    iz = rand_binary(rng, (4, 10))
    base = np.asarray(binary_decode.reconstruct_mask(jnp.asarray(ip), jnp.asarray(iz)))
    got = np.asarray(
        binary_decode.reconstruct_mask(jnp.asarray(ip), jnp.asarray(iz), block_n=block_n)
    )
    assert_allclose(got, base)


# ------------------------------------------------------------- decode matmul


@settings(**SETTINGS)
@given(
    m=st.integers(2, 32),
    k=st.integers(1, 8),
    n=st.integers(2, 32),
    b=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_matmul_matches_ref(m, k, n, b, seed):
    rng = np.random.default_rng(seed)
    ip = jnp.asarray(rand_binary(rng, (m, k)))
    iz = jnp.asarray(rand_binary(rng, (k, n)))
    w = jnp.asarray(rand_f32(rng, (m, n)))
    x = jnp.asarray(rand_f32(rng, (b, m)))
    got = np.asarray(binary_decode.decode_matmul(ip, iz, w, x))
    want = np.asarray(ref.decode_matmul_ref(ip, iz, w, x))
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_decode_matmul_zero_factors_zero_output():
    ip = jnp.zeros((8, 4), jnp.float32)
    iz = jnp.zeros((4, 6), jnp.float32)
    rng = np.random.default_rng(2)
    w = jnp.asarray(rand_f32(rng, (8, 6)))
    x = jnp.asarray(rand_f32(rng, (3, 8)))
    got = np.asarray(binary_decode.decode_matmul(ip, iz, w, x))
    assert_allclose(got, np.zeros((3, 6), np.float32))


def test_decode_matmul_full_mask_equals_dense():
    rng = np.random.default_rng(3)
    ip = jnp.ones((8, 2), jnp.float32)
    iz = jnp.ones((2, 6), jnp.float32)
    w = jnp.asarray(rand_f32(rng, (8, 6)))
    x = jnp.asarray(rand_f32(rng, (4, 8)))
    got = np.asarray(binary_decode.decode_matmul(ip, iz, w, x))
    want = np.asarray(jnp.matmul(x, w))
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- NMF update


@settings(**SETTINGS)
@given(
    m=st.integers(2, 24),
    k=st.integers(1, 6),
    n=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_nmf_updates_match_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(np.abs(rand_f32(rng, (m, n))) + 0.01)
    w = jnp.asarray(np.abs(rand_f32(rng, (m, k))) + 0.01)
    h = jnp.asarray(np.abs(rand_f32(rng, (k, n))) + 0.01)
    got_h = np.asarray(nmf_update.nmf_update_h(v, w, h))
    want_h = np.asarray(ref.nmf_update_h_ref(v, w, h))
    assert_allclose(got_h, want_h, rtol=2e-4, atol=1e-6)
    got_w = np.asarray(nmf_update.nmf_update_w(v, w, h))
    want_w = np.asarray(ref.nmf_update_w_ref(v, w, h))
    assert_allclose(got_w, want_w, rtol=2e-4, atol=1e-6)


def test_nmf_objective_monotone():
    """Lee-Seung updates never increase ||V - WH||_F^2."""
    rng = np.random.default_rng(7)
    v = jnp.asarray(np.abs(rng.standard_normal((30, 20))).astype(np.float32) + 0.05)
    w = jnp.asarray(np.abs(rng.standard_normal((30, 5))).astype(np.float32) + 0.05)
    h = jnp.asarray(np.abs(rng.standard_normal((5, 20))).astype(np.float32) + 0.05)
    prev = float(ref.nmf_objective_ref(v, w, h))
    for _ in range(15):
        w, h = nmf_update.nmf_step(v, w, h)
        cur = float(ref.nmf_objective_ref(v, w, h))
        assert cur <= prev * (1 + 1e-4), f"objective rose: {prev} -> {cur}"
        prev = cur


def test_nmf_preserves_nonnegativity():
    rng = np.random.default_rng(8)
    v = jnp.asarray(np.abs(rng.standard_normal((12, 10))).astype(np.float32))
    w = jnp.asarray(np.abs(rng.standard_normal((12, 3))).astype(np.float32) + 0.01)
    h = jnp.asarray(np.abs(rng.standard_normal((3, 10))).astype(np.float32) + 0.01)
    for _ in range(5):
        w, h = nmf_update.nmf_step(v, w, h)
    assert np.all(np.asarray(w) >= 0)
    assert np.all(np.asarray(h) >= 0)


# --------------------------------------------------- static perf-model checks


def test_vmem_estimate_within_budget():
    # The FC1 serving tile must fit comfortably in 16 MiB VMEM.
    bytes_ = binary_decode.vmem_estimate_bytes(m=800, k=256, n=500, b=64, block_n=128)
    assert bytes_ < 4 * 2**20, f"VMEM estimate too large: {bytes_}"


def test_mxu_estimate_monotone_in_rank():
    utils = [binary_decode.mxu_utilization_estimate(800, k) for k in (4, 16, 64, 128, 256)]
    assert utils == sorted(utils)
    assert utils[-1] == 1.0
