"""L1 Pallas kernels for low-rank binary index decompression.

The paper's deployment claim is that the pruning mask can be
*decompressed by a binary matrix multiplication* — a regular, fully
parallel operation — instead of a CSR gather or a sequential Viterbi
decoder. These kernels are that decompressor:

* ``reconstruct_mask``  — I_a = min(I_p @ I_z, 1), tiled over columns.
* ``decode_matmul``     — the fused serving hot path
                          y = x @ (W o I_a): the mask tile is decoded,
                          applied to the weight tile, and consumed by
                          the matmul *without ever materialising the
                          full mask in HBM*.

TPU mapping (docs/ARCHITECTURE.md): I_p/I_z live in VMEM
(k(m+n) bits — tiny), each grid step decodes one (m x BN) mask tile on
the MXU and fuses the apply into the weight load of the main matmul.
``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; structure, not wallclock, is what we optimise here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n, preferred=128):
    """Largest divisor of ``n`` that is <= preferred (grid must tile n)."""
    best = 1
    for d in range(1, n + 1):
        if n % d == 0 and d <= preferred:
            best = d
    return best


def _mask_kernel(ip_ref, iz_ref, o_ref):
    prod = jnp.dot(ip_ref[...], iz_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.minimum(prod, 1.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n",))
def reconstruct_mask(ip, iz, block_n=None):
    """Decode the full binary mask I_a = min(I_p @ I_z, 1).

    ip: (m, k) float {0,1};  iz: (k, n) float {0,1}  ->  (m, n) float {0,1}.
    """
    m, k = ip.shape
    k2, n = iz.shape
    assert k == k2, f"rank mismatch {k} vs {k2}"
    bn = block_n or _pick_block(n)
    grid = (n // bn,)
    return pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), ip.dtype),
        interpret=True,
    )(ip, iz)


def _decode_matmul_kernel(ip_ref, iz_ref, w_ref, x_ref, o_ref):
    # Decode one (m x BN) mask tile on the fly ...
    prod = jnp.dot(ip_ref[...], iz_ref[...], preferred_element_type=jnp.float32)
    mask = jnp.minimum(prod, 1.0).astype(w_ref.dtype)
    # ... fuse the apply into the weight tile and feed the MXU matmul.
    weff = w_ref[...] * mask
    o_ref[...] = jnp.dot(x_ref[...], weff, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_n",))
def decode_matmul(ip, iz, w, x, block_n=None):
    """Fused mask-decode + masked matmul: y = x @ (W o min(I_p I_z, 1)).

    ip: (m, k);  iz: (k, n);  w: (m, n);  x: (b, m)  ->  y: (b, n).
    """
    m, k = ip.shape
    _, n = iz.shape
    b = x.shape[0]
    assert w.shape == (m, n), f"w shape {w.shape} != {(m, n)}"
    assert x.shape[1] == m, f"x inner dim {x.shape[1]} != {m}"
    bn = block_n or _pick_block(n)
    grid = (n // bn,)
    return pl.pallas_call(
        _decode_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, bn), lambda j: (0, j)),
            pl.BlockSpec((m, bn), lambda j: (0, j)),
            pl.BlockSpec((b, m), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(ip, iz, w, x)


def vmem_estimate_bytes(m, k, n, b, block_n=128, dtype_bytes=4):
    """Static VMEM footprint estimate for one decode_matmul grid step.

    Used by docs/ARCHITECTURE.md §Performance-notes and the fig-/perf-benches to reason about
    real-TPU block sizing (interpret mode gives no hardware signal).
    """
    bn = min(block_n, n)
    ip_b = m * k * dtype_bytes
    iz_b = k * bn * dtype_bytes
    w_b = m * bn * dtype_bytes
    x_b = b * m * dtype_bytes
    o_b = b * bn * dtype_bytes
    return ip_b + iz_b + w_b + x_b + o_b


def mxu_utilization_estimate(m, k, bn=128, mxu=128):
    """Fraction of MXU lanes fed by the decode matmul (m x k)·(k x bn).

    k >= mxu saturates the systolic array; smaller k relies on the
    fused main matmul (m-dim) to keep utilisation high.
    """
    return min(k, mxu) / mxu * min(bn, mxu) / mxu
