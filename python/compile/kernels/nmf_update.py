"""L1 Pallas kernel for the Lee-Seung multiplicative NMF update.

Algorithm 1 step 2 factorises the magnitude matrix M with NMF. The
multiplicative update is two matmuls plus a fused elementwise
multiply-divide; the matmuls map straight onto the MXU, and the
ratio step is the Pallas kernel below (one VMEM-resident tile per grid
step, no intermediate HBM traffic for num/den).

    H <- H * (W^T V) / (W^T W H + eps)
    W <- W * (V H^T) / (W H H^T + eps)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-9


def _ratio_kernel(base_ref, num_ref, den_ref, o_ref, *, eps):
    o_ref[...] = base_ref[...] * num_ref[...] / (den_ref[...] + eps)


def _pick_block(n, preferred=128):
    best = 1
    for d in range(1, n + 1):
        if n % d == 0 and d <= preferred:
            best = d
    return best


@functools.partial(jax.jit, static_argnames=("eps",))
def multiplicative_ratio(base, num, den, eps=EPS):
    """Fused elementwise ``base * num / (den + eps)`` as a Pallas kernel."""
    assert base.shape == num.shape == den.shape
    r, c = base.shape
    br = _pick_block(r, 128)
    grid = (r // br,)
    kernel = functools.partial(_ratio_kernel, eps=eps)
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, c), base.dtype),
        interpret=True,
    )(base, num, den)


@jax.jit
def nmf_update_h(v, w, h):
    """One multiplicative update of H (MXU matmuls + Pallas ratio)."""
    num = jnp.matmul(w.T, v)
    den = jnp.matmul(jnp.matmul(w.T, w), h)
    return multiplicative_ratio(h, num, den)


@jax.jit
def nmf_update_w(v, w, h):
    """One multiplicative update of W (MXU matmuls + Pallas ratio)."""
    num = jnp.matmul(v, h.T)
    den = jnp.matmul(w, jnp.matmul(h, h.T))
    return multiplicative_ratio(w, num, den)


@jax.jit
def nmf_step(v, w, h):
    """Full alternating update (H then W), as lowered for the runtime."""
    h2 = nmf_update_h(v, w, h)
    w2 = nmf_update_w(v, w, h2)
    return w2, h2
