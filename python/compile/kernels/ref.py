"""Pure-jnp oracles for every Pallas kernel (the L1 correctness contract).

These are the mathematical definitions straight from the paper:

* ``mask_ref``           — Eq. (3): boolean product I_a = I_p (x) I_z,
                           realised in float as min(I_p @ I_z, 1).
* ``decode_matmul_ref``  — serving hot path: y = x @ (W o I_a).
* ``nmf_update_h_ref`` / ``nmf_update_w_ref``
                         — Lee-Seung multiplicative updates used by
                           Algorithm 1 step 2 (NMF of the magnitude
                           matrix M).

pytest + hypothesis compare the Pallas kernels against these across a
sweep of shapes and dtypes (python/tests/test_kernels.py).
"""

import jax.numpy as jnp

EPS = 1e-9


def mask_ref(ip, iz):
    """Boolean product of binary factor matrices, as float {0,1}."""
    prod = jnp.matmul(ip.astype(jnp.float32), iz.astype(jnp.float32))
    return jnp.minimum(prod, 1.0)


def decode_matmul_ref(ip, iz, w, x):
    """y = x @ (W o mask) with the mask decoded from (I_p, I_z)."""
    mask = mask_ref(ip, iz).astype(w.dtype)
    return jnp.matmul(x, w * mask)


def nmf_update_h_ref(v, w, h, eps=EPS):
    """H <- H * (W^T V) / (W^T W H + eps)."""
    num = jnp.matmul(w.T, v)
    den = jnp.matmul(jnp.matmul(w.T, w), h) + eps
    return h * num / den


def nmf_update_w_ref(v, w, h, eps=EPS):
    """W <- W * (V H^T) / (W H H^T + eps)."""
    num = jnp.matmul(v, h.T)
    den = jnp.matmul(w, jnp.matmul(h, h.T)) + eps
    return w * num / den


def nmf_objective_ref(v, w, h):
    """Frobenius objective ||V - WH||_F^2 (monotone under the updates)."""
    r = v - jnp.matmul(w, h)
    return jnp.sum(r * r)
