"""AOT lowering: JAX/Pallas entry points -> HLO text artifacts.

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the xla_extension
0.5.1 bundled with the published ``xla`` 0.1.6 crate rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``); Python never appears on
the Rust request path.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import nmf_update

# NMF offload tile geometry: FC1 (800x500) tiled 4x4 -> 200x125 blocks,
# rank 32 (the table-2 "tiled" configuration, scaled to FC1).
NMF_TILE_M = 200
NMF_TILE_N = 125
NMF_RANK = 32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def nmf_step_entry(v, w, h):
    w2, h2 = nmf_update.nmf_step(v, w, h)
    return (w2, h2)


def entries():
    """(name, fn, example_args) for every artifact."""
    z = jnp.zeros
    nmf_args = (
        z((NMF_TILE_M, NMF_TILE_N), jnp.float32),
        z((NMF_TILE_M, NMF_RANK), jnp.float32),
        z((NMF_RANK, NMF_TILE_N), jnp.float32),
    )
    return [
        ("train_step", model.train_step, model.example_args_train()),
        ("predict", model.predict, model.example_args_predict()),
        ("decode_matmul", model.decode_matmul_entry, model.example_args_decode()),
        ("nmf_step", nmf_step_entry, nmf_args),
    ]


def shape_str(args):
    return ";".join(
        "x".join(str(d) for d in a.shape) if a.shape else "scalar" for a in args
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single entry by name")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, fn, ex_args in entries():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*ex_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest_lines.append(
            f"{name} inputs={len(ex_args)} in_shapes={shape_str(ex_args)} "
            f"sha256={digest} bytes={len(text)}"
        )
        print(f"wrote {path}: {len(text)} chars sha={digest}")

    if not args.only:
        with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest_lines) + "\n")
        print(f"wrote {args.out_dir}/manifest.txt ({len(manifest_lines)} entries)")


if __name__ == "__main__":
    main()
