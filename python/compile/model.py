"""L2 — the JAX model: LeNet-FC classifier with a low-rank-masked FC1.

Architecture (paper §2.2 FC stack, input adapted to the synthetic
16x16 task — see docs/ARCHITECTURE.md §Substitutions):

    x (B, 256) -> FC0 (256x800) -> ReLU
               -> FC1 (800x500, masked by I_a = min(I_p I_z, 1)) -> ReLU
               -> FC2 (500x10)  -> logits

FC1 is exactly the paper's 800x500 layer. The mask is *decoded inside
the lowered graph* from the binary factors (I_p, I_z) using the L1
Pallas kernel, so the artifact the Rust runtime serves consumes the
compressed index directly — the "decompression is a binary matmul"
claim is exercised on the request path.

Pre-training uses all-ones rank-k factors (mask == 1 everywhere), so a
single train-step artifact covers both the dense and the masked phase.
"""

import jax
import jax.numpy as jnp

from .kernels import binary_decode

# Fixed artifact geometry (the Rust runtime mirrors these constants in
# rust/src/runtime/artifacts.rs — keep in sync).
INPUT_DIM = 256
HIDDEN0 = 800
HIDDEN1 = 500
NUM_CLASSES = 10
BATCH = 64
RANK = 16


def init_params(key):
    """He-initialised parameters as a flat tuple (w0,b0,w1,b1,w2,b2)."""
    k0, k1, k2 = jax.random.split(key, 3)

    def he(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    return (
        he(k0, INPUT_DIM, (INPUT_DIM, HIDDEN0)),
        jnp.zeros((HIDDEN0,), jnp.float32),
        he(k1, HIDDEN0, (HIDDEN0, HIDDEN1)),
        jnp.zeros((HIDDEN1,), jnp.float32),
        he(k2, HIDDEN1, (HIDDEN1, NUM_CLASSES)),
        jnp.zeros((NUM_CLASSES,), jnp.float32),
    )


def dense_mask_factors():
    """Rank-RANK all-ones factors: mask == 1 (the pre-training phase)."""
    ip = jnp.ones((HIDDEN0, RANK), jnp.float32)
    iz = jnp.ones((RANK, HIDDEN1), jnp.float32)
    return ip, iz


def forward(params, ip, iz, x):
    """Logits for a batch. The FC1 mask is decoded by the Pallas kernel."""
    w0, b0, w1, b1, w2, b2 = params
    h0 = jax.nn.relu(jnp.matmul(x, w0) + b0)
    # Mask decode: constant w.r.t. params (stop_gradient), so autodiff
    # masks dL/dW1 without differentiating through the Pallas call.
    mask = jax.lax.stop_gradient(binary_decode.reconstruct_mask(ip, iz))
    h1 = jax.nn.relu(jnp.matmul(h0, w1 * mask) + b1)
    return jnp.matmul(h1, w2) + b2


def loss_fn(params, ip, iz, x, y_onehot):
    """Mean softmax cross-entropy."""
    logits = forward(params, ip, iz, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def train_step(w0, b0, w1, b1, w2, b2, ip, iz, x, y_onehot, lr):
    """One SGD step. ``lr`` has shape (1,) (scalar literals are awkward
    to feed through the PJRT text path). Returns (loss, new params...)."""
    params = (w0, b0, w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(loss_fn)(params, ip, iz, x, y_onehot)
    step = lr[0]
    new_params = tuple(p - step * g for p, g in zip(params, grads))
    return (loss,) + new_params


def predict(w0, b0, w1, b1, w2, b2, ip, iz, x):
    """Serving entry point: logits for a batch."""
    return (forward((w0, b0, w1, b1, w2, b2), ip, iz, x),)


def decode_matmul_entry(ip, iz, w, x):
    """Standalone fused decode+matmul (the serving microkernel artifact)."""
    return (binary_decode.decode_matmul(ip, iz, w, x),)


def example_args_train():
    z = jnp.zeros
    return (
        z((INPUT_DIM, HIDDEN0), jnp.float32),
        z((HIDDEN0,), jnp.float32),
        z((HIDDEN0, HIDDEN1), jnp.float32),
        z((HIDDEN1,), jnp.float32),
        z((HIDDEN1, NUM_CLASSES), jnp.float32),
        z((NUM_CLASSES,), jnp.float32),
        z((HIDDEN0, RANK), jnp.float32),
        z((RANK, HIDDEN1), jnp.float32),
        z((BATCH, INPUT_DIM), jnp.float32),
        z((BATCH, NUM_CLASSES), jnp.float32),
        z((1,), jnp.float32),
    )


def example_args_predict():
    return example_args_train()[:9]


def example_args_decode(m=HIDDEN0, k=RANK, n=HIDDEN1, b=BATCH):
    z = jnp.zeros
    return (
        z((m, k), jnp.float32),
        z((k, n), jnp.float32),
        z((m, n), jnp.float32),
        z((b, m), jnp.float32),
    )
