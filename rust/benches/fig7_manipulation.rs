//! Figure 7: unpruned-weight histograms under manipulation methods
//! 1 (none), 2 (square), 3 (amplify 1/(1-S) above threshold) — the
//! paper's claim: method 3 yields the sharpest drop at the threshold
//! and keeps more large weights.

mod bench_common;

use bench_common::{fc1_weights, quick, report_dir};
use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::pruning::manip::ManipMethod;
use lrbi::report::figures::{unpruned_histogram, write_histogram};
use lrbi::util::bench::write_table_csv;

fn main() {
    let w = fc1_weights(1);
    let s = 0.95;
    let t = lrbi::pruning::magnitude::threshold_for_sparsity(&w, s) as f64;
    let mut rows = Vec::new();
    let mut raw_costs = Vec::new();
    for method in ManipMethod::all() {
        let mut cfg = Algorithm1Config::new(if quick() { 16 } else { 64 }, s);
        cfg.manip = method;
        if quick() {
            cfg.sp_grid = vec![0.3, 0.6];
            cfg.nmf.max_iters = 12;
        }
        let f = algorithm1(&w, &cfg).expect("algorithm1");
        let h = unpruned_histogram(&w, &f.mask, 61);
        let nz = h.mass_below_abs(t);
        println!(
            "{:<28} raw-cost {:>9.2} near-zero kept {:>6}  {}",
            method.label(),
            f.raw_cost,
            nz,
            h.sparkline()
        );
        let tag = match method {
            ManipMethod::None => "m1",
            ManipMethod::Square => "m2",
            ManipMethod::AmplifyAboveThreshold => "m3",
        };
        write_histogram(&report_dir().join(format!("fig7_hist_{tag}.csv")), &h).unwrap();
        rows.push(vec![
            method.label().to_string(),
            format!("{:.2}", f.raw_cost),
            nz.to_string(),
        ]);
        raw_costs.push(f.raw_cost);
    }
    write_table_csv(
        report_dir().join("fig7.csv").to_str().unwrap(),
        &["method", "raw_cost", "near_zero_kept"],
        &rows,
    )
    .unwrap();
    assert!(
        raw_costs[2] < raw_costs[0],
        "method 3 must beat method 1 on raw cost: {raw_costs:?}"
    );
    println!("\nmethod 3 < method 1 on raw cost ✓ {raw_costs:?}");
}
