//! Figure 6: unpruned-weight histograms for (1x1, k=128), (2x2, k=64),
//! (4x4, k=32) tilings of FC1 — all at the same index budget. Claim:
//! more tiles -> deeper near-zero drop (and lower Cost).

mod bench_common;

use bench_common::{fc1_weights, quick, report_dir};
use lrbi::bmf::algorithm1::Algorithm1Config;
use lrbi::report::figures::{unpruned_histogram, write_histogram};
use lrbi::tiling::{compress_tiled, equal_budget_rank, RankPlan, TilePlan};
use lrbi::util::bench::write_table_csv;

fn main() {
    let w = fc1_weights(1);
    let s = 0.95;
    let t = lrbi::pruning::magnitude::threshold_for_sparsity(&w, s) as f64;
    let plans = [
        (TilePlan::new(1, 1), "1x1"),
        (TilePlan::new(2, 2), "2x2"),
        (TilePlan::new(4, 4), "4x4"),
    ];
    let mut rows = Vec::new();
    let mut costs = Vec::new();
    for (plan, label) in plans {
        let k = equal_budget_rank(800, 500, plan, 128).expect("plan fits FC1");
        let mut base = Algorithm1Config::new(k, s);
        if quick() {
            base.sp_grid = vec![0.3, 0.6];
            base.nmf.max_iters = 12;
        }
        let res = compress_tiled(&w, plan, &RankPlan::Uniform(k), &base).expect("tiled");
        let h = unpruned_histogram(&w, &res.mask, 61);
        let nz = h.mass_below_abs(t);
        println!(
            "{label} (k={k:>3}): index {:>7} bits, cost {:>9.2}, near-zero kept {:>6}  {}",
            res.index_bits(),
            res.cost(),
            nz,
            h.sparkline()
        );
        write_histogram(&report_dir().join(format!("fig6_hist_{label}.csv")), &h).unwrap();
        rows.push(vec![
            label.to_string(),
            k.to_string(),
            res.index_bits().to_string(),
            format!("{:.2}", res.cost()),
            nz.to_string(),
        ]);
        costs.push(res.cost());
    }
    write_table_csv(
        report_dir().join("fig6.csv").to_str().unwrap(),
        &["tiles", "rank", "index_bits", "cost", "near_zero_kept"],
        &rows,
    )
    .unwrap();
    // equal budget across plans
    let bits: Vec<&String> = rows.iter().map(|r| &r[2]).collect();
    assert!(bits.windows(2).all(|p| p[0] == p[1]), "budgets must match: {bits:?}");
    println!("\nequal index budget across tilings ✓; costs {costs:?}");
}
