//! Figures 4 and 5: tiling increases the variance of NMF factor values
//! (smaller sample size per block), giving the binary conversion a
//! wider threshold spectrum. We reproduce both histograms: weight
//! values after NMF reconstruction (Fig 4) and the M_p/M_z factor
//! values (Fig 5), for 1, 4 and 16 tiles.

mod bench_common;

use bench_common::report_dir;
use lrbi::nmf::{nmf, NmfConfig};
use lrbi::report::figures::write_histogram;
use lrbi::tensor::Matrix;
use lrbi::tiling::TilePlan;
use lrbi::util::bench::write_table_csv;
use lrbi::util::rng::Rng;
use lrbi::util::stats::{Histogram, Welford};

fn main() {
    // Fig 4's setup: a random Gaussian weight matrix.
    let mut rng = Rng::new(4);
    let w = Matrix::gaussian(256, 256, 0.0, 1.0, &mut rng).abs();
    let mut rows = Vec::new();
    for (plan, label, rank) in [
        (TilePlan::new(1, 1), "1x1", 32usize),
        (TilePlan::new(2, 2), "2x2", 16),
        (TilePlan::new(4, 4), "4x4", 8),
    ] {
        let mut recon_hist = Histogram::new(0.0, 4.0, 60);
        let mut factor_hist = Histogram::new(0.0, 2.0, 60);
        let mut factor_var = Welford::new();
        for spec in plan.tiles(w.rows(), w.cols()).unwrap() {
            let sub = w.submatrix(spec.r0, spec.r1, spec.c0, spec.c1).unwrap();
            let mut cfg = NmfConfig::new(rank);
            cfg.seed ^= spec.id as u64;
            let res = nmf(&sub, &cfg).expect("nmf");
            let approx = res.w.matmul(&res.h).unwrap();
            recon_hist.add_all(approx.data());
            factor_hist.add_all(res.w.data());
            factor_hist.add_all(res.h.data());
            for &v in res.w.data().iter().chain(res.h.data()) {
                factor_var.add(v as f64);
            }
        }
        println!(
            "{label}: factor std {:.4} | recon hist {}",
            factor_var.std(),
            recon_hist.sparkline()
        );
        write_histogram(&report_dir().join(format!("fig4_recon_{label}.csv")), &recon_hist)
            .unwrap();
        write_histogram(&report_dir().join(format!("fig5_factors_{label}.csv")), &factor_hist)
            .unwrap();
        rows.push(vec![label.to_string(), format!("{:.5}", factor_var.std())]);
    }
    write_table_csv(
        report_dir().join("fig5_factor_std.csv").to_str().unwrap(),
        &["tiles", "factor_std"],
        &rows,
    )
    .unwrap();
    // Fig 5's claim: factor std grows with tile count.
    let stds: Vec<f64> = rows.iter().map(|r| r[1].parse().unwrap()).collect();
    assert!(
        stds.windows(2).all(|p| p[1] > p[0] * 0.98),
        "factor std should grow (or hold) with tiles: {stds:?}"
    );
    println!("factor variance grows with tiling ✓ {stds:?}");
}
