//! §Perf: network serving frontend — end-to-end request latency and
//! throughput through the `lrbi serve --listen` TCP stack (acceptor →
//! wire protocol → dynamic batcher → sparse-kernel SpMM plan → demux).
//!
//! For every (kernel format × client count × batch window) cell the
//! bench starts an in-process server on `127.0.0.1:0`, drives it with
//! concurrent TCP load-generator clients, and reports p50/p95/p99
//! per-request latency plus throughput. Besides the human-readable
//! table and `reports/perf_serve_loadgen.csv`, it writes
//! `BENCH_serve.json` at the repository root (schema
//! `lrbi-bench-serve-v1`, documented in README.md and
//! docs/SERVING.md) so serving-path changes have end-to-end numbers
//! to regress against.
//!
//!     cargo run --release --bench perf_serve_loadgen
//!     LRBI_BENCH_QUICK=1 cargo run --release --bench perf_serve_loadgen
//!
//! Set `LRBI_SERVE_ADDR=host:port` to aim the load generator at an
//! already-running `lrbi serve --listen` frontend instead (the cell's
//! kernel is then reported as "remote").

mod bench_common;

use bench_common::{quick, report_dir};
use lrbi::coordinator::metrics::Metrics;
use lrbi::runtime::artifacts::GEOMETRY;
use lrbi::serve::batcher::BatchPolicy;
use lrbi::serve::engine::{MlpParams, NativeBackend};
use lrbi::serve::kernels::KernelFormat;
use lrbi::serve::protocol::RowBatch;
use lrbi::serve::server::{ClientOptions, ModelHub, NetClient, RetryPolicy, ServeOptions, Server};
use lrbi::util::bench::{print_table, write_table_csv};
use lrbi::util::bits::BitMatrix;
use lrbi::util::rng::Rng;
use lrbi::util::stats::percentile;
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Cell {
    kernel: String,
    clients: usize,
    window_ms: u64,
    requests: usize,
    rps: f64,
    p50_us: f64,
    p95_us: f64,
    p99_us: f64,
    mean_flush: f64,
    rejected_overload: u64,
}

/// Drive `clients` concurrent TCP clients, `per_client` single-row
/// requests each; returns every request's wall latency in ns.
fn run_load(
    addr: std::net::SocketAddr,
    clients: usize,
    per_client: usize,
    input_dim: usize,
) -> Vec<f64> {
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                // Transient overloads under the most aggressive cells
                // are retried with jittered backoff instead of killing
                // the worker; retry time counts against the request's
                // measured latency, which is what an end-to-end client
                // actually experiences.
                let opts = ClientOptions {
                    connect_timeout: Some(Duration::from_secs(5)),
                    retry: RetryPolicy { seed: 0xBE5C + c as u64, ..RetryPolicy::default() },
                    ..ClientOptions::default()
                };
                let mut client = NetClient::connect_with(addr, opts).expect("connect");
                let mut rng = Rng::new(0xBE5C + c as u64);
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let row: Vec<f32> = (0..input_dim).map(|_| rng.next_f32()).collect();
                    let batch = RowBatch::from_rows(&[row]).expect("batch");
                    let t0 = Instant::now();
                    let logits = client.infer("", batch).expect("infer");
                    lat.push(t0.elapsed().as_nanos() as f64);
                    assert_eq!(logits.rows(), 1);
                }
                lat
            })
        })
        .collect();
    let mut all = Vec::with_capacity(clients * per_client);
    for w in workers {
        all.extend(w.join().expect("load client"));
    }
    all
}

fn percentiles_us(lat_ns: &[f64]) -> (f64, f64, f64) {
    (
        percentile(lat_ns, 0.50) / 1e3,
        percentile(lat_ns, 0.95) / 1e3,
        percentile(lat_ns, 0.99) / 1e3,
    )
}

fn main() {
    let g = GEOMETRY;
    let total_requests: usize = if quick() { 128 } else { 512 };
    let client_sweep: &[usize] = if quick() { &[4] } else { &[2, 8, 32] };
    let mut cells: Vec<Cell> = Vec::new();

    if let Ok(addr) = std::env::var("LRBI_SERVE_ADDR") {
        // Remote mode: sweep client counts against a live server.
        // Resolve via ToSocketAddrs so hostnames work, not just IPs.
        use std::net::ToSocketAddrs;
        let addr: std::net::SocketAddr = addr
            .to_socket_addrs()
            .expect("LRBI_SERVE_ADDR host:port")
            .next()
            .expect("LRBI_SERVE_ADDR resolves to no address");
        for &clients in client_sweep {
            let per_client = (total_requests / clients).max(1);
            let t0 = Instant::now();
            let lat = run_load(addr, clients, per_client, g.input_dim);
            let wall = t0.elapsed().as_secs_f64();
            let (p50, p95, p99) = percentiles_us(&lat);
            println!(
                "remote {addr}: {clients} clients -> {:.0} req/s, p50 {:.0}us p99 {:.0}us",
                lat.len() as f64 / wall,
                p50,
                p99
            );
            cells.push(Cell {
                kernel: "remote".into(),
                clients,
                window_ms: 0,
                requests: lat.len(),
                rps: lat.len() as f64 / wall,
                p50_us: p50,
                p95_us: p95,
                p99_us: p99,
                mean_flush: 0.0,
                rejected_overload: 0,
            });
        }
    } else {
        // In-process sweep: kernel format × client count × batch window.
        let params = MlpParams::init(11);
        let mut frng = Rng::new(12);
        let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| frng.bernoulli(0.25));
        let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| frng.bernoulli(0.25));
        let window_sweep: &[u64] = if quick() { &[1] } else { &[1, 4] };
        for fmt in KernelFormat::ALL {
            for &window_ms in window_sweep {
                for &clients in client_sweep {
                    let metrics = Arc::new(Metrics::new());
                    let backend =
                        NativeBackend::with_format(params.clone(), fmt, &ip, &iz)
                            .expect("backend")
                            .with_metrics(Arc::clone(&metrics));
                    let policy = BatchPolicy {
                        max_batch: 64,
                        max_wait: Duration::from_millis(window_ms),
                    };
                    let opts = ServeOptions {
                        max_conns: clients + 4,
                        max_queue: 1024,
                        policy,
                        ..ServeOptions::default()
                    };
                    let hub = ModelHub::from_backend(
                        "default",
                        backend,
                        policy,
                        opts.max_queue,
                        Arc::clone(&metrics),
                    );
                    let server =
                        Server::bind("127.0.0.1:0", Arc::new(hub), &opts).expect("bind");
                    let addr = server.local_addr();
                    let handle = server.handle();
                    let runner = std::thread::spawn(move || server.run());

                    // warm the accept + kernel paths outside the timed
                    // run, then snapshot so the cell reports deltas —
                    // warm-up flushes must not skew mean_flush.
                    run_load(addr, 1, 4, g.input_dim);
                    let warm = metrics.snapshot();

                    let per_client = (total_requests / clients).max(1);
                    let t0 = Instant::now();
                    let lat = run_load(addr, clients, per_client, g.input_dim);
                    let wall = t0.elapsed().as_secs_f64();
                    handle.shutdown();
                    runner.join().expect("server thread").expect("server run");

                    let (p50, p95, p99) = percentiles_us(&lat);
                    let snap = metrics.snapshot();
                    let flushes = snap.batch_flush_count - warm.batch_flush_count;
                    let mean_flush = if flushes == 0 {
                        0.0
                    } else {
                        (snap.batch_size_sum - warm.batch_size_sum) as f64 / flushes as f64
                    };
                    let rejected_overload =
                        snap.net_rejected_overload - warm.net_rejected_overload;
                    println!(
                        "{}/w{window_ms}ms/c{clients}: {:.0} req/s, p50 {:.0}us \
                         p95 {:.0}us p99 {:.0}us (mean flush {mean_flush:.1})",
                        fmt.name(),
                        lat.len() as f64 / wall,
                        p50,
                        p95,
                        p99,
                    );
                    cells.push(Cell {
                        kernel: fmt.name().to_string(),
                        clients,
                        window_ms,
                        requests: lat.len(),
                        rps: lat.len() as f64 / wall,
                        p50_us: p50,
                        p95_us: p95,
                        p99_us: p99,
                        mean_flush,
                        rejected_overload,
                    });
                }
            }
        }
    }

    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.kernel.clone(),
                c.clients.to_string(),
                c.window_ms.to_string(),
                c.requests.to_string(),
                format!("{:.1}", c.rps),
                format!("{:.1}", c.p50_us),
                format!("{:.1}", c.p95_us),
                format!("{:.1}", c.p99_us),
                format!("{:.2}", c.mean_flush),
                c.rejected_overload.to_string(),
            ]
        })
        .collect();
    let header = [
        "kernel",
        "clients",
        "batch_window_ms",
        "requests",
        "throughput_rps",
        "p50_us",
        "p95_us",
        "p99_us",
        "mean_flush",
        "rejected_overload",
    ];
    print_table("serve loadgen: latency/throughput by kernel × clients × window", &header, &rows);
    write_table_csv(
        report_dir().join("perf_serve_loadgen.csv").to_str().unwrap(),
        &header,
        &rows,
    )
    .unwrap();

    // Machine-readable trajectory point at the repository root.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"lrbi-bench-serve-v1\",\n");
    json.push_str("  \"bench\": \"perf_serve_loadgen\",\n");
    json.push_str(&format!(
        "  \"geometry\": {{\"input_dim\": {}, \"hidden0\": {}, \"hidden1\": {}, \
         \"classes\": {}, \"rank\": {}}},\n",
        g.input_dim, g.hidden0, g.hidden1, g.classes, g.rank
    ));
    json.push_str(&format!("  \"quick\": {},\n", quick()));
    json.push_str("  \"results\": [\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"clients\": {}, \"batch_window_ms\": {}, \
             \"requests\": {}, \"throughput_rps\": {:.1}, \"p50_us\": {:.1}, \
             \"p95_us\": {:.1}, \"p99_us\": {:.1}, \"mean_flush\": {:.2}, \
             \"rejected_overload\": {}}}{}\n",
            c.kernel,
            c.clients,
            c.window_ms,
            c.requests,
            c.rps,
            c.p50_us,
            c.p95_us,
            c.p99_us,
            c.mean_flush,
            c.rejected_overload,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    std::fs::write(out, &json).expect("write BENCH_serve.json");
    println!("\nwrote {out} ({} cells)", cells.len());
}
