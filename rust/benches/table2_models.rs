//! Table 2: compression ratio + accuracy proxy for ResNet32 (CIFAR10),
//! AlexNet FC5/FC6 (ImageNet), LSTM (PTB). Compression ratios are
//! exact arithmetic on real layer shapes and must match the paper;
//! the accuracy column is proxied (docs/ARCHITECTURE.md §Substitutions) by
//! retraining the synthetic classifier at the same (S, rank-budget)
//! and reporting relative accuracy retention.

mod bench_common;

use bench_common::{quick, report_dir};
use lrbi::bmf::algorithm1::Algorithm1Config;
use lrbi::bmf::compression_ratio;
use lrbi::coordinator::metrics::Metrics;
use lrbi::coordinator::sweep::{compress_model, SweepOptions};
use lrbi::models::alexnet::{fc5_tiling, fc6_tiling, tiled_index_bits, FC5_COLS, FC5_ROWS, FC6_COLS, FC6_ROWS};
use lrbi::models::resnet32::{index_compression_ratio, resnet32};
use lrbi::train::data::SyntheticDigits;
use lrbi::train::loop_::{NativeTrainer, TrainConfig, TrainLog};
use lrbi::util::bench::{print_table, write_table_csv};

/// Accuracy-retention proxy: retrain the synthetic classifier with the
/// given (sparsity, rank) on FC1 and report final/pre-prune accuracy.
fn retention(s: f64, rank: usize) -> f64 {
    let pre = if quick() { 50 } else { 250 };
    let post = if quick() { 70 } else { 500 };
    let train = SyntheticDigits::default().generate(2048);
    let test = SyntheticDigits { seed: 0xAB, ..Default::default() }.generate(500);
    let cfg = TrainConfig {
        pretrain_steps: pre,
        retrain_steps: post,
        eval_every: usize::MAX,
        ..Default::default()
    };
    let mut t = NativeTrainer::new(cfg);
    let mut log = TrainLog::default();
    t.train(&train, &test, pre, &mut log).unwrap();
    let before = t.evaluate(&test).unwrap();
    let mut a1 = Algorithm1Config::new(rank, s);
    a1.manip = lrbi::pruning::manip::ManipMethod::AmplifyAboveThreshold;
    t.prune_fc1(&a1).unwrap();
    t.train(&train, &test, post, &mut log).unwrap();
    let after = t.evaluate(&test).unwrap();
    after / before
}

fn main() {
    let resnet = resnet32();
    let mut rows: Vec<Vec<String>> = Vec::new();

    // ResNet32 rows (paper: 3.09x @ 91.8%, 5.12x @ 91.5%; baseline 92.5%)
    for (ranks, label) in [([8usize, 16, 32], "8/16/32"), ([8, 8, 8], "8/8/8")] {
        let ratio = index_compression_ratio(&resnet, ranks);
        let ret = retention(0.70, ranks[0]);
        rows.push(vec![
            "ResNet32/CIFAR10".into(),
            "0.70".into(),
            label.into(),
            format!("{ratio:.2}x"),
            format!("{:.1}% retained", ret * 100.0),
        ]);
    }
    // AlexNet FC rows (paper: FC5 8.20x, FC6 4.14x @ ~full top-5)
    let (p5, k5) = fc5_tiling();
    let r5 = (FC5_ROWS * FC5_COLS) as f64 / tiled_index_bits(FC5_ROWS, FC5_COLS, p5, k5) as f64;
    rows.push(vec![
        "AlexNet-FC5/ImageNet".into(),
        "0.91".into(),
        format!("{k5} tiled 16x8"),
        format!("{r5:.2}x"),
        format!("{:.1}% retained", retention(0.91, 12) * 100.0),
    ]);
    let (p6, k6) = fc6_tiling();
    let r6 = (FC6_ROWS * FC6_COLS) as f64 / tiled_index_bits(FC6_ROWS, FC6_COLS, p6, k6) as f64;
    rows.push(vec![
        "AlexNet-FC6/ImageNet".into(),
        "0.91".into(),
        format!("{k6} tiled 8x8"),
        format!("{r6:.2}x"),
        format!("{:.1}% retained", retention(0.91, 24) * 100.0),
    ]);
    // LSTM row (paper: 1.82x, 89.6 -> 89.0 PPW)
    rows.push(vec![
        "LSTM/PTB".into(),
        "0.60".into(),
        "145".into(),
        format!("{:.2}x", compression_ratio(600, 1200, 145)),
        format!("{:.1}% retained", retention(0.60, 64) * 100.0),
    ]);

    print_table(
        "Table 2: compression ratio + accuracy-retention proxy",
        &["Model", "S", "Rank", "Comp. Ratio", "Accuracy proxy"],
        &rows,
    );
    write_table_csv(
        report_dir().join("table2.csv").to_str().unwrap(),
        &["model", "s", "rank", "ratio", "retention"],
        &rows,
    )
    .unwrap();

    // Also run the actual coordinator over real layer shapes for the
    // ResNet32 8/8/8 row (validates the parallel pipeline end to end;
    // synthetic weights, exact cost accounting).
    if !quick() {
        let mut opts = SweepOptions::new(0.70, 8);
        opts.base.sp_grid = vec![0.2, 0.4, 0.6, 0.8];
        opts.base.nmf.max_iters = 20;
        let metrics = Metrics::new();
        let rep = compress_model(&resnet, &opts, &metrics).expect("compress resnet32");
        println!(
            "\ncoordinator run (ResNet32, 8/8/8): ratio {:.2}x, sparsity {:.3}, {} jobs, cost {:.1}",
            rep.compression_ratio(),
            rep.sparsity(),
            metrics.snapshot().jobs_done,
            rep.cost()
        );
    }
}
