//! Figure 2: S_z, Cost, and accuracy across the S_p sweep for FC1 at
//! S=0.95 with k in {16, 64, 256}. The S_z and Cost series come
//! straight from Algorithm 1's sweep log; accuracy is evaluated at a
//! coarse S_p subset by retraining with the corresponding mask.

mod bench_common;

use bench_common::{fc1_weights, quick, report_dir};
use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::util::bench::write_table_csv;

fn main() {
    let w = fc1_weights(1);
    let s = 0.95;
    let ranks: Vec<usize> = if quick() { vec![16] } else { vec![16, 64, 256] };
    let mut rows: Vec<Vec<String>> = Vec::new();
    for &k in &ranks {
        let mut cfg = Algorithm1Config::new(k, s);
        if quick() {
            cfg.sp_grid = vec![0.2, 0.5, 0.8];
            cfg.nmf.max_iters = 15;
        }
        let f = algorithm1(&w, &cfg).expect("algorithm1");
        println!("\nrank {k}: best S_p={:.2} S_z={:.2} cost={:.2}", f.sp, f.sz, f.cost);
        println!("{:>6} {:>8} {:>10} {:>10}", "S_p", "S_z", "S_a", "Cost");
        for p in &f.sweep {
            println!("{:>6.2} {:>8.3} {:>10.4} {:>10.2}", p.sp, p.sz, p.achieved, p.cost);
            rows.push(vec![
                k.to_string(),
                format!("{:.3}", p.sp),
                format!("{:.4}", p.sz),
                format!("{:.4}", p.achieved),
                format!("{:.3}", p.cost),
            ]);
        }
        // paper shape check: the cost curve is U-ish — the best point
        // is strictly better than the grid edges for reasonable ranks
        let first = f.sweep.first().unwrap().cost;
        let last = f.sweep.last().unwrap().cost;
        assert!(
            f.cost <= first && f.cost <= last,
            "sweep minimum must not be at the edge by construction"
        );
    }
    write_table_csv(
        report_dir().join("fig2_sweep.csv").to_str().unwrap(),
        &["rank", "sp", "sz", "achieved", "cost"],
        &rows,
    )
    .unwrap();
    println!("\nwrote reports/fig2_sweep.csv");
}
