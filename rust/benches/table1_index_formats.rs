//! Table 1 (right): FC1 index size across formats, plus encode/decode
//! throughput of each format (the parallelism argument of §1 made
//! measurable).

mod bench_common;

use bench_common::{fc1_weights, report_dir};
use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::formats::binary::BinaryIndex;
use lrbi::formats::csr::Csr16;
use lrbi::formats::format_comparison;
use lrbi::formats::lowrank::LowRankIndex;
use lrbi::formats::relative::Csr5Relative;
use lrbi::pruning::magnitude_mask;
use lrbi::util::bench::{print_table, write_table_csv, Bench};

fn main() {
    let w = fc1_weights(1);
    let s = 0.95;
    let f = algorithm1(&w, &Algorithm1Config::new(16, s)).expect("algorithm1");
    let rows_data = format_comparison(&w, s, f.index_bits(), "k=16").expect("format comparison");
    let rows: Vec<Vec<String>> = rows_data
        .iter()
        .map(|r| vec![r.name.clone(), format!("{:.1}KB", r.kb()), r.comment.clone()])
        .collect();
    print_table(
        "Table 1 (right): LeNet-5 FC1 index size (S=0.95)",
        &["Method", "Index Size", "Comment"],
        &rows,
    );
    write_table_csv(
        report_dir().join("table1_right.csv").to_str().unwrap(),
        &["method", "kb", "comment"],
        &rows,
    )
    .unwrap();

    // decode throughput: the deployment claim is that the low-rank
    // decode (binary matmul) is regular and fast vs CSR gathers.
    println!("\ndecode throughput (full 800x500 mask):");
    let (mask, _) = magnitude_mask(&w, s);
    let bin = BinaryIndex::encode(&mask);
    let c16 = Csr16::encode(&mask).expect("16-bit CSR encode");
    let c5 = Csr5Relative::encode(&mask);
    let lr = LowRankIndex::encode(&f);
    let mut bench = Bench::new();
    bench.run("decode/binary-bitmap", || {
        std::hint::black_box(bin.decode());
    });
    bench.run("decode/csr16", || {
        std::hint::black_box(c16.decode().unwrap());
    });
    bench.run("decode/csr5-relative", || {
        std::hint::black_box(c5.decode());
    });
    bench.run("decode/lowrank-boolmatmul", || {
        std::hint::black_box(lr.decode().unwrap());
    });
    bench
        .write_csv(report_dir().join("table1_decode_perf.csv").to_str().unwrap())
        .unwrap();
}
