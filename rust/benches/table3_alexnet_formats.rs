//! Table 3: AlexNet FC5/FC6 index sizes across five formats at S=0.91.
//! Binary/Viterbi/Proposed are exact arithmetic; CSR sizes are
//! measured on the real 9216x4096 / 4096x4096 masks (full run) or a
//! sampled block scaled up (quick mode) — identical statistics either
//! way since masks are i.i.d. at fixed sparsity.

mod bench_common;

use bench_common::{quick, report_dir};
use lrbi::formats::csr::Csr16;
use lrbi::formats::relative::Csr5Relative;
use lrbi::formats::viterbi;
use lrbi::models::alexnet::{fc5_tiling, fc6_tiling, tiled_index_bits, FC5_COLS, FC5_ROWS, FC6_COLS, FC6_ROWS};
use lrbi::pruning::magnitude_mask;
use lrbi::tensor::Matrix;
use lrbi::util::bench::{print_table, write_table_csv};
use lrbi::util::rng::Rng;

fn layer_sizes(rows: usize, cols: usize, s: f64, seed: u64) -> (f64, f64, f64, f64) {
    let (sr, sc) = if quick() { (1024.min(rows), 1024.min(cols)) } else { (rows, cols) };
    let scale = (rows * cols) as f64 / (sr * sc) as f64;
    let mut rng = Rng::new(seed);
    let w = Matrix::gaussian(sr, sc, 0.0, 0.02, &mut rng);
    let (mask, _) = magnitude_mask(&w, s);
    let bin = (rows * cols) as f64 / 8.0;
    let c16 = Csr16::encode(&mask).expect("16-bit CSR encode").index_bytes() as f64 * scale;
    let c5 = Csr5Relative::encode(&mask).index_bytes() as f64 * scale;
    let vit = viterbi::index_bytes(rows, cols) as f64;
    (bin, c16, c5, vit)
}

fn main() {
    let s = 0.91;
    let (b5, c16_5, c5_5, v5) = layer_sizes(FC5_ROWS, FC5_COLS, s, 1);
    let (b6, c16_6, c5_6, v6) = layer_sizes(FC6_ROWS, FC6_COLS, s, 2);
    let (p5, _) = fc5_tiling();
    let (p6, _) = fc6_tiling();
    // Table 3 footnote: k=32 for both layers
    let lr5 = tiled_index_bits(FC5_ROWS, FC5_COLS, p5, 32) as f64 / 8.0;
    let lr6 = tiled_index_bits(FC6_ROWS, FC6_COLS, p6, 32) as f64 / 8.0;

    let kb = |b: f64| format!("{:.0}KB", b / 1024.0);
    let rows = vec![
        vec!["Binary".into(), kb(b5), kb(b6), kb(b5 + b6), "1bit/weight".into()],
        vec!["CSR(16bit)".into(), kb(c16_5), kb(c16_6), kb(c16_5 + c16_6), String::new()],
        vec!["CSR(5bit)".into(), kb(c5_5), kb(c5_6), kb(c5_5 + c5_6), "Relative Indexing".into()],
        vec!["Viterbi".into(), kb(v5), kb(v6), kb(v5 + v6), "5X Encoder".into()],
        vec!["Proposed".into(), kb(lr5), kb(lr6), kb(lr5 + lr6), "k=32, tiled".into()],
    ];
    print_table(
        "Table 3: AlexNet FC5/FC6 index size (S=0.91); paper row order preserved",
        &["Method", "FC5", "FC6", "Sum", "Comment"],
        &rows,
    );
    println!(
        "paper: Binary 4608/2048, CSR16 6962/3099, CSR5 2176/968, Viterbi 922/410, Proposed 556/256"
    );
    write_table_csv(
        report_dir().join("table3.csv").to_str().unwrap(),
        &["method", "fc5_kb", "fc6_kb", "sum_kb", "comment"],
        &rows,
    )
    .unwrap();
    // shape assertions: strict ordering Proposed < Viterbi < CSR5 < Binary
    assert!(lr5 + lr6 < v5 + v6);
    assert!(v5 + v6 < c5_5 + c5_6);
    assert!(c5_5 + c5_6 < b5 + b6);
    println!("ordering matches the paper: Proposed < Viterbi < CSR5 < Binary ✓");
}
