//! Table 4 (appendix): ResNet32 rank-triple x pruning-rate grid —
//! exact compression ratios + accuracy-retention proxy, including the
//! "w/o BMF" baseline row (plain magnitude pruning, ratio 1x).

mod bench_common;

use bench_common::{quick, report_dir};
use lrbi::bmf::algorithm1::Algorithm1Config;
use lrbi::models::resnet32::{index_compression_ratio, rank_triples, resnet32};
use lrbi::train::data::SyntheticDigits;
use lrbi::train::loop_::{NativeTrainer, TrainConfig, TrainLog};
use lrbi::util::bench::{print_table, write_table_csv};

fn retention(s: f64, rank: usize, use_bmf: bool) -> f64 {
    let pre = if quick() { 40 } else { 200 };
    let post = if quick() { 60 } else { 400 };
    let train = SyntheticDigits::default().generate(2048);
    let test = SyntheticDigits { seed: 0xAC, ..Default::default() }.generate(400);
    let cfg = TrainConfig {
        pretrain_steps: pre,
        retrain_steps: post,
        eval_every: usize::MAX,
        ..Default::default()
    };
    let mut t = NativeTrainer::new(cfg);
    let mut log = TrainLog::default();
    t.train(&train, &test, pre, &mut log).unwrap();
    let before = t.evaluate(&test).unwrap();
    if use_bmf {
        let mut a1 = Algorithm1Config::new(rank, s);
        a1.manip = lrbi::pruning::manip::ManipMethod::AmplifyAboveThreshold;
        t.prune_fc1(&a1).unwrap();
    } else {
        // magnitude-pruning baseline (the paper's bottom row)
        let (mask, _) = lrbi::pruning::magnitude_mask(&t.params.w1, s);
        t.mask = mask.clone();
        for i in 0..mask.rows() {
            for j in 0..mask.cols() {
                if !mask.get(i, j) {
                    t.params.w1.set(i, j, 0.0);
                }
            }
        }
    }
    t.train(&train, &test, post, &mut log).unwrap();
    t.evaluate(&test).unwrap() / before
}

fn main() {
    let m = resnet32();
    let sparsities = [0.60, 0.70, 0.80];
    let triples = if quick() {
        vec![[8usize, 16, 32]]
    } else {
        rank_triples()
    };
    let mut rows = Vec::new();
    for ranks in &triples {
        let ratio = index_compression_ratio(&m, *ranks);
        let mut row = vec![
            format!("{}/{}/{}", ranks[0], ranks[1], ranks[2]),
            format!("{ratio:.2}x"),
        ];
        for &s in &sparsities {
            row.push(format!("{:.1}%", retention(s, ranks[1], true) * 100.0));
        }
        println!("ranks {:?}: ratio {ratio:.2}x done", ranks);
        rows.push(row);
    }
    // baseline row (w/o BMF)
    let mut base_row = vec!["w/o BMF".to_string(), "1x".to_string()];
    for &s in &sparsities {
        base_row.push(format!("{:.1}%", retention(s, 0, false) * 100.0));
    }
    rows.push(base_row);
    print_table(
        "Table 4: ResNet32 comp. ratio + retention proxy per (rank, S)",
        &["Rank", "Comp. Ratio", "S=0.60", "S=0.70", "S=0.80"],
        &rows,
    );
    write_table_csv(
        report_dir().join("table4.csv").to_str().unwrap(),
        &["rank", "ratio", "s060", "s070", "s080"],
        &rows,
    )
    .unwrap();
}
