//! §Perf: serving throughput/latency — native backend (isolates the
//! coordinator overhead) and PJRT backend (full artifact path),
//! across batching policies.

mod bench_common;

use bench_common::{quick, report_dir};
use lrbi::coordinator::metrics::Metrics;
use lrbi::runtime::artifacts::{ArtifactSet, GEOMETRY};
use lrbi::runtime::client::Runtime;
use lrbi::serve::batcher::BatchPolicy;
use lrbi::serve::engine::{MlpParams, NativeBackend, PjrtBackend, ServingEngine};
use lrbi::tensor::Matrix;
use lrbi::util::bench::write_table_csv;
use lrbi::util::bits::BitMatrix;
use lrbi::util::rng::Rng;
use lrbi::util::stats::percentile;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn drive(engine: &ServingEngine, clients: usize, per_client: usize) -> (f64, f64, f64) {
    let client = engine.client();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let cl = client.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(90 + c as u64);
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..GEOMETRY.input_dim).map(|_| rng.next_f32()).collect();
                    let t = Instant::now();
                    cl.call(x).unwrap().unwrap();
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                }
                lat
            })
        })
        .collect();
    let mut lat = Vec::new();
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = (clients * per_client) as f64;
    (total / wall, percentile(&lat, 0.5), percentile(&lat, 0.99))
}

fn main() {
    let g = GEOMETRY;
    let per_client = if quick() { 16 } else { 64 };
    // The PJRT rows need `make artifacts` + real xla bindings (not the
    // vendored stub); probe once and skip them gracefully otherwise.
    let pjrt_available = match ArtifactSet::open("artifacts") {
        Ok(set) => Runtime::new(set)
            .and_then(|mut rt| rt.load("predict"))
            .is_ok(),
        Err(_) => false,
    };
    if !pjrt_available {
        println!("pjrt backend skipped (artifacts/bindings unavailable)");
    }
    let mut rows = Vec::new();
    for (max_batch, wait_ms) in [(1usize, 0u64), (16, 1), (64, 2)] {
        let policy = BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        };
        // native backend
        let params = MlpParams::init(1);
        let mut rng = Rng::new(2);
        let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.25));
        let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.25));
        let backend = NativeBackend::new(params.clone(), &ip, &iz).unwrap();
        let engine = ServingEngine::start(backend, policy, Arc::new(Metrics::new()));
        let (rps, p50, p99) = drive(&engine, 8, per_client);
        println!(
            "native  batch<={max_batch:<3} wait={wait_ms}ms: {rps:>8.0} req/s  p50 {p50:>6.2}ms  p99 {p99:>7.2}ms"
        );
        rows.push(vec![
            "native".into(),
            max_batch.to_string(),
            format!("{rps:.0}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
        ]);

        // PJRT backend (full artifact path)
        if pjrt_available {
            let params2 = params.clone();
            let ipf = Matrix::from_vec(g.hidden0, g.rank, ip.to_f32()).unwrap();
            let izf = Matrix::from_vec(g.rank, g.hidden1, iz.to_f32()).unwrap();
            let engine = ServingEngine::start_with(
                move || {
                    let rt = Runtime::new(ArtifactSet::open("artifacts")?)?;
                    PjrtBackend::new(rt, &params2, &ipf, &izf)
                },
                policy,
                Arc::new(Metrics::new()),
            );
            let (rps, p50, p99) = drive(&engine, 8, per_client);
            println!(
                "pjrt    batch<={max_batch:<3} wait={wait_ms}ms: {rps:>8.0} req/s  p50 {p50:>6.2}ms  p99 {p99:>7.2}ms"
            );
            rows.push(vec![
                "pjrt".into(),
                max_batch.to_string(),
                format!("{rps:.0}"),
                format!("{p50:.2}"),
                format!("{p99:.2}"),
            ]);
        }
    }
    write_table_csv(
        report_dir().join("perf_serving.csv").to_str().unwrap(),
        &["backend", "max_batch", "req_per_s", "p50_ms", "p99_ms"],
        &rows,
    )
    .unwrap();
}
