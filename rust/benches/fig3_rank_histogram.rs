//! Figure 3: histogram of unpruned FC1 weights right after Algorithm 1
//! at S=0.95 for ranks 4..256. The paper's claim: higher rank prunes
//! more near-zero weights (the histogram notch at 0 deepens).

mod bench_common;

use bench_common::{fc1_weights, quick, report_dir};
use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::report::figures::{unpruned_histogram, write_histogram};
use lrbi::util::bench::write_table_csv;

fn main() {
    let w = fc1_weights(1);
    let s = 0.95;
    let ranks: Vec<usize> = if quick() { vec![4, 64] } else { vec![4, 16, 64, 256] };
    let t = lrbi::pruning::magnitude::threshold_for_sparsity(&w, s) as f64;
    let mut near_zero = Vec::new();
    let mut rows = Vec::new();
    for &k in &ranks {
        let mut cfg = Algorithm1Config::new(k, s);
        if quick() {
            cfg.sp_grid = vec![0.3, 0.6];
            cfg.nmf.max_iters = 15;
        }
        let f = algorithm1(&w, &cfg).expect("algorithm1");
        let h = unpruned_histogram(&w, &f.mask, 61);
        let nz = h.mass_below_abs(t);
        println!(
            "rank {k:>3}: kept {:>6}, near-zero kept {:>6}  {}",
            h.count(),
            nz,
            h.sparkline()
        );
        write_histogram(&report_dir().join(format!("fig3_hist_k{k}.csv")), &h).unwrap();
        near_zero.push(nz);
        rows.push(vec![k.to_string(), h.count().to_string(), nz.to_string()]);
    }
    write_table_csv(
        report_dir().join("fig3_nearzero.csv").to_str().unwrap(),
        &["rank", "kept", "near_zero_kept"],
        &rows,
    )
    .unwrap();
    // the paper's monotone claim — asserted only at full fidelity
    // (quick mode runs a 2-point sweep that degrades the factorization)
    if !quick() {
        assert!(
            near_zero.first().unwrap() > near_zero.last().unwrap(),
            "higher rank must keep fewer near-zero weights: {near_zero:?}"
        );
        println!("\nhigher rank -> fewer near-zero survivors ✓ {near_zero:?}");
    } else {
        println!("\n(quick mode: trend assertion skipped) {near_zero:?}");
    }
}
