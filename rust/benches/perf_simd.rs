//! §Perf: SIMD micro-kernel dispatch — `spmm` wall time across
//! simd {on, off} × threads × all seven kernel formats on the
//! FC1-shaped layer. Writes the human table, a CSV under `reports/`,
//! and the machine-readable `BENCH_simd.json` at the repository root
//! (schema `lrbi-bench-simd-v1`, documented in README.md) so the
//! vectorized hot path has numbers to regress against.
//!
//! The `off` cells pin the scalar tier via the same process-global
//! hook the bit-identity tests use (`tensor::simd::force_scalar`), so
//! one run measures both paths on identical plans and inputs; outputs
//! are byte-identical by construction (re-asserted here per cell).
//!
//!     cargo run --release --bench perf_simd
//!     LRBI_BENCH_QUICK=1 cargo run --release --bench perf_simd

mod bench_common;

use bench_common::{fc1_weights, quick, report_dir};
use lrbi::coordinator::pool::ExecCtx;
use lrbi::formats::StoredIndex;
use lrbi::runtime::artifacts::GEOMETRY;
use lrbi::serve::kernels::{
    build_kernel_exec, build_kernel_from_stored_exec, KernelFormat, SparseKernel,
};
use lrbi::tensor::{simd, Matrix};
use lrbi::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
use lrbi::util::bench::{write_table_csv, Bench};
use lrbi::util::bits::BitMatrix;
use lrbi::util::rng::Rng;

/// Factor density giving a boolean product of two `d`-dense rank-`k`
/// factors a mask sparsity near `s`: solves `s = (1 - d²)^k`.
fn factor_density(sparsity: f64, rank: usize) -> f64 {
    (1.0 - sparsity.powf(1.0 / rank as f64)).sqrt()
}

struct Cell {
    kernel: &'static str,
    simd_on: bool,
    threads: usize,
    spmm_ns: f64,
}

fn main() {
    let g = GEOMETRY;
    let w = fc1_weights(1);
    let (m, n, rank) = (g.hidden0, g.hidden1, g.rank);
    let sparsity = 0.9;
    let mut rng = Rng::new(2);
    let x = Matrix::gaussian(g.batch, m, 0.0, 1.0, &mut rng);
    let d = factor_density(sparsity, rank);
    let mut fr = Rng::new(3);
    let ip = BitMatrix::from_fn(m, rank, |_, _| fr.bernoulli(d));
    let iz = BitMatrix::from_fn(rank, n, |_, _| fr.bernoulli(d));
    let plan = TilePlan::new(4, 4);
    let tiles: Vec<TileFactors> = plan
        .tiles(m, n)
        .expect("tile plan")
        .iter()
        .map(|spec| {
            let k = rank / 4;
            TileFactors {
                rank: k,
                ip: BitMatrix::from_fn(spec.rows(), k, |_, _| fr.bernoulli(factor_density(sparsity, k))),
                iz: BitMatrix::from_fn(k, spec.cols(), |_, _| fr.bernoulli(factor_density(sparsity, k))),
            }
        })
        .collect();
    let tiled =
        StoredIndex::Tiled(TiledLowRankIndex::new(m, n, plan, tiles).expect("tiled index"));

    let probed = simd::probed_tier();
    let thread_sweep: &[usize] = if quick() { &[1] } else { &[1, 4] };
    let mut cells: Vec<Cell> = Vec::new();
    for &threads in thread_sweep {
        let ctx = ExecCtx::new(threads, None);
        let mut kernels: Vec<Box<dyn SparseKernel>> = KernelFormat::ALL
            .iter()
            .map(|&fmt| build_kernel_exec(fmt, &w, &ip, &iz, &ctx, None).expect("build"))
            .collect();
        kernels.push(build_kernel_from_stored_exec(&tiled, &w, &ctx, None).expect("tiled"));
        for kern in &kernels {
            // byte-identity sanity per cell (the pinned contract)
            simd::force_scalar(true);
            let scalar_out = kern.spmm(&x).expect("scalar spmm");
            simd::force_scalar(false);
            assert_eq!(
                kern.spmm(&x).expect("simd spmm").data(),
                scalar_out.data(),
                "{}: SIMD output must be byte-identical to scalar",
                kern.name()
            );
            for simd_on in [false, true] {
                simd::force_scalar(!simd_on);
                let mut bench = Bench::new();
                let label = format!(
                    "{}/{}/t{threads}",
                    kern.name(),
                    if simd_on { probed.label() } else { "scalar" }
                );
                let ns = bench.run(&label, || {
                    let _ = std::hint::black_box(kern.spmm(&x).expect("spmm"));
                });
                cells.push(Cell { kernel: kern.name(), simd_on, threads, spmm_ns: ns });
            }
            simd::force_scalar(false);
        }
    }

    // speedup of the simd cell vs the scalar cell at the same config
    let off_ns = |kernel: &str, threads: usize| {
        cells
            .iter()
            .find(|c| c.kernel == kernel && c.threads == threads && !c.simd_on)
            .map(|c| c.spmm_ns)
            .unwrap_or(f64::NAN)
    };
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.kernel.to_string(),
                if c.simd_on { probed.label().to_string() } else { "scalar".to_string() },
                c.threads.to_string(),
                format!("{:.1}", c.spmm_ns),
                format!("{:.3}", off_ns(c.kernel, c.threads) / c.spmm_ns),
            ]
        })
        .collect();
    write_table_csv(
        report_dir().join("perf_simd.csv").to_str().unwrap(),
        &["kernel", "tier", "threads", "spmm_ns", "speedup_vs_scalar"],
        &rows,
    )
    .unwrap();

    // Machine-readable trajectory point at the repository root.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"lrbi-bench-simd-v1\",\n");
    json.push_str("  \"bench\": \"perf_simd\",\n");
    json.push_str(&format!("  \"probed_tier\": \"{}\",\n", probed.label()));
    json.push_str(&format!(
        "  \"geometry\": {{\"m\": {m}, \"n\": {n}, \"batch\": {}, \"rank\": {rank}, \
         \"sparsity\": {sparsity}}},\n",
        g.batch
    ));
    json.push_str(&format!("  \"quick\": {},\n", quick()));
    json.push_str("  \"results\": [\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"simd\": \"{}\", \"tier\": \"{}\", \"threads\": {}, \
             \"spmm_ns\": {:.1}, \"speedup_vs_scalar\": {:.4}}}{}\n",
            c.kernel,
            if c.simd_on { "on" } else { "off" },
            if c.simd_on { probed.label() } else { "scalar" },
            c.threads,
            c.spmm_ns,
            off_ns(c.kernel, c.threads) / c.spmm_ns,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_simd.json");
    std::fs::write(out, &json).expect("write BENCH_simd.json");
    println!("\nwrote {out} ({} cells, probed tier: {})", cells.len(), probed.label());
}
