//! Table 1 (left): LeNet-5 accuracy at the 20K/40K/50K/60K checkpoints
//! vs BMF rank, plus the compression-ratio column. Training runs on
//! the synthetic digit task (scaled steps — see docs/ARCHITECTURE.md
//! §Substitutions); the *pattern* to reproduce is: accuracy collapses
//! right after pruning, retraining recovers it, and higher rank ends
//! slightly higher.

mod bench_common;

use bench_common::{quick, report_dir};
use lrbi::bmf::algorithm1::Algorithm1Config;
use lrbi::bmf::compression_ratio;
use lrbi::train::data::SyntheticDigits;
use lrbi::train::loop_::{NativeTrainer, TrainConfig, TrainLog};
use lrbi::util::bench::{print_table, write_table_csv};

fn main() {
    let ranks: Vec<usize> =
        if quick() { vec![4, 16] } else { vec![4, 8, 16, 32, 64, 128, 256] };
    // scaled checkpoints: paper's 20K/40K/50K/60K -> pre/(+r/2)/(+3r/4)/(+r)
    let pre = if quick() { 60 } else { 300 };
    let retrain = if quick() { 80 } else { 600 };
    let train = SyntheticDigits::default().generate(4096);
    let test = SyntheticDigits { seed: 0xE7A1, ..Default::default() }.generate(1000);

    let mut rows = Vec::new();
    for &k in &ranks {
        let cfg = TrainConfig {
            pretrain_steps: pre,
            retrain_steps: retrain,
            eval_every: usize::MAX,
            ..Default::default()
        };
        let mut t = NativeTrainer::new(cfg);
        let mut log = TrainLog::default();
        t.train(&train, &test, pre, &mut log).expect("pretrain");
        let mut a1 = Algorithm1Config::new(k, 0.95);
        a1.manip = lrbi::pruning::manip::ManipMethod::AmplifyAboveThreshold;
        t.prune_fc1(&a1).expect("prune");
        let acc_20k = t.evaluate(&test).unwrap(); // right after pruning
        t.train(&train, &test, retrain / 2, &mut log).unwrap();
        let acc_40k = t.evaluate(&test).unwrap();
        t.train(&train, &test, retrain / 4, &mut log).unwrap();
        let acc_50k = t.evaluate(&test).unwrap();
        t.train(&train, &test, retrain / 4, &mut log).unwrap();
        let acc_60k = t.evaluate(&test).unwrap();
        rows.push(vec![
            k.to_string(),
            format!("{:.3}", acc_20k),
            format!("{:.3}", acc_40k),
            format!("{:.3}", acc_50k),
            format!("{:.3}", acc_60k),
            format!("{:.1}x", compression_ratio(800, 500, k)),
        ]);
        println!(
            "rank {k}: post-prune {acc_20k:.3} -> retrained {acc_60k:.3} (ratio {:.1}x)",
            compression_ratio(800, 500, k)
        );
    }
    print_table(
        "Table 1 (left): accuracy checkpoints vs rank (synthetic task)",
        &["k", "post-prune", "+50%", "+75%", "final", "Comp. Ratio"],
        &rows,
    );
    let path = report_dir().join("table1_left.csv");
    write_table_csv(
        path.to_str().unwrap(),
        &["k", "acc_postprune", "acc_mid", "acc_late", "acc_final", "ratio"],
        &rows,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
