//! §Perf: parallel execution-plan scaling — `spmm` wall time across
//! threads × kernel formats × sparsity on the FC1-shaped layer. This
//! is the repo's first machine-readable perf trajectory point: besides
//! the human-readable table and `reports/perf_spmm_scaling.csv`, it
//! writes `BENCH_spmm.json` at the repository root (schema
//! `lrbi-bench-spmm-v1`, documented in README.md) so future changes
//! have numbers to regress against.
//!
//!     cargo run --release --bench perf_spmm_scaling
//!     LRBI_BENCH_QUICK=1 cargo run --release --bench perf_spmm_scaling

mod bench_common;

use bench_common::{fc1_weights, quick, report_dir};
use lrbi::coordinator::pool::ExecCtx;
use lrbi::formats::StoredIndex;
use lrbi::runtime::artifacts::GEOMETRY;
use lrbi::serve::kernels::{
    build_kernel_exec, build_kernel_from_stored_exec, KernelFormat, SparseKernel,
};
use lrbi::tensor::Matrix;
use lrbi::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
use lrbi::util::bench::{write_table_csv, Bench};
use lrbi::util::bits::BitMatrix;
use lrbi::util::rng::Rng;

/// Factor density giving a boolean product of two `d`-dense rank-`k`
/// factors a mask sparsity near `s`: solves `s = (1 - d²)^k`.
fn factor_density(sparsity: f64, rank: usize) -> f64 {
    (1.0 - sparsity.powf(1.0 / rank as f64)).sqrt()
}

struct Cell {
    kernel: &'static str,
    sparsity: f64,
    threads: usize,
    shards: usize,
    index_bytes: usize,
    spmm_ns: f64,
}

fn main() {
    let g = GEOMETRY;
    let w = fc1_weights(1);
    let (m, n, rank) = (g.hidden0, g.hidden1, g.rank);
    let mut rng = Rng::new(2);
    let x = Matrix::gaussian(g.batch, m, 0.0, 1.0, &mut rng);
    let thread_sweep: &[usize] = if quick() { &[1, 4] } else { &[1, 2, 4, 8] };
    let rates: &[f64] = if quick() { &[0.9] } else { &[0.8, 0.9, 0.95] };

    let mut cells: Vec<Cell> = Vec::new();
    for &s in rates {
        // Synthetic factors at the target sparsity: the bench measures
        // plan execution, not Algorithm 1.
        let d = factor_density(s, rank);
        let mut fr = Rng::new(3);
        let ip = BitMatrix::from_fn(m, rank, |_, _| fr.bernoulli(d));
        let iz = BitMatrix::from_fn(rank, n, |_, _| fr.bernoulli(d));
        // A 4×4 tiled variant of the same budget for the fifth kernel.
        let plan = TilePlan::new(4, 4);
        let tiles: Vec<TileFactors> = plan
            .tiles(m, n)
            .expect("tile plan")
            .iter()
            .map(|spec| {
                let k = rank / 4;
                TileFactors {
                    rank: k,
                    ip: BitMatrix::from_fn(spec.rows(), k, |_, _| {
                        fr.bernoulli(factor_density(s, k))
                    }),
                    iz: BitMatrix::from_fn(k, spec.cols(), |_, _| {
                        fr.bernoulli(factor_density(s, k))
                    }),
                }
            })
            .collect();
        let tiled = StoredIndex::Tiled(
            TiledLowRankIndex::new(m, n, plan, tiles).expect("tiled index"),
        );

        for &threads in thread_sweep {
            let ctx = ExecCtx::new(threads, None);
            println!("\nS={s:.2}, threads={threads}:");
            let mut bench = Bench::new();
            let mut kernels: Vec<Box<dyn SparseKernel>> = KernelFormat::ALL
                .iter()
                .map(|&fmt| build_kernel_exec(fmt, &w, &ip, &iz, &ctx, None).expect("build"))
                .collect();
            kernels.push(
                build_kernel_from_stored_exec(&tiled, &w, &ctx, None).expect("tiled build"),
            );
            for kern in &kernels {
                let _ = kern.spmm(&x).expect("warmup");
                let label = format!("{}/S{s:.2}/t{threads}", kern.name());
                let ns = bench.run(&label, || {
                    let _ = std::hint::black_box(kern.spmm(&x).expect("spmm"));
                });
                cells.push(Cell {
                    kernel: kern.name(),
                    sparsity: s,
                    threads,
                    shards: kern.plan_shards(),
                    index_bytes: kern.index_bytes(),
                    spmm_ns: ns,
                });
            }
        }
    }

    // speedup vs the same kernel/sparsity at threads = 1
    let t1_ns = |kernel: &str, s: f64| {
        cells
            .iter()
            .find(|c| c.kernel == kernel && c.sparsity == s && c.threads == 1)
            .map(|c| c.spmm_ns)
            .unwrap_or(f64::NAN)
    };
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.kernel.to_string(),
                format!("{:.2}", c.sparsity),
                c.threads.to_string(),
                c.shards.to_string(),
                format!("{:.1}", c.spmm_ns),
                format!("{:.3}", t1_ns(c.kernel, c.sparsity) / c.spmm_ns),
                c.index_bytes.to_string(),
            ]
        })
        .collect();
    write_table_csv(
        report_dir().join("perf_spmm_scaling.csv").to_str().unwrap(),
        &["kernel", "sparsity", "threads", "shards", "spmm_ns", "speedup_vs_t1", "index_bytes"],
        &rows,
    )
    .unwrap();

    // Machine-readable trajectory point at the repository root.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"lrbi-bench-spmm-v1\",\n");
    json.push_str("  \"bench\": \"perf_spmm_scaling\",\n");
    json.push_str(&format!(
        "  \"geometry\": {{\"m\": {m}, \"n\": {n}, \"batch\": {}, \"rank\": {rank}}},\n",
        g.batch
    ));
    json.push_str(&format!("  \"quick\": {},\n", quick()));
    json.push_str("  \"results\": [\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"sparsity\": {:.2}, \"threads\": {}, \"shards\": {}, \
             \"spmm_ns\": {:.1}, \"speedup_vs_t1\": {:.4}, \"index_bytes\": {}}}{}\n",
            c.kernel,
            c.sparsity,
            c.threads,
            c.shards,
            c.spmm_ns,
            t1_ns(c.kernel, c.sparsity) / c.spmm_ns,
            c.index_bytes,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_spmm.json");
    std::fs::write(out, &json).expect("write BENCH_spmm.json");
    println!("\nwrote {out} ({} cells)", cells.len());
}
