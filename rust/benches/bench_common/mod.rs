//! Shared helpers for the bench binaries (harness = false).
#![allow(dead_code)]

use lrbi::tensor::Matrix;
use lrbi::util::rng::Rng;

/// Synthetic FC1 weights (LeNet-5 800x500) — the workload of every
/// MNIST-section figure/table. Uses the trained-network magnitude
/// model (row/col lognormal scales), not plain i.i.d. Gaussian — see
/// `models::pretrained_like_weights` and docs/ARCHITECTURE.md
/// §Workload-realism.
pub fn fc1_weights(seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    lrbi::models::pretrained_like_weights(800, 500, 0.05, 0.8, &mut rng)
}

/// Where bench CSVs go.
pub fn report_dir() -> std::path::PathBuf {
    let p = std::path::PathBuf::from("reports");
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Quick mode trims sweeps for smoke runs (LRBI_BENCH_QUICK=1).
pub fn quick() -> bool {
    std::env::var("LRBI_BENCH_QUICK").is_ok()
}
