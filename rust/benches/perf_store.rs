//! §Perf: the artifact store — packed `.lrbi` bytes per format
//! (file, index section, and the format's own `index_bytes()` claim)
//! and cold-load latency: read + CRC + decode, and decode-to-kernel,
//! measured separately. The paper's Table-1 byte claims become file
//! regions here; the load numbers are what a hot-swap deploy pays.

mod bench_common;

use bench_common::{quick, report_dir};
use lrbi::formats::StoredIndex;
use lrbi::runtime::artifacts::GEOMETRY;
use lrbi::serve::engine::MlpParams;
use lrbi::serve::kernels::build_kernel_from_stored;
use lrbi::store::{Artifact, ArtifactMeta, Container, SectionKind};
use lrbi::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
use lrbi::util::bench::write_table_csv;
use lrbi::util::bits::BitMatrix;
use lrbi::util::rng::Rng;
use std::time::Instant;

fn main() {
    let g = GEOMETRY;
    let reps = if quick() { 3 } else { 10 };
    let params = MlpParams::init(1);
    let mut rng = Rng::new(2);
    let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.25));
    let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.25));

    let dir = std::env::temp_dir().join(format!("lrbi_perf_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // tiled artifact: 2x2 plan, equal rank per tile
    let plan = TilePlan::new(2, 2);
    let tiles: Vec<TileFactors> = plan
        .tiles(g.hidden0, g.hidden1)
        .unwrap()
        .iter()
        .map(|s| TileFactors {
            rank: g.rank / 2,
            ip: BitMatrix::from_fn(s.rows(), g.rank / 2, |_, _| rng.bernoulli(0.25)),
            iz: BitMatrix::from_fn(g.rank / 2, s.cols(), |_, _| rng.bernoulli(0.25)),
        })
        .collect();
    let tiled = StoredIndex::Tiled(
        TiledLowRankIndex::new(g.hidden0, g.hidden1, plan, tiles).unwrap(),
    );

    let mut artifacts: Vec<(String, Artifact)> = ["dense", "csr", "relative", "lowrank"]
        .into_iter()
        .map(|name| {
            (
                name.to_string(),
                Artifact::pack_factors(params.clone(), name, &ip, &iz, "perf_store").unwrap(),
            )
        })
        .collect();
    artifacts.push((
        "tiled".into(),
        Artifact {
            params: params.clone(),
            index: tiled,
            meta: ArtifactMeta {
                sparsity: 0.0,
                cost: 0.0,
                rank: 0,
                provenance: "perf_store".into(),
            },
        },
    ));

    println!(
        "{:<9} {:>9} {:>11} {:>11} {:>10} {:>10}",
        "format", "file B", "section B", "index B", "load ms", "kernel ms"
    );
    let mut rows = Vec::new();
    for (name, art) in &artifacts {
        let path = dir.join(format!("{name}.lrbi"));
        art.write(&path).unwrap();
        let file_bytes = std::fs::metadata(&path).unwrap().len();
        let c = Container::read(&path).unwrap();
        let kind = SectionKind::INDEX_KINDS
            .into_iter()
            .find(|k| c.section(*k).is_some())
            .unwrap();
        let section_bytes = c.section(kind).unwrap().len();
        let index_bytes = art.index.index_bytes();

        // cold load: read + CRC + decode into format structs
        let mut load_ms = 0.0;
        let mut kernel_ms = 0.0;
        for _ in 0..reps {
            let t0 = Instant::now();
            let loaded = Artifact::read(&path).unwrap();
            load_ms += t0.elapsed().as_secs_f64() * 1e3;
            let t1 = Instant::now();
            let k = build_kernel_from_stored(&loaded.index, &loaded.params.w1, None).unwrap();
            kernel_ms += t1.elapsed().as_secs_f64() * 1e3;
            assert_eq!(k.rows(), g.hidden0);
        }
        load_ms /= reps as f64;
        kernel_ms /= reps as f64;
        println!(
            "{name:<9} {file_bytes:>9} {section_bytes:>11} {index_bytes:>11} {load_ms:>10.3} {kernel_ms:>10.3}"
        );
        rows.push(vec![
            name.clone(),
            file_bytes.to_string(),
            section_bytes.to_string(),
            index_bytes.to_string(),
            format!("{load_ms:.3}"),
            format!("{kernel_ms:.3}"),
        ]);
    }
    write_table_csv(
        report_dir().join("perf_store.csv").to_str().unwrap(),
        &["format", "file_bytes", "index_section_bytes", "index_bytes", "cold_load_ms", "kernel_build_ms"],
        &rows,
    )
    .unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
