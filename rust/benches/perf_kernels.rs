//! §Perf: sparse-execution kernels — the masked FC1 matmul executed
//! directly on each index representation (dense-masked baseline, CSR
//! gather-accumulate, 5-bit relative streaming, fused low-rank,
//! Viterbi shift-register regeneration, 4-bit dCSR deltas) at the
//! paper's pruning rates. Reports per-kernel build (decode) time,
//! per-call spmm time, index size, and agreement with the baseline.
//! Note the `viterbi` row's `max_abs_err` is expectedly large: the
//! format is mask-shaping, so it serves a different (shaped) mask
//! than the exact `I_p ⊗ I_z` product the baseline uses.
//!
//!     cargo run --release --bench perf_kernels
//!     LRBI_BENCH_QUICK=1 cargo run --release --bench perf_kernels

mod bench_common;

use bench_common::{fc1_weights, quick, report_dir};
use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::runtime::artifacts::GEOMETRY;
use lrbi::serve::kernels::{build_kernel, KernelFormat, SparseKernel};
use lrbi::tensor::Matrix;
use lrbi::util::bench::write_table_csv;
use lrbi::util::rng::Rng;
use std::time::Instant;

fn main() {
    let g = GEOMETRY;
    let w = fc1_weights(1);
    let mut rng = Rng::new(2);
    let x = Matrix::gaussian(g.batch, g.hidden0, 0.0, 1.0, &mut rng);
    let reps = if quick() { 3 } else { 30 };
    let rates: &[f64] = if quick() { &[0.9] } else { &[0.8, 0.9, 0.95] };

    let mut rows = Vec::new();
    for &s in rates {
        // Real factors from Algorithm 1 (trimmed sweep: the bench
        // measures kernels, not the factorization).
        let mut cfg = Algorithm1Config::new(g.rank, s);
        cfg.sp_grid = vec![0.4, 0.6, 0.8];
        cfg.nmf.max_iters = 25;
        let f = algorithm1(&w, &cfg).expect("algorithm1");
        println!(
            "\nS={s:.2} (achieved {:.3}), rank {}: {} index bytes",
            f.achieved_sparsity,
            f.rank,
            f.index_bytes()
        );

        let mut dense_out: Option<Matrix> = None;
        let mut dense_ms = 0.0f64;
        for fmt in KernelFormat::ALL {
            let t0 = Instant::now();
            let kernel = build_kernel(fmt, &w, &f.ip, &f.iz, None).expect("build");
            let build_ms = t0.elapsed().as_secs_f64() * 1e3;

            let _ = kernel.spmm(&x).expect("warmup"); // warm caches
            let t1 = Instant::now();
            let mut out = kernel.spmm(&x).expect("spmm");
            for _ in 1..reps {
                out = kernel.spmm(&x).expect("spmm");
            }
            let spmm_ms = t1.elapsed().as_secs_f64() * 1e3 / reps as f64;

            let max_err = match &dense_out {
                None => {
                    dense_ms = spmm_ms;
                    dense_out = Some(out);
                    0.0
                }
                Some(base) => out
                    .data()
                    .iter()
                    .zip(base.data())
                    .map(|(a, b)| (a - b).abs() as f64)
                    .fold(0.0, f64::max),
            };
            let speedup = dense_ms / spmm_ms;
            println!(
                "  {:<8} index {:>8.1} KB  build {:>7.2} ms  spmm {:>7.3} ms  {:>5.2}x vs dense  max err {max_err:.2e}",
                fmt.name(),
                kernel.index_bytes() as f64 / 1000.0,
                build_ms,
                spmm_ms,
                speedup,
            );
            rows.push(vec![
                fmt.name().to_string(),
                format!("{s:.2}"),
                format!("{:.3}", kernel.index_bytes() as f64 / 1000.0),
                format!("{build_ms:.3}"),
                format!("{spmm_ms:.4}"),
                format!("{speedup:.3}"),
                format!("{max_err:.3e}"),
            ]);
        }
    }
    write_table_csv(
        report_dir().join("perf_kernels.csv").to_str().unwrap(),
        &[
            "kernel",
            "sparsity",
            "index_kb",
            "build_ms",
            "spmm_ms",
            "speedup_vs_dense",
            "max_abs_err",
        ],
        &rows,
    )
    .unwrap();
}
