//! §Perf: micro-benchmarks of every L3 hot path. Run via
//! `cargo bench --bench perf_hot_paths`; results land as CSVs under `reports/`.

mod bench_common;

use bench_common::{fc1_weights, report_dir};
use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::bmf::convert::{threshold_binarize, SortedMags};
use lrbi::nmf::{nmf, NmfConfig};
use lrbi::tensor::Matrix;
use lrbi::util::bench::Bench;
use lrbi::util::bits::BitMatrix;
use lrbi::util::rng::Rng;

fn main() {
    let mut bench = Bench::new();
    let w = fc1_weights(1);
    let m = w.abs();

    // 1. bitset boolean matmul (the decode hot path): 800x256 x 256x500
    let mut rng = Rng::new(2);
    for k in [16usize, 64, 256] {
        let ip = BitMatrix::from_fn(800, k, |_, _| rng.bernoulli(0.3));
        let iz = BitMatrix::from_fn(k, 500, |_, _| rng.bernoulli(0.3));
        let ns = bench.run(&format!("bool_product/800x{k}x500"), || {
            std::hint::black_box(ip.bool_product(&iz));
        });
        let bits = 800.0 * 500.0;
        println!("      -> {:.2} Gbit/s mask decode", bits / ns);
    }

    // 2. threshold conversion (per sweep point)
    let sorted = SortedMags::new(&m);
    bench.run("threshold_binarize/800x500", || {
        std::hint::black_box(threshold_binarize(&m, sorted.threshold(0.5)));
    });
    bench.run("sorted_mags_build/800x500", || {
        std::hint::black_box(SortedMags::new(&m));
    });

    // 3. NMF iterations (rank 16, full FC1)
    bench.run("nmf/800x500xk16/10iters", || {
        let cfg = NmfConfig { rank: 16, max_iters: 10, tol: 0.0, seed: 3 };
        std::hint::black_box(nmf(&m, &cfg).unwrap());
    });

    // 4. dense matmul (threaded) used by NMF
    let mut rng2 = Rng::new(4);
    let a = Matrix::gaussian(800, 500, 0.0, 1.0, &mut rng2);
    let b = Matrix::gaussian(500, 64, 0.0, 1.0, &mut rng2);
    let ns = bench.run("matmul/800x500x64", || {
        std::hint::black_box(a.matmul(&b).unwrap());
    });
    let flops = 2.0 * 800.0 * 500.0 * 64.0;
    println!("      -> {:.2} GFLOP/s", flops / ns);

    // 5. full Algorithm 1 at the paper's headline config
    let mut cfg = Algorithm1Config::new(16, 0.95);
    cfg.sp_grid = vec![0.2, 0.4, 0.6, 0.8]; // 4-point sweep per sample
    bench.samples = 3;
    let ns = bench.run("algorithm1/fc1/k16/4-point-sweep", || {
        std::hint::black_box(algorithm1(&w, &cfg).unwrap());
    });
    println!("      -> full 19-point sweep est: {:.2} s", ns * 19.0 / 4.0 / 1e9);

    bench
        .write_csv(report_dir().join("perf_hot_paths.csv").to_str().unwrap())
        .unwrap();
}
