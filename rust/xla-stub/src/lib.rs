//! Minimal API-compatible stand-in for the `xla` PJRT bindings.
//!
//! The lrbi runtime layer (`runtime::client`) targets the real `xla`
//! crate's surface: `PjRtClient::cpu()`, HLO-text compilation, and
//! literal marshalling. That crate links the PJRT C API and is not
//! available in hermetic build environments, so this stub provides the
//! same types and signatures with *execution* unavailable at runtime:
//! literal construction/reshaping/readback work (they are pure Rust),
//! while `compile`/`execute` return an error. Everything that does not
//! require PJRT — the whole compression pipeline, the native serving
//! backend, and all sparse-execution kernels — is unaffected.
//!
//! To run the real artifact path, point Cargo at genuine bindings:
//!
//! ```toml
//! [patch.'crates-io']            # or a [patch] on the path dep
//! xla = { git = "..." }
//! ```

/// Error type mirroring `xla::Error` (a message wrapper here).
#[derive(Debug, Clone)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: lrbi was built against the vendored xla stub \
         (swap in real PJRT bindings to execute artifacts)"
    ))
}

type Result<T> = std::result::Result<T, Error>;

/// A host literal: flat f32 buffer + dims (rank ≤ 2 is all lrbi uses).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out the flat buffer.
    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from(v)).collect())
    }

    /// Tuple elements — stub literals are never tuples.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple readback"))
    }
}

/// Parsed HLO module handle (the stub only checks the file is readable).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Read an HLO-text artifact. I/O errors are reported; parsing is
    /// deferred to the (unavailable) compile step.
    pub fn from_text_file(path: &str) -> Result<Self> {
        std::fs::read_to_string(path)
            .map(|_| HloModuleProto)
            .map_err(|e| Error(format!("read {path}: {e}")))
    }
}

/// A computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (never constructible from the stub's paths).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Synchronous device→host copy.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("buffer readback"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with host inputs.
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execution"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client — constructible so artifact-set validation and
    /// graceful-skip logic can run; compilation is where the stub stops.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    /// Platform name (diagnostics).
    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    /// Compile a computation — unavailable in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[7]).is_err());
    }

    #[test]
    fn execution_paths_report_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "xla-stub");
        let err = client.compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
