//! CLI smoke tests: the compiled binary's commands run end to end.

use lrbi::cli;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(|t| t.to_string()).collect()
}

#[test]
fn info_and_unknown() {
    assert_eq!(cli::run(argv("info")), 0);
    assert_eq!(cli::run(argv("definitely-not-a-command")), 2);
}

#[test]
fn compress_lenet_quick() {
    assert_eq!(
        cli::run(argv("compress --model lenet5 --sparsity 0.9 --rank 4 --threads 4")),
        0
    );
}

#[test]
fn compress_from_config_file() {
    let dir = std::env::temp_dir().join("lrbi_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        "[compress]\nmodel = \"lenet5\"\nsparsity = 0.9\nranks = [4]\n",
    )
    .unwrap();
    assert_eq!(cli::run(argv(&format!("compress --config {}", path.display()))), 0);
}

#[test]
fn report_writes_files() {
    let dir = std::env::temp_dir().join("lrbi_cli_reports");
    let _ = std::fs::remove_dir_all(&dir);
    assert_eq!(cli::run(argv(&format!("report --out {}", dir.display()))), 0);
    assert!(dir.join("table1_right.csv").exists());
    assert!(dir.join("table4_ratios.csv").exists());
}

#[test]
fn serve_synthetic_traffic() {
    assert_eq!(cli::run(argv("serve --requests 64 --max-batch 16 --max-wait-ms 1")), 0);
}

#[test]
fn pack_inspect_serve_artifact_roundtrip() {
    let dir = std::env::temp_dir().join(format!("lrbi_cli_pack_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let file = dir.join("model.lrbi");
    let file = file.display();
    assert_eq!(
        cli::run(argv(&format!(
            "pack --out {file} --format=lowrank --rank 8 --sparsity 0.9"
        ))),
        0
    );
    assert_eq!(cli::run(argv(&format!("inspect --artifact {file}"))), 0);
    assert_eq!(
        cli::run(argv(&format!("serve --artifact {file} --requests 32 --max-batch 16"))),
        0
    );
    // pack without a destination is an error
    assert_eq!(cli::run(argv("pack --format lowrank")), 2);
    // inspecting garbage is a typed error, not a panic
    let bad = dir.join("bad.lrbi");
    std::fs::write(&bad, b"not an artifact").unwrap();
    assert_eq!(cli::run(argv(&format!("inspect --artifact {}", bad.display()))), 2);
    let _ = std::fs::remove_dir_all(std::env::temp_dir().join(format!(
        "lrbi_cli_pack_{}",
        std::process::id()
    )));
}

#[test]
fn pack_registry_and_serve_with_hot_swap() {
    let dir = std::env::temp_dir().join(format!("lrbi_cli_reg_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let reg = dir.display();
    assert_eq!(
        cli::run(argv(&format!("pack --registry {reg} --name v1 --format csr --rank 8"))),
        0
    );
    assert_eq!(
        cli::run(argv(&format!(
            "pack --registry {reg} --name v2 --format relative --rank 8 --tiles 1"
        ))),
        0
    );
    assert_eq!(
        cli::run(argv(&format!("pack --registry {reg} --name tiled4 --tiles 2 --rank 8"))),
        0
    );
    assert_eq!(
        cli::run(argv(&format!("serve --registry {reg} --requests 24 --swap v1"))),
        0
    );
    let _ = std::fs::remove_dir_all(&dir);
}
