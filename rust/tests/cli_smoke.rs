//! CLI smoke tests: the compiled binary's commands run end to end.

use lrbi::cli;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(|t| t.to_string()).collect()
}

#[test]
fn info_and_unknown() {
    assert_eq!(cli::run(argv("info")), 0);
    assert_eq!(cli::run(argv("definitely-not-a-command")), 2);
}

#[test]
fn compress_lenet_quick() {
    assert_eq!(
        cli::run(argv("compress --model lenet5 --sparsity 0.9 --rank 4 --threads 4")),
        0
    );
}

#[test]
fn compress_from_config_file() {
    let dir = std::env::temp_dir().join("lrbi_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        "[compress]\nmodel = \"lenet5\"\nsparsity = 0.9\nranks = [4]\n",
    )
    .unwrap();
    assert_eq!(cli::run(argv(&format!("compress --config {}", path.display()))), 0);
}

#[test]
fn report_writes_files() {
    let dir = std::env::temp_dir().join("lrbi_cli_reports");
    let _ = std::fs::remove_dir_all(&dir);
    assert_eq!(cli::run(argv(&format!("report --out {}", dir.display()))), 0);
    assert!(dir.join("table1_right.csv").exists());
    assert!(dir.join("table4_ratios.csv").exists());
}

#[test]
fn serve_synthetic_traffic() {
    assert_eq!(cli::run(argv("serve --requests 64 --max-batch 16 --max-wait-ms 1")), 0);
}
