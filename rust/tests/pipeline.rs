//! End-to-end native pipeline integration: magnitude prune → BMF
//! factorize (tiled, manipulated) → serialize → decode → serve-ready
//! mask, plus cross-format consistency.

use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
use lrbi::coordinator::metrics::Metrics;
use lrbi::coordinator::sweep::{compress_model, SweepOptions};
use lrbi::formats::binary::BinaryIndex;
use lrbi::formats::csr::Csr16;
use lrbi::formats::lowrank::LowRankIndex;
use lrbi::formats::relative::Csr5Relative;
use lrbi::models::lenet::lenet5;
use lrbi::pruning::magnitude_mask;
use lrbi::pruning::manip::ManipMethod;
use lrbi::tensor::Matrix;
use lrbi::tiling::{compress_tiled, RankPlan, TilePlan};
use lrbi::util::rng::Rng;

fn fast_cfg(rank: usize, s: f64) -> Algorithm1Config {
    let mut c = Algorithm1Config::new(rank, s);
    c.sp_grid = vec![0.2, 0.4, 0.6, 0.8];
    c.nmf.max_iters = 20;
    c
}

#[test]
fn full_fc1_compression_roundtrip() {
    // the paper's headline config: FC1 800x500, S=0.95, k=16
    let mut rng = Rng::new(42);
    let w = Matrix::gaussian(800, 500, 0.0, 0.05, &mut rng);
    let f = algorithm1(&w, &fast_cfg(16, 0.95)).unwrap();
    assert!((f.achieved_sparsity - 0.95).abs() < 0.01);
    assert!((f.compression_ratio() - 19.23).abs() < 0.1);
    // serialize + decode round-trip
    let enc = LowRankIndex::encode(&f);
    assert_eq!(enc.index_bytes(), 2600); // the paper's 2.6KB
    assert_eq!(enc.decode().unwrap(), f.mask);
}

#[test]
fn bmf_cost_trends_match_paper() {
    // Calibrated expectations on i.i.d. Gaussian weights (the magnitude
    // matrix has limited low-rank structure, so absolute cost is
    // nonzero — exactly the paper's premise). The *trends* the paper
    // claims must hold: (a) BMF beats a random same-sparsity mask,
    // (b) cost is monotone non-increasing in rank (Figure 3 / Table 1).
    let mut rng = Rng::new(7);
    let w = Matrix::gaussian(120, 100, 0.0, 0.1, &mut rng);
    let s = 0.9;
    let (reference, _) = magnitude_mask(&w, s);
    let mags = w.abs();
    let mut rand_cost = 0.0;
    let mut rng2 = Rng::new(8);
    for i in 0..w.rows() {
        for j in 0..w.cols() {
            if reference.get(i, j) && !rng2.bernoulli(1.0 - s) {
                rand_cost += mags.get(i, j) as f64;
            }
        }
    }
    let mut costs = Vec::new();
    for rank in [4usize, 16, 32] {
        let f = algorithm1(&w, &Algorithm1Config::new(rank, s)).unwrap();
        assert!(
            f.raw_cost < rand_cost * 0.9,
            "rank {rank}: BMF cost {} not below random {rand_cost}",
            f.raw_cost
        );
        costs.push(f.raw_cost);
    }
    assert!(costs[0] > costs[1] && costs[1] > costs[2], "cost must fall with rank: {costs:?}");
    // at rank 32 the advantage is substantial (calibrated: ~0.66x)
    assert!(costs[2] < rand_cost * 0.75, "rank-32 cost {} vs random {rand_cost}", costs[2]);
}

#[test]
fn tiled_equal_budget_reduces_cost() {
    // Figure 6's claim: at equal index budget, more tiles -> lower
    // cost (deeper near-zero drop). Verify cost ordering on a
    // Gaussian FC1 substitute (smaller for test speed).
    let mut rng = Rng::new(9);
    let w = Matrix::gaussian(200, 120, 0.0, 0.1, &mut rng);
    let base = fast_cfg(16, 0.9);
    let single = compress_tiled(&w, TilePlan::new(1, 1), &RankPlan::Uniform(16), &base).unwrap();
    let mut cfg4 = base.clone();
    cfg4.rank = 8;
    let tiled4 =
        compress_tiled(&w, TilePlan::new(2, 2), &RankPlan::Uniform(8), &cfg4).unwrap();
    // equal budget check: 16*(200+120) = 5120 vs 4 * 8*(100+60) = 5120
    assert_eq!(single.index_bits(), tiled4.index_bits());
    assert!(
        tiled4.cost() < single.cost() * 1.10,
        "tiled cost {} should not exceed single-tile cost {} materially",
        tiled4.cost(),
        single.cost()
    );
}

#[test]
fn manipulation_method3_protects_large_weights() {
    let mut rng = Rng::new(10);
    let w = Matrix::gaussian(150, 100, 0.0, 0.1, &mut rng);
    let s = 0.9;
    let mut plain = Algorithm1Config::new(8, s);
    plain.manip = ManipMethod::None;
    let mut m3 = Algorithm1Config::new(8, s);
    m3.manip = ManipMethod::AmplifyAboveThreshold;
    let f_plain = algorithm1(&w, &plain).unwrap();
    let f_m3 = algorithm1(&w, &m3).unwrap();
    // §3.2's claim, measured on the raw (unmanipulated) magnitudes:
    // manipulation lowers the cost of unintended prunes (calibrated:
    // ~0.71x vs ~0.79x of random at rank 8).
    assert!(
        f_m3.raw_cost < f_plain.raw_cost,
        "method 3 raw cost {} should beat method 1 {}",
        f_m3.raw_cost,
        f_plain.raw_cost
    );
    // and it must keep more of the largest weights than method 1
    let mut idx: Vec<(usize, usize)> = (0..w.rows())
        .flat_map(|i| (0..w.cols()).map(move |j| (i, j)))
        .collect();
    idx.sort_by(|a, b| {
        w.get(b.0, b.1)
            .abs()
            .partial_cmp(&w.get(a.0, a.1).abs())
            .unwrap()
    });
    let top = &idx[..30];
    let kept = |m: &lrbi::util::bits::BitMatrix| top.iter().filter(|&&(i, j)| m.get(i, j)).count();
    let (k3, k1) = (kept(&f_m3.mask), kept(&f_plain.mask));
    assert!(k3 >= k1, "method 3 kept {k3}/30 top weights vs method 1 {k1}/30");
}

#[test]
fn model_sweep_to_format_table_consistency() {
    let model = lenet5();
    let mut opts = SweepOptions::new(0.95, 16);
    opts.base.sp_grid = vec![0.3, 0.6];
    opts.base.nmf.max_iters = 12;
    let rep = compress_model(&model, &opts, &Metrics::new()).unwrap();
    assert_eq!(rep.layers.len(), 1); // only fc1 is compressible
    let fc1 = &rep.layers[0];
    // the mask must round-trip through every exact format
    let bin = BinaryIndex::encode(&fc1.mask);
    assert_eq!(bin.decode(), fc1.mask);
    let c16 = Csr16::encode(&fc1.mask).unwrap();
    assert_eq!(c16.decode().unwrap(), fc1.mask);
    let c5 = Csr5Relative::encode(&fc1.mask);
    assert_eq!(c5.decode(), fc1.mask);
    // and sizes must be ordered as in Table 1R
    assert!(bin.index_bytes() > c16.index_bytes() || fc1.sparsity < 0.9);
    assert!(c16.index_bytes() > c5.index_bytes());
    assert!(c5.index_bytes() > fc1.index_bits / 8);
}
