//! Integration tests for the `.lrbi` artifact store: pack → write →
//! load → serve round-trips must be *bit-identical* to serving the
//! in-memory compression, for every kernel format and a tiled plan;
//! corrupt files must surface typed errors, never panics.

use lrbi::formats::StoredIndex;
use lrbi::runtime::artifacts::GEOMETRY;
use lrbi::serve::engine::{InferenceBackend, MlpParams, NativeBackend};
use lrbi::serve::kernels::{build_kernel_from_stored, KernelFormat, SparseKernel};
use lrbi::store::{Artifact, Container, Registry, SectionKind};
use lrbi::tensor::Matrix;
use lrbi::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
use lrbi::util::bits::BitMatrix;
use lrbi::util::error::Error;
use lrbi::util::prop;
use lrbi::util::rng::Rng;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lrbi_store_it_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn geometry_factors(seed: u64) -> (BitMatrix, BitMatrix) {
    let g = GEOMETRY;
    let mut rng = Rng::new(seed);
    (
        BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.25)),
        BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.25)),
    )
}

/// The PR's acceptance criterion: `pack` → `serve --artifact` logits
/// must be bit-identical to serving the in-memory compression, for
/// all six kernel formats; and the on-disk index section must cost
/// `index_bytes()` plus only a fixed shape header. Viterbi joins this
/// loop because both construction paths shape the same mask through
/// the same deterministic encoder — the stored stream and the
/// factor-built stream are byte-identical.
#[test]
fn packed_artifact_serves_bit_identical_logits_all_formats() {
    let dir = tmp("formats");
    let params = MlpParams::init(51);
    let (ip, iz) = geometry_factors(52);
    let mut rng = Rng::new(53);
    let x = Matrix::gaussian(4, GEOMETRY.input_dim, 0.0, 1.0, &mut rng);
    for (fmt, name) in [
        (KernelFormat::DenseMasked, "dense"),
        (KernelFormat::Csr, "csr"),
        (KernelFormat::Relative, "relative"),
        (KernelFormat::LowRankFused, "lowrank"),
        (KernelFormat::Viterbi, "viterbi"),
        (KernelFormat::Dcsr, "dcsr"),
    ] {
        // in-memory serving path
        let mut mem = NativeBackend::with_format(params.clone(), fmt, &ip, &iz).unwrap();
        let want = mem.predict(&x).unwrap();

        // pack → file → load → serve
        let art = Artifact::pack_factors(params.clone(), name, &ip, &iz, "it").unwrap();
        let path = dir.join(format!("{name}.lrbi"));
        art.write(&path).unwrap();
        let loaded = Artifact::read(&path).unwrap();
        let mut srv = NativeBackend::from_artifact(&loaded).unwrap();
        let got = srv.predict(&x).unwrap();
        assert_eq!(got.data(), want.data(), "{name}: logits must be bit-identical");

        // on-disk index section ≈ index_bytes (within the shape header)
        let c = Container::read(&path).unwrap();
        let kind = SectionKind::INDEX_KINDS
            .into_iter()
            .find(|k| c.section(*k).is_some())
            .unwrap();
        let section_len = c.section(kind).unwrap().len();
        let overhead = section_len - loaded.index.index_bytes();
        assert!(overhead <= 12, "{name}: section overhead {overhead}B > header");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Same criterion for a tiled plan with mixed per-tile ranks.
#[test]
fn packed_tiled_artifact_serves_bit_identical_logits() {
    let dir = tmp("tiled");
    let params = MlpParams::init(61);
    let (m, n) = (params.w1.rows(), params.w1.cols());
    let plan = TilePlan::new(2, 3);
    let mut rng = Rng::new(62);
    let tiles: Vec<TileFactors> = plan
        .tiles(m, n)
        .unwrap()
        .iter()
        .map(|s| {
            let k = 4 + s.id % 3; // per-tile ranks 4..6
            TileFactors {
                rank: k,
                ip: BitMatrix::from_fn(s.rows(), k, |_, _| rng.bernoulli(0.2)),
                iz: BitMatrix::from_fn(k, s.cols(), |_, _| rng.bernoulli(0.2)),
            }
        })
        .collect();
    let stored = TiledLowRankIndex::new(m, n, plan, tiles).unwrap();
    let index = StoredIndex::Tiled(stored);

    let x = Matrix::gaussian(3, GEOMETRY.input_dim, 0.0, 1.0, &mut rng);
    // in-memory: kernel built straight from the in-memory stored index
    let art = Artifact {
        params: params.clone(),
        index,
        meta: lrbi::store::ArtifactMeta {
            sparsity: 0.0,
            cost: 0.0,
            rank: 0,
            provenance: "it tiled".into(),
        },
    };
    let mut mem = NativeBackend::from_artifact(&art).unwrap();
    let want = mem.predict(&x).unwrap();

    let path = dir.join("tiled.lrbi");
    art.write(&path).unwrap();
    let loaded = Artifact::read(&path).unwrap();
    assert_eq!(loaded.index.format_name(), "tiled");
    let mut srv = NativeBackend::from_artifact(&loaded).unwrap();
    assert_eq!(srv.predict(&x).unwrap().data(), want.data(), "tiled logits");

    // the loaded index is structurally identical, and its kernel
    // executes without assembling the dense mask
    let kern = build_kernel_from_stored(&loaded.index, &params.w1, None).unwrap();
    assert_eq!(kern.name(), "tiled");
    assert_eq!(kern.index_bytes(), art.index.index_bytes());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property: random factor pairs round-trip through pack/load with an
/// identical decoded mask in every storable format.
#[test]
fn property_pack_load_mask_roundtrip() {
    prop::check("store roundtrip", 8, |rng| {
        let m = prop::dim(rng, 2, 40);
        let n = prop::dim(rng, 2, 60);
        let k = prop::dim(rng, 1, 6);
        let d = 0.1 + rng.next_f64() * 0.4;
        let mut r2 = Rng::new(rng.next_u64());
        let ip = BitMatrix::from_fn(m, k, |_, _| r2.bernoulli(d));
        let iz = BitMatrix::from_fn(k, n, |_, _| r2.bernoulli(d));
        for name in ["dense", "csr", "relative", "lowrank", "viterbi", "dcsr"] {
            let stored = StoredIndex::from_factors(name, &ip, &iz).unwrap();
            let want = stored.decode_mask().unwrap();
            // serialize the index through a full container round-trip
            let params = tiny_params(m, n, &mut r2);
            let art = Artifact {
                params,
                index: stored,
                meta: lrbi::store::ArtifactMeta {
                    sparsity: want.sparsity(),
                    cost: 0.0,
                    rank: k as u32,
                    provenance: "prop".into(),
                },
            };
            let back = Artifact::from_bytes(art.to_bytes()).unwrap();
            assert_eq!(back.index.decode_mask().unwrap(), want, "{name}");
            assert_eq!(back.index.index_bytes(), art.index.index_bytes(), "{name}");
        }
    });
}

fn tiny_params(m: usize, n: usize, rng: &mut Rng) -> MlpParams {
    MlpParams {
        w0: Matrix::gaussian(3, m, 0.0, 0.5, rng),
        b0: vec![0.0; m],
        w1: Matrix::gaussian(m, n, 0.0, 0.5, rng),
        b1: vec![0.0; n],
        w2: Matrix::gaussian(n, 2, 0.0, 0.5, rng),
        b2: vec![0.0; 2],
    }
}

fn sample_artifact_bytes() -> Vec<u8> {
    sample_artifact_bytes_for("lowrank")
}

fn sample_artifact_bytes_for(format: &str) -> Vec<u8> {
    let mut rng = Rng::new(71);
    let params = tiny_params(24, 36, &mut rng);
    let ip = BitMatrix::from_fn(24, 4, |_, _| rng.bernoulli(0.3));
    let iz = BitMatrix::from_fn(4, 36, |_, _| rng.bernoulli(0.3));
    Artifact::pack_factors(params, format, &ip, &iz, "corruption")
        .unwrap()
        .to_bytes()
}

/// Corruption must always produce a typed `Error::Store` — truncated
/// files, flipped payload bytes, bad magic, unsupported versions —
/// and must never panic. The truncation/flip sweep runs over the
/// low-rank sample plus the two stream-decoded formats (Viterbi input
/// bits, dCSR nibbles), whose decoders walk variable-length payloads
/// and so have the most to prove about bounds handling.
#[test]
fn corruption_yields_typed_errors_never_panics() {
    for format in ["lowrank", "viterbi", "dcsr"] {
        let bytes = sample_artifact_bytes_for(format);
        assert!(Artifact::from_bytes(bytes.clone()).is_ok(), "{format}");

        // truncation at every prefix length
        for cut in (0..bytes.len()).step_by(7) {
            match Artifact::from_bytes(bytes[..cut].to_vec()) {
                Err(Error::Store(_)) => {}
                other => panic!("{format} cut at {cut}: expected Error::Store, got {other:?}"),
            }
        }

        // single-byte flips anywhere in the file
        for i in (0..bytes.len()).step_by(3) {
            let mut b = bytes.clone();
            b[i] ^= 0x10;
            match Artifact::from_bytes(b) {
                // flips in header/table/payload are all caught...
                Err(Error::Store(_)) => {}
                // ...except a flip that only changes provenance text etc.
                // is impossible: every payload byte is CRC-covered, and
                // table/header bytes fail structural validation. A flip
                // that produced Ok would be a checksum hole.
                other => panic!("{format} flip at {i}: expected Error::Store, got {other:?}"),
            }
        }
    }
    let bytes = sample_artifact_bytes();

    // bad magic
    let mut b = bytes.clone();
    b[0..4].copy_from_slice(b"NOPE");
    let err = Artifact::from_bytes(b).unwrap_err();
    assert!(err.to_string().contains("magic"), "{err}");

    // unsupported version
    let mut b = bytes.clone();
    b[4] = 0x7F;
    let err = Artifact::from_bytes(b).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");

    // flipped CRC field in the section table (entry 0 crc at offset 16+20)
    let mut b = bytes.clone();
    b[36] ^= 0xFF;
    let err = Artifact::from_bytes(b).unwrap_err();
    assert!(err.to_string().contains("crc"), "{err}");
}

/// End-to-end registry flow: publish from one process-lifetime,
/// reopen, serve, hot-swap.
#[test]
fn registry_end_to_end() {
    let dir = tmp("registry_e2e");
    let params = MlpParams::init(81);
    let (ip, iz) = geometry_factors(82);
    let (ip2, iz2) = geometry_factors(83);
    {
        let mut reg = Registry::create(&dir).unwrap();
        reg.publish(
            "lowrank-a",
            &Artifact::pack_factors(params.clone(), "lowrank", &ip, &iz, "e2e").unwrap(),
        )
        .unwrap();
        reg.publish(
            "csr-b",
            &Artifact::pack_factors(params.clone(), "csr", &ip2, &iz2, "e2e").unwrap(),
        )
        .unwrap();
    }
    let reg = Registry::open(&dir).unwrap();
    assert_eq!(reg.names(), vec!["lowrank-a", "csr-b"]);
    let metrics = std::sync::Arc::new(lrbi::coordinator::metrics::Metrics::new());
    let mut srv =
        lrbi::serve::variants::VariantServer::from_registry(&reg, 4, metrics.clone()).unwrap();
    let mut rng = Rng::new(84);
    let x = Matrix::gaussian(1, GEOMETRY.input_dim, 0.0, 1.0, &mut rng);
    let a = srv.predict(srv.id_of("lowrank-a").unwrap(), &x).unwrap();
    let b = srv.predict(srv.id_of("csr-b").unwrap(), &x).unwrap();
    assert_ne!(a.data(), b.data());

    // loading "csr-b" by artifact path must serve bit-identically
    let direct = Artifact::read(reg.path_of("csr-b").unwrap()).unwrap();
    let mut be = NativeBackend::from_artifact(&direct).unwrap();
    assert_eq!(be.predict(&x).unwrap().data(), b.data());

    let snap = metrics.snapshot();
    assert_eq!(snap.artifact_loads, 2);
    assert_eq!(snap.hot_swaps, 0);
    let _ = std::fs::remove_dir_all(&dir);
}
