//! Integration tests for the network serving frontend: wire-protocol
//! round-trips and corruption behavior (typed errors, never panics —
//! mirroring the `tests/store.rs` fuzz style), plus localhost smoke
//! tests proving that N concurrent TCP clients get logits
//! **bit-identical** to direct in-process `NativeBackend` inference
//! for every kernel format, that overload is an explicit rejection
//! frame, and that hot-swap/stats/shutdown work over the wire.

use lrbi::coordinator::metrics::Metrics;
use lrbi::coordinator::pool::ExecCtx;
use lrbi::formats::StoredIndex;
use lrbi::serve::batcher::BatchPolicy;
use lrbi::serve::engine::{InferenceBackend, MlpParams, NativeBackend, ServingEngine};
use lrbi::serve::protocol::{self, ErrorCode, Frame, ReadError, RowBatch, MAX_FRAME};
use lrbi::serve::server::{ModelHub, ModelSlot, NetClient, ServeOptions, Server};
use lrbi::store::{Artifact, ArtifactMeta, Registry};
use lrbi::tensor::Matrix;
use lrbi::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
use lrbi::util::bits::BitMatrix;
use lrbi::util::error::Result;
use lrbi::util::prop;
use lrbi::util::rng::Rng;
use std::net::TcpStream;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

// ---------------------------------------------------------------- helpers

/// Small model (6 → 20 → 30 → 4) so every test serves in milliseconds.
fn small_params(seed: u64) -> MlpParams {
    let mut rng = Rng::new(seed);
    MlpParams {
        w0: Matrix::gaussian(6, 20, 0.0, 0.5, &mut rng),
        b0: vec![0.1; 20],
        w1: Matrix::gaussian(20, 30, 0.0, 0.5, &mut rng),
        b1: vec![0.2; 30],
        w2: Matrix::gaussian(30, 4, 0.0, 0.5, &mut rng),
        b2: vec![0.0; 4],
    }
}

fn small_artifact(params: &MlpParams, format: &str, seed: u64) -> Artifact {
    let mut rng = Rng::new(seed);
    let ip = BitMatrix::from_fn(20, 4, |_, _| rng.bernoulli(0.3));
    let iz = BitMatrix::from_fn(4, 30, |_, _| rng.bernoulli(0.3));
    Artifact::pack_factors(params.clone(), format, &ip, &iz, "server test").unwrap()
}

fn tiled_artifact(params: &MlpParams, seed: u64) -> Artifact {
    let (m, n) = (params.w1.rows(), params.w1.cols());
    let plan = TilePlan::new(2, 3);
    let mut rng = Rng::new(seed);
    let tiles: Vec<TileFactors> = plan
        .tiles(m, n)
        .unwrap()
        .iter()
        .map(|s| {
            let k = 3 + s.id % 2;
            TileFactors {
                rank: k,
                ip: BitMatrix::from_fn(s.rows(), k, |_, _| rng.bernoulli(0.3)),
                iz: BitMatrix::from_fn(k, s.cols(), |_, _| rng.bernoulli(0.3)),
            }
        })
        .collect();
    Artifact {
        params: params.clone(),
        index: StoredIndex::Tiled(TiledLowRankIndex::new(m, n, plan, tiles).unwrap()),
        meta: ArtifactMeta { sparsity: 0.0, cost: 0.0, rank: 0, provenance: "server test".into() },
    }
}

/// Bind on an ephemeral port and run the server on its own thread.
fn start_server(
    hub: ModelHub,
    opts: &ServeOptions,
) -> (
    std::net::SocketAddr,
    lrbi::serve::server::ServerHandle,
    std::thread::JoinHandle<Result<()>>,
) {
    let server = Server::bind("127.0.0.1:0", Arc::new(hub), opts).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    (addr, handle, runner)
}

fn random_row(rng: &mut Rng, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

// ------------------------------------------------------- protocol properties

#[test]
fn frame_encode_decode_round_trip_property() {
    prop::check("frame round-trip", 200, |rng| {
        let rows = prop::dim(rng, 0, 4);
        let cols = if rows == 0 { 0 } else { prop::dim(rng, 1, 9) };
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.next_f32() * 8.0 - 4.0).collect();
        let batch = RowBatch::new(rows, cols, data).unwrap();
        let key: String =
            (0..prop::dim(rng, 0, 12)).map(|_| (b'a' + rng.next_range(26) as u8) as char).collect();
        let frame = match rng.next_range(8) {
            0 => Frame::Infer { key, batch },
            1 => Frame::Logits(batch),
            2 => Frame::Error {
                code: *prop::choose(rng, &ErrorCode::ALL),
                message: key,
            },
            3 => Frame::StatsRequest,
            4 => Frame::Stats(
                (0..prop::dim(rng, 0, 6))
                    .map(|i| (format!("counter_{i}"), rng.next_u64()))
                    .collect(),
            ),
            5 => Frame::Swap { key },
            6 => Frame::Ok { message: key },
            _ => Frame::Shutdown,
        };
        let wire = protocol::encode(&frame);
        let mut r = &wire[..];
        let decoded = protocol::read_frame(&mut r).expect("decode").expect("frame");
        assert_eq!(decoded, frame);
        assert!(r.is_empty(), "exactly one frame consumed");
    });
}

#[test]
fn truncated_streams_yield_typed_errors_never_panics() {
    let batch = RowBatch::from_rows(&[vec![1.0, 2.0, 3.0]]).unwrap();
    let wire = protocol::encode(&Frame::Infer { key: "k".into(), batch });
    for cut in 0..wire.len() {
        let mut r = &wire[..cut];
        match protocol::read_frame(&mut r) {
            Ok(None) => assert_eq!(cut, 0, "only an empty stream is a clean EOF"),
            Ok(Some(f)) => panic!("truncated stream decoded to {}", f.type_name()),
            Err(ReadError::Wire(e)) => assert_eq!(e.code, ErrorCode::BadFrame, "cut at {cut}"),
            Err(ReadError::Io(e)) => panic!("unexpected io error at {cut}: {e}"),
        }
    }
}

#[test]
fn corrupted_frames_never_panic_property() {
    prop::check("corruption fuzz", 300, |rng| {
        let rows = prop::dim(rng, 1, 3);
        let data: Vec<f32> = (0..rows * 5).map(|_| rng.next_f32()).collect();
        let batch = RowBatch::new(rows, 5, data).unwrap();
        let frame = if rng.next_range(2) == 0 {
            Frame::Infer { key: "model".into(), batch }
        } else {
            Frame::Stats(vec![("requests".into(), rng.next_u64())])
        };
        let mut wire = protocol::encode(&frame);
        let pos = rng.next_range(wire.len() as u64) as usize;
        wire[pos] ^= 1u8 << rng.next_range(8);
        let mut r = &wire[..];
        // Any typed outcome is fine (a flipped f32 byte still decodes);
        // the property is that corruption never panics or hangs.
        let _ = protocol::read_frame(&mut r);
    });
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    let mut wire = (MAX_FRAME + 7).to_le_bytes().to_vec();
    wire.extend_from_slice(&[1u8; 16]);
    let mut r = &wire[..];
    match protocol::read_frame(&mut r) {
        Err(ReadError::Wire(e)) => assert_eq!(e.code, ErrorCode::TooLarge),
        other => panic!("expected TooLarge, got {other:?}"),
    }
}

// --------------------------------------------------------- localhost smoke

/// The PR's acceptance criterion: N concurrent TCP clients receive
/// logits bit-identical to direct in-process `NativeBackend`
/// inference, for every kernel format (and a tiled artifact).
#[test]
fn concurrent_clients_get_bit_identical_logits_for_every_format() {
    let params = small_params(81);
    let mut artifacts = vec![tiled_artifact(&params, 90)];
    for format in ["dense", "csr", "relative", "lowrank"] {
        artifacts.push(small_artifact(&params, format, 82));
    }
    for artifact in artifacts {
        let format = artifact.index.format_name();
        let metrics = Arc::new(Metrics::new());
        let hub = ModelHub::from_artifact(
            "m",
            &artifact,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
            64,
            Arc::clone(&metrics),
            ExecCtx::single(),
        )
        .unwrap();
        let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
        let mut direct = NativeBackend::from_artifact(&artifact).unwrap();

        let clients: usize = 4;
        let per_client: usize = 6;
        let workers: Vec<_> = (0..clients)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut client = NetClient::connect(addr).unwrap();
                    let mut rng = Rng::new(1000 + c as u64);
                    let mut out = Vec::new();
                    for _ in 0..per_client {
                        let row = random_row(&mut rng, 6);
                        let logits = client
                            .infer("", RowBatch::from_rows(&[row.clone()]).unwrap())
                            .unwrap();
                        assert_eq!((logits.rows(), logits.cols()), (1, 4));
                        out.push((row, logits.row(0).to_vec()));
                    }
                    out
                })
            })
            .collect();
        for worker in workers {
            for (row, got) in worker.join().unwrap() {
                let x = Matrix::from_fn(1, 6, |_, j| row[j]);
                let want = direct.predict(&x).unwrap();
                assert_eq!(
                    got.as_slice(),
                    want.row(0),
                    "{format}: wire logits must be bit-identical to in-process"
                );
            }
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.net_requests, (clients * per_client) as u64, "{format}");
        assert_eq!(snap.net_conns_accepted, clients as u64, "{format}");
        handle.shutdown();
        runner.join().unwrap().unwrap();
    }
}

#[test]
fn unknown_model_and_bad_shape_are_typed_error_frames() {
    let params = small_params(70);
    let artifact = small_artifact(&params, "csr", 71);
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::new(Metrics::new()),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(addr).unwrap();

    let good_row = RowBatch::from_rows(&[vec![0.5; 6]]).unwrap();
    match client.call(&Frame::Infer { key: "nope".into(), batch: good_row }).unwrap() {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::UnknownModel);
            assert!(message.contains('m'), "lists available models: {message}");
        }
        other => panic!("expected ERROR, got {}", other.type_name()),
    }

    let bad_row = RowBatch::from_rows(&[vec![0.5; 7]]).unwrap();
    match client.call(&Frame::Infer { key: String::new(), batch: bad_row }).unwrap() {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::BadShape),
        other => panic!("expected ERROR, got {}", other.type_name()),
    }

    // A server-to-client frame sent by a client is a typed bad-frame
    // error, and the connection stays usable afterwards.
    let logits_frame = Frame::Logits(RowBatch::from_rows(&[vec![0.0; 4]]).unwrap());
    match client.call(&logits_frame).unwrap() {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::BadFrame),
        other => panic!("expected ERROR, got {}", other.type_name()),
    }
    let ok = client.infer("m", RowBatch::from_rows(&[vec![0.5; 6]]).unwrap()).unwrap();
    assert_eq!(ok.cols(), 4);

    handle.shutdown();
    runner.join().unwrap().unwrap();
}

#[test]
fn wrong_version_byte_gets_bad_version_frame() {
    let params = small_params(60);
    let artifact = small_artifact(&params, "lowrank", 61);
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::new(Metrics::new()),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut wire = protocol::encode(&Frame::StatsRequest);
    wire[4] = 9; // version byte
    use std::io::Write;
    stream.write_all(&wire).unwrap();
    match protocol::read_frame(&mut stream).unwrap().unwrap() {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::BadVersion),
        other => panic!("expected ERROR, got {}", other.type_name()),
    }
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

// ------------------------------------------------------------- overload

/// A backend that parks inside `predict` until released — makes the
/// bounded queue fill deterministically.
struct BlockingBackend {
    dim: usize,
    classes: usize,
    entered: mpsc::Sender<()>,
    release: Arc<(Mutex<bool>, Condvar)>,
}

impl InferenceBackend for BlockingBackend {
    fn batch(&self) -> usize {
        1
    }
    fn input_dim(&self) -> usize {
        self.dim
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn predict_into(&mut self, _x: &Matrix, out: &mut Matrix) -> Result<()> {
        let _ = self.entered.send(());
        let (lock, cv) = &*self.release;
        let mut go = lock.lock().unwrap();
        while !*go {
            go = cv.wait(go).unwrap();
        }
        out.reset_zero(1, self.classes);
        Ok(())
    }
}

/// The acceptance criterion's overload half: when the bounded request
/// queue is full, the server answers with an explicit `overloaded`
/// error frame instead of stalling the client.
#[test]
fn full_request_queue_returns_explicit_overload_frame() {
    let params = small_params(50);
    let artifact = small_artifact(&params, "dense", 51);
    let metrics = Arc::new(Metrics::new());
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::clone(&metrics),
        ExecCtx::single(),
    )
    .unwrap();

    // Register a second model whose executor we can park, with a
    // 2-deep submit queue.
    let (entered_tx, entered_rx) = mpsc::channel();
    let release = Arc::new((Mutex::new(false), Condvar::new()));
    let backend = BlockingBackend {
        dim: 6,
        classes: 4,
        entered: entered_tx,
        release: Arc::clone(&release),
    };
    let engine = ServingEngine::start_bounded(
        backend,
        BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
        2,
        Arc::clone(&metrics),
    );
    let filler = engine.client();
    hub.install_slot("block", ModelSlot::from_engine(engine, 6, 4, "blocking"));

    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());

    // One wire request parks the executor inside predict ...
    let parked = std::thread::spawn(move || {
        let mut client = NetClient::connect(addr).unwrap();
        client.infer("block", RowBatch::from_rows(&[vec![0.0; 6]]).unwrap())
    });
    entered_rx.recv_timeout(Duration::from_secs(10)).expect("executor parked");
    // ... then the 2-deep queue is filled directly ...
    let _r1 = filler.try_submit(vec![0.0; 6]).expect("queue slot 1");
    let _r2 = filler.try_submit(vec![0.0; 6]).expect("queue slot 2");
    assert!(filler.try_submit(vec![0.0; 6]).is_err(), "queue must now be full");

    // ... so the next wire request is rejected with a typed frame.
    let mut client = NetClient::connect(addr).unwrap();
    match client
        .call(&Frame::Infer {
            key: "block".into(),
            batch: RowBatch::from_rows(&[vec![0.0; 6]]).unwrap(),
        })
        .unwrap()
    {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::Overloaded);
            assert!(message.contains("queue"), "{message}");
        }
        other => panic!("expected ERROR(overloaded), got {}", other.type_name()),
    }
    assert!(metrics.snapshot().net_rejected_overload >= 1);

    // Release the executor: the parked request completes normally.
    {
        let (lock, cv) = &*release;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }
    let logits = parked.join().unwrap().unwrap();
    assert_eq!((logits.rows(), logits.cols()), (1, 4));

    handle.shutdown();
    runner.join().unwrap().unwrap();
}

#[test]
fn connections_beyond_max_conns_get_rejection_frame() {
    let params = small_params(40);
    let artifact = small_artifact(&params, "relative", 41);
    let metrics = Arc::new(Metrics::new());
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::clone(&metrics),
        ExecCtx::single(),
    )
    .unwrap();
    let opts = ServeOptions { max_conns: 1, ..ServeOptions::default() };
    let (addr, handle, runner) = start_server(hub, &opts);

    // First client occupies the only slot (a round-trip guarantees
    // its handler is registered before the second connect).
    let mut first = NetClient::connect(addr).unwrap();
    assert!(!first.stats().unwrap().is_empty());

    // Second connection is answered with one overload frame + close.
    let mut second = TcpStream::connect(addr).unwrap();
    match protocol::read_frame(&mut second).unwrap() {
        Some(Frame::Error { code, .. }) => assert_eq!(code, ErrorCode::Overloaded),
        other => panic!("expected ERROR(overloaded), got {other:?}"),
    }
    assert!(protocol::read_frame(&mut second).unwrap().is_none(), "then EOF");
    assert_eq!(metrics.snapshot().net_conns_rejected, 1);

    // Releasing the first slot re-admits clients.
    drop(first);
    while handle.active_connections() > 0 {
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut third = NetClient::connect(addr).unwrap();
    assert!(third.infer("m", RowBatch::from_rows(&[vec![0.1; 6]]).unwrap()).is_ok());

    handle.shutdown();
    runner.join().unwrap().unwrap();
}

// ------------------------------------------------- hot swap, stats, shutdown

#[test]
fn hot_swap_over_the_wire_switches_kernels_between_requests() {
    let dir = std::env::temp_dir().join(format!("lrbi_server_swap_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let params = small_params(30);
    let mut registry = Registry::create(&dir).unwrap();
    registry.publish("a", &small_artifact(&params, "lowrank", 31)).unwrap();

    let metrics = Arc::new(Metrics::new());
    let hub = ModelHub::from_registry(
        &dir,
        BatchPolicy::default(),
        64,
        Arc::clone(&metrics),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(addr).unwrap();

    let mut rng = Rng::new(33);
    let row = random_row(&mut rng, 6);
    let batch = RowBatch::from_rows(&[row.clone()]).unwrap();
    let before = client.infer("a", batch.clone()).unwrap();

    // Swapping a name the registry does not have is a typed error.
    assert!(client.swap("ghost").is_err());

    // Publish a re-compression under the same name and swap it in.
    let swapped = small_artifact(&params, "csr", 99);
    registry.publish("a", &swapped).unwrap();
    let message = client.swap("a").unwrap();
    assert!(message.contains("swapped"), "{message}");

    let after = client.infer("a", batch).unwrap();
    assert_ne!(after.data(), before.data(), "swapped index must change logits");
    let mut direct = NativeBackend::from_artifact(&swapped).unwrap();
    let x = Matrix::from_fn(1, 6, |_, j| row[j]);
    assert_eq!(
        after.row(0),
        direct.predict(&x).unwrap().row(0),
        "post-swap logits bit-identical to the new artifact"
    );
    assert_eq!(metrics.snapshot().hot_swaps, 1);

    handle.shutdown();
    runner.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stats_frame_serializes_the_metrics_snapshot() {
    let params = small_params(20);
    let artifact = small_artifact(&params, "lowrank", 21);
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::new(Metrics::new()),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(addr).unwrap();
    for _ in 0..3 {
        client.infer("m", RowBatch::from_rows(&[vec![0.2; 6]]).unwrap()).unwrap();
    }
    let stats = client.stats().unwrap();
    let get = |k: &str| {
        stats
            .iter()
            .find(|(n, _)| n == k)
            .unwrap_or_else(|| panic!("missing counter '{k}'"))
            .1
    };
    assert_eq!(get("net_requests"), 3);
    assert_eq!(get("net_conns_accepted"), 1);
    assert_eq!(get("requests"), 3, "engine-side counter flows through");
    assert!(get("kernel_spmms") >= 3);
    assert!(get("spmm_shards") >= 1, "PR3 plan counters are exposed");
    for name in lrbi::coordinator::metrics::SPMM_NS_COUNTER_NAMES {
        assert!(stats.iter().any(|(n, _)| n == name), "missing {name}");
    }
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

#[test]
fn shutdown_frame_stops_the_server_gracefully() {
    let params = small_params(10);
    let artifact = small_artifact(&params, "dense", 11);
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::new(Metrics::new()),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, _handle, runner) = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(addr).unwrap();
    client.infer("m", RowBatch::from_rows(&[vec![0.3; 6]]).unwrap()).unwrap();
    let message = client.shutdown_server().unwrap();
    assert!(message.contains("shutting down"), "{message}");
    // run() returns once handlers drain — no external trigger needed.
    runner.join().unwrap().unwrap();
}
