//! Integration tests for the network serving frontend: wire-protocol
//! round-trips and corruption behavior (typed errors, never panics —
//! mirroring the `tests/store.rs` fuzz style), plus localhost smoke
//! tests proving that N concurrent TCP clients get logits
//! **bit-identical** to direct in-process `NativeBackend` inference
//! for every kernel format, that overload is an explicit rejection
//! frame, and that hot-swap/stats/shutdown work over the wire.

use lrbi::coordinator::metrics::Metrics;
use lrbi::coordinator::pool::ExecCtx;
use lrbi::formats::StoredIndex;
use lrbi::serve::batcher::BatchPolicy;
use lrbi::serve::engine::{InferenceBackend, MlpParams, NativeBackend, ServingEngine};
use lrbi::serve::protocol::{self, ErrorCode, Frame, ReadError, RowBatch, MAX_FRAME};
use lrbi::serve::server::{ModelHub, ModelSlot, NetClient, ServeOptions, Server};
use lrbi::store::{Artifact, ArtifactMeta, Registry};
use lrbi::tensor::Matrix;
use lrbi::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
use lrbi::util::bits::BitMatrix;
use lrbi::util::error::Result;
use lrbi::util::prop;
use lrbi::util::rng::Rng;
use std::net::TcpStream;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

// ---------------------------------------------------------------- helpers

/// Small model (6 → 20 → 30 → 4) so every test serves in milliseconds.
fn small_params(seed: u64) -> MlpParams {
    let mut rng = Rng::new(seed);
    MlpParams {
        w0: Matrix::gaussian(6, 20, 0.0, 0.5, &mut rng),
        b0: vec![0.1; 20],
        w1: Matrix::gaussian(20, 30, 0.0, 0.5, &mut rng),
        b1: vec![0.2; 30],
        w2: Matrix::gaussian(30, 4, 0.0, 0.5, &mut rng),
        b2: vec![0.0; 4],
    }
}

fn small_artifact(params: &MlpParams, format: &str, seed: u64) -> Artifact {
    let mut rng = Rng::new(seed);
    let ip = BitMatrix::from_fn(20, 4, |_, _| rng.bernoulli(0.3));
    let iz = BitMatrix::from_fn(4, 30, |_, _| rng.bernoulli(0.3));
    Artifact::pack_factors(params.clone(), format, &ip, &iz, "server test").unwrap()
}

fn tiled_artifact(params: &MlpParams, seed: u64) -> Artifact {
    let (m, n) = (params.w1.rows(), params.w1.cols());
    let plan = TilePlan::new(2, 3);
    let mut rng = Rng::new(seed);
    let tiles: Vec<TileFactors> = plan
        .tiles(m, n)
        .unwrap()
        .iter()
        .map(|s| {
            let k = 3 + s.id % 2;
            TileFactors {
                rank: k,
                ip: BitMatrix::from_fn(s.rows(), k, |_, _| rng.bernoulli(0.3)),
                iz: BitMatrix::from_fn(k, s.cols(), |_, _| rng.bernoulli(0.3)),
            }
        })
        .collect();
    Artifact {
        params: params.clone(),
        index: StoredIndex::Tiled(TiledLowRankIndex::new(m, n, plan, tiles).unwrap()),
        meta: ArtifactMeta { sparsity: 0.0, cost: 0.0, rank: 0, provenance: "server test".into() },
    }
}

/// Bind on an ephemeral port and run the server on its own thread.
fn start_server(
    hub: ModelHub,
    opts: &ServeOptions,
) -> (
    std::net::SocketAddr,
    lrbi::serve::server::ServerHandle,
    std::thread::JoinHandle<Result<()>>,
) {
    let server = Server::bind("127.0.0.1:0", Arc::new(hub), opts).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    (addr, handle, runner)
}

fn random_row(rng: &mut Rng, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

// ------------------------------------------------------- protocol properties

#[test]
fn frame_encode_decode_round_trip_property() {
    prop::check("frame round-trip", 200, |rng| {
        let rows = prop::dim(rng, 0, 4);
        let cols = if rows == 0 { 0 } else { prop::dim(rng, 1, 9) };
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.next_f32() * 8.0 - 4.0).collect();
        let batch = RowBatch::new(rows, cols, data).unwrap();
        let key: String =
            (0..prop::dim(rng, 0, 12)).map(|_| (b'a' + rng.next_range(26) as u8) as char).collect();
        let deadline_us = match rng.next_range(3) {
            0 => None,
            1 => Some(rng.next_range(5_000_000)),
            _ => Some(rng.next_u64()),
        };
        let frame = match rng.next_range(8) {
            0 => Frame::Infer { key, batch, deadline_us },
            1 => Frame::Logits(batch),
            2 => Frame::Error {
                code: *prop::choose(rng, &ErrorCode::ALL),
                message: key,
            },
            3 => Frame::StatsRequest,
            4 => Frame::Stats(
                (0..prop::dim(rng, 0, 6))
                    .map(|i| (format!("counter_{i}"), rng.next_u64()))
                    .collect(),
            ),
            5 => Frame::Swap { key },
            6 => Frame::Ok { message: key },
            _ => Frame::Shutdown,
        };
        let wire = protocol::encode(&frame);
        let mut r = &wire[..];
        let decoded = protocol::read_frame(&mut r).expect("decode").expect("frame");
        assert_eq!(decoded, frame);
        assert!(r.is_empty(), "exactly one frame consumed");
    });
}

#[test]
fn truncated_streams_yield_typed_errors_never_panics() {
    let batch = RowBatch::from_rows(&[vec![1.0, 2.0, 3.0]]).unwrap();
    let wire =
        protocol::encode(&Frame::Infer { key: "k".into(), batch, deadline_us: Some(1_000) });
    for cut in 0..wire.len() {
        let mut r = &wire[..cut];
        match protocol::read_frame(&mut r) {
            Ok(None) => assert_eq!(cut, 0, "only an empty stream is a clean EOF"),
            Ok(Some(f)) => panic!("truncated stream decoded to {}", f.type_name()),
            Err(ReadError::Wire(e)) => assert_eq!(e.code, ErrorCode::BadFrame, "cut at {cut}"),
            Err(ReadError::Io(e)) => panic!("unexpected io error at {cut}: {e}"),
        }
    }
}

#[test]
fn corrupted_frames_never_panic_property() {
    prop::check("corruption fuzz", 300, |rng| {
        let rows = prop::dim(rng, 1, 3);
        let data: Vec<f32> = (0..rows * 5).map(|_| rng.next_f32()).collect();
        let batch = RowBatch::new(rows, 5, data).unwrap();
        let frame = if rng.next_range(2) == 0 {
            let deadline_us = (rng.next_range(2) == 0).then(|| rng.next_u64());
            Frame::Infer { key: "model".into(), batch, deadline_us }
        } else {
            Frame::Stats(vec![("requests".into(), rng.next_u64())])
        };
        let mut wire = protocol::encode(&frame);
        let pos = rng.next_range(wire.len() as u64) as usize;
        wire[pos] ^= 1u8 << rng.next_range(8);
        let mut r = &wire[..];
        // Any typed outcome is fine (a flipped f32 byte still decodes);
        // the property is that corruption never panics or hangs.
        let _ = protocol::read_frame(&mut r);
    });
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    let mut wire = (MAX_FRAME + 7).to_le_bytes().to_vec();
    wire.extend_from_slice(&[1u8; 16]);
    let mut r = &wire[..];
    match protocol::read_frame(&mut r) {
        Err(ReadError::Wire(e)) => assert_eq!(e.code, ErrorCode::TooLarge),
        other => panic!("expected TooLarge, got {other:?}"),
    }
}

// --------------------------------------------------------- localhost smoke

/// The PR's acceptance criterion: N concurrent TCP clients receive
/// logits bit-identical to direct in-process `NativeBackend`
/// inference, for every kernel format (and a tiled artifact).
#[test]
fn concurrent_clients_get_bit_identical_logits_for_every_format() {
    let params = small_params(81);
    let mut artifacts = vec![tiled_artifact(&params, 90)];
    for format in ["dense", "csr", "relative", "lowrank"] {
        artifacts.push(small_artifact(&params, format, 82));
    }
    for artifact in artifacts {
        let format = artifact.index.format_name();
        let metrics = Arc::new(Metrics::new());
        let hub = ModelHub::from_artifact(
            "m",
            &artifact,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
            64,
            Arc::clone(&metrics),
            ExecCtx::single(),
        )
        .unwrap();
        let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
        let mut direct = NativeBackend::from_artifact(&artifact).unwrap();

        let clients: usize = 4;
        let per_client: usize = 6;
        let workers: Vec<_> = (0..clients)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut client = NetClient::connect(addr).unwrap();
                    let mut rng = Rng::new(1000 + c as u64);
                    let mut out = Vec::new();
                    for _ in 0..per_client {
                        let row = random_row(&mut rng, 6);
                        let logits = client
                            .infer("", RowBatch::from_rows(&[row.clone()]).unwrap())
                            .unwrap();
                        assert_eq!((logits.rows(), logits.cols()), (1, 4));
                        out.push((row, logits.row(0).to_vec()));
                    }
                    out
                })
            })
            .collect();
        for worker in workers {
            for (row, got) in worker.join().unwrap() {
                let x = Matrix::from_fn(1, 6, |_, j| row[j]);
                let want = direct.predict(&x).unwrap();
                assert_eq!(
                    got.as_slice(),
                    want.row(0),
                    "{format}: wire logits must be bit-identical to in-process"
                );
            }
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.net_requests, (clients * per_client) as u64, "{format}");
        assert_eq!(snap.net_conns_accepted, clients as u64, "{format}");
        handle.shutdown();
        runner.join().unwrap().unwrap();
    }
}

#[test]
fn unknown_model_and_bad_shape_are_typed_error_frames() {
    let params = small_params(70);
    let artifact = small_artifact(&params, "csr", 71);
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::new(Metrics::new()),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(addr).unwrap();

    let good_row = RowBatch::from_rows(&[vec![0.5; 6]]).unwrap();
    match client
        .call(&Frame::Infer { key: "nope".into(), batch: good_row, deadline_us: None })
        .unwrap()
    {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::UnknownModel);
            assert!(message.contains('m'), "lists available models: {message}");
        }
        other => panic!("expected ERROR, got {}", other.type_name()),
    }

    let bad_row = RowBatch::from_rows(&[vec![0.5; 7]]).unwrap();
    match client
        .call(&Frame::Infer { key: String::new(), batch: bad_row, deadline_us: None })
        .unwrap()
    {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::BadShape),
        other => panic!("expected ERROR, got {}", other.type_name()),
    }

    // A server-to-client frame sent by a client is a typed bad-frame
    // error, and the connection stays usable afterwards.
    let logits_frame = Frame::Logits(RowBatch::from_rows(&[vec![0.0; 4]]).unwrap());
    match client.call(&logits_frame).unwrap() {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::BadFrame),
        other => panic!("expected ERROR, got {}", other.type_name()),
    }
    let ok = client.infer("m", RowBatch::from_rows(&[vec![0.5; 6]]).unwrap()).unwrap();
    assert_eq!(ok.cols(), 4);

    handle.shutdown();
    runner.join().unwrap().unwrap();
}

#[test]
fn wrong_version_byte_gets_bad_version_frame() {
    let params = small_params(60);
    let artifact = small_artifact(&params, "lowrank", 61);
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::new(Metrics::new()),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut wire = protocol::encode(&Frame::StatsRequest);
    wire[4] = 9; // version byte
    use std::io::Write;
    stream.write_all(&wire).unwrap();
    match protocol::read_frame(&mut stream).unwrap().unwrap() {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::BadVersion),
        other => panic!("expected ERROR, got {}", other.type_name()),
    }
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

// ------------------------------------------------------------- overload

/// A backend that parks inside `predict` until released — makes the
/// bounded queue fill deterministically.
struct BlockingBackend {
    dim: usize,
    classes: usize,
    entered: mpsc::Sender<()>,
    release: Arc<(Mutex<bool>, Condvar)>,
}

impl InferenceBackend for BlockingBackend {
    fn batch(&self) -> usize {
        1
    }
    fn input_dim(&self) -> usize {
        self.dim
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn predict_into(&mut self, _x: &Matrix, out: &mut Matrix) -> Result<()> {
        let _ = self.entered.send(());
        let (lock, cv) = &*self.release;
        let mut go = lock.lock().unwrap();
        while !*go {
            go = cv.wait(go).unwrap();
        }
        out.reset_zero(1, self.classes);
        Ok(())
    }
}

/// The acceptance criterion's overload half: when the bounded request
/// queue is full, the server answers with an explicit `overloaded`
/// error frame instead of stalling the client.
#[test]
fn full_request_queue_returns_explicit_overload_frame() {
    let params = small_params(50);
    let artifact = small_artifact(&params, "dense", 51);
    let metrics = Arc::new(Metrics::new());
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::clone(&metrics),
        ExecCtx::single(),
    )
    .unwrap();

    // Register a second model whose executor we can park, with a
    // 2-deep submit queue.
    let (entered_tx, entered_rx) = mpsc::channel();
    let release = Arc::new((Mutex::new(false), Condvar::new()));
    let backend = BlockingBackend {
        dim: 6,
        classes: 4,
        entered: entered_tx,
        release: Arc::clone(&release),
    };
    let engine = ServingEngine::start_bounded(
        backend,
        BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
        2,
        Arc::clone(&metrics),
    );
    let filler = engine.client();
    hub.install_slot("block", ModelSlot::from_engine(engine, 6, 4, "blocking"));

    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());

    // One wire request parks the executor inside predict ...
    let parked = std::thread::spawn(move || {
        let mut client = NetClient::connect(addr).unwrap();
        client.infer("block", RowBatch::from_rows(&[vec![0.0; 6]]).unwrap())
    });
    entered_rx.recv_timeout(Duration::from_secs(10)).expect("executor parked");
    // ... then the 2-deep queue is filled directly ...
    let _r1 = filler.try_submit(vec![0.0; 6]).expect("queue slot 1");
    let _r2 = filler.try_submit(vec![0.0; 6]).expect("queue slot 2");
    assert!(filler.try_submit(vec![0.0; 6]).is_err(), "queue must now be full");

    // ... so the next wire request is rejected with a typed frame.
    let mut client = NetClient::connect(addr).unwrap();
    match client
        .call(&Frame::Infer {
            key: "block".into(),
            batch: RowBatch::from_rows(&[vec![0.0; 6]]).unwrap(),
            deadline_us: None,
        })
        .unwrap()
    {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::Overloaded);
            assert!(message.contains("queue"), "{message}");
        }
        other => panic!("expected ERROR(overloaded), got {}", other.type_name()),
    }
    assert!(metrics.snapshot().net_rejected_overload >= 1);

    // Release the executor: the parked request completes normally.
    {
        let (lock, cv) = &*release;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }
    let logits = parked.join().unwrap().unwrap();
    assert_eq!((logits.rows(), logits.cols()), (1, 4));

    handle.shutdown();
    runner.join().unwrap().unwrap();
}

#[test]
fn connections_beyond_max_conns_get_rejection_frame() {
    let params = small_params(40);
    let artifact = small_artifact(&params, "relative", 41);
    let metrics = Arc::new(Metrics::new());
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::clone(&metrics),
        ExecCtx::single(),
    )
    .unwrap();
    let opts = ServeOptions { max_conns: 1, ..ServeOptions::default() };
    let (addr, handle, runner) = start_server(hub, &opts);

    // First client occupies the only slot (a round-trip guarantees
    // its handler is registered before the second connect).
    let mut first = NetClient::connect(addr).unwrap();
    assert!(!first.stats().unwrap().is_empty());

    // Second connection is answered with one overload frame + close.
    let mut second = TcpStream::connect(addr).unwrap();
    match protocol::read_frame(&mut second).unwrap() {
        Some(Frame::Error { code, .. }) => assert_eq!(code, ErrorCode::Overloaded),
        other => panic!("expected ERROR(overloaded), got {other:?}"),
    }
    assert!(protocol::read_frame(&mut second).unwrap().is_none(), "then EOF");
    assert_eq!(metrics.snapshot().net_conns_rejected, 1);

    // Releasing the first slot re-admits clients.
    drop(first);
    while handle.active_connections() > 0 {
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut third = NetClient::connect(addr).unwrap();
    assert!(third.infer("m", RowBatch::from_rows(&[vec![0.1; 6]]).unwrap()).is_ok());

    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// ISSUE 8 satellite: a slow-loris peer — half a length prefix, then
/// silence — must be reaped by the idle timeout with a typed error,
/// free its handler thread (the `--max-conns` slot), and count as a
/// protocol error. Before PR 8 this connection held its slot for the
/// full 300 s default.
#[test]
fn slow_loris_half_frame_is_reaped_and_frees_the_slot() {
    use std::io::Write;
    let params = small_params(55);
    let artifact = small_artifact(&params, "dense", 56);
    let metrics = Arc::new(Metrics::new());
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::clone(&metrics),
        ExecCtx::single(),
    )
    .unwrap();
    let opts = ServeOptions {
        max_conns: 1,
        idle_timeout: Duration::from_millis(200),
        ..ServeOptions::default()
    };
    let (addr, handle, runner) = start_server(hub, &opts);

    let mut loris = TcpStream::connect(addr).unwrap();
    loris.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    loris.write_all(&[0x10, 0x00]).unwrap(); // 2 of 4 prefix bytes, then silence
    match protocol::read_frame(&mut loris).unwrap() {
        Some(Frame::Error { code, message }) => {
            assert_eq!(code, ErrorCode::BadFrame);
            assert!(message.contains("timed out inside"), "{message}");
        }
        other => panic!("expected ERROR(bad-frame), got {other:?}"),
    }
    assert!(
        protocol::read_frame(&mut loris).unwrap().is_none(),
        "a mid-frame stall cannot be re-synced: the server must close"
    );
    assert!(metrics.snapshot().net_protocol_errors >= 1);

    // The handler thread (and with it the only connection slot) is
    // free again: a healthy client is admitted and served.
    while handle.active_connections() > 0 {
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut client = NetClient::connect(addr).unwrap();
    let logits = client.infer("m", RowBatch::from_rows(&[vec![0.4; 6]]).unwrap()).unwrap();
    assert_eq!((logits.rows(), logits.cols()), (1, 4));

    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// ISSUE 8 tentpole, wire level: an INFER carrying `deadline_us: 0`
/// (already expired on arrival) is answered DEADLINE_EXCEEDED, the
/// shed is counted, and no spmm runs for it; a generous deadline on
/// the same connection serves identically to a deadline-free request.
#[test]
fn expired_wire_deadline_is_shed_and_generous_one_serves() {
    let params = small_params(57);
    let artifact = small_artifact(&params, "csr", 58);
    let metrics = Arc::new(Metrics::new());
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::clone(&metrics),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(addr).unwrap();

    let mut rng = Rng::new(59);
    let row = random_row(&mut rng, 6);
    let batch = RowBatch::from_rows(&[row]).unwrap();
    let spmms_before = metrics.snapshot().kernel_spmms;
    match client
        .call(&Frame::Infer { key: "m".into(), batch: batch.clone(), deadline_us: Some(0) })
        .unwrap()
    {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::DeadlineExceeded);
            assert!(message.contains("expired"), "{message}");
        }
        other => panic!("expected ERROR(deadline-exceeded), got {}", other.type_name()),
    }
    let snap = metrics.snapshot();
    assert!(snap.net_deadline_exceeded >= 1, "shed must be counted");
    assert_eq!(snap.kernel_spmms, spmms_before, "shed rows must never reach spmm");

    // Same connection, 30 s budget: byte-identical to deadline-free.
    let with = match client
        .call(&Frame::Infer {
            key: "m".into(),
            batch: batch.clone(),
            deadline_us: Some(30_000_000),
        })
        .unwrap()
    {
        Frame::Logits(l) => l,
        other => panic!("expected LOGITS, got {}", other.type_name()),
    };
    let without = client.infer("m", batch).unwrap();
    assert_eq!(with.data(), without.data(), "deadline must not change logits");

    handle.shutdown();
    runner.join().unwrap().unwrap();
}

// ------------------------------------------------- hot swap, stats, shutdown

#[test]
fn hot_swap_over_the_wire_switches_kernels_between_requests() {
    let dir = std::env::temp_dir().join(format!("lrbi_server_swap_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let params = small_params(30);
    let mut registry = Registry::create(&dir).unwrap();
    registry.publish("a", &small_artifact(&params, "lowrank", 31)).unwrap();

    let metrics = Arc::new(Metrics::new());
    let hub = ModelHub::from_registry(
        &dir,
        BatchPolicy::default(),
        64,
        Arc::clone(&metrics),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(addr).unwrap();

    let mut rng = Rng::new(33);
    let row = random_row(&mut rng, 6);
    let batch = RowBatch::from_rows(&[row.clone()]).unwrap();
    let before = client.infer("a", batch.clone()).unwrap();

    // Swapping a name the registry does not have is a typed error.
    assert!(client.swap("ghost").is_err());

    // Publish a re-compression under the same name and swap it in.
    let swapped = small_artifact(&params, "csr", 99);
    registry.publish("a", &swapped).unwrap();
    let message = client.swap("a").unwrap();
    assert!(message.contains("swapped"), "{message}");

    let after = client.infer("a", batch).unwrap();
    assert_ne!(after.data(), before.data(), "swapped index must change logits");
    let mut direct = NativeBackend::from_artifact(&swapped).unwrap();
    let x = Matrix::from_fn(1, 6, |_, j| row[j]);
    assert_eq!(
        after.row(0),
        direct.predict(&x).unwrap().row(0),
        "post-swap logits bit-identical to the new artifact"
    );
    assert_eq!(metrics.snapshot().hot_swaps, 1);

    handle.shutdown();
    runner.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stats_frame_serializes_the_metrics_snapshot() {
    let params = small_params(20);
    let artifact = small_artifact(&params, "lowrank", 21);
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::new(Metrics::new()),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(addr).unwrap();
    for _ in 0..3 {
        client.infer("m", RowBatch::from_rows(&[vec![0.2; 6]]).unwrap()).unwrap();
    }
    let stats = client.stats().unwrap();
    let get = |k: &str| {
        stats
            .iter()
            .find(|(n, _)| n == k)
            .unwrap_or_else(|| panic!("missing counter '{k}'"))
            .1
    };
    assert_eq!(get("net_requests"), 3);
    assert_eq!(get("net_conns_accepted"), 1);
    assert_eq!(get("requests"), 3, "engine-side counter flows through");
    assert!(get("kernel_spmms") >= 3);
    assert!(get("spmm_shards") >= 1, "PR3 plan counters are exposed");
    for name in lrbi::coordinator::metrics::SPMM_NS_COUNTER_NAMES {
        assert!(stats.iter().any(|(n, _)| n == name), "missing {name}");
    }
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// ISSUE 7 acceptance: two kernels served over TCP with telemetry
/// recording produce logits byte-identical to direct in-process
/// inference with no telemetry attached, and both the STATS2 frame
/// and the `--metrics-addr` Prometheus scrape report per-stage
/// p50/p95/p99 with non-zero counts for every pipeline stage.
#[test]
fn stats_v2_and_http_scrape_report_per_stage_percentiles() {
    use lrbi::coordinator::telemetry::STAGE_NAMES;
    use lrbi::runtime::artifacts::GEOMETRY;
    use lrbi::serve::kernels::KernelFormat;
    use lrbi::serve::metrics_http::MetricsServer;
    use std::io::{Read, Write};

    let g = GEOMETRY;
    let params = MlpParams::init(77);
    let mut rng = Rng::new(78);
    let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.3));
    let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.3));
    let metrics = Arc::new(Metrics::new());
    // 2 plan threads so the lowrank kernel's reduction shards fan out
    // and the merge stage actually runs (single-shard plans skip it).
    let ctx = ExecCtx::new(2, Some(Arc::clone(&metrics)));
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
    let kernels = [KernelFormat::LowRankFused, KernelFormat::Relative];
    let lowrank = NativeBackend::with_format_exec(
        params.clone(),
        kernels[0],
        &ip,
        &iz,
        Arc::clone(&ctx),
    )
    .unwrap()
    .with_metrics(Arc::clone(&metrics));
    let hub = ModelHub::from_backend("lowrank", lowrank, policy, 64, Arc::clone(&metrics));
    let relative =
        NativeBackend::with_format_exec(params.clone(), kernels[1], &ip, &iz, ctx)
            .unwrap()
            .with_metrics(Arc::clone(&metrics));
    hub.install_backend("relative", relative);

    let (addr, handle, runner) = start_server(hub, &ServeOptions::default());
    let scraper = MetricsServer::bind("127.0.0.1:0", Arc::clone(&metrics)).unwrap();
    let mut client = NetClient::connect(addr).unwrap();

    // Drive both kernels and pin byte-identity against direct
    // in-process backends that carry no metrics/telemetry at all.
    let mut rng = Rng::new(79);
    for (key, fmt) in ["lowrank", "relative"].into_iter().zip(kernels) {
        let mut direct = NativeBackend::with_format(params.clone(), fmt, &ip, &iz).unwrap();
        for _ in 0..8 {
            let row = random_row(&mut rng, g.input_dim);
            let got = client
                .infer(key, RowBatch::from_rows(&[row.clone()]).unwrap())
                .unwrap();
            assert_eq!((got.rows(), got.cols()), (1, g.classes), "{key}");
            let mut x = Matrix::zeros(g.batch, g.input_dim);
            for (j, &v) in row.iter().enumerate() {
                x.set(0, j, v);
            }
            let want = direct.predict(&x).unwrap();
            assert_eq!(
                got.row(0),
                &want.row(0)[..g.classes],
                "{key}: telemetry-on wire logits must be byte-identical to telemetry-off"
            );
        }
    }

    // STATS v1 still answers on the same connection (framing compat).
    assert!(!client.stats().unwrap().is_empty());

    // STATS2: every pipeline stage has traffic and real percentiles.
    let (counters, hists) = client.stats_v2().unwrap();
    assert!(counters.iter().any(|(n, v)| n == "net_requests" && *v == 16));
    let stage = |name: &str| {
        hists
            .iter()
            .find(|h| h.name == "stage_ns" && h.labels == format!("stage={name}"))
            .unwrap_or_else(|| panic!("missing stage series '{name}'"))
    };
    for name in STAGE_NAMES {
        let h = stage(name);
        assert!(h.count > 0, "stage '{name}' must have samples, got {h:?}");
        assert!(h.sum > 0, "stage '{name}' must have spent time, got {h:?}");
        assert!(
            h.p50 > 0 && h.p50 <= h.p95 && h.p95 <= h.p99,
            "stage '{name}' percentiles must be non-zero and ordered, got {h:?}"
        );
    }
    for key in ["lowrank", "relative"] {
        let h = hists
            .iter()
            .find(|h| h.name == "spmm_ns" && h.labels == format!("kernel={key}"))
            .unwrap_or_else(|| panic!("missing spmm series '{key}'"));
        assert!(h.count > 0 && h.p50 > 0, "kernel '{key}': {h:?}");
        let r = hists
            .iter()
            .find(|h| h.name == "request_ns" && h.labels == format!("model={key}"))
            .unwrap_or_else(|| panic!("missing request series '{key}'"));
        assert_eq!(r.count, 8, "model '{key}': {r:?}");
    }
    assert!(
        hists.iter().any(|h| h.name == "spmm_shard_ns" && h.count > 0),
        "per-shard timings must flow from the exec pool"
    );

    // The Prometheus scrape reports the same stages with counts.
    let mut conn = TcpStream::connect(scraper.local_addr()).unwrap();
    conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut response = String::new();
    conn.read_to_string(&mut response).unwrap();
    let body = response.split("\r\n\r\n").nth(1).expect("http body");
    for name in STAGE_NAMES {
        for q in ["0.5", "0.95", "0.99"] {
            let line = format!("lrbi_stage_ns{{stage=\"{name}\",quantile=\"{q}\"}}");
            assert!(body.contains(&line), "scrape missing {line}");
        }
        let count_line = format!("lrbi_stage_ns_count{{stage=\"{name}\"}}");
        let count: u64 = body
            .lines()
            .find_map(|l| l.strip_prefix(count_line.as_str()))
            .unwrap_or_else(|| panic!("scrape missing {count_line}"))
            .trim()
            .parse()
            .unwrap();
        assert!(count > 0, "scrape reports zero samples for stage '{name}'");
    }
    assert!(body.contains("lrbi_spmm_ns{kernel=\"relative\",quantile=\"0.5\"}"));
    assert!(body.contains("# TYPE lrbi_net_requests counter"));

    drop(scraper);
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

#[test]
fn shutdown_frame_stops_the_server_gracefully() {
    let params = small_params(10);
    let artifact = small_artifact(&params, "dense", 11);
    let hub = ModelHub::from_artifact(
        "m",
        &artifact,
        BatchPolicy::default(),
        64,
        Arc::new(Metrics::new()),
        ExecCtx::single(),
    )
    .unwrap();
    let (addr, _handle, runner) = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(addr).unwrap();
    client.infer("m", RowBatch::from_rows(&[vec![0.3; 6]]).unwrap()).unwrap();
    let message = client.shutdown_server().unwrap();
    assert!(message.contains("shutting down"), "{message}");
    // run() returns once handlers drain — no external trigger needed.
    runner.join().unwrap().unwrap();
}
