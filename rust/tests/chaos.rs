//! Chaos suite for the deterministic fault-injection harness
//! (`util::fault`, ISSUE 8): every injection point is driven against
//! a live localhost server and must degrade into a *typed* error — a
//! reply frame, a clean close, or a typed `Error` — never a panic or
//! a hang. Surviving requests stay byte-identical to direct
//! in-process inference, and every injected fault moves the
//! process-global `faults_injected` counter.
//!
//! The fault plan is process-global, so every test serializes around
//! [`fault::test_guard`] and clears the plan before returning.

use lrbi::coordinator::metrics::{self, Metrics};
use lrbi::coordinator::pool::ExecCtx;
use lrbi::serve::batcher::BatchPolicy;
use lrbi::serve::engine::{InferenceBackend, MlpParams, NativeBackend};
use lrbi::serve::protocol::{ErrorCode, Frame, RowBatch};
use lrbi::serve::server::{
    ClientOptions, ModelHub, NetClient, RetryPolicy, ServeOptions, Server,
};
use lrbi::store::Artifact;
use lrbi::tensor::Matrix;
use lrbi::util::bits::BitMatrix;
use lrbi::util::error::{Error, Result};
use lrbi::util::fault::{self, FaultPlan};
use lrbi::util::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------- helpers

/// Small model (6 → 20 → 30 → 4) so every chaos round trips in
/// milliseconds even with stalls injected.
fn small_params(seed: u64) -> MlpParams {
    let mut rng = Rng::new(seed);
    MlpParams {
        w0: Matrix::gaussian(6, 20, 0.0, 0.5, &mut rng),
        b0: vec![0.1; 20],
        w1: Matrix::gaussian(20, 30, 0.0, 0.5, &mut rng),
        b1: vec![0.2; 30],
        w2: Matrix::gaussian(30, 4, 0.0, 0.5, &mut rng),
        b2: vec![0.0; 4],
    }
}

fn small_artifact(params: &MlpParams, format: &str, seed: u64) -> Artifact {
    let mut rng = Rng::new(seed);
    let ip = BitMatrix::from_fn(20, 4, |_, _| rng.bernoulli(0.3));
    let iz = BitMatrix::from_fn(4, 30, |_, _| rng.bernoulli(0.3));
    Artifact::pack_factors(params.clone(), format, &ip, &iz, "chaos test").unwrap()
}

/// Wider masked layer (20 → 160) so the dense kernel plans several
/// output-column shards — the shard faults only exist on the pooled
/// multi-shard path (`run_inner` falls back to inline for one shard).
fn wide_artifact(seed: u64) -> Artifact {
    let mut rng = Rng::new(seed);
    let params = MlpParams {
        w0: Matrix::gaussian(6, 20, 0.0, 0.5, &mut rng),
        b0: vec![0.1; 20],
        w1: Matrix::gaussian(20, 160, 0.0, 0.5, &mut rng),
        b1: vec![0.2; 160],
        w2: Matrix::gaussian(160, 4, 0.0, 0.5, &mut rng),
        b2: vec![0.0; 4],
    };
    let ip = BitMatrix::from_fn(20, 4, |_, _| rng.bernoulli(0.3));
    let iz = BitMatrix::from_fn(4, 160, |_, _| rng.bernoulli(0.3));
    Artifact::pack_factors(params, "dense", &ip, &iz, "chaos test").unwrap()
}

/// Boot a server over `artifact` on an ephemeral port; `ctx` chooses
/// single-threaded or pooled plan execution (the shard faults only
/// exist on the pooled path).
fn start_server(
    artifact: &Artifact,
    metrics: Arc<Metrics>,
    ctx: Arc<ExecCtx>,
) -> (
    std::net::SocketAddr,
    lrbi::serve::server::ServerHandle,
    std::thread::JoinHandle<Result<()>>,
) {
    let hub = ModelHub::from_artifact(
        "m",
        artifact,
        BatchPolicy::default(),
        64,
        metrics,
        ctx,
    )
    .unwrap();
    let server = Server::bind("127.0.0.1:0", Arc::new(hub), &ServeOptions::default()).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    (addr, handle, runner)
}

fn one_row_batch(seed: u64) -> (Vec<f32>, RowBatch) {
    let mut rng = Rng::new(seed);
    let row: Vec<f32> = (0..6).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let batch = RowBatch::from_rows(&[row.clone()]).unwrap();
    (row, batch)
}

/// Direct in-process logits for `row` — the byte-identity reference.
fn direct_logits(artifact: &Artifact, row: &[f32]) -> Vec<f32> {
    let mut direct = NativeBackend::from_artifact(artifact).unwrap();
    let x = Matrix::from_fn(1, 6, |_, j| row[j]);
    direct.predict(&x).unwrap().row(0).to_vec()
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("lrbi_chaos_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ------------------------------------------------------ connection faults

/// With no plan installed the hooks must be invisible: logits over
/// the wire stay byte-identical to direct inference (the hooks are
/// compiled into release builds, so this is the "chaos off" baseline
/// every other test implicitly relies on).
#[test]
fn disabled_plan_serves_byte_identical_logits() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = small_artifact(&small_params(70), "dense", 71);
    let (addr, handle, runner) =
        start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let mut client = NetClient::connect(addr).unwrap();
    let (row, batch) = one_row_batch(72);
    let got = client.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// `read_stall` delays the frame read but must not change a byte of
/// the reply; every injected stall is counted.
#[test]
fn read_stall_delays_but_serves_identically() {
    let _g = fault::test_guard();
    let artifact = small_artifact(&small_params(73), "csr", 74);
    let (addr, handle, runner) =
        start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let injected_before = fault::injected_total();
    fault::install(FaultPlan::parse("read_stall=1+2:20").unwrap());

    let mut client = NetClient::connect(addr).unwrap();
    let (row, batch) = one_row_batch(75);
    let want = direct_logits(&artifact, &row);
    for _ in 0..2 {
        let got = client.infer("m", batch.clone()).unwrap();
        assert_eq!(got.row(0), want.as_slice(), "stalled read must not corrupt logits");
    }
    assert!(fault::injected_total() >= injected_before + 2, "both stalls counted");

    fault::clear();
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// `read_truncate` turns the next frame into a typed `bad-frame`
/// reply; the connection stays usable afterwards (a truncated frame
/// is a *reply*, not a close).
#[test]
fn read_truncate_is_a_typed_bad_frame_and_the_conn_survives() {
    let _g = fault::test_guard();
    let artifact = small_artifact(&small_params(76), "bitmap", 77);
    let metrics = Arc::new(Metrics::new());
    let (addr, handle, runner) =
        start_server(&artifact, Arc::clone(&metrics), ExecCtx::single());
    fault::install(FaultPlan::parse("read_truncate=1").unwrap());

    let mut client = NetClient::connect(addr).unwrap();
    let (row, batch) = one_row_batch(78);
    match client.call(&Frame::Infer { key: "m".into(), batch: batch.clone(), deadline_us: None }) {
        Ok(Frame::Error { code, message }) => {
            assert_eq!(code, ErrorCode::BadFrame);
            assert!(message.contains("truncated"), "{message}");
        }
        other => panic!("expected ERROR(bad-frame), got {other:?}"),
    }
    assert!(metrics.snapshot().net_protocol_errors >= 1);

    // Hit 2 is clean: the same connection serves correct logits.
    let got = client.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());

    fault::clear();
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// `conn_close` drops the connection instead of serving: the client
/// sees a typed error (close or reset, depending on timing — never a
/// hang), and a fresh connection works because only hit 1 is faulted.
#[test]
fn conn_close_is_survivable_by_reconnecting() {
    let _g = fault::test_guard();
    let artifact = small_artifact(&small_params(79), "dense", 80);
    let (addr, handle, runner) =
        start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    fault::install(FaultPlan::parse("conn_close=1").unwrap());

    let mut client = NetClient::connect(addr).unwrap();
    let (row, batch) = one_row_batch(81);
    match client.infer("m", batch.clone()) {
        Err(Error::Protocol(_)) | Err(Error::Io(_)) => {}
        other => panic!("expected a typed close/reset error, got {other:?}"),
    }

    let mut fresh = NetClient::connect(addr).unwrap();
    let got = fresh.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());

    fault::clear();
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// `write_stall` delays the reply write; the bytes that eventually
/// arrive are untouched.
#[test]
fn write_stall_delays_the_reply_but_not_its_bytes() {
    let _g = fault::test_guard();
    let artifact = small_artifact(&small_params(82), "csr", 83);
    let (addr, handle, runner) =
        start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    fault::install(FaultPlan::parse("write_stall=1:20").unwrap());

    let mut client = NetClient::connect(addr).unwrap();
    let (row, batch) = one_row_batch(84);
    let got = client.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());

    fault::clear();
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

// --------------------------------------------------------- executor faults

/// A panic injected into shard 0 of a pooled plan execution surfaces
/// as a typed `internal` error frame — the worker pool's unwind fence
/// catches it — and the pool keeps serving afterwards.
#[test]
fn shard_panic_is_typed_and_the_pool_survives() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = wide_artifact(85);
    let (addr, handle, runner) =
        start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::new(2, None));
    let mut client = NetClient::connect(addr).unwrap();
    let (row, batch) = one_row_batch(87);

    // Warm up on a clean path first, so the faulted hit ordinal below
    // deterministically lands on *our* request's spmm.
    client.infer("m", batch.clone()).unwrap();
    fault::install(FaultPlan::parse("shard_panic=1").unwrap());

    match client.infer("m", batch.clone()) {
        Err(Error::Protocol(m)) => {
            assert!(m.contains("parallel shard panicked"), "{m}");
        }
        other => panic!("expected ERROR(internal) with the panic message, got {other:?}"),
    }

    // Same connection, same pool: hit 2 is clean and byte-identical.
    let got = client.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());

    fault::clear();
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// A stalled shard slows the flush but completes it — logits stay
/// byte-identical to a clean pooled run.
#[test]
fn slow_shard_completes_with_identical_logits() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = wide_artifact(88);
    let (addr, handle, runner) =
        start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::new(2, None));
    let mut client = NetClient::connect(addr).unwrap();
    let (row, batch) = one_row_batch(90);
    client.infer("m", batch.clone()).unwrap(); // warm-up: pin hit ordinals

    let injected_before = fault::injected_total();
    fault::install(FaultPlan::parse("slow_shard=1:30").unwrap());
    let got = client.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());
    assert!(fault::injected_total() >= injected_before + 1);

    fault::clear();
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

// ----------------------------------------------------- client retry + shed

/// ISSUE 8 acceptance: a client with a retry budget recovers from an
/// injected transient overload — the first two INFERs are rejected
/// `overloaded`, the third serves, and both retries are observed in
/// the process-wide retry counter.
#[test]
fn retry_recovers_from_injected_transient_overload() {
    let _g = fault::test_guard();
    let artifact = small_artifact(&small_params(91), "dense", 92);
    let metrics = Arc::new(Metrics::new());
    let (addr, handle, runner) =
        start_server(&artifact, Arc::clone(&metrics), ExecCtx::single());
    fault::install(FaultPlan::parse("infer_overload=1+2").unwrap());

    let opts = ClientOptions {
        retry: RetryPolicy {
            max_retries: 3,
            base_backoff: Duration::from_millis(5),
            ..RetryPolicy::default()
        },
        ..ClientOptions::default()
    };
    let retries_before = metrics::net_retries_total();
    let overloads_before = metrics.snapshot().net_rejected_overload;
    let mut client = NetClient::connect_with(addr, opts).unwrap();
    let (row, batch) = one_row_batch(93);
    let got = client.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());
    assert!(metrics::net_retries_total() >= retries_before + 2, "two retries observed");
    assert!(metrics.snapshot().net_rejected_overload >= overloads_before + 2);

    fault::clear();
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// Without a retry budget the same injected overload is surfaced to
/// the caller as the typed `overloaded` protocol error.
#[test]
fn overload_without_retry_budget_is_a_typed_error() {
    let _g = fault::test_guard();
    let artifact = small_artifact(&small_params(94), "csr", 95);
    let (addr, handle, runner) =
        start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    fault::install(FaultPlan::parse("infer_overload=1").unwrap());

    let mut client = NetClient::connect(addr).unwrap();
    let (_, batch) = one_row_batch(96);
    match client.infer("m", batch) {
        Err(Error::Protocol(m)) => assert!(m.starts_with("overloaded"), "{m}"),
        other => panic!("expected ERROR(overloaded), got {other:?}"),
    }

    fault::clear();
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

// --------------------------------------------------------- artifact faults

/// Corrupted artifact loads (one flipped bit, a short read) must come
/// back as typed [`Error::Store`] values — the CRC and the bounds
/// checks catch them — and a clean re-read succeeds.
#[test]
fn artifact_corruption_is_a_typed_store_error() {
    let _g = fault::test_guard();
    fault::clear();
    let dir = tmp_dir("artifact");
    let path = dir.join("m.lrbi");
    let artifact = small_artifact(&small_params(97), "lowrank", 98);
    artifact.write(&path).unwrap();

    fault::install(FaultPlan::parse("artifact_bitflip=1, seed=41").unwrap());
    match Artifact::read(&path) {
        Err(Error::Store(_)) => {} // typed, not a panic
        other => panic!("bitflip: expected Error::Store, got {other:?}"),
    }

    fault::install(FaultPlan::parse("artifact_short_read=1").unwrap());
    match Artifact::read(&path) {
        Err(Error::Store(_)) => {}
        other => panic!("short read: expected Error::Store, got {other:?}"),
    }

    // The file on disk was never touched: a clean read round-trips.
    fault::clear();
    let back = Artifact::read(&path).unwrap();
    assert_eq!(back.meta.provenance, "chaos test");
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------ worker-tier faults

type Running = (
    std::net::SocketAddr,
    lrbi::serve::server::ServerHandle,
    std::thread::JoinHandle<Result<()>>,
);

/// A router server over worker addresses in `spec` (`|` = replicas,
/// `,` = shards), dialing workers with `copts`.
fn start_router(spec: &str, copts: ClientOptions, metrics: Arc<Metrics>) -> Running {
    use lrbi::serve::router::ShardGroup;
    let group = Arc::new(ShardGroup::connect(spec, "m", copts, metrics).unwrap());
    let hub = ModelHub::from_remote("m", group);
    let server = Server::bind("127.0.0.1:0", Arc::new(hub), &ServeOptions::default()).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    (addr, handle, runner)
}

fn stop((_, handle, runner): Running) {
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

/// `worker_conn_drop` with a replica behind it: the router counts the
/// failure, fails over to the replica, and the served logits stay
/// byte-identical — the client never sees the fault.
#[test]
fn worker_conn_drop_fails_over_to_the_replica() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = small_artifact(&small_params(200), "dense", 201);
    let metrics = Arc::new(Metrics::new());
    let replica_a = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let replica_b = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let spec = format!("{}|{}", replica_a.0, replica_b.0);
    let router = start_router(&spec, ClientOptions::default(), Arc::clone(&metrics));
    let mut client = NetClient::connect(router.0).unwrap();
    let (row, batch) = one_row_batch(202);

    // Hit 1 = replica A's scatter attempt; replica B's is hit 2 and
    // stays clean, so fail-over must serve the request.
    fault::install(FaultPlan::parse("worker_conn_drop=1").unwrap());
    let got = client.infer("m", batch).unwrap();
    assert_eq!(
        got.row(0),
        direct_logits(&artifact, &row).as_slice(),
        "failed-over logits must stay byte-identical"
    );
    let snap = metrics.snapshot();
    assert!(snap.net_worker_failures >= 1, "the drop is counted");
    assert!(snap.net_worker_failovers >= 1, "the fail-over is counted");
    assert_eq!(snap.net_worker_unavailable, 0, "the request was served");

    fault::clear();
    stop(router);
    stop(replica_a);
    stop(replica_b);
}

/// `worker_conn_drop` with no replica: a typed `unavailable` error —
/// never a panic or wrong logits — and the very next request heals by
/// re-dialing; a client with a retry budget absorbs the whole episode.
#[test]
fn worker_conn_drop_without_replica_is_typed_unavailable_then_recovers() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = small_artifact(&small_params(203), "csr", 204);
    let metrics = Arc::new(Metrics::new());
    let worker = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let router =
        start_router(&worker.0.to_string(), ClientOptions::default(), Arc::clone(&metrics));
    let mut client = NetClient::connect(router.0).unwrap();
    let (row, batch) = one_row_batch(205);

    fault::install(FaultPlan::parse("worker_conn_drop=1").unwrap());
    match client
        .call(&Frame::Infer { key: "m".into(), batch: batch.clone(), deadline_us: None })
        .unwrap()
    {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::Unavailable);
            assert!(message.contains("no replica"), "{message}");
        }
        other => panic!("expected ERROR(unavailable), got {other:?}"),
    }
    assert!(metrics.snapshot().net_worker_unavailable >= 1);

    // Only hit 1 was faulted: the same connection heals on the next
    // request because the router re-dials the dropped worker.
    let got = client.infer("m", batch.clone()).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());

    // A retrying client rides straight through the same fault:
    // `unavailable` is retried like `overloaded`.
    fault::install(FaultPlan::parse("worker_conn_drop=1").unwrap());
    let retries_before = metrics::net_retries_total();
    let opts = ClientOptions {
        retry: RetryPolicy {
            max_retries: 3,
            base_backoff: Duration::from_millis(5),
            ..RetryPolicy::default()
        },
        ..ClientOptions::default()
    };
    let mut retrying = NetClient::connect_with(router.0, opts).unwrap();
    let got = retrying.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());
    assert!(metrics::net_retries_total() >= retries_before + 1, "the retry is observed");

    fault::clear();
    stop(router);
    stop(worker);
}

/// `partial_stall` longer than the router's worker I/O timeout: the
/// router abandons the stalled worker with a typed `unavailable`
/// (never a hang), drops the poisoned connection so the late PARTIAL
/// can't pollute a later request, and the next request serves
/// correct bytes on a fresh dial.
#[test]
fn partial_stall_outlasting_the_io_timeout_is_typed_and_recovers() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = small_artifact(&small_params(206), "lowrank", 207);
    let metrics = Arc::new(Metrics::new());
    let worker = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let copts = ClientOptions {
        io_timeout: Some(Duration::from_millis(100)),
        ..ClientOptions::default()
    };
    let router = start_router(&worker.0.to_string(), copts, Arc::clone(&metrics));
    let mut client = NetClient::connect(router.0).unwrap();
    let (row, batch) = one_row_batch(208);

    fault::install(FaultPlan::parse("partial_stall=1:400").unwrap());
    match client
        .call(&Frame::Infer { key: "m".into(), batch: batch.clone(), deadline_us: None })
        .unwrap()
    {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::Unavailable),
        other => panic!("expected ERROR(unavailable), got {other:?}"),
    }
    assert!(metrics.snapshot().net_worker_failures >= 1);

    // Give the stalled worker handler time to finish its late write
    // into the dropped connection, then serve cleanly on a fresh one.
    std::thread::sleep(Duration::from_millis(400));
    let got = client.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());

    fault::clear();
    stop(router);
    stop(worker);
}

/// `worker_swap_fail` aborts a rolling swap partway: the swap is a
/// typed error, the group degrades (infers answer `unavailable`, so
/// mixed-artifact logits can never be gathered), and a later clean
/// SWAP heals the group onto the new artifact's exact bytes.
#[test]
fn worker_swap_fail_degrades_until_a_later_swap_succeeds() {
    let _g = fault::test_guard();
    fault::clear();
    let params = small_params(209);
    let old = small_artifact(&params, "lowrank", 210);
    let new = small_artifact(&params, "csr", 211);

    let mut dirs = Vec::new();
    let mut registries = Vec::new();
    let mut workers = Vec::new();
    for w in 0..2 {
        let dir = tmp_dir(&format!("swapfail_{w}"));
        let mut registry = lrbi::store::Registry::create(dir.join("reg")).unwrap();
        registry.publish("m", &old).unwrap();
        let hub = ModelHub::from_registry(
            dir.join("reg"),
            BatchPolicy::default(),
            64,
            Arc::new(Metrics::new()),
            ExecCtx::single(),
        )
        .unwrap();
        let server =
            Server::bind("127.0.0.1:0", Arc::new(hub), &ServeOptions::default()).unwrap();
        let addr = server.local_addr();
        let handle = server.handle();
        let runner = std::thread::spawn(move || server.run());
        workers.push((addr, handle, runner));
        registries.push(registry);
        dirs.push(dir);
    }
    let spec = format!("{},{}", workers[0].0, workers[1].0);
    let metrics = Arc::new(Metrics::new());
    let router = start_router(&spec, ClientOptions::default(), Arc::clone(&metrics));
    let mut client = NetClient::connect(router.0).unwrap();
    let (row, batch) = one_row_batch(212);

    let before = client.infer("m", batch.clone()).unwrap();
    assert_eq!(before.row(0), direct_logits(&old, &row).as_slice());

    for registry in &mut registries {
        registry.publish("m", &new).unwrap();
    }

    // Hit 1 = the first worker's swap step: the roll aborts with a
    // typed error before any worker swapped.
    fault::install(FaultPlan::parse("worker_swap_fail=1").unwrap());
    match client.swap("m") {
        Err(Error::Protocol(m)) => assert!(m.contains("aborted"), "{m}"),
        other => panic!("expected a typed swap failure, got {other:?}"),
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.net_worker_swap_failures, 1);
    assert_eq!(snap.net_worker_swaps, 0, "no worker swapped before the abort");

    // Degraded: infers answer `unavailable` — never logits that might
    // mix artifact versions across shards.
    match client
        .call(&Frame::Infer { key: "m".into(), batch: batch.clone(), deadline_us: None })
        .unwrap()
    {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::Unavailable);
            assert!(message.contains("degraded"), "{message}");
        }
        other => panic!("expected ERROR(unavailable) while degraded, got {other:?}"),
    }
    assert!(metrics.snapshot().net_worker_unavailable >= 1);

    // A clean SWAP heals the group end-to-end onto the new bytes.
    fault::clear();
    let message = client.swap("m").unwrap();
    assert!(message.contains("rolling swap"), "{message}");
    let after = client.infer("m", batch).unwrap();
    assert_eq!(
        after.row(0),
        direct_logits(&new, &row).as_slice(),
        "healed group serves the new artifact's exact bytes"
    );
    assert_eq!(metrics.snapshot().net_worker_swaps, 2);

    stop(router);
    for w in workers {
        stop(w);
    }
    for dir in dirs {
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// --------------------------------------------------- supervision (ISSUE 10)

use lrbi::serve::router::{HedgePolicy, ShardGroup, SupervisorOptions};

/// Worker bound to an *exact* address — a crashed worker restarting on
/// its old port, which the supervisor must reintegrate (or, serving
/// stale bytes, refuse to).
fn start_server_at(
    addr: std::net::SocketAddr,
    artifact: &Artifact,
    metrics: Arc<Metrics>,
) -> Running {
    let hub = ModelHub::from_artifact(
        "m",
        artifact,
        BatchPolicy::default(),
        64,
        metrics,
        ExecCtx::single(),
    )
    .unwrap();
    let server = Server::bind(addr, Arc::new(hub), &ServeOptions::default()).unwrap();
    let local = server.local_addr();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    (local, handle, runner)
}

/// Router whose `ShardGroup` stays reachable, so tests can drive
/// `supervise_tick()` deterministically instead of racing a
/// background prober thread.
fn start_router_sup(
    spec: &str,
    copts: ClientOptions,
    sup: SupervisorOptions,
    metrics: Arc<Metrics>,
) -> (Running, Arc<ShardGroup>) {
    let group =
        Arc::new(ShardGroup::connect_with(spec, "m", copts, sup, metrics).unwrap());
    let hub = ModelHub::from_remote("m", Arc::clone(&group));
    let server = Server::bind("127.0.0.1:0", Arc::new(hub), &ServeOptions::default()).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    ((addr, handle, runner), group)
}

/// Supervision knobs scaled for a test: a nonzero (but never-firing)
/// health interval marks the group *supervised* — the scatter path
/// skips non-closed replicas and leaves reintegration to the ticks
/// the test drives by hand.
fn fast_sup() -> SupervisorOptions {
    SupervisorOptions {
        health_interval: Duration::from_secs(3600),
        hedge: HedgePolicy::Disabled,
        breaker_failures: 2,
        breaker_cooldown: Duration::from_millis(50),
        breaker_successes: 2,
        dial_backoff: RetryPolicy {
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(40),
            ..RetryPolicy::default()
        },
        ..SupervisorOptions::default()
    }
}

/// A replica stalled mid-PARTIAL past `--hedge-ms`: the hedge fires at
/// the second replica, its reply wins, and the served logits are
/// byte-identical to direct inference — workers compute the full
/// forward pass and `assemble` only copies, so either replica's
/// PARTIAL is byte-substitutable.
#[test]
fn hedged_scatter_rides_out_a_partial_stall_with_identical_bytes() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = small_artifact(&small_params(220), "dense", 221);
    let metrics = Arc::new(Metrics::new());
    let a = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let b = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let spec = format!("{}|{}", a.0, b.0);
    let sup = SupervisorOptions {
        hedge: HedgePolicy::Fixed(Duration::from_millis(40)),
        ..fast_sup()
    };
    let (router, _group) =
        start_router_sup(&spec, ClientOptions::default(), sup, Arc::clone(&metrics));
    let mut client = NetClient::connect(router.0).unwrap();
    let (row, batch) = one_row_batch(222);

    // Hit 1 = replica A's PARTIAL write, stalled well past the hedge
    // delay; replica B's (hit 2) is clean and must win the race.
    fault::install(FaultPlan::parse("partial_stall=1:400").unwrap());
    let t0 = Instant::now();
    let got = client.infer("m", batch.clone()).unwrap();
    assert_eq!(
        got.row(0),
        direct_logits(&artifact, &row).as_slice(),
        "hedged logits must stay byte-identical"
    );
    assert!(
        t0.elapsed() < Duration::from_millis(350),
        "the hedge answered before the stall cleared ({:?})",
        t0.elapsed()
    );
    let snap = metrics.snapshot();
    assert!(snap.net_hedges_fired >= 1, "the hedge is counted");
    assert!(snap.net_hedges_won >= 1, "the hedge win is counted");
    assert_eq!(snap.net_worker_unavailable, 0, "the request was served");
    fault::clear();

    // The stalled attempt drains into a dropped channel; once it
    // finishes, the primary serves cleanly again.
    std::thread::sleep(Duration::from_millis(400));
    let got = client.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());
    stop(router);
    stop(a);
    stop(b);
}

/// `hedge_stall` — the router-side injection point: the *primary
/// attempt thread* stalls before writing its SCATTER, so the hedge
/// timer (not a worker timeout) is what rescues the request.
#[test]
fn hedge_stall_on_the_primary_is_won_by_the_second_replica() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = small_artifact(&small_params(223), "csr", 224);
    let metrics = Arc::new(Metrics::new());
    let a = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let b = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let spec = format!("{}|{}", a.0, b.0);
    let sup = SupervisorOptions {
        hedge: HedgePolicy::Fixed(Duration::from_millis(30)),
        ..fast_sup()
    };
    let (router, _group) =
        start_router_sup(&spec, ClientOptions::default(), sup, Arc::clone(&metrics));
    let mut client = NetClient::connect(router.0).unwrap();
    let (row, batch) = one_row_batch(225);

    let injected_before = fault::injected_total();
    fault::install(FaultPlan::parse("hedge_stall=1:300").unwrap());
    let t0 = Instant::now();
    let got = client.infer("m", batch.clone()).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());
    assert!(
        t0.elapsed() < Duration::from_millis(250),
        "the hedge answered while the primary was still stalled ({:?})",
        t0.elapsed()
    );
    assert!(fault::injected_total() > injected_before, "the stall was injected");
    let snap = metrics.snapshot();
    assert!(snap.net_hedges_fired >= 1);
    assert!(snap.net_hedges_won >= 1);
    fault::clear();

    std::thread::sleep(Duration::from_millis(300));
    let got = client.infer("m", batch).unwrap();
    assert_eq!(got.row(0), direct_logits(&artifact, &row).as_slice());
    stop(router);
    stop(a);
    stop(b);
}

/// Regression for the connect storm: a replica that is *down* must
/// not be re-dialed on every request. Seeded equal-jitter exponential
/// backoff gates the re-dials, and the breaker stops them entirely —
/// 50 requests may cost only a handful of dial attempts.
#[test]
fn dead_replica_redials_are_bounded_by_backoff_and_breaker() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = small_artifact(&small_params(226), "dense", 227);
    let metrics = Arc::new(Metrics::new());
    let live = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    // A port that was bound once and released: connecting is refused
    // immediately, exactly like a crashed worker.
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let spec = format!("{dead}|{}", live.0);
    let (router, group) =
        start_router_sup(&spec, ClientOptions::default(), fast_sup(), Arc::clone(&metrics));
    let mut client = NetClient::connect(router.0).unwrap();
    let (row, batch) = one_row_batch(228);
    let reference = direct_logits(&artifact, &row);

    for _ in 0..50 {
        let got = client.infer("m", batch.clone()).unwrap();
        assert_eq!(got.row(0), reference.as_slice(), "fail-over stays byte-identical");
        std::thread::sleep(Duration::from_millis(1));
    }
    let dials = group.dial_attempts();
    assert!(dials >= 1, "the dead replica was tried at least once");
    assert!(
        dials <= 10,
        "50 requests must not storm the dead replica with dials, got {dials}"
    );
    let snap = metrics.snapshot();
    assert!(snap.net_breaker_opens >= 1, "repeated dial failures open the breaker");
    assert_eq!(snap.net_worker_unavailable, 0, "every request was served");
    stop(router);
    stop(live);
}

/// A quarantined worker that restarts serving a *stale* artifact
/// (wrong head width) passes the liveness PING but fails the
/// artifact re-probe: it must stay quarantined — rejoining would
/// gather mixed-artifact logits.
#[test]
fn stale_worker_fails_the_reintegration_reprobe_and_stays_out() {
    let _g = fault::test_guard();
    fault::clear();
    let params = small_params(230);
    let art4 = small_artifact(&params, "dense", 231);
    // Same trunk, 3-class head — the shape a worker left behind by a
    // fleet-wide swap would serve.
    let art3 = {
        let mut rng = Rng::new(232);
        let params3 = MlpParams {
            w2: Matrix::gaussian(30, 3, 0.0, 0.5, &mut rng),
            b2: vec![0.0; 3],
            ..params.clone()
        };
        let ip = BitMatrix::from_fn(20, 4, |_, _| rng.bernoulli(0.3));
        let iz = BitMatrix::from_fn(4, 30, |_, _| rng.bernoulli(0.3));
        Artifact::pack_factors(params3, "dense", &ip, &iz, "chaos test").unwrap()
    };
    let metrics = Arc::new(Metrics::new());
    let x = start_server(&art4, Arc::new(Metrics::new()), ExecCtx::single());
    let y = start_server(&art4, Arc::new(Metrics::new()), ExecCtx::single());
    let sup = SupervisorOptions {
        breaker_failures: 1,
        breaker_cooldown: Duration::from_millis(20),
        breaker_successes: 1,
        ..fast_sup()
    };
    let (router, group) = start_router_sup(
        &format!("{}|{}", x.0, y.0),
        ClientOptions::default(),
        sup,
        Arc::clone(&metrics),
    );
    let mut client = NetClient::connect(router.0).unwrap();
    let (row, batch) = one_row_batch(234);
    let reference = direct_logits(&art4, &row);
    assert_eq!(client.infer("m", batch.clone()).unwrap().row(0), reference.as_slice());

    let x_addr = x.0;
    stop(x);
    // The health probe finds the dead conn; threshold 1 opens x.
    group.supervise_tick();
    assert!(metrics.snapshot().net_breaker_opens >= 1, "the probe opened x's breaker");

    // x "restarts" on its old address — but serving the stale bytes.
    let x_stale = start_server_at(x_addr, &art3, Arc::new(Metrics::new()));
    std::thread::sleep(Duration::from_millis(30)); // past the cooldown
    for _ in 0..3 {
        group.supervise_tick();
        std::thread::sleep(Duration::from_millis(30));
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.net_reintegrations, 0, "a stale worker must never rejoin");
    assert!(snap.net_breaker_half_opens >= 1, "the probe did walk half-open");
    assert!(snap.net_breaker_opens >= 2, "the failed re-probe re-quarantined x");
    assert_eq!(snap.net_breaker_closes, 0);

    // Traffic keeps flowing — on the healthy replica, correct bytes.
    assert_eq!(client.infer("m", batch).unwrap().row(0), reference.as_slice());
    stop(router);
    stop(x_stale);
    stop(y);
}

/// The acceptance drill (ISSUE 10): 2 shards x 2 replicas; one
/// replica is killed mid-load. Every request keeps serving
/// byte-identical logits, the dead replica's breaker opens, and when
/// the worker restarts on its old address the supervisor reintegrates
/// it — no operator SWAP, no router restart — after which scatters
/// demonstrably reach it again.
#[test]
fn killed_replica_quarantines_then_reintegrates_without_an_operator() {
    let _g = fault::test_guard();
    fault::clear();
    let artifact = small_artifact(&small_params(240), "dense", 241);
    let metrics = Arc::new(Metrics::new());
    let a = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let b = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let c = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let d = start_server(&artifact, Arc::new(Metrics::new()), ExecCtx::single());
    let spec = format!("{}|{},{}|{}", a.0, b.0, c.0, d.0);
    let (router, group) =
        start_router_sup(&spec, ClientOptions::default(), fast_sup(), Arc::clone(&metrics));
    let mut client = NetClient::connect(router.0).unwrap();
    let (row, batch) = one_row_batch(242);
    let reference = direct_logits(&artifact, &row);

    for _ in 0..5 {
        assert_eq!(client.infer("m", batch.clone()).unwrap().row(0), reference.as_slice());
    }

    // Kill shard 0's primary mid-load.
    let a_addr = a.0;
    stop(a);
    for _ in 0..5 {
        assert_eq!(
            client.infer("m", batch.clone()).unwrap().row(0),
            reference.as_slice(),
            "every request during the outage still serves identical bytes"
        );
    }
    let snap = metrics.snapshot();
    assert!(snap.net_breaker_opens >= 1, "the dead replica's breaker opened");
    assert_eq!(snap.net_worker_unavailable, 0, "no request was lost");
    assert_eq!(snap.net_worker_swaps, 0, "no operator SWAP");

    // The worker restarts on its original address with the same
    // artifact; supervision ticks walk it cooldown -> half-open ->
    // artifact re-probe -> closed.
    let a2_metrics = Arc::new(Metrics::new());
    let a2 = start_server_at(a_addr, &artifact, Arc::clone(&a2_metrics));
    let mut reintegrated = false;
    for _ in 0..20 {
        std::thread::sleep(Duration::from_millis(60));
        group.supervise_tick();
        if metrics.snapshot().net_reintegrations >= 1 {
            reintegrated = true;
            break;
        }
    }
    assert!(reintegrated, "the replica rejoins without a SWAP or router restart");

    // Subsequent scatters actually reach the reintegrated primary.
    let base = a2_metrics.snapshot().net_requests;
    for _ in 0..3 {
        assert_eq!(client.infer("m", batch.clone()).unwrap().row(0), reference.as_slice());
    }
    assert!(
        a2_metrics.snapshot().net_requests >= base + 3,
        "scatters reach the reintegrated replica"
    );
    assert_eq!(metrics.snapshot().net_worker_swaps, 0, "still no operator action");
    stop(router);
    stop(a2);
    stop(b);
    stop(c);
    stop(d);
}
