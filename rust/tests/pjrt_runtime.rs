//! Integration: the PJRT runtime executes the AOT artifacts and agrees
//! with the native Rust oracles.
//!
//! Quarantine (ISSUE 1 triage): these tests need (a) `make artifacts`
//! — the HLO text emitted by `python/compile/aot.py`, which requires
//! JAX — and (b) real `xla` PJRT bindings rather than the vendored
//! `xla-stub` the crate builds against by default. Neither is present
//! in the hermetic build container, so each test probes the runtime
//! first and skips (pass, with a note on stderr) when the artifact
//! path cannot execute. The native oracles these tests compare
//! against are themselves covered by the pure-Rust suites.

use lrbi::nmf;
use lrbi::runtime::artifacts::{ArtifactSet, GEOMETRY, NMF_TILE};
use lrbi::runtime::client::{literal_matrix, literal_vec, matrix_literal, Runtime};
use lrbi::serve::engine::{InferenceBackend, MlpParams, NativeBackend};
use lrbi::tensor::Matrix;
use lrbi::train::data::SyntheticDigits;
use lrbi::train::loop_::{PjrtTrainer, TrainConfig};
use lrbi::util::bits::BitMatrix;
use lrbi::util::rng::Rng;

/// The PJRT runtime if the artifact path is runnable, else `None`
/// (missing artifacts, or built against the xla stub).
fn runtime() -> Option<Runtime> {
    let set = ArtifactSet::open("artifacts").ok()?;
    let mut rt = Runtime::new(set).ok()?;
    rt.load("predict").ok()?;
    Some(rt)
}

/// Standard skip message for the quarantined tests.
fn skip_note() {
    eprintln!("skipping: PJRT artifacts/bindings unavailable (see module docs)");
}

fn random_factors(seed: u64, density: f64) -> (Matrix, Matrix, BitMatrix, BitMatrix) {
    let g = GEOMETRY;
    let mut rng = Rng::new(seed);
    let ip_bits = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(density));
    let iz_bits = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(density));
    let ip = Matrix::from_vec(g.hidden0, g.rank, ip_bits.to_f32()).unwrap();
    let iz = Matrix::from_vec(g.rank, g.hidden1, iz_bits.to_f32()).unwrap();
    (ip, iz, ip_bits, iz_bits)
}

#[test]
fn decode_matmul_artifact_matches_native() {
    let Some(mut rt) = runtime() else {
        return skip_note();
    };
    let g = GEOMETRY;
    let mut rng = Rng::new(1);
    let (ip, iz, ip_bits, iz_bits) = random_factors(2, 0.3);
    let w = Matrix::gaussian(g.hidden0, g.hidden1, 0.0, 0.1, &mut rng);
    let x = Matrix::gaussian(g.batch, g.hidden0, 0.0, 1.0, &mut rng);
    let out = rt
        .execute(
            "decode_matmul",
            &[
                matrix_literal(&ip).unwrap(),
                matrix_literal(&iz).unwrap(),
                matrix_literal(&w).unwrap(),
                matrix_literal(&x).unwrap(),
            ],
        )
        .unwrap();
    let got = literal_matrix(&out[0], g.batch, g.hidden1).unwrap();
    // native oracle: y = x @ (w o mask)
    let mask = ip_bits.bool_product(&iz_bits);
    let mut wm = w.clone();
    for i in 0..wm.rows() {
        for j in 0..wm.cols() {
            if !mask.get(i, j) {
                wm.set(i, j, 0.0);
            }
        }
    }
    let want = x.matmul(&wm).unwrap();
    let mut max_rel = 0.0f64;
    for (a, b) in got.data().iter().zip(want.data()) {
        let rel = ((a - b).abs() / (b.abs() + 1e-3)) as f64;
        max_rel = max_rel.max(rel);
    }
    // 5e-3: the native oracle compiles with target-cpu=native (FMA
    // contraction), so its 800-term f32 dot products round differently
    // from XLA's accumulation order.
    assert!(max_rel < 5e-3, "decode_matmul mismatch: max rel err {max_rel}");
}

#[test]
fn nmf_step_artifact_matches_native_updates() {
    let Some(mut rt) = runtime() else {
        return skip_note();
    };
    let (m, n, k) = NMF_TILE;
    let mut rng = Rng::new(3);
    let v = Matrix::gaussian(m, n, 0.0, 1.0, &mut rng).abs();
    let w = Matrix::gaussian(m, k, 0.5, 0.1, &mut rng).abs();
    let h = Matrix::gaussian(k, n, 0.5, 0.1, &mut rng).abs();
    let out = rt
        .execute(
            "nmf_step",
            &[
                matrix_literal(&v).unwrap(),
                matrix_literal(&w).unwrap(),
                matrix_literal(&h).unwrap(),
            ],
        )
        .unwrap();
    let w2 = literal_matrix(&out[0], m, k).unwrap();
    let h2 = literal_matrix(&out[1], k, n).unwrap();
    // native oracle: H then W update
    let mut h_ref = h.clone();
    nmf::update_h(&v, &w, &mut h_ref).unwrap();
    let mut w_ref = w.clone();
    nmf::update_w(&v, &mut w_ref, &h_ref).unwrap();
    for (a, b) in h2.data().iter().zip(h_ref.data()) {
        assert!((a - b).abs() / (b.abs() + 1e-4) < 5e-3, "H mismatch {a} vs {b}");
    }
    for (a, b) in w2.data().iter().zip(w_ref.data()) {
        assert!((a - b).abs() / (b.abs() + 1e-4) < 5e-3, "W mismatch {a} vs {b}");
    }
    // and the objective must not increase
    let before = nmf::objective(&v, &w, &h).unwrap();
    let after = nmf::objective(&v, &w2, &h2).unwrap();
    assert!(after <= before * (1.0 + 1e-6), "objective rose {before} -> {after}");
}

#[test]
fn predict_artifact_matches_native_backend() {
    let Some(mut rt) = runtime() else {
        return skip_note();
    };
    let g = GEOMETRY;
    let params = MlpParams::init(4);
    let (ip, iz, ip_bits, iz_bits) = random_factors(5, 0.25);
    let mut rng = Rng::new(6);
    let x = Matrix::gaussian(g.batch, g.input_dim, 0.0, 1.0, &mut rng);
    let inputs = vec![
        matrix_literal(&params.w0).unwrap(),
        xla::Literal::vec1(&params.b0),
        matrix_literal(&params.w1).unwrap(),
        xla::Literal::vec1(&params.b1),
        matrix_literal(&params.w2).unwrap(),
        xla::Literal::vec1(&params.b2),
        matrix_literal(&ip).unwrap(),
        matrix_literal(&iz).unwrap(),
        matrix_literal(&x).unwrap(),
    ];
    let out = rt.execute("predict", &inputs).unwrap();
    let got = literal_matrix(&out[0], g.batch, g.classes).unwrap();
    let mut native = NativeBackend::new(params, &ip_bits, &iz_bits).unwrap();
    let want = native.predict(&x).unwrap();
    for (a, b) in got.data().iter().zip(want.data()) {
        assert!((a - b).abs() < 2e-3, "predict mismatch {a} vs {b}");
    }
}

#[test]
fn train_step_artifact_learns() {
    let Some(rt) = runtime() else {
        return skip_note();
    };
    let mut cfg = TrainConfig::default();
    cfg.batch = GEOMETRY.batch;
    cfg.lr = 0.1;
    let mut t = PjrtTrainer::new(rt, cfg).unwrap();
    let data = SyntheticDigits::default().generate(GEOMETRY.batch * 2);
    let (x, y) = data.batch(0, GEOMETRY.batch);
    let first = t.train_step(&x, &y).unwrap();
    let mut last = first;
    for _ in 0..25 {
        last = t.train_step(&x, &y).unwrap();
    }
    assert!(
        last < first * 0.5,
        "PJRT train_step failed to learn: {first} -> {last}"
    );
}

#[test]
fn train_step_respects_low_rank_mask() {
    let Some(rt) = runtime() else {
        return skip_note();
    };
    let cfg = TrainConfig { batch: GEOMETRY.batch, ..Default::default() };
    let mut t = PjrtTrainer::new(rt, cfg).unwrap();
    let data = SyntheticDigits::default().generate(GEOMETRY.batch);
    let (x, y) = data.batch(0, GEOMETRY.batch);
    // sparse factors -> mask; pruned entries of w1 must stay EXACTLY fixed
    let (ip, iz, ip_bits, iz_bits) = random_factors(7, 0.2);
    t.ip = ip;
    t.iz = iz;
    let mask = ip_bits.bool_product(&iz_bits);
    let before = t.params.w1.clone();
    for _ in 0..3 {
        t.train_step(&x, &y).unwrap();
    }
    let mut moved_pruned = 0;
    let mut moved_kept = 0;
    for i in 0..mask.rows() {
        for j in 0..mask.cols() {
            let changed = (t.params.w1.get(i, j) - before.get(i, j)).abs() > 0.0;
            if mask.get(i, j) {
                moved_kept += usize::from(changed);
            } else {
                moved_pruned += usize::from(changed);
            }
        }
    }
    assert_eq!(moved_pruned, 0, "pruned weights must not receive gradient");
    assert!(moved_kept > 0, "kept weights should update");
}

#[test]
fn pjrt_and_native_trainers_agree_on_first_loss() {
    // Same init seed, same batch: the artifact's loss and the native
    // backprop's loss must agree to float tolerance — a cross-layer
    // equivalence check of the ENTIRE L1+L2 lowering vs the L3 oracle.
    use lrbi::train::loop_::NativeTrainer;
    let cfg = TrainConfig { batch: GEOMETRY.batch, seed: 33, lr: 0.1, ..Default::default() };
    let data = SyntheticDigits::default().generate(GEOMETRY.batch);
    let (x, y) = data.batch(0, GEOMETRY.batch);

    let mut native = NativeTrainer::new(cfg.clone());
    let Some(rt) = runtime() else {
        return skip_note();
    };
    let mut pjrt = PjrtTrainer::new(rt, cfg).unwrap();
    // force identical initial parameters
    pjrt.params = native.params.clone();
    let l_native = native.train_step(&x, &y).unwrap();
    let l_pjrt = pjrt.train_step(&x, &y).unwrap();
    assert!(
        (l_native - l_pjrt).abs() < 1e-3,
        "losses diverge: native {l_native} vs pjrt {l_pjrt}"
    );
    // one more step: parameters evolved identically enough
    let l2_native = native.train_step(&x, &y).unwrap();
    let l2_pjrt = pjrt.train_step(&x, &y).unwrap();
    assert!(
        (l2_native - l2_pjrt).abs() < 5e-3,
        "step-2 losses diverge: {l2_native} vs {l2_pjrt}"
    );
}
