//! Cross-format kernel equivalence: every [`SparseKernel`]
//! implementation must produce the same masked-layer output (and the
//! same serving logits) for the same mask and weights, within f32
//! tolerance — the contract that lets the engine pick its execution
//! strategy by format at startup.
//!
//! Parallel determinism: each kernel's execution plan must produce
//! **bit-identical** output at every thread count (fixed shard
//! partition + fixed shard→merge order) — pinned here for all six
//! factor formats plus the tiled kernel. Viterbi is *mask-shaping*
//! (it serves the nearest convolutional-code-representable mask, not
//! the exact `I_p ⊗ I_z` product), so equivalence tests compare it
//! against a dense oracle over its own decoded mask; the other five
//! formats are mask-exact. `LRBI_THREADS` (used by the
//! CI smoke matrix and `scripts/verify.sh`) selects the pooled thread
//! count for `threads_env_smoke`; `LRBI_SIMD` (`off`/`0`/`scalar`
//! pins the scalar micro-kernels) is exercised the same way by the CI
//! `simd-matrix` job, with in-process SIMD-vs-scalar byte identity
//! pinned by `simd_and_scalar_spmm_byte_identical`.

use lrbi::coordinator::pool::ExecCtx;
use lrbi::formats::StoredIndex;
use lrbi::serve::engine::{InferenceBackend, MlpParams, NativeBackend};
use lrbi::serve::kernels::{
    build_kernel, build_kernel_exec, build_kernel_from_stored_exec, KernelFormat, SparseKernel,
};
use lrbi::tensor::simd;
use lrbi::tensor::Matrix;
use lrbi::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
use lrbi::util::bits::BitMatrix;
use lrbi::util::prop;
use lrbi::util::rng::Rng;

/// Dense oracle: `x · (W ⊙ (I_p ⊗ I_z))` via the pruning-path helper.
fn reference(w: &Matrix, ip: &BitMatrix, iz: &BitMatrix, x: &Matrix) -> Matrix {
    let wm = lrbi::pruning::prune_with_mask(w, &ip.bool_product(iz)).unwrap();
    x.matmul(&wm).unwrap()
}

/// Dense oracle over the mask the Viterbi encoder actually serves
/// (the shaped approximation of `I_p ⊗ I_z`, not the exact product).
fn viterbi_reference(w: &Matrix, ip: &BitMatrix, iz: &BitMatrix, x: &Matrix) -> Matrix {
    let mask = lrbi::formats::viterbi::ViterbiIndex::shape_mask(&ip.bool_product(iz)).decode();
    let wm = lrbi::pruning::prune_with_mask(w, &mask).unwrap();
    x.matmul(&wm).unwrap()
}

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-3 * (1.0 + b.abs())
}

#[test]
fn kernels_agree_with_dense_reference() {
    prop::check("kernel cross-format equivalence", 12, |rng| {
        let m = prop::dim(rng, 1, 90);
        let n = prop::dim(rng, 1, 150);
        let k = prop::dim(rng, 1, 8);
        let batch = prop::dim(rng, 1, 6);
        let dp = rng.next_f64() * 0.5;
        let dz = rng.next_f64() * 0.5;
        let mut r2 = Rng::new(rng.next_u64());
        let ip = BitMatrix::from_fn(m, k, |_, _| r2.bernoulli(dp));
        let iz = BitMatrix::from_fn(k, n, |_, _| r2.bernoulli(dz));
        let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut r2);
        let x = Matrix::gaussian(batch, m, 0.0, 1.0, &mut r2);
        let want = reference(&w, &ip, &iz, &x);
        let want_vit = viterbi_reference(&w, &ip, &iz, &x);
        for fmt in KernelFormat::ALL {
            let kernel = build_kernel(fmt, &w, &ip, &iz, None).unwrap();
            let got = kernel.spmm(&x).unwrap();
            assert_eq!((got.rows(), got.cols()), (batch, n), "{}", fmt.name());
            let oracle = if fmt == KernelFormat::Viterbi { &want_vit } else { &want };
            for (a, b) in got.data().iter().zip(oracle.data()) {
                assert!(
                    close(*a, *b),
                    "{} at m={m} n={n} k={k}: {a} vs {b}",
                    fmt.name()
                );
            }
        }
    });
}

#[test]
fn kernels_agree_on_degenerate_masks() {
    let mut rng = Rng::new(5);
    let w = Matrix::gaussian(40, 70, 0.0, 1.0, &mut rng);
    let x = Matrix::gaussian(3, 40, 0.0, 1.0, &mut rng);
    // all-zero mask (everything pruned) and all-ones mask (nothing pruned)
    let cases = [
        (BitMatrix::zeros(40, 4), BitMatrix::zeros(4, 70)),
        (
            BitMatrix::from_fn(40, 4, |_, _| true),
            BitMatrix::from_fn(4, 70, |_, _| true),
        ),
    ];
    for (ip, iz) in &cases {
        let want = reference(&w, ip, iz, &x);
        // The all-zero mask is exactly Viterbi-representable (the
        // all-zero input stream emits it); the all-ones mask is not,
        // so Viterbi compares against its own shaped mask instead.
        let want_vit = viterbi_reference(&w, ip, iz, &x);
        for fmt in KernelFormat::ALL {
            let kernel = build_kernel(fmt, &w, ip, iz, None).unwrap();
            let got = kernel.spmm(&x).unwrap();
            let oracle = if fmt == KernelFormat::Viterbi { &want_vit } else { &want };
            for (a, b) in got.data().iter().zip(oracle.data()) {
                assert!(close(*a, *b), "{}: {a} vs {b}", fmt.name());
            }
        }
    }
}

/// A random tiled low-rank index over an `m × n` layer (2×3 plan,
/// mixed per-tile ranks) — the fifth kernel of the determinism sweep.
fn random_tiled(m: usize, n: usize, rng: &mut Rng) -> TiledLowRankIndex {
    let plan = TilePlan::new(2.min(m), 3.min(n));
    let specs = plan.tiles(m, n).unwrap();
    let tiles: Vec<TileFactors> = specs
        .iter()
        .map(|s| {
            let k = 2 + s.id % 3;
            TileFactors {
                rank: k,
                ip: BitMatrix::from_fn(s.rows(), k, |_, _| rng.bernoulli(0.3)),
                iz: BitMatrix::from_fn(k, s.cols(), |_, _| rng.bernoulli(0.3)),
            }
        })
        .collect();
    TiledLowRankIndex::new(m, n, plan, tiles).unwrap()
}

#[test]
fn parallel_spmm_bit_identical_across_thread_counts() {
    prop::check("spmm thread determinism", 6, |rng| {
        let m = prop::dim(rng, 20, 220);
        let n = prop::dim(rng, 12, 180);
        let k = prop::dim(rng, 1, 8);
        let batch = prop::dim(rng, 1, 5);
        let dp = 0.1 + rng.next_f64() * 0.4;
        let dz = 0.1 + rng.next_f64() * 0.4;
        let mut r2 = Rng::new(rng.next_u64());
        let ip = BitMatrix::from_fn(m, k, |_, _| r2.bernoulli(dp));
        let iz = BitMatrix::from_fn(k, n, |_, _| r2.bernoulli(dz));
        let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut r2);
        let x = Matrix::gaussian(batch, m, 0.0, 1.0, &mut r2);
        // all six factor formats
        for fmt in KernelFormat::ALL {
            let base = build_kernel(fmt, &w, &ip, &iz, None)
                .unwrap()
                .spmm(&x)
                .unwrap();
            for threads in [2usize, 8] {
                let ctx = ExecCtx::new(threads, None);
                let kern = build_kernel_exec(fmt, &w, &ip, &iz, &ctx, None).unwrap();
                assert_eq!(
                    kern.spmm(&x).unwrap().data(),
                    base.data(),
                    "{} at m={m} n={n} k={k} threads={threads}",
                    fmt.name()
                );
            }
        }
        // the tiled kernel (only constructible from a stored index)
        let stored = StoredIndex::Tiled(random_tiled(m, n, &mut r2));
        let base = build_kernel_from_stored_exec(&stored, &w, &ExecCtx::single(), None)
            .unwrap()
            .spmm(&x)
            .unwrap();
        for threads in [2usize, 8] {
            let ctx = ExecCtx::new(threads, None);
            let kern = build_kernel_from_stored_exec(&stored, &w, &ctx, None).unwrap();
            assert_eq!(
                kern.spmm(&x).unwrap().data(),
                base.data(),
                "tiled at m={m} n={n} threads={threads}"
            );
        }
    });
}

/// SIMD/scalar bit-identity: all seven kernels × threads {1, 4} must
/// produce byte-identical spmm output with the vector micro-kernels
/// dispatched and with the scalar tier pinned. `force_scalar` is a
/// process-global toggle and this suite is its only writer; because
/// the invariant under test *is* byte-identity across tiers, another
/// test observing a mid-toggle tier cannot be affected unless the
/// invariant itself is broken (in which case some test fails, which
/// is the point). On hardware without AVX2/NEON both runs take the
/// scalar path and the comparison is trivially exact; the CI
/// `simd-matrix` job additionally runs this whole suite under
/// `LRBI_SIMD=off` and `on`.
#[test]
fn simd_and_scalar_spmm_byte_identical() {
    let mut rng = Rng::new(88);
    let (m, n, k) = (210, 190, 6);
    let ip = BitMatrix::from_fn(m, k, |_, _| rng.bernoulli(0.35));
    let iz = BitMatrix::from_fn(k, n, |_, _| rng.bernoulli(0.35));
    let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut rng);
    let stored_tiled = StoredIndex::Tiled(random_tiled(m, n, &mut rng));
    // batch 9 exercises both full vector lanes and remainder lanes
    for batch in [1usize, 9] {
        let x = Matrix::gaussian(batch, m, 0.0, 1.0, &mut rng);
        for threads in [1usize, 4] {
            let ctx = ExecCtx::new(threads, None);
            let mut kernels: Vec<Box<dyn SparseKernel>> = KernelFormat::ALL
                .iter()
                .map(|&fmt| build_kernel_exec(fmt, &w, &ip, &iz, &ctx, None).unwrap())
                .collect();
            kernels.push(build_kernel_from_stored_exec(&stored_tiled, &w, &ctx, None).unwrap());
            for kern in &kernels {
                simd::force_scalar(true);
                let scalar = kern.spmm(&x).unwrap();
                simd::force_scalar(false);
                let auto = kern.spmm(&x).unwrap();
                assert_eq!(
                    auto.data(),
                    scalar.data(),
                    "{} batch={batch} threads={threads} tier={:?}",
                    kern.name(),
                    simd::probed_tier()
                );
            }
        }
    }
    simd::force_scalar(false);
}

/// The `LRBI_SIMD` env knob (mirroring `LRBI_THREADS`): when CI pins
/// `off`/`0`/`scalar`, the probe must resolve to the scalar tier.
#[test]
fn lrbi_simd_env_off_pins_scalar_tier() {
    let pinned = matches!(
        std::env::var("LRBI_SIMD").map(|v| v.to_ascii_lowercase()).as_deref(),
        Ok("off") | Ok("0") | Ok("scalar")
    );
    if pinned {
        assert_eq!(simd::probed_tier(), simd::SimdTier::Scalar);
        assert_eq!(simd::tier(), simd::SimdTier::Scalar);
    }
}

#[test]
fn threads_env_smoke() {
    // CI smoke matrix: LRBI_THREADS ∈ {1, 4} (see
    // .github/workflows/verify.yml); defaults to 2 when unset.
    let threads: usize = std::env::var("LRBI_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let mut rng = Rng::new(77);
    let (m, n, k) = (310, 270, 6);
    let ip = BitMatrix::from_fn(m, k, |_, _| rng.bernoulli(0.3));
    let iz = BitMatrix::from_fn(k, n, |_, _| rng.bernoulli(0.3));
    let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut rng);
    let x = Matrix::gaussian(3, m, 0.0, 1.0, &mut rng);
    let ctx = ExecCtx::new(threads, None);
    for fmt in KernelFormat::ALL {
        let single = build_kernel(fmt, &w, &ip, &iz, None).unwrap();
        let pooled = build_kernel_exec(fmt, &w, &ip, &iz, &ctx, None).unwrap();
        assert!(
            pooled.plan_shards() > 1,
            "{}: a {m}x{n} layer must shard (got {})",
            fmt.name(),
            pooled.plan_shards()
        );
        assert_eq!(
            pooled.spmm(&x).unwrap().data(),
            single.spmm(&x).unwrap().data(),
            "{} with LRBI_THREADS={threads}",
            fmt.name()
        );
    }
}

#[test]
fn full_serving_logits_identical_across_formats() {
    let params = MlpParams::init(31);
    let g = lrbi::runtime::artifacts::GEOMETRY;
    let mut rng = Rng::new(32);
    let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.2));
    let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.2));
    let x = Matrix::gaussian(g.batch, g.input_dim, 0.0, 1.0, &mut rng);
    let mut want: Option<Matrix> = None;
    for fmt in KernelFormat::ALL {
        let mut backend = NativeBackend::with_format(params.clone(), fmt, &ip, &iz).unwrap();
        let got = backend.predict(&x).unwrap();
        if fmt == KernelFormat::Viterbi {
            // Mask-shaping format: serve logits must match a dense
            // backend over the same shaped mask, not the exact-mask
            // baseline the other formats share.
            let mask =
                lrbi::formats::viterbi::ViterbiIndex::shape_mask(&ip.bool_product(&iz)).decode();
            let mut shaped = NativeBackend::with_mask(params.clone(), &mask).unwrap();
            let base = shaped.predict(&x).unwrap();
            for (a, b) in got.data().iter().zip(base.data()) {
                assert!(close(*a, *b), "viterbi vs shaped-mask oracle: {a} vs {b}");
            }
            continue;
        }
        match &want {
            None => want = Some(got),
            Some(base) => {
                for (a, b) in got.data().iter().zip(base.data()) {
                    assert!(close(*a, *b), "{}: {a} vs {b}", fmt.name());
                }
            }
        }
    }
}
