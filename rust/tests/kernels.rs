//! Cross-format kernel equivalence: every [`SparseKernel`]
//! implementation must produce the same masked-layer output (and the
//! same serving logits) for the same mask and weights, within f32
//! tolerance — the contract that lets the engine pick its execution
//! strategy by format at startup.

use lrbi::serve::engine::{InferenceBackend, MlpParams, NativeBackend};
use lrbi::serve::kernels::{build_kernel, KernelFormat};
use lrbi::tensor::Matrix;
use lrbi::util::bits::BitMatrix;
use lrbi::util::prop;
use lrbi::util::rng::Rng;

/// Dense oracle: `x · (W ⊙ (I_p ⊗ I_z))` via the pruning-path helper.
fn reference(w: &Matrix, ip: &BitMatrix, iz: &BitMatrix, x: &Matrix) -> Matrix {
    let wm = lrbi::pruning::prune_with_mask(w, &ip.bool_product(iz)).unwrap();
    x.matmul(&wm).unwrap()
}

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-3 * (1.0 + b.abs())
}

#[test]
fn kernels_agree_with_dense_reference() {
    prop::check("kernel cross-format equivalence", 12, |rng| {
        let m = prop::dim(rng, 1, 90);
        let n = prop::dim(rng, 1, 150);
        let k = prop::dim(rng, 1, 8);
        let batch = prop::dim(rng, 1, 6);
        let dp = rng.next_f64() * 0.5;
        let dz = rng.next_f64() * 0.5;
        let mut r2 = Rng::new(rng.next_u64());
        let ip = BitMatrix::from_fn(m, k, |_, _| r2.bernoulli(dp));
        let iz = BitMatrix::from_fn(k, n, |_, _| r2.bernoulli(dz));
        let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut r2);
        let x = Matrix::gaussian(batch, m, 0.0, 1.0, &mut r2);
        let want = reference(&w, &ip, &iz, &x);
        for fmt in KernelFormat::ALL {
            let kernel = build_kernel(fmt, &w, &ip, &iz, None).unwrap();
            let got = kernel.spmm(&x).unwrap();
            assert_eq!((got.rows(), got.cols()), (batch, n), "{}", fmt.name());
            for (a, b) in got.data().iter().zip(want.data()) {
                assert!(
                    close(*a, *b),
                    "{} at m={m} n={n} k={k}: {a} vs {b}",
                    fmt.name()
                );
            }
        }
    });
}

#[test]
fn kernels_agree_on_degenerate_masks() {
    let mut rng = Rng::new(5);
    let w = Matrix::gaussian(40, 70, 0.0, 1.0, &mut rng);
    let x = Matrix::gaussian(3, 40, 0.0, 1.0, &mut rng);
    // all-zero mask (everything pruned) and all-ones mask (nothing pruned)
    let cases = [
        (BitMatrix::zeros(40, 4), BitMatrix::zeros(4, 70)),
        (
            BitMatrix::from_fn(40, 4, |_, _| true),
            BitMatrix::from_fn(4, 70, |_, _| true),
        ),
    ];
    for (ip, iz) in &cases {
        let want = reference(&w, ip, iz, &x);
        for fmt in KernelFormat::ALL {
            let kernel = build_kernel(fmt, &w, ip, iz, None).unwrap();
            let got = kernel.spmm(&x).unwrap();
            for (a, b) in got.data().iter().zip(want.data()) {
                assert!(close(*a, *b), "{}: {a} vs {b}", fmt.name());
            }
        }
    }
}

#[test]
fn full_serving_logits_identical_across_formats() {
    let params = MlpParams::init(31);
    let g = lrbi::runtime::artifacts::GEOMETRY;
    let mut rng = Rng::new(32);
    let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.2));
    let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.2));
    let x = Matrix::gaussian(g.batch, g.input_dim, 0.0, 1.0, &mut rng);
    let mut want: Option<Matrix> = None;
    for fmt in KernelFormat::ALL {
        let mut backend = NativeBackend::with_format(params.clone(), fmt, &ip, &iz).unwrap();
        let got = backend.predict(&x).unwrap();
        match &want {
            None => want = Some(got),
            Some(base) => {
                for (a, b) in got.data().iter().zip(base.data()) {
                    assert!(close(*a, *b), "{}: {a} vs {b}", fmt.name());
                }
            }
        }
    }
}
