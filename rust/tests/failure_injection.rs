//! Failure injection: the coordinator and serving stack must degrade
//! loudly, not silently.

use lrbi::coordinator::pool::{parallel_map, WorkerPool};
use lrbi::runtime::artifacts::ArtifactSet;
use lrbi::serve::batcher::BatchPolicy;
use lrbi::serve::engine::ServingEngine;
use lrbi::coordinator::metrics::Metrics;
use lrbi::util::error::Error;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

#[test]
fn pool_survives_panicking_job() {
    let pool = WorkerPool::new(2, 8);
    let done = Arc::new(AtomicU64::new(0));
    // a panicking job must not take the pool down (the panic unwinds
    // the worker's job closure; subsequent jobs still run because the
    // panic is confined to the closure call)
    let _ = pool.submit(|| {
        let result = std::panic::catch_unwind(|| panic!("injected"));
        assert!(result.is_err());
    });
    for _ in 0..10 {
        let d = Arc::clone(&done);
        pool.submit(move || {
            d.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
    }
    drop(pool);
    assert_eq!(done.load(Ordering::Relaxed), 10);
}

#[test]
fn parallel_map_propagates_errors_as_values() {
    let items: Vec<u32> = (0..20).collect();
    let results: Vec<Result<u32, String>> = parallel_map(&items, 4, |&x| {
        if x == 13 {
            Err("unlucky".to_string())
        } else {
            Ok(x)
        }
    });
    assert_eq!(results.iter().filter(|r| r.is_err()).count(), 1);
    assert!(results[13].is_err());
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("lrbi_corrupt_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // manifest referencing files that don't exist
    std::fs::write(
        dir.join("manifest.txt"),
        "train_step inputs=11 in_shapes=1 sha256=x bytes=1\n\
         predict inputs=9 in_shapes=1 sha256=x bytes=1\n\
         decode_matmul inputs=4 in_shapes=1 sha256=x bytes=1\n\
         nmf_step inputs=3 in_shapes=1 sha256=x bytes=1\n",
    )
    .unwrap();
    let err = ArtifactSet::open(&dir).unwrap_err();
    assert!(err.to_string().contains("not found"), "{err}");
    // malformed manifest line
    std::fs::write(dir.join("manifest.txt"), "what even is this\n").unwrap();
    assert!(ArtifactSet::open(&dir).is_err());
}

#[test]
fn engine_factory_failure_answers_all_requests_with_error() {
    struct Never;
    impl lrbi::serve::engine::InferenceBackend for Never {
        fn batch(&self) -> usize {
            1
        }
        fn input_dim(&self) -> usize {
            1
        }
        fn classes(&self) -> usize {
            1
        }
        fn predict(&mut self, _x: &lrbi::tensor::Matrix) -> lrbi::Result<lrbi::tensor::Matrix> {
            unreachable!()
        }
    }
    let engine = ServingEngine::start_with(
        || -> lrbi::Result<Never> { Err(Error::Runtime("backend exploded".into())) },
        BatchPolicy::default(),
        Arc::new(Metrics::new()),
    );
    let r = engine.infer(vec![1.0]);
    assert!(r.is_err());
    assert!(r.unwrap_err().to_string().contains("backend exploded"));
}

#[test]
fn wrong_input_count_rejected_by_runtime() {
    // only runs when artifacts exist (they do under `make test`)
    if let Ok(set) = ArtifactSet::open("artifacts") {
        let mut rt = lrbi::runtime::client::Runtime::new(set).unwrap();
        match rt.execute("predict", &[]) {
            Ok(_) => panic!("expected an input-count error"),
            Err(err) => {
                // real bindings: input-count validation; xla stub:
                // compilation is the step that reports unavailability
                let msg = err.to_string();
                assert!(
                    msg.contains("expected 9 inputs") || msg.contains("stub"),
                    "{err}"
                )
            }
        }
    }
}
