//! Serving integration: compressed model behind the dynamic batcher,
//! PJRT backend (artifact path) under concurrent load, and the
//! zero-allocation steady state (batches 2..N must be served entirely
//! from pooled/persistent buffers).

use lrbi::coordinator::metrics::Metrics;
use lrbi::coordinator::pool::ExecCtx;
use lrbi::coordinator::telemetry::Stage;
use lrbi::runtime::artifacts::{ArtifactSet, GEOMETRY};
use lrbi::runtime::client::Runtime;
use lrbi::serve::batcher::BatchPolicy;
use lrbi::serve::engine::{MlpParams, NativeBackend, PjrtBackend, ServingEngine};
use lrbi::serve::kernels::{KernelFormat, SparseKernel};
use lrbi::tensor::Matrix;
use lrbi::util::bits::BitMatrix;
use lrbi::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn sparse_factors(seed: u64) -> (BitMatrix, BitMatrix) {
    let g = GEOMETRY;
    let mut rng = Rng::new(seed);
    (
        BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.25)),
        BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.25)),
    )
}

#[test]
fn native_engine_under_concurrent_load() {
    let params = MlpParams::init(20);
    let (ip, iz) = sparse_factors(21);
    let backend = NativeBackend::new(params, &ip, &iz).unwrap();
    let metrics = Arc::new(Metrics::new());
    let engine = ServingEngine::start(
        backend,
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) },
        Arc::clone(&metrics),
    );
    let client = engine.client();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let c = client.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(30 + t);
                for _ in 0..64 {
                    let x: Vec<f32> =
                        (0..GEOMETRY.input_dim).map(|_| rng.next_f32()).collect();
                    let (logits, stages) = c.call(x).unwrap().unwrap();
                    assert_eq!(logits.len(), GEOMETRY.classes);
                    assert!(stages.spmm > 0, "every served row carries its spmm timing");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.requests, 256);
    assert!(snap.mean_batch_size() > 1.0, "batcher never batched");
}

/// Acceptance criterion (ISSUE 5): after the first flush has sized
/// every pooled buffer, the serving hot path allocates nothing —
/// `spmm_alloc_bytes` goes flat while `scratch_reuse` and
/// `batch_buffer_reuse` keep climbing. Exercised for a reduction-shard
/// kernel (lowrank: pooled partials) and for the relative kernel
/// (pooled partials + the SIMD input transpose when a vector tier is
/// active).
#[test]
fn steady_state_serving_allocates_nothing_on_the_spmm_hot_path() {
    for format in [KernelFormat::LowRankFused, KernelFormat::Relative] {
        let params = MlpParams::init(60);
        let (ip, iz) = {
            let g = GEOMETRY;
            let mut rng = Rng::new(61);
            (
                BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.3)),
                BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.3)),
            )
        };
        let metrics = Arc::new(Metrics::new());
        // threads 2 ⇒ the plans actually fan out; the ctx carries the
        // metrics so scratch checkouts are observable.
        let ctx = ExecCtx::new(2, Some(Arc::clone(&metrics)));
        let backend =
            NativeBackend::with_format_exec(params, format, &ip, &iz, ctx).unwrap();
        assert!(backend.kernel().plan_shards() > 1, "plan must shard for this test");
        let engine = ServingEngine::start(
            backend,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            Arc::clone(&metrics),
        );
        // warm-up flush: sizes every pooled buffer (and may allocate)
        engine.infer(vec![0.5; GEOMETRY.input_dim]).unwrap();
        let warm = metrics.snapshot();
        assert!(
            warm.spmm_alloc_bytes > 0,
            "{}: the first flush must have gone through the scratch pool",
            format.name()
        );
        for i in 0..10 {
            engine.infer(vec![0.01 * i as f32; GEOMETRY.input_dim]).unwrap();
        }
        let snap = metrics.snapshot();
        assert_eq!(
            snap.spmm_alloc_bytes, warm.spmm_alloc_bytes,
            "{}: batches 2..N allocated on the hot path",
            format.name()
        );
        assert!(
            snap.scratch_reuse > warm.scratch_reuse,
            "{}: steady-state flushes must reuse pooled scratch",
            format.name()
        );
        assert!(
            snap.batch_buffer_reuse >= 10,
            "{}: every steady-state flush must recycle the request buffer (got {})",
            format.name(),
            snap.batch_buffer_reuse
        );
        assert_eq!(snap.batch_flush_count, 11);
        // ISSUE 7: the telemetry histograms were recording the whole
        // time (lock-free fetch_adds into preallocated buckets) and the
        // hot path still allocated nothing after warm-up.
        assert_eq!(
            metrics.telemetry.stage(Stage::Queue).count(),
            11,
            "{}: every request's queue wait must land in the stage histogram",
            format.name()
        );
        assert!(
            metrics.telemetry.stage(Stage::Spmm).count() >= 1
                && metrics.telemetry.stage(Stage::Spmm).sum() > 0,
            "{}: spmm stage timings must record while staying allocation-free",
            format.name()
        );
    }
}

#[test]
fn pjrt_engine_matches_native_logits() {
    // Quarantine (ISSUE 1 triage): the PJRT path needs `make artifacts`
    // (JAX) and real xla bindings, not the vendored stub — probe first
    // and skip when it cannot execute. The same logits equivalence is
    // covered natively across all sparse kernels in tests/kernels.rs.
    {
        let Ok(set) = ArtifactSet::open("artifacts") else {
            return eprintln!("skipping: artifacts not present");
        };
        let Ok(mut probe) = Runtime::new(set) else {
            return eprintln!("skipping: PJRT client unavailable");
        };
        if probe.load("predict").is_err() {
            return eprintln!("skipping: PJRT compilation unavailable (xla stub)");
        }
    }
    let params = MlpParams::init(22);
    let (ip_bits, iz_bits) = sparse_factors(23);
    let g = GEOMETRY;
    let ip = Matrix::from_vec(g.hidden0, g.rank, ip_bits.to_f32()).unwrap();
    let iz = Matrix::from_vec(g.rank, g.hidden1, iz_bits.to_f32()).unwrap();

    // PJRT backend built inside the serving thread (it is !Send)
    let params_for_pjrt = params.clone();
    let metrics = Arc::new(Metrics::new());
    let engine = ServingEngine::start_with(
        move || {
            let set = ArtifactSet::open("artifacts")?;
            let rt = Runtime::new(set)?;
            PjrtBackend::new(rt, &params_for_pjrt, &ip, &iz)
        },
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(1) },
        Arc::clone(&metrics),
    );

    use lrbi::serve::engine::InferenceBackend;
    let mut native = NativeBackend::new(params, &ip_bits, &iz_bits).unwrap();
    let mut rng = Rng::new(24);
    for _ in 0..4 {
        let x: Vec<f32> = (0..g.input_dim).map(|_| rng.next_f32() - 0.5).collect();
        let got = engine.infer(x.clone()).unwrap();
        let mut xm = Matrix::zeros(g.batch, g.input_dim);
        for (j, &v) in x.iter().enumerate() {
            xm.set(0, j, v);
        }
        let want = native.predict(&xm).unwrap();
        for (a, b) in got.iter().zip(want.row(0)) {
            assert!((a - b).abs() < 2e-3, "pjrt {a} vs native {b}");
        }
    }
    assert!(metrics.snapshot().requests >= 4);
}
