//! Cross-process router/worker cluster tests (see `docs/CLUSTER.md`):
//! a router scatters each INFER's rows to worker servers that each
//! serve a contiguous slice of output columns, gathers the `PARTIAL`
//! replies in fixed shard order, and must hand back logits
//! **byte-identical** to a single-process `NativeBackend` — for every
//! kernel format, at shard counts {1, 2, 4}, with worker thread pools
//! of {1, 4}, and straight through a coordinated rolling `SWAP`. Also
//! pins model-key routing across two worker fleets and the typed
//! `unknown-model` error for a key the router does not serve.

use lrbi::coordinator::metrics::Metrics;
use lrbi::coordinator::pool::ExecCtx;
use lrbi::formats::StoredIndex;
use lrbi::serve::batcher::BatchPolicy;
use lrbi::serve::engine::{InferenceBackend, MlpParams, NativeBackend};
use lrbi::serve::protocol::{ErrorCode, Frame, RowBatch};
use lrbi::serve::router::ShardGroup;
use lrbi::serve::server::{ClientOptions, ModelHub, NetClient, ServeOptions, Server};
use lrbi::store::{Artifact, ArtifactMeta, Registry};
use lrbi::tensor::Matrix;
use lrbi::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
use lrbi::util::bits::BitMatrix;
use lrbi::util::error::Result;
use lrbi::util::prop;
use lrbi::util::rng::Rng;
use std::net::SocketAddr;
use std::sync::Arc;
use std::thread::JoinHandle;

// ---------------------------------------------------------------- helpers

/// Small model (6 → 20 → 30 → 4) so a whole cluster boots in
/// milliseconds; 4 output columns means 4 shards degrade to one
/// column per worker — the extreme split.
fn small_params(seed: u64) -> MlpParams {
    let mut rng = Rng::new(seed);
    MlpParams {
        w0: Matrix::gaussian(6, 20, 0.0, 0.5, &mut rng),
        b0: vec![0.1; 20],
        w1: Matrix::gaussian(20, 30, 0.0, 0.5, &mut rng),
        b1: vec![0.2; 30],
        w2: Matrix::gaussian(30, 4, 0.0, 0.5, &mut rng),
        b2: vec![0.0; 4],
    }
}

fn small_artifact(params: &MlpParams, format: &str, seed: u64) -> Artifact {
    let mut rng = Rng::new(seed);
    let ip = BitMatrix::from_fn(20, 4, |_, _| rng.bernoulli(0.3));
    let iz = BitMatrix::from_fn(4, 30, |_, _| rng.bernoulli(0.3));
    Artifact::pack_factors(params.clone(), format, &ip, &iz, "cluster test").unwrap()
}

fn tiled_artifact(params: &MlpParams, seed: u64) -> Artifact {
    let (m, n) = (params.w1.rows(), params.w1.cols());
    let plan = TilePlan::new(2, 3);
    let mut rng = Rng::new(seed);
    let tiles: Vec<TileFactors> = plan
        .tiles(m, n)
        .unwrap()
        .iter()
        .map(|s| {
            let k = 3 + s.id % 2;
            TileFactors {
                rank: k,
                ip: BitMatrix::from_fn(s.rows(), k, |_, _| rng.bernoulli(0.3)),
                iz: BitMatrix::from_fn(k, s.cols(), |_, _| rng.bernoulli(0.3)),
            }
        })
        .collect();
    Artifact {
        params: params.clone(),
        index: StoredIndex::Tiled(TiledLowRankIndex::new(m, n, plan, tiles).unwrap()),
        meta: ArtifactMeta { sparsity: 0.0, cost: 0.0, rank: 0, provenance: "cluster test".into() },
    }
}

/// The full kernel-format matrix the repo's bit-identity contract
/// covers: six packable formats plus the tiled artifact path.
fn all_format_artifacts(params: &MlpParams, seed: u64) -> Vec<Artifact> {
    let mut artifacts = vec![tiled_artifact(params, seed)];
    for format in ["dense", "csr", "relative", "lowrank", "viterbi", "dcsr"] {
        artifacts.push(small_artifact(params, format, seed + 1));
    }
    artifacts
}

type Running = (SocketAddr, lrbi::serve::server::ServerHandle, JoinHandle<Result<()>>);

/// Bind on an ephemeral port and run the server on its own thread.
fn start_server(hub: ModelHub, opts: &ServeOptions) -> Running {
    let server = Server::bind("127.0.0.1:0", Arc::new(hub), opts).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    (addr, handle, runner)
}

/// One worker: an ordinary wire server over `artifact` (key "m") with
/// an spmm plan pool of `threads` threads.
fn start_worker(artifact: &Artifact, threads: usize) -> Running {
    let metrics = Arc::new(Metrics::new());
    let ctx = ExecCtx::new(threads, Some(Arc::clone(&metrics)));
    let hub = ModelHub::from_artifact(
        "m",
        artifact,
        BatchPolicy::default(),
        64,
        metrics,
        ctx,
    )
    .unwrap();
    start_server(hub, &ServeOptions::default())
}

/// A router over one shard per worker address, asking workers for
/// model "m" and exposing it under the same key.
fn start_router(workers: &[SocketAddr], metrics: Arc<Metrics>) -> Running {
    let spec: Vec<String> = workers.iter().map(|a| a.to_string()).collect();
    let group = Arc::new(
        ShardGroup::connect(&spec.join(","), "m", ClientOptions::default(), metrics).unwrap(),
    );
    assert_eq!(group.shard_count(), workers.len());
    start_server(ModelHub::from_remote("m", group), &ServeOptions::default())
}

fn stop((_, handle, runner): Running) {
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

fn random_row(rng: &mut Rng, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

// ------------------------------------------------ bit-identity test matrix

/// The headline contract: for every kernel format × shard count
/// {1, 2, 4} × worker thread pool {1, 4}, logits served through the
/// router are byte-identical to a direct in-process `NativeBackend`
/// over the same artifact. With 4 output columns, 4 shards means each
/// worker contributes exactly one column.
#[test]
fn router_logits_bit_identical_for_every_format_shard_count_and_thread_pool() {
    let params = small_params(100);
    for artifact in all_format_artifacts(&params, 101) {
        let format = artifact.index.format_name();
        let mut direct = NativeBackend::from_artifact(&artifact).unwrap();
        for threads in [1usize, 4] {
            for shard_count in [1usize, 2, 4] {
                let workers: Vec<Running> =
                    (0..shard_count).map(|_| start_worker(&artifact, threads)).collect();
                let worker_addrs: Vec<SocketAddr> = workers.iter().map(|w| w.0).collect();
                let router_metrics = Arc::new(Metrics::new());
                let router = start_router(&worker_addrs, Arc::clone(&router_metrics));

                let mut client = NetClient::connect(router.0).unwrap();
                let mut rng = Rng::new(110);
                for rows in [1usize, 3, 5] {
                    let inputs: Vec<Vec<f32>> =
                        (0..rows).map(|_| random_row(&mut rng, 6)).collect();
                    let got =
                        client.infer("m", RowBatch::from_rows(&inputs).unwrap()).unwrap();
                    assert_eq!(got.rows(), rows);
                    assert_eq!(got.cols(), 4);
                    for (i, input) in inputs.iter().enumerate() {
                        let x = Matrix::from_fn(1, 6, |_, j| input[j]);
                        assert_eq!(
                            got.row(i),
                            direct.predict(&x).unwrap().row(0),
                            "format {format}, {shard_count} shard(s), {threads} thread(s), \
                             row {i}: routed logits must be byte-identical"
                        );
                    }
                }
                // Empty batches take the router's fast path and still
                // carry the model's width.
                let empty = client.infer("m", RowBatch::new(0, 0, Vec::new()).unwrap()).unwrap();
                assert_eq!((empty.rows(), empty.cols()), (0, 4));

                let snap = router_metrics.snapshot();
                assert!(
                    snap.net_worker_requests >= (3 * shard_count) as u64,
                    "format {format}: scatters must be counted \
                     (saw {})",
                    snap.net_worker_requests
                );
                assert_eq!(snap.net_worker_failures, 0, "healthy cluster: no failures");

                stop(router);
                for w in workers {
                    stop(w);
                }
            }
        }
    }
}

// ----------------------------------------------------- rolling swap

/// A coordinated rolling SWAP across every worker keeps the
/// bit-identity contract: before the swap the router serves the old
/// artifact's bytes, after it the new artifact's — never a mixture.
#[test]
fn rolling_swap_switches_every_worker_and_stays_bit_identical() {
    let params = small_params(120);
    let old = small_artifact(&params, "lowrank", 121);
    let new = small_artifact(&params, "csr", 122);

    // Each worker serves its own registry so SWAP has a reload source.
    let mut dirs = Vec::new();
    let mut registries = Vec::new();
    let mut workers = Vec::new();
    for w in 0..2 {
        let dir = std::env::temp_dir()
            .join(format!("lrbi_cluster_swap_{}_{w}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut registry = Registry::create(&dir).unwrap();
        registry.publish("m", &old).unwrap();
        let hub = ModelHub::from_registry(
            &dir,
            BatchPolicy::default(),
            64,
            Arc::new(Metrics::new()),
            ExecCtx::single(),
        )
        .unwrap();
        workers.push(start_server(hub, &ServeOptions::default()));
        registries.push(registry);
        dirs.push(dir);
    }
    let worker_addrs: Vec<SocketAddr> = workers.iter().map(|w| w.0).collect();
    let router_metrics = Arc::new(Metrics::new());
    let router = start_router(&worker_addrs, Arc::clone(&router_metrics));
    let mut client = NetClient::connect(router.0).unwrap();

    let mut rng = Rng::new(123);
    let input = random_row(&mut rng, 6);
    let batch = RowBatch::from_rows(&[input.clone()]).unwrap();
    let x = Matrix::from_fn(1, 6, |_, j| input[j]);

    let before = client.infer("m", batch.clone()).unwrap();
    let mut direct_old = NativeBackend::from_artifact(&old).unwrap();
    assert_eq!(before.row(0), direct_old.predict(&x).unwrap().row(0));

    // Republish under the same name on every worker, then one SWAP to
    // the router rolls all of them.
    for registry in &mut registries {
        registry.publish("m", &new).unwrap();
    }
    let message = client.swap("m").unwrap();
    assert!(message.contains("rolling swap"), "{message}");

    let after = client.infer("m", batch).unwrap();
    assert_ne!(after.data(), before.data(), "swap must change the logits");
    let mut direct_new = NativeBackend::from_artifact(&new).unwrap();
    assert_eq!(
        after.row(0),
        direct_new.predict(&x).unwrap().row(0),
        "post-swap routed logits bit-identical to the new artifact"
    );
    let snap = router_metrics.snapshot();
    assert_eq!(snap.net_worker_swaps, 2, "one swap step per worker");
    assert_eq!(snap.net_worker_swap_failures, 0);
    assert_eq!(snap.hot_swaps, 1, "the coordinated swap counts once");

    stop(router);
    for w in workers {
        stop(w);
    }
    for dir in dirs {
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// --------------------------------------------- split/reassemble property

/// Property: any batch shape routed through any shard count
/// reassembles to exactly the unsharded bytes — the gather is a
/// fixed-order copy, so there is no floating-point reassociation to
/// observe. Workers boot once; each case connects a fresh router over
/// a prefix of them.
#[test]
fn random_batch_and_shard_splits_reassemble_exactly() {
    let params = small_params(130);
    let artifact = small_artifact(&params, "csr", 131);
    let mut direct = NativeBackend::from_artifact(&artifact).unwrap();
    let workers: Vec<Running> = (0..4).map(|_| start_worker(&artifact, 1)).collect();
    let worker_addrs: Vec<SocketAddr> = workers.iter().map(|w| w.0).collect();

    prop::check("router split/reassemble", 12, |rng| {
        let shard_count = 1 + rng.next_range(4) as usize;
        let rows = 1 + rng.next_range(7) as usize;
        let router_metrics = Arc::new(Metrics::new());
        let router = start_router(&worker_addrs[..shard_count], router_metrics);
        let mut client = NetClient::connect(router.0).unwrap();
        let inputs: Vec<Vec<f32>> = (0..rows).map(|_| random_row(rng, 6)).collect();
        let got = client.infer("m", RowBatch::from_rows(&inputs).unwrap()).unwrap();
        for (i, input) in inputs.iter().enumerate() {
            let x = Matrix::from_fn(1, 6, |_, j| input[j]);
            assert_eq!(
                got.row(i),
                direct.predict(&x).unwrap().row(0),
                "{rows} row(s) across {shard_count} shard(s), row {i}"
            );
        }
        stop(router);
    });

    for w in workers {
        stop(w);
    }
}

// ------------------------------------------------------ model-key routing

/// A router can front several worker fleets under different model
/// keys; each key's logits match its own fleet's artifact, and a key
/// the router does not serve is a typed `unknown-model` error.
#[test]
fn model_key_routing_selects_the_right_worker_fleet() {
    let params = small_params(140);
    let art_a = small_artifact(&params, "dense", 141);
    let art_b = small_artifact(&params, "relative", 142);
    let worker_a = start_worker(&art_a, 1);
    let worker_b = start_worker(&art_b, 1);

    let metrics = Arc::new(Metrics::new());
    let group_a = Arc::new(
        ShardGroup::connect(
            &worker_a.0.to_string(),
            "m",
            ClientOptions::default(),
            Arc::clone(&metrics),
        )
        .unwrap(),
    );
    let group_b = Arc::new(
        ShardGroup::connect(
            &worker_b.0.to_string(),
            "m",
            ClientOptions::default(),
            Arc::clone(&metrics),
        )
        .unwrap(),
    );
    let hub = ModelHub::from_remote("alpha", group_a);
    hub.install_remote("beta", group_b);
    let router = start_server(hub, &ServeOptions::default());
    let mut client = NetClient::connect(router.0).unwrap();

    let mut rng = Rng::new(143);
    let input = random_row(&mut rng, 6);
    let batch = RowBatch::from_rows(&[input.clone()]).unwrap();
    let x = Matrix::from_fn(1, 6, |_, j| input[j]);

    let got_a = client.infer("alpha", batch.clone()).unwrap();
    let got_b = client.infer("beta", batch.clone()).unwrap();
    let mut direct_a = NativeBackend::from_artifact(&art_a).unwrap();
    let mut direct_b = NativeBackend::from_artifact(&art_b).unwrap();
    assert_eq!(got_a.row(0), direct_a.predict(&x).unwrap().row(0), "alpha fleet");
    assert_eq!(got_b.row(0), direct_b.predict(&x).unwrap().row(0), "beta fleet");
    // An empty key resolves to the hub's default remote slot.
    let got_default = client.infer("", batch.clone()).unwrap();
    assert_eq!(got_default.data(), got_a.data(), "default key is alpha");

    match client
        .call(&Frame::Infer { key: "gamma".into(), batch, deadline_us: None })
        .unwrap()
    {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::UnknownModel);
            assert!(message.contains("gamma"), "{message}");
        }
        other => panic!("expected a typed error, got {}", other.type_name()),
    }

    stop(router);
    stop(worker_a);
    stop(worker_b);
}

// ------------------------------------------------- replicated shards

/// Replicas within a shard (`a|b` spec) are interchangeable: the
/// router serves identical bytes no matter which replica answers, and
/// the spec parser's shard count reflects groups, not endpoints.
#[test]
fn replicated_shard_serves_identical_bytes() {
    let params = small_params(150);
    let artifact = small_artifact(&params, "lowrank", 151);
    let mut direct = NativeBackend::from_artifact(&artifact).unwrap();
    // Shard 0 has two replicas over the same artifact; shard 1 has one.
    let replica_a = start_worker(&artifact, 1);
    let replica_b = start_worker(&artifact, 1);
    let solo = start_worker(&artifact, 1);
    let spec = format!("{}|{},{}", replica_a.0, replica_b.0, solo.0);
    let metrics = Arc::new(Metrics::new());
    let group =
        Arc::new(ShardGroup::connect(&spec, "m", ClientOptions::default(), metrics).unwrap());
    assert_eq!(group.shard_count(), 2, "replicas do not add shards");
    assert_eq!(group.classes(), 4);
    let router = start_server(ModelHub::from_remote("m", group), &ServeOptions::default());
    let mut client = NetClient::connect(router.0).unwrap();

    let mut rng = Rng::new(152);
    for _ in 0..4 {
        let input = random_row(&mut rng, 6);
        let got = client.infer("m", RowBatch::from_rows(&[input.clone()]).unwrap()).unwrap();
        let x = Matrix::from_fn(1, 6, |_, j| input[j]);
        assert_eq!(got.row(0), direct.predict(&x).unwrap().row(0));
    }

    stop(router);
    stop(replica_a);
    stop(replica_b);
    stop(solo);
}
