//! # lrbi — Low-Rank Binary Indexing for Network Pruning
//!
//! Reproduction of "Network Pruning for Low-Rank Binary Indexing"
//! (Lee, Kwon, Kim, Kapoor, Wei — 2019) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! See `README.md` at the repository root for the quickstart and the
//! paper figure/table → bench map, and `docs/ARCHITECTURE.md` for the
//! module map and data flow (including the sparse-execution kernel
//! layer in [`serve::kernels`]).

pub mod bmf;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod formats;
pub mod models;
pub mod nmf;
pub mod pruning;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tensor;
pub mod tiling;
pub mod train;
pub mod util;

pub use util::error::{Error, Result};
