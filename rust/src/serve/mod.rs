//! Serving engine over compressed models: dynamic batching, decode
//! cache, and sparse-execution kernels that run the masked layer
//! directly on each index representation (or the PJRT artifact path;
//! the native kernels keep the full pipeline testable without
//! artifacts). Each kernel compiles a shard-parallel execution plan
//! (`plan`) run on the coordinator's shared
//! [`ExecCtx`](crate::coordinator::pool::ExecCtx).

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod kernels;
pub(crate) mod plan;
pub mod variants;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use cache::LruCache;
pub use engine::{InferenceBackend, NativeBackend, ServingEngine};
pub use kernels::{
    build_kernel, build_kernel_exec, build_kernel_from_stored, build_kernel_from_stored_exec,
    KernelFormat, SparseKernel,
};
