//! Serving engine over compressed models: dynamic batching, decode
//! cache, masked inference via the PJRT runtime (or a native fallback
//! so the full pipeline is testable without artifacts).

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod variants;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use cache::LruCache;
pub use engine::{InferenceBackend, NativeBackend, ServingEngine};
