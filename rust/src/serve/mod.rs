//! Serving engine over compressed models — from the socket down to
//! the sparse kernel:
//!
//! - [`server`] / [`protocol`]: the TCP network frontend (`lrbi serve
//!   --listen`) and its versioned, length-prefixed wire format with
//!   typed error frames, admission control, `STATS`, hot-swap, and
//!   graceful shutdown (specs: `docs/PROTOCOL.md`, ops guide:
//!   `docs/SERVING.md`).
//! - [`router`] / [`shard`]: the router/worker cluster tier — a
//!   router scatters each request's rows to workers that each serve a
//!   contiguous slice of output columns and gathers the partials in
//!   fixed order, bit-identical to a single process (topology and
//!   failure modes: `docs/CLUSTER.md`).
//! - [`batcher`]: dynamic request batching — concurrent clients' rows
//!   coalesce into shared executions behind a bounded submit queue
//!   that *rejects* (never silently stalls) when full.
//! - [`engine`] / [`variants`]: fixed-batch inference backends and
//!   multi-variant serving with the LRU decode [`cache`].
//! - [`metrics_http`]: the `--metrics-addr` plaintext HTTP/1.0
//!   endpoint exposing the telemetry histograms in Prometheus text
//!   format (see `docs/OBSERVABILITY.md`).
//! - [`kernels`]: sparse-execution kernels that run the masked layer
//!   directly on each index representation (or the PJRT artifact
//!   path; the native kernels keep the full pipeline testable without
//!   artifacts). Each kernel compiles a shard-parallel execution plan
//!   (`plan`) run on the coordinator's shared
//!   [`ExecCtx`](crate::coordinator::pool::ExecCtx).

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod kernels;
pub mod metrics_http;
pub(crate) mod plan;
pub mod protocol;
pub mod router;
pub mod server;
pub mod shard;
pub mod variants;

pub use batcher::{BatchPolicy, DynamicBatcher, SubmitError};
pub use cache::LruCache;
pub use engine::{InferenceBackend, NativeBackend, ServingEngine};
pub use kernels::{
    build_kernel, build_kernel_exec, build_kernel_from_stored, build_kernel_from_stored_exec,
    KernelFormat, SparseKernel,
};
pub use metrics_http::MetricsServer;
pub use protocol::{
    ErrorCode, Frame, HistSummary, RowBatch, WireError, MAX_FRAME, PROTOCOL_VERSION,
};
pub use router::ShardGroup;
pub use server::{
    ClientOptions, ModelHub, ModelSlot, NetClient, RetryPolicy, ServeOptions, Server, ServerHandle,
};
