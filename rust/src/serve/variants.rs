//! Multi-variant serving: several compressed *index versions* of the
//! same model (e.g. different ranks or re-compressions) served from
//! one engine. Each variant's [`SparseKernel`] is built at most once
//! via the LRU decode cache — the serving analogue of the paper's
//! on-chip decompressor, with `Metrics::cache_{hits,misses}` making
//! the decode amortisation observable and the `kernel_*` counters
//! separating decode cost from per-request compute.
//!
//! Variants come from two places: in-memory factor pairs
//! ([`IndexVariant`], the pre-store behavior) or `.lrbi` artifacts in
//! a [`Registry`] ([`VariantServer::from_registry`]), which can also
//! be **hot-swapped** into a running server
//! ([`VariantServer::hot_swap`]) — the production deploy path: pack a
//! new compression, publish it, swap it in without restarting.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::ExecCtx;
use crate::formats::StoredIndex;
use crate::serve::cache::LruCache;
use crate::serve::engine::MlpParams;
use crate::serve::kernels::{
    build_kernel_exec, build_kernel_from_stored_exec, KernelFormat, SparseKernel,
};
use crate::store::{Artifact, Registry};
use crate::tensor::Matrix;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// A compressed FC1 index variant.
#[derive(Debug, Clone)]
pub struct IndexVariant {
    /// Stable id (cache key).
    pub id: u64,
    /// Left factor.
    pub ip: BitMatrix,
    /// Right factor.
    pub iz: BitMatrix,
}

/// How a registered variant's index is held.
enum VariantIndex {
    /// In-memory factor pair; executes with the server-wide format.
    Factors { ip: BitMatrix, iz: BitMatrix },
    /// A stored index (loaded from an artifact); executes with the
    /// kernel for its own representation.
    Stored(StoredIndex),
}

struct Variant {
    id: u64,
    name: Option<String>,
    index: VariantIndex,
}

/// Serves any registered variant; builds each variant's sparse kernel
/// lazily and caches it, so the per-format decode runs once per
/// resident variant rather than once per request.
pub struct VariantServer {
    params: MlpParams,
    format: KernelFormat,
    variants: Vec<Variant>,
    cache: LruCache<u64, Box<dyn SparseKernel>>,
    metrics: Arc<Metrics>,
    next_id: u64,
    /// Execution context every variant's kernel plan runs on.
    ctx: Arc<ExecCtx>,
}

impl VariantServer {
    /// Build with a cache bound (variants beyond this get re-decoded
    /// on demand — bounded memory is the point of the paper's format).
    /// Uses the dense-masked baseline kernel; see
    /// [`VariantServer::with_format`] to execute on the compressed
    /// representation directly.
    pub fn new(
        params: MlpParams,
        variants: Vec<IndexVariant>,
        cache_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::with_format(params, KernelFormat::DenseMasked, variants, cache_cap, metrics)
    }

    /// Build selecting the sparse-execution kernel for `format`
    /// (applies to factor variants; artifact variants execute in
    /// their stored representation).
    pub fn with_format(
        params: MlpParams,
        format: KernelFormat,
        variants: Vec<IndexVariant>,
        cache_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        let next_id = variants.iter().map(|v| v.id + 1).max().unwrap_or(1);
        VariantServer {
            params,
            format,
            variants: variants
                .into_iter()
                .map(|v| Variant {
                    id: v.id,
                    name: None,
                    index: VariantIndex::Factors { ip: v.ip, iz: v.iz },
                })
                .collect(),
            cache: LruCache::new(cache_cap),
            metrics,
            next_id,
            ctx: ExecCtx::single(),
        }
    }

    /// Set the execution context kernels are built against (`lrbi
    /// serve --registry … --threads N`). Flushes the kernel cache so
    /// already-built kernels are rebuilt on the new context; output is
    /// bit-identical either way (plans don't depend on the context).
    pub fn set_exec(&mut self, ctx: Arc<ExecCtx>) {
        self.ctx = ctx;
        self.cache.clear();
    }

    /// Build a server over every artifact in a registry. The first
    /// entry supplies the dense params; the remaining artifacts must
    /// carry identical params (a registry holds index variants of
    /// *one* model — deploy a different model by [`Self::hot_swap`]).
    /// Each load is timed into `Metrics::artifact_loads`.
    pub fn from_registry(
        registry: &Registry,
        cache_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Result<Self> {
        if registry.is_empty() {
            return Err(Error::store(format!(
                "registry {} is empty — publish artifacts with `lrbi pack --registry`",
                registry.dir().display()
            )));
        }
        let mut server: Option<VariantServer> = None;
        for entry in registry.entries() {
            let t0 = Instant::now();
            let artifact = registry.load(&entry.name)?;
            metrics.record_artifact_load(t0);
            match &mut server {
                None => {
                    let mut s = VariantServer::with_format(
                        artifact.params.clone(),
                        KernelFormat::DenseMasked,
                        Vec::new(),
                        cache_cap,
                        Arc::clone(&metrics),
                    );
                    s.install(&entry.name, artifact.index)?;
                    server = Some(s);
                }
                Some(s) => {
                    if s.params != artifact.params {
                        return Err(Error::store(format!(
                            "artifact '{}' carries different dense params than the \
                             registry's first entry; a registry serves index variants \
                             of one model",
                            entry.name
                        )));
                    }
                    s.install(&entry.name, artifact.index)?;
                }
            }
        }
        Ok(server.expect("registry non-empty"))
    }

    /// Register (or replace) a named stored-index variant. Returns its
    /// id. Does not touch params — see [`Self::hot_swap`] for full
    /// artifact deployment.
    fn install(&mut self, name: &str, index: StoredIndex) -> Result<u64> {
        let (m, n) = index.shape();
        if m != self.params.w1.rows() || n != self.params.w1.cols() {
            return Err(Error::store(format!(
                "artifact '{name}' index {m}x{n} vs masked layer {}x{}",
                self.params.w1.rows(),
                self.params.w1.cols()
            )));
        }
        if let Some(v) = self.variants.iter_mut().find(|v| v.name.as_deref() == Some(name)) {
            v.index = VariantIndex::Stored(index);
            let id = v.id;
            self.cache.remove(&id);
            return Ok(id);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.variants.push(Variant {
            id,
            name: Some(name.to_string()),
            index: VariantIndex::Stored(index),
        });
        Ok(id)
    }

    /// Every registered variant's index shape (factor or stored).
    fn variant_shape(v: &Variant) -> (usize, usize) {
        match &v.index {
            VariantIndex::Factors { ip, iz } => (ip.rows(), iz.cols()),
            VariantIndex::Stored(s) => s.shape(),
        }
    }

    /// Hot-swap an artifact into the running server under `name`:
    /// replaces (or registers) that variant's index, and if the
    /// artifact's dense params differ from the server's, adopts them
    /// and invalidates *every* cached kernel (the weights changed
    /// under all variants). Rejected — with the server untouched — if
    /// the new masked-layer shape is incompatible with the incoming
    /// index or with any already-registered variant. Counted in
    /// `Metrics::hot_swaps`.
    pub fn hot_swap(&mut self, name: &str, artifact: &Artifact) -> Result<u64> {
        let (w1r, w1c) = (artifact.params.w1.rows(), artifact.params.w1.cols());
        let (m, n) = artifact.index.shape();
        if m != w1r || n != w1c {
            return Err(Error::store(format!(
                "artifact '{name}' index {m}x{n} vs its masked layer {w1r}x{w1c}"
            )));
        }
        if self.params != artifact.params {
            // Adopting new params affects every variant — refuse the
            // swap outright if any *other* variant would be orphaned
            // by the new masked-layer shape.
            for v in &self.variants {
                if v.name.as_deref() == Some(name) {
                    continue; // being replaced
                }
                let (vm, vn) = Self::variant_shape(v);
                if vm != w1r || vn != w1c {
                    return Err(Error::store(format!(
                        "hot swap of '{name}' would change the masked layer to \
                         {w1r}x{w1c}, orphaning variant {} ({vm}x{vn})",
                        v.id
                    )));
                }
            }
            self.params = artifact.params.clone();
            self.cache.clear();
        }
        let id = self.install(name, artifact.index.clone())?;
        self.metrics.hot_swaps.fetch_add(1, Ordering::Relaxed);
        Ok(id)
    }

    /// Load `name` from the registry (timed into
    /// `Metrics::artifact_loads`) and [`Self::hot_swap`] it.
    pub fn hot_swap_from_registry(&mut self, registry: &Registry, name: &str) -> Result<u64> {
        let t0 = Instant::now();
        let artifact = registry.load(name)?;
        self.metrics.record_artifact_load(t0);
        self.hot_swap(name, &artifact)
    }

    /// Registered variant ids.
    pub fn variant_ids(&self) -> Vec<u64> {
        self.variants.iter().map(|v| v.id).collect()
    }

    /// Id of a named (artifact-backed) variant.
    pub fn id_of(&self, name: &str) -> Option<u64> {
        self.variants
            .iter()
            .find(|v| v.name.as_deref() == Some(name))
            .map(|v| v.id)
    }

    /// The kernel format factor variants execute with.
    pub fn format(&self) -> KernelFormat {
        self.format
    }

    /// Input feature dimension (drives request generation).
    pub fn input_dim(&self) -> usize {
        self.params.w0.rows()
    }

    /// Output classes.
    pub fn classes(&self) -> usize {
        self.params.w2.cols()
    }

    /// Ensure the variant's kernel is resident, building it on miss.
    fn ensure_kernel(&mut self, id: u64) -> Result<()> {
        if self.cache.get(&id).is_some() {
            self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        self.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
        let v = self
            .variants
            .iter()
            .find(|v| v.id == id)
            .ok_or_else(|| Error::invalid(format!("unknown variant {id}")))?;
        // The decompression step: per-format index decode/encode.
        let kernel = match &v.index {
            VariantIndex::Factors { ip, iz } => build_kernel_exec(
                self.format,
                &self.params.w1,
                ip,
                iz,
                &self.ctx,
                Some(&self.metrics),
            )?,
            VariantIndex::Stored(stored) => build_kernel_from_stored_exec(
                stored,
                &self.params.w1,
                &self.ctx,
                Some(&self.metrics),
            )?,
        };
        self.cache.put(id, kernel);
        Ok(())
    }

    /// Forward a batch through the chosen variant.
    pub fn predict(&mut self, variant: u64, x: &Matrix) -> Result<Matrix> {
        self.ensure_kernel(variant)?;
        let mut h0 = x.matmul(&self.params.w0)?;
        add_bias(&mut h0, &self.params.b0);
        h0.map_inplace(|v| v.max(0.0));
        let kernel = self.cache.get(&variant).expect("ensured above");
        let t0 = Instant::now();
        let mut h1 = kernel.spmm(&h0)?;
        self.metrics.record_spmm(t0);
        add_bias(&mut h1, &self.params.b1);
        h1.map_inplace(|v| v.max(0.0));
        let mut out = h1.matmul(&self.params.w2)?;
        add_bias(&mut out, &self.params.b2);
        Ok(out)
    }
}

fn add_bias(m: &mut Matrix, b: &[f32]) {
    let cols = m.cols();
    for (idx, v) in m.data_mut().iter_mut().enumerate() {
        *v += b[idx % cols];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::GEOMETRY;
    use crate::util::rng::Rng;

    fn variant(id: u64, seed: u64) -> IndexVariant {
        let g = GEOMETRY;
        let mut rng = Rng::new(seed);
        IndexVariant {
            id,
            ip: BitMatrix::from_fn(g.hidden0, 8, |_, _| rng.bernoulli(0.3)),
            iz: BitMatrix::from_fn(8, g.hidden1, |_, _| rng.bernoulli(0.3)),
        }
    }

    #[test]
    fn decode_runs_once_per_cached_variant() {
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::new(
            MlpParams::init(1),
            vec![variant(1, 10), variant(2, 20)],
            4,
            Arc::clone(&metrics),
        );
        let x = Matrix::zeros(2, GEOMETRY.input_dim);
        for _ in 0..5 {
            srv.predict(1, &x).unwrap();
            srv.predict(2, &x).unwrap();
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.cache_misses, 2, "one decode per variant");
        assert_eq!(snap.cache_hits, 8);
    }

    #[test]
    fn eviction_forces_redecode() {
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::new(
            MlpParams::init(2),
            vec![variant(1, 10), variant(2, 20), variant(3, 30)],
            2, // cache smaller than variant count
            Arc::clone(&metrics),
        );
        let x = Matrix::zeros(1, GEOMETRY.input_dim);
        for id in [1, 2, 3, 1, 2, 3] {
            srv.predict(id, &x).unwrap();
        }
        let snap = metrics.snapshot();
        assert!(snap.cache_misses > 3, "eviction must force re-decodes");
    }

    #[test]
    fn variants_give_different_logits() {
        let mut srv = VariantServer::new(
            MlpParams::init(3),
            vec![variant(1, 10), variant(2, 20)],
            4,
            Arc::new(Metrics::new()),
        );
        let mut rng = Rng::new(4);
        let x = Matrix::gaussian(1, GEOMETRY.input_dim, 0.0, 1.0, &mut rng);
        let a = srv.predict(1, &x).unwrap();
        let b = srv.predict(2, &x).unwrap();
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn kernel_formats_agree_across_variants() {
        let mut rng = Rng::new(6);
        let x = Matrix::gaussian(3, GEOMETRY.input_dim, 0.0, 1.0, &mut rng);
        let params = MlpParams::init(9);
        let make = |fmt| {
            VariantServer::with_format(
                params.clone(),
                fmt,
                vec![variant(1, 10)],
                4,
                Arc::new(Metrics::new()),
            )
        };
        let want = make(KernelFormat::DenseMasked).predict(1, &x).unwrap();
        for fmt in KernelFormat::ALL {
            let mut srv = make(fmt);
            let got = srv.predict(1, &x).unwrap();
            for (a, b) in got.data().iter().zip(want.data()) {
                assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{}: {a} vs {b}", fmt.name());
            }
        }
    }

    #[test]
    fn set_exec_rebuilds_kernels_with_identical_logits() {
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::with_format(
            MlpParams::init(12),
            KernelFormat::Csr,
            vec![variant(1, 10)],
            4,
            Arc::clone(&metrics),
        );
        let mut rng = Rng::new(13);
        let x = Matrix::gaussian(2, GEOMETRY.input_dim, 0.0, 1.0, &mut rng);
        let single = srv.predict(1, &x).unwrap();
        srv.set_exec(crate::coordinator::pool::ExecCtx::new(4, Some(Arc::clone(&metrics))));
        let pooled = srv.predict(1, &x).unwrap();
        assert_eq!(pooled.data(), single.data(), "bit-identical across contexts");
        assert_eq!(
            metrics.snapshot().kernel_decodes,
            2,
            "set_exec flushes the cache, forcing one rebuild"
        );
        assert!(metrics.snapshot().spmm_shards > 0, "plan execution recorded");
    }

    #[test]
    fn decode_and_compute_counters_recorded() {
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::with_format(
            MlpParams::init(4),
            KernelFormat::LowRankFused,
            vec![variant(1, 10)],
            2,
            Arc::clone(&metrics),
        );
        let x = Matrix::zeros(1, GEOMETRY.input_dim);
        srv.predict(1, &x).unwrap();
        srv.predict(1, &x).unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.kernel_decodes, 1, "kernel built once");
        assert_eq!(snap.kernel_spmms, 2, "spmm per request");
    }

    #[test]
    fn unknown_variant_rejected() {
        let mut srv =
            VariantServer::new(MlpParams::init(5), vec![], 2, Arc::new(Metrics::new()));
        let x = Matrix::zeros(1, GEOMETRY.input_dim);
        assert!(srv.predict(9, &x).is_err());
    }

    fn small_params(seed: u64) -> MlpParams {
        let mut rng = Rng::new(seed);
        MlpParams {
            w0: Matrix::gaussian(6, 20, 0.0, 0.5, &mut rng),
            b0: vec![0.1; 20],
            w1: Matrix::gaussian(20, 30, 0.0, 0.5, &mut rng),
            b1: vec![0.2; 30],
            w2: Matrix::gaussian(30, 4, 0.0, 0.5, &mut rng),
            b2: vec![0.0; 4],
        }
    }

    fn small_artifact(params: &MlpParams, format: &str, seed: u64) -> crate::store::Artifact {
        let mut rng = Rng::new(seed);
        let ip = BitMatrix::from_fn(20, 4, |_, _| rng.bernoulli(0.3));
        let iz = BitMatrix::from_fn(4, 30, |_, _| rng.bernoulli(0.3));
        crate::store::Artifact::pack_factors(params.clone(), format, &ip, &iz, "variants test")
            .unwrap()
    }

    #[test]
    fn registry_serving_and_hot_swap() {
        let dir = std::env::temp_dir()
            .join(format!("lrbi_variants_reg_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let params = small_params(40);
        let mut reg = crate::store::Registry::create(&dir).unwrap();
        reg.publish("v1", &small_artifact(&params, "lowrank", 41)).unwrap();
        reg.publish("v2", &small_artifact(&params, "csr", 42)).unwrap();

        let metrics = Arc::new(Metrics::new());
        let mut srv =
            VariantServer::from_registry(&reg, 4, Arc::clone(&metrics)).unwrap();
        assert_eq!(srv.variant_ids().len(), 2);
        let (id1, id2) = (srv.id_of("v1").unwrap(), srv.id_of("v2").unwrap());
        let mut rng = Rng::new(43);
        let x = Matrix::gaussian(2, 6, 0.0, 1.0, &mut rng);
        let a = srv.predict(id1, &x).unwrap();
        let b = srv.predict(id2, &x).unwrap();
        assert_ne!(a.data(), b.data(), "different indexes, different logits");
        assert_eq!(metrics.snapshot().artifact_loads, 2);

        // hot-swap v1 to a re-compression: logits change, swap counted,
        // v2 untouched (its kernel stays cached).
        reg.publish("v1", &small_artifact(&params, "relative", 99)).unwrap();
        let swapped_id = srv.hot_swap_from_registry(&reg, "v1").unwrap();
        assert_eq!(swapped_id, id1, "hot swap keeps the variant id");
        let a2 = srv.predict(id1, &x).unwrap();
        assert_ne!(a2.data(), a.data(), "swapped index must change logits");
        assert_eq!(srv.predict(id2, &x).unwrap().data(), b.data());
        let snap = metrics.snapshot();
        assert_eq!(snap.hot_swaps, 1);
        assert_eq!(snap.artifact_loads, 3);

        // swapping in different dense params adopts them and
        // invalidates every cached kernel.
        let other = small_params(77);
        let misses_before = metrics.snapshot().cache_misses;
        srv.hot_swap("v1", &small_artifact(&other, "lowrank", 41)).unwrap();
        let b2 = srv.predict(id2, &x).unwrap();
        assert_ne!(b2.data(), b.data(), "new params must change v2's logits");
        assert!(metrics.snapshot().cache_misses > misses_before);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hot_swap_rejecting_shape_change_leaves_server_intact() {
        let params = small_params(50);
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::new(params.clone(), vec![], 4, Arc::clone(&metrics));
        srv.hot_swap("a", &small_artifact(&params, "lowrank", 51)).unwrap();
        srv.hot_swap("b", &small_artifact(&params, "csr", 52)).unwrap();
        let mut rng = Rng::new(53);
        let x = Matrix::gaussian(1, 6, 0.0, 1.0, &mut rng);
        let before = srv.predict(srv.id_of("b").unwrap(), &x).unwrap();

        // an artifact whose masked layer is a different shape (20x31)
        let mut other = small_params(54);
        other.w1 = Matrix::gaussian(20, 31, 0.0, 0.5, &mut Rng::new(55));
        other.b1 = vec![0.0; 31];
        other.w2 = Matrix::gaussian(31, 4, 0.0, 0.5, &mut Rng::new(56));
        let ip = BitMatrix::from_fn(20, 4, |_, _| true);
        let iz = BitMatrix::from_fn(4, 31, |_, _| true);
        let art =
            crate::store::Artifact::pack_factors(other, "lowrank", &ip, &iz, "t").unwrap();
        let err = srv.hot_swap("a", &art).unwrap_err();
        assert!(err.to_string().contains("orphaning"), "{err}");
        // server untouched: old variants still serve identically
        assert_eq!(srv.predict(srv.id_of("b").unwrap(), &x).unwrap().data(), before.data());
        assert_eq!(metrics.snapshot().hot_swaps, 2, "failed swap not counted");
    }

    #[test]
    fn registry_with_mismatched_params_rejected() {
        let dir = std::env::temp_dir()
            .join(format!("lrbi_variants_mismatch_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut reg = crate::store::Registry::create(&dir).unwrap();
        reg.publish("a", &small_artifact(&small_params(1), "lowrank", 2)).unwrap();
        reg.publish("b", &small_artifact(&small_params(2), "lowrank", 3)).unwrap();
        let err = VariantServer::from_registry(&reg, 4, Arc::new(Metrics::new())).unwrap_err();
        assert!(err.to_string().contains("dense params"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
