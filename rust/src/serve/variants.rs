//! Multi-variant serving: several compressed *index versions* of the
//! same model (e.g. different ranks or re-compressions) served from
//! one engine. Each variant's [`SparseKernel`] is built at most once
//! via the LRU decode cache — the serving analogue of the paper's
//! on-chip decompressor, with `Metrics::cache_{hits,misses}` making
//! the decode amortisation observable and the `kernel_*` counters
//! separating decode cost from per-request compute.

use crate::coordinator::metrics::Metrics;
use crate::serve::cache::LruCache;
use crate::serve::engine::MlpParams;
use crate::serve::kernels::{build_kernel, KernelFormat, SparseKernel};
use crate::tensor::Matrix;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// A compressed FC1 index variant.
#[derive(Debug, Clone)]
pub struct IndexVariant {
    /// Stable id (cache key).
    pub id: u64,
    /// Left factor.
    pub ip: BitMatrix,
    /// Right factor.
    pub iz: BitMatrix,
}

/// Serves any registered variant; builds each variant's sparse kernel
/// lazily and caches it, so the per-format decode runs once per
/// resident variant rather than once per request.
pub struct VariantServer {
    params: MlpParams,
    format: KernelFormat,
    variants: Vec<IndexVariant>,
    cache: LruCache<u64, Box<dyn SparseKernel>>,
    metrics: Arc<Metrics>,
}

impl VariantServer {
    /// Build with a cache bound (variants beyond this get re-decoded
    /// on demand — bounded memory is the point of the paper's format).
    /// Uses the dense-masked baseline kernel; see
    /// [`VariantServer::with_format`] to execute on the compressed
    /// representation directly.
    pub fn new(
        params: MlpParams,
        variants: Vec<IndexVariant>,
        cache_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::with_format(params, KernelFormat::DenseMasked, variants, cache_cap, metrics)
    }

    /// Build selecting the sparse-execution kernel for `format`.
    pub fn with_format(
        params: MlpParams,
        format: KernelFormat,
        variants: Vec<IndexVariant>,
        cache_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        VariantServer {
            params,
            format,
            variants,
            cache: LruCache::new(cache_cap),
            metrics,
        }
    }

    /// Registered variant ids.
    pub fn variant_ids(&self) -> Vec<u64> {
        self.variants.iter().map(|v| v.id).collect()
    }

    /// The kernel format every variant executes with.
    pub fn format(&self) -> KernelFormat {
        self.format
    }

    /// Ensure the variant's kernel is resident, building it on miss.
    fn ensure_kernel(&mut self, id: u64) -> Result<()> {
        if self.cache.get(&id).is_some() {
            self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        self.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
        let v = self
            .variants
            .iter()
            .find(|v| v.id == id)
            .ok_or_else(|| Error::invalid(format!("unknown variant {id}")))?;
        // The decompression step: per-format index decode/encode.
        let kernel = build_kernel(self.format, &self.params.w1, &v.ip, &v.iz, Some(&self.metrics))?;
        self.cache.put(id, kernel);
        Ok(())
    }

    /// Forward a batch through the chosen variant.
    pub fn predict(&mut self, variant: u64, x: &Matrix) -> Result<Matrix> {
        self.ensure_kernel(variant)?;
        let mut h0 = x.matmul(&self.params.w0)?;
        add_bias(&mut h0, &self.params.b0);
        h0.map_inplace(|v| v.max(0.0));
        let kernel = self.cache.get(&variant).expect("ensured above");
        let t0 = Instant::now();
        let mut h1 = kernel.spmm(&h0)?;
        self.metrics.record_spmm(t0);
        add_bias(&mut h1, &self.params.b1);
        h1.map_inplace(|v| v.max(0.0));
        let mut out = h1.matmul(&self.params.w2)?;
        add_bias(&mut out, &self.params.b2);
        Ok(out)
    }
}

fn add_bias(m: &mut Matrix, b: &[f32]) {
    let cols = m.cols();
    for (idx, v) in m.data_mut().iter_mut().enumerate() {
        *v += b[idx % cols];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::GEOMETRY;
    use crate::util::rng::Rng;

    fn variant(id: u64, seed: u64) -> IndexVariant {
        let g = GEOMETRY;
        let mut rng = Rng::new(seed);
        IndexVariant {
            id,
            ip: BitMatrix::from_fn(g.hidden0, 8, |_, _| rng.bernoulli(0.3)),
            iz: BitMatrix::from_fn(8, g.hidden1, |_, _| rng.bernoulli(0.3)),
        }
    }

    #[test]
    fn decode_runs_once_per_cached_variant() {
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::new(
            MlpParams::init(1),
            vec![variant(1, 10), variant(2, 20)],
            4,
            Arc::clone(&metrics),
        );
        let x = Matrix::zeros(2, GEOMETRY.input_dim);
        for _ in 0..5 {
            srv.predict(1, &x).unwrap();
            srv.predict(2, &x).unwrap();
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.cache_misses, 2, "one decode per variant");
        assert_eq!(snap.cache_hits, 8);
    }

    #[test]
    fn eviction_forces_redecode() {
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::new(
            MlpParams::init(2),
            vec![variant(1, 10), variant(2, 20), variant(3, 30)],
            2, // cache smaller than variant count
            Arc::clone(&metrics),
        );
        let x = Matrix::zeros(1, GEOMETRY.input_dim);
        for id in [1, 2, 3, 1, 2, 3] {
            srv.predict(id, &x).unwrap();
        }
        let snap = metrics.snapshot();
        assert!(snap.cache_misses > 3, "eviction must force re-decodes");
    }

    #[test]
    fn variants_give_different_logits() {
        let mut srv = VariantServer::new(
            MlpParams::init(3),
            vec![variant(1, 10), variant(2, 20)],
            4,
            Arc::new(Metrics::new()),
        );
        let mut rng = Rng::new(4);
        let x = Matrix::gaussian(1, GEOMETRY.input_dim, 0.0, 1.0, &mut rng);
        let a = srv.predict(1, &x).unwrap();
        let b = srv.predict(2, &x).unwrap();
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn kernel_formats_agree_across_variants() {
        let mut rng = Rng::new(6);
        let x = Matrix::gaussian(3, GEOMETRY.input_dim, 0.0, 1.0, &mut rng);
        let params = MlpParams::init(9);
        let make = |fmt| {
            VariantServer::with_format(
                params.clone(),
                fmt,
                vec![variant(1, 10)],
                4,
                Arc::new(Metrics::new()),
            )
        };
        let want = make(KernelFormat::DenseMasked).predict(1, &x).unwrap();
        for fmt in KernelFormat::ALL {
            let mut srv = make(fmt);
            let got = srv.predict(1, &x).unwrap();
            for (a, b) in got.data().iter().zip(want.data()) {
                assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{}: {a} vs {b}", fmt.name());
            }
        }
    }

    #[test]
    fn decode_and_compute_counters_recorded() {
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::with_format(
            MlpParams::init(4),
            KernelFormat::LowRankFused,
            vec![variant(1, 10)],
            2,
            Arc::clone(&metrics),
        );
        let x = Matrix::zeros(1, GEOMETRY.input_dim);
        srv.predict(1, &x).unwrap();
        srv.predict(1, &x).unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.kernel_decodes, 1, "kernel built once");
        assert_eq!(snap.kernel_spmms, 2, "spmm per request");
    }

    #[test]
    fn unknown_variant_rejected() {
        let mut srv =
            VariantServer::new(MlpParams::init(5), vec![], 2, Arc::new(Metrics::new()));
        let x = Matrix::zeros(1, GEOMETRY.input_dim);
        assert!(srv.predict(9, &x).is_err());
    }
}
