//! Multi-variant serving: several compressed *index versions* of the
//! same model (e.g. different ranks or re-compressions) served from
//! one engine. The decoded+masked FC1 is materialised at most once per
//! variant via the LRU decode cache — the serving analogue of the
//! paper's on-chip decompressor, with `Metrics::cache_{hits,misses}`
//! making the decode amortisation observable.

use crate::coordinator::metrics::Metrics;
use crate::serve::cache::LruCache;
use crate::serve::engine::MlpParams;
use crate::tensor::Matrix;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// A compressed FC1 index variant.
#[derive(Debug, Clone)]
pub struct IndexVariant {
    /// Stable id (cache key).
    pub id: u64,
    /// Left factor.
    pub ip: BitMatrix,
    /// Right factor.
    pub iz: BitMatrix,
}

/// Serves any registered variant; decodes lazily, caches the masked
/// FC1 weight per variant.
pub struct VariantServer {
    params: MlpParams,
    variants: Vec<IndexVariant>,
    cache: LruCache<u64, Matrix>,
    metrics: Arc<Metrics>,
}

impl VariantServer {
    /// Build with a cache bound (variants beyond this get re-decoded
    /// on demand — bounded memory is the point of the paper's format).
    pub fn new(
        params: MlpParams,
        variants: Vec<IndexVariant>,
        cache_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        VariantServer { params, variants, cache: LruCache::new(cache_cap), metrics }
    }

    /// Registered variant ids.
    pub fn variant_ids(&self) -> Vec<u64> {
        self.variants.iter().map(|v| v.id).collect()
    }

    fn masked_w1(&mut self, id: u64) -> Result<&Matrix> {
        if self.cache.get(&id).is_some() {
            self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
            let v = self
                .variants
                .iter()
                .find(|v| v.id == id)
                .ok_or_else(|| Error::invalid(format!("unknown variant {id}")))?;
            // the decompression step: boolean matmul + mask apply
            let mask = v.ip.bool_product(&v.iz);
            let mut w1 = self.params.w1.clone();
            for i in 0..mask.rows() {
                for j in 0..mask.cols() {
                    if !mask.get(i, j) {
                        w1.set(i, j, 0.0);
                    }
                }
            }
            self.cache.put(id, w1);
        }
        Ok(self.cache.get(&id).expect("just inserted"))
    }

    /// Forward a batch through the chosen variant.
    pub fn predict(&mut self, variant: u64, x: &Matrix) -> Result<Matrix> {
        let p_w0 = self.params.w0.clone();
        let p_b0 = self.params.b0.clone();
        let p_b1 = self.params.b1.clone();
        let p_w2 = self.params.w2.clone();
        let p_b2 = self.params.b2.clone();
        let w1 = self.masked_w1(variant)?;
        let mut h0 = x.matmul(&p_w0)?;
        add_bias(&mut h0, &p_b0);
        h0.map_inplace(|v| v.max(0.0));
        let mut h1 = h0.matmul(w1)?;
        add_bias(&mut h1, &p_b1);
        h1.map_inplace(|v| v.max(0.0));
        let mut out = h1.matmul(&p_w2)?;
        add_bias(&mut out, &p_b2);
        Ok(out)
    }
}

fn add_bias(m: &mut Matrix, b: &[f32]) {
    let cols = m.cols();
    for (idx, v) in m.data_mut().iter_mut().enumerate() {
        *v += b[idx % cols];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::GEOMETRY;
    use crate::util::rng::Rng;

    fn variant(id: u64, seed: u64) -> IndexVariant {
        let g = GEOMETRY;
        let mut rng = Rng::new(seed);
        IndexVariant {
            id,
            ip: BitMatrix::from_fn(g.hidden0, 8, |_, _| rng.bernoulli(0.3)),
            iz: BitMatrix::from_fn(8, g.hidden1, |_, _| rng.bernoulli(0.3)),
        }
    }

    #[test]
    fn decode_runs_once_per_cached_variant() {
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::new(
            MlpParams::init(1),
            vec![variant(1, 10), variant(2, 20)],
            4,
            Arc::clone(&metrics),
        );
        let x = Matrix::zeros(2, GEOMETRY.input_dim);
        for _ in 0..5 {
            srv.predict(1, &x).unwrap();
            srv.predict(2, &x).unwrap();
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.cache_misses, 2, "one decode per variant");
        assert_eq!(snap.cache_hits, 8);
    }

    #[test]
    fn eviction_forces_redecode() {
        let metrics = Arc::new(Metrics::new());
        let mut srv = VariantServer::new(
            MlpParams::init(2),
            vec![variant(1, 10), variant(2, 20), variant(3, 30)],
            2, // cache smaller than variant count
            Arc::clone(&metrics),
        );
        let x = Matrix::zeros(1, GEOMETRY.input_dim);
        for id in [1, 2, 3, 1, 2, 3] {
            srv.predict(id, &x).unwrap();
        }
        let snap = metrics.snapshot();
        assert!(snap.cache_misses > 3, "eviction must force re-decodes");
    }

    #[test]
    fn variants_give_different_logits() {
        let mut srv = VariantServer::new(
            MlpParams::init(3),
            vec![variant(1, 10), variant(2, 20)],
            4,
            Arc::new(Metrics::new()),
        );
        let mut rng = Rng::new(4);
        let x = Matrix::gaussian(1, GEOMETRY.input_dim, 0.0, 1.0, &mut rng);
        let a = srv.predict(1, &x).unwrap();
        let b = srv.predict(2, &x).unwrap();
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn unknown_variant_rejected() {
        let mut srv =
            VariantServer::new(MlpParams::init(5), vec![], 2, Arc::new(Metrics::new()));
        let x = Matrix::zeros(1, GEOMETRY.input_dim);
        assert!(srv.predict(9, &x).is_err());
    }
}
