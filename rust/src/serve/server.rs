//! The network serving frontend: a `std::net` TCP server speaking the
//! `serve::protocol` wire format (`lrbi serve --listen ADDR`).
//!
//! Architecture — one OS thread per live connection, all feeding the
//! per-model [`DynamicBatcher`](crate::serve::batcher::DynamicBatcher):
//!
//! ```text
//! clients ──TCP──▶ acceptor ──▶ conn handler threads
//!                     │              │  (decode INFER, submit rows)
//!                 --max-conns        ▼
//!                  rejection   ModelHub { key → ModelSlot }
//!                                    │  bounded queue (--max-queue)
//!                                    ▼
//!                          ServingEngine executor
//!                        (DynamicBatcher → SparseKernel SpMM plan)
//! ```
//!
//! Rows from concurrent connections coalesce into shared plan
//! executions (the whole point of dynamic batching), and each row's
//! reply channel demultiplexes its logits back to the connection that
//! sent it. Because every kernel computes each output row from its
//! input row alone, logits served over the wire are **bit-identical**
//! to a direct in-process [`NativeBackend`] call (pinned by
//! `tests/server.rs`).
//!
//! Admission control is explicit, never a silent stall:
//! - at accept time, a connection beyond `--max-conns` is answered
//!   with one [`ErrorCode::Overloaded`] frame and closed;
//! - at submit time, a request that does not fit the bounded engine
//!   queue (`--max-queue`) is answered with an `overloaded` error
//!   frame (rows already admitted still execute; their results are
//!   discarded).
//!
//! Hot-swap safety: `SWAP name` rebuilds that model's engine from the
//! registry and replaces the [`ModelHub`] entry atomically. In-flight
//! requests hold an `Arc` to the old slot, so their batches finish on
//! the old kernel; requests arriving after the swap see the new one.
//! The old executor thread drains and exits once its last reference
//! drops.
//!
//! Graceful shutdown (a `SHUTDOWN` frame, or [`ServerHandle::shutdown`]):
//! stop accepting, half-close every connection's read side so blocked
//! readers wake, finish in-flight requests, join the handlers, return
//! from [`Server::run`]. Operations guide: `docs/SERVING.md`.
//!
//! Deadlines: an INFER may carry a microsecond budget (`deadline_us`,
//! protocol minor revision — absent encodes byte-identically to v0).
//! The budget is measured from decode; an already-expired request is
//! answered [`ErrorCode::DeadlineExceeded`] before any row is queued,
//! admission control additionally sheds requests whose remaining
//! budget is below the model's observed p95 (`net_shed_predicted`),
//! and the batcher/executor shed expired rows at dequeue — before
//! spmm runs (`net_deadline_exceeded`). See `docs/ROBUSTNESS.md`.
//!
//! Observability: every INFER gets a trace id and a per-stage timing
//! breakdown (decode → queue → batch → spmm → merge → write) recorded
//! into the shared [`Telemetry`](crate::coordinator::telemetry)
//! histograms; `STATS2` frames and the `--metrics-addr` scrape expose
//! the summaries, and requests over `LRBI_SLOW_MS` log their breakdown
//! (`docs/OBSERVABILITY.md`).

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::ExecCtx;
use crate::coordinator::telemetry::{LatencyHistogram, Stage, StageNanos};
use crate::serve::batcher::{BatchPolicy, SubmitError};
use crate::serve::engine::{InferenceBackend, NativeBackend, ServingEngine};
use crate::serve::protocol::{self, ErrorCode, Frame, HistSummary, ReadError, RowBatch, WireError};
use crate::serve::router::ShardGroup;
use crate::serve::shard;
use crate::store::{Artifact, Registry};
use crate::util::error::{Error, Result};
use crate::util::log::Level;
use std::collections::HashMap;
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Read timeout on every connection: a peer that sits silent this
/// long between requests has its `--max-conns` slot reclaimed, so
/// idle (or dead) clients cannot permanently deny service — see
/// docs/SERVING.md §Overload behavior.
const CONN_IDLE_TIMEOUT: Duration = Duration::from_secs(300);

/// Write timeout on every connection: a peer that stops *reading*
/// must not pin its handler in `write_frame` forever — that handler
/// holds a connection slot and would block graceful shutdown's join.
const CONN_WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Requests slower than this end-to-end (decode → write, in ns) emit
/// an `INFO` line with their trace id and per-stage breakdown, so a
/// tail-latency spike names its stage without a debugger attached.
/// Tuned via `LRBI_SLOW_MS` (milliseconds, default 100); parsed once.
fn slow_request_threshold_ns() -> u64 {
    static THRESHOLD: OnceLock<u64> = OnceLock::new();
    *THRESHOLD.get_or_init(|| {
        std::env::var("LRBI_SLOW_MS")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(100)
            .saturating_mul(1_000_000)
    })
}

/// Frontend sizing knobs (`lrbi serve --listen` flags).
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Concurrent connections admitted; the next accept is answered
    /// with an `overloaded` error frame and closed (`--max-conns`).
    pub max_conns: usize,
    /// Bound of each model's request queue; a request that does not
    /// fit is rejected with an `overloaded` error frame
    /// (`--max-queue`).
    pub max_queue: usize,
    /// Dynamic-batching policy every model engine runs
    /// (`--max-batch`, `--max-wait-ms`).
    pub policy: BatchPolicy,
    /// Per-connection read timeout (`--idle-timeout-ms`): a peer
    /// silent this long — including one stalled *mid-frame*, the
    /// slow-loris case — has its connection slot reclaimed.
    pub idle_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_conns: 64,
            max_queue: 256,
            policy: BatchPolicy::default(),
            idle_timeout: CONN_IDLE_TIMEOUT,
        }
    }
}

/// What executes a slot's requests: an in-process engine, or — on a
/// router — a scatter/gather over remote worker shards.
enum SlotKind {
    /// A running [`ServingEngine`] over a local backend.
    Engine(ServingEngine),
    /// Router tier: scatter `SCATTER` frames to worker shards and
    /// gather their `PARTIAL` column slices (see `serve::router`).
    Remote(Arc<ShardGroup>),
}

/// One served model: what executes it plus the geometry the frontend
/// validates requests against.
pub struct ModelSlot {
    kind: SlotKind,
    /// Input width requests must match; `0` on remote slots — the
    /// router cannot discover it, so the workers are the authority
    /// and answer `bad-shape` themselves.
    input_dim: usize,
    classes: usize,
    kernel: &'static str,
    /// Per-model end-to-end latency series (`request_ns{model=…}`),
    /// attached by [`ModelHub::install_slot`] so the hub's registry
    /// owns the series; a slot built outside a hub records nowhere.
    request_hist: Option<Arc<LatencyHistogram>>,
}

impl ModelSlot {
    /// Wrap an already-running engine (the generic path; tests and
    /// benches use it to serve custom backends).
    pub fn from_engine(
        engine: ServingEngine,
        input_dim: usize,
        classes: usize,
        kernel: &'static str,
    ) -> Self {
        ModelSlot {
            kind: SlotKind::Engine(engine),
            input_dim,
            classes,
            kernel,
            request_hist: None,
        }
    }

    /// Wrap a connected shard group (the router path). The output
    /// width was probed from the workers; the input width is unknown
    /// here (`input_dim` 0), so shape validation happens worker-side.
    pub fn from_remote(group: Arc<ShardGroup>) -> Self {
        let classes = group.classes();
        ModelSlot {
            kind: SlotKind::Remote(group),
            input_dim: 0,
            classes,
            kernel: "remote",
            request_hist: None,
        }
    }

    /// Input feature dimension requests must match (0 on remote slots:
    /// the workers validate shape).
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn is_remote(&self) -> bool {
        matches!(self.kind, SlotKind::Remote(_))
    }

    fn metrics(&self) -> Arc<Metrics> {
        match &self.kind {
            SlotKind::Engine(engine) => engine.metrics(),
            SlotKind::Remote(group) => group.metrics(),
        }
    }

    /// Output classes per row.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Name of the sparse kernel executing this model.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel
    }

    /// Run a wire batch: every row is submitted to the engine's
    /// batcher without blocking (so concurrent connections coalesce
    /// into shared plan executions), then the replies are collected in
    /// row order. A full queue rejects the request with
    /// [`ErrorCode::Overloaded`] — rows already admitted still execute
    /// and their results are discarded. The returned [`StageNanos`] is
    /// the per-stage **max** over the request's rows (a row that
    /// straggled in a different flush dominates, which is what the
    /// slow-request log should name).
    ///
    /// A request carrying a `deadline` is shed with
    /// [`ErrorCode::DeadlineExceeded`] **before** any row reaches the
    /// queue when (a) the deadline already passed, or (b) this model's
    /// observed p95 end-to-end latency exceeds the remaining budget
    /// (predictive admission control off the `request_ns` histogram —
    /// a cold model with no samples never predictive-sheds). Rows that
    /// are admitted carry the deadline into the batcher, which pulls
    /// the flush window forward and sheds expired rows at dequeue.
    fn infer_batch(
        &self,
        batch: &RowBatch,
        deadline: Option<Instant>,
    ) -> std::result::Result<(RowBatch, StageNanos), WireError> {
        if batch.rows() == 0 {
            return RowBatch::new(0, self.classes, Vec::new())
                .map(|b| (b, StageNanos::default()))
                .map_err(|e| WireError::new(ErrorCode::Internal, e));
        }
        // Remote slots carry input_dim 0 (unknown at the router); the
        // workers run this same check and their typed `bad-shape`
        // propagates back without fail-over.
        if self.input_dim != 0 && batch.cols() != self.input_dim {
            return Err(WireError::new(
                ErrorCode::BadShape,
                format!("rows are {} wide, model expects {}", batch.cols(), self.input_dim),
            ));
        }
        if let Some(d) = deadline {
            let metrics = self.metrics();
            let now = Instant::now();
            if now >= d {
                metrics.net_deadline_exceeded.fetch_add(batch.rows() as u64, Ordering::Relaxed);
                return Err(WireError::new(
                    ErrorCode::DeadlineExceeded,
                    "deadline expired before admission; request shed",
                ));
            }
            let remaining_ns = (d - now).as_nanos().min(u64::MAX as u128) as u64;
            if let Some(hist) = &self.request_hist {
                let p95 = hist.snapshot().quantile(0.95);
                if p95 > remaining_ns {
                    metrics.net_shed_predicted.fetch_add(1, Ordering::Relaxed);
                    return Err(WireError::new(
                        ErrorCode::DeadlineExceeded,
                        format!(
                            "predicted completion {p95}ns (observed p95) exceeds remaining \
                             budget {remaining_ns}ns; shed before queueing"
                        ),
                    ));
                }
            }
        }
        let engine = match &self.kind {
            // Router path: scatter to the workers, gather the column
            // slices. Per-stage timings live on the workers (scraped
            // via their own STATS2); the router reports defaults.
            SlotKind::Remote(group) => {
                return group
                    .scatter_gather(batch, deadline)
                    .map(|logits| (logits, StageNanos::default()));
            }
            SlotKind::Engine(engine) => engine,
        };
        let client = engine.client();
        let mut pending = Vec::with_capacity(batch.rows());
        for i in 0..batch.rows() {
            match client.try_submit_with(batch.row(i).to_vec(), deadline) {
                Ok(rx) => pending.push(rx),
                Err(SubmitError::Overloaded) => {
                    // Drain what was admitted so the executor's reply
                    // sends don't linger, then reject explicitly.
                    for rx in pending {
                        let _ = rx.recv();
                    }
                    return Err(WireError::new(
                        ErrorCode::Overloaded,
                        format!(
                            "request queue full after {i} of {} rows; retry with backoff",
                            batch.rows()
                        ),
                    ));
                }
                Err(SubmitError::Closed) => {
                    return Err(WireError::new(ErrorCode::Internal, "serving engine stopped"));
                }
            }
        }
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(pending.len());
        let mut stages = StageNanos::default();
        for rx in pending {
            match rx.recv() {
                Ok(Ok((logits, st))) => {
                    rows.push(logits);
                    stages.max_with(&st);
                }
                // The executor already counted the shed row in
                // `net_deadline_exceeded`; here it only needs its
                // typed wire code.
                Ok(Err(e @ Error::Deadline(_))) => {
                    return Err(WireError::new(ErrorCode::DeadlineExceeded, e));
                }
                Ok(Err(e)) => return Err(WireError::new(ErrorCode::Internal, e)),
                Err(_) => {
                    return Err(WireError::new(ErrorCode::Internal, "serving engine stopped"));
                }
            }
        }
        RowBatch::from_rows(&rows)
            .map(|b| (b, stages))
            .map_err(|e| WireError::new(ErrorCode::Internal, e))
    }
}

/// The set of models a server exposes, keyed by registry name (an
/// empty wire key selects the default). Swappable under load.
pub struct ModelHub {
    models: RwLock<HashMap<String, Arc<ModelSlot>>>,
    default_key: String,
    registry_dir: Option<PathBuf>,
    policy: BatchPolicy,
    queue_cap: usize,
    metrics: Arc<Metrics>,
    ctx: Arc<ExecCtx>,
}

impl ModelHub {
    fn empty(
        default_key: &str,
        registry_dir: Option<PathBuf>,
        policy: BatchPolicy,
        queue_cap: usize,
        metrics: Arc<Metrics>,
        ctx: Arc<ExecCtx>,
    ) -> Self {
        ModelHub {
            models: RwLock::new(HashMap::new()),
            default_key: default_key.to_string(),
            registry_dir,
            policy,
            queue_cap,
            metrics,
            ctx,
        }
    }

    /// One in-memory backend under `key` (the `--kernel` synthetic
    /// path; no registry, so `SWAP` frames are refused).
    pub fn from_backend(
        key: &str,
        backend: NativeBackend,
        policy: BatchPolicy,
        queue_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        let ctx = ExecCtx::single();
        let hub = Self::empty(key, None, policy, queue_cap, metrics, ctx);
        hub.install_backend(key, backend);
        hub
    }

    /// A router hub: one connected shard group under `key`
    /// (`--router --workers LIST`). No local registry — `SWAP name`
    /// rolls across the group's workers instead (see `docs/CLUSTER.md`).
    pub fn from_remote(key: &str, group: Arc<ShardGroup>) -> Self {
        let metrics = group.metrics();
        let ctx = ExecCtx::single();
        let hub = Self::empty(key, None, BatchPolicy::default(), 0, metrics, ctx);
        hub.install_remote(key, group);
        hub
    }

    /// Register (or replace) `key` with a router-side shard group
    /// (model-key routing: one hub can front several worker fleets).
    pub fn install_remote(&self, key: &str, group: Arc<ShardGroup>) {
        self.install_slot(key, ModelSlot::from_remote(group));
    }

    /// One artifact under `key` (`--artifact model.lrbi`).
    pub fn from_artifact(
        key: &str,
        artifact: &Artifact,
        policy: BatchPolicy,
        queue_cap: usize,
        metrics: Arc<Metrics>,
        ctx: Arc<ExecCtx>,
    ) -> Result<Self> {
        let backend = NativeBackend::from_artifact_exec(artifact, Arc::clone(&ctx))?
            .with_metrics(Arc::clone(&metrics));
        let hub = Self::empty(key, None, policy, queue_cap, metrics, ctx);
        hub.install_backend(key, backend);
        Ok(hub)
    }

    /// Every artifact in a registry, one engine per entry
    /// (`--registry dir`); the first manifest entry is the default
    /// model, and `SWAP name` reloads `name` from this registry.
    pub fn from_registry(
        dir: impl AsRef<Path>,
        policy: BatchPolicy,
        queue_cap: usize,
        metrics: Arc<Metrics>,
        ctx: Arc<ExecCtx>,
    ) -> Result<Self> {
        let registry = Registry::open(&dir)?;
        if registry.is_empty() {
            return Err(Error::store(format!(
                "registry {} is empty — publish artifacts with `lrbi pack --registry`",
                registry.dir().display()
            )));
        }
        let default = registry.entries()[0].name.clone();
        let hub = Self::empty(
            &default,
            Some(dir.as_ref().to_path_buf()),
            policy,
            queue_cap,
            metrics,
            ctx,
        );
        for entry in registry.entries() {
            let name = entry.name.clone();
            let t0 = Instant::now();
            let artifact = registry.load(&name)?;
            hub.metrics.record_artifact_load(t0);
            let backend = NativeBackend::from_artifact_exec(&artifact, Arc::clone(&hub.ctx))?
                .with_metrics(Arc::clone(&hub.metrics));
            hub.install_backend(&name, backend);
        }
        Ok(hub)
    }

    /// Register (or replace) `key` with a freshly-started engine over
    /// `backend`; returns the kernel name now serving `key`. The
    /// batching policy is clamped to the backend's fixed batch size.
    pub fn install_backend(&self, key: &str, backend: NativeBackend) -> &'static str {
        let input_dim = backend.input_dim();
        let classes = backend.classes();
        let kernel = backend.kernel_name();
        let policy = BatchPolicy {
            max_batch: self.policy.max_batch.min(backend.batch()).max(1),
            max_wait: self.policy.max_wait,
        };
        let engine = ServingEngine::start_bounded(
            backend,
            policy,
            self.queue_cap,
            Arc::clone(&self.metrics),
        );
        self.install_slot(key, ModelSlot::from_engine(engine, input_dim, classes, kernel));
        kernel
    }

    /// Register (or replace) `key` with a pre-built slot (custom
    /// backends in tests/benches). The slot is wired to this hub's
    /// `request_ns{model=key}` latency series; a swap reuses the
    /// existing series, so the model's history survives the reload.
    pub fn install_slot(&self, key: &str, mut slot: ModelSlot) {
        slot.request_hist = Some(self.metrics.telemetry.request_histogram(key));
        self.models
            .write()
            .expect("model hub lock")
            .insert(key.to_string(), Arc::new(slot));
    }

    /// Look up a model; the empty key means the default model.
    pub fn get(&self, key: &str) -> Option<Arc<ModelSlot>> {
        let key = if key.is_empty() { self.default_key.as_str() } else { key };
        self.models.read().expect("model hub lock").get(key).cloned()
    }

    /// Registered model keys, sorted.
    pub fn keys(&self) -> Vec<String> {
        let mut keys: Vec<String> =
            self.models.read().expect("model hub lock").keys().cloned().collect();
        keys.sort();
        keys
    }

    /// The key an empty wire key resolves to.
    pub fn default_key(&self) -> &str {
        &self.default_key
    }

    /// Metrics shared by every engine in the hub.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Hot-swap: reload `name` from the registry this hub was built
    /// over and replace (or add) that model's engine. In-flight
    /// requests finish on the old kernel (they hold its slot);
    /// requests arriving after the swap see the new artifact.
    pub fn swap(&self, name: &str) -> Result<String> {
        // Router tier: a remote slot swaps by rolling across its
        // workers, not from a local registry. `SWAP name` rolls the
        // group registered under `name`, falling back to the default
        // model's group — which covers the usual flow of republishing
        // a new artifact under the same registry name on the workers.
        let remote = self
            .get(name)
            .filter(|slot| slot.is_remote())
            .or_else(|| self.get("").filter(|slot| slot.is_remote()));
        if let Some(slot) = remote {
            if let SlotKind::Remote(group) = &slot.kind {
                let message = group.rolling_swap(name)?;
                self.metrics.hot_swaps.fetch_add(1, Ordering::Relaxed);
                return Ok(message);
            }
        }
        let dir = self.registry_dir.as_ref().ok_or_else(|| {
            Error::invalid("hot swap requires a server started with --registry")
        })?;
        let registry = Registry::open(dir)?;
        let t0 = Instant::now();
        let artifact = registry.load(name)?;
        self.metrics.record_artifact_load(t0);
        let backend = NativeBackend::from_artifact_exec(&artifact, Arc::clone(&self.ctx))?
            .with_metrics(Arc::clone(&self.metrics));
        let kernel = self.install_backend(name, backend);
        self.metrics.hot_swaps.fetch_add(1, Ordering::Relaxed);
        Ok(format!(
            "swapped '{name}' in (kernel '{kernel}'); in-flight batches finish on the old kernel"
        ))
    }
}

/// Shared acceptor/handler state.
struct ServerState {
    shutdown: AtomicBool,
    active: AtomicUsize,
    next_conn: AtomicU64,
    /// Read-half handles of live connections, half-closed on shutdown
    /// so blocked readers wake without cutting in-flight replies.
    conns: Mutex<HashMap<u64, TcpStream>>,
    addr: SocketAddr,
}

impl ServerState {
    fn conns_lock(&self) -> std::sync::MutexGuard<'_, HashMap<u64, TcpStream>> {
        // A handler that panicked while holding the lock must not take
        // the whole server down with a poisoned-lock panic.
        self.conns.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    fn begin_shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return; // already shutting down
        }
        // Wake every reader blocked in read_frame; the write half
        // stays open so in-flight replies still go out. (A connection
        // racing registration against this sweep half-closes itself:
        // the acceptor re-checks the flag after inserting.)
        for stream in self.conns_lock().values() {
            let _ = stream.shutdown(Shutdown::Read);
        }
        // Wake the acceptor with a no-op connection. A wildcard bind
        // (0.0.0.0 / [::]) is not connectable everywhere — aim at the
        // matching loopback address instead.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
    }
}

/// Cloneable trigger for graceful shutdown (also fired by a client
/// `SHUTDOWN` frame).
#[derive(Clone)]
pub struct ServerHandle {
    state: Arc<ServerState>,
}

impl ServerHandle {
    /// Stop accepting, wake blocked readers, let in-flight requests
    /// finish; [`Server::run`] then joins the handlers and returns.
    pub fn shutdown(&self) {
        self.state.begin_shutdown();
    }

    /// Whether shutdown has been triggered.
    pub fn is_shutdown(&self) -> bool {
        self.state.shutdown.load(Ordering::SeqCst)
    }

    /// Live connection count (admission-control observability).
    pub fn active_connections(&self) -> usize {
        self.state.active.load(Ordering::SeqCst)
    }
}

/// Decrements the live-connection count and unregisters the read-half
/// clone even if the handler unwinds.
struct ConnGuard {
    state: Arc<ServerState>,
    id: u64,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.state.conns_lock().remove(&self.id);
        self.state.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A bound (not yet running) TCP frontend over a [`ModelHub`].
pub struct Server {
    listener: TcpListener,
    hub: Arc<ModelHub>,
    max_conns: usize,
    idle_timeout: Duration,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:4000`; port 0 picks a free port,
    /// read it back with [`Server::local_addr`]).
    pub fn bind(addr: impl ToSocketAddrs, hub: Arc<ModelHub>, opts: &ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        Ok(Server {
            listener,
            hub,
            max_conns: opts.max_conns.max(1),
            idle_timeout: opts.idle_timeout,
            state: Arc::new(ServerState {
                shutdown: AtomicBool::new(false),
                active: AtomicUsize::new(0),
                next_conn: AtomicU64::new(0),
                conns: Mutex::new(HashMap::new()),
                addr: local,
            }),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// A shutdown trigger usable from any thread.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { state: Arc::clone(&self.state) }
    }

    /// Accept and serve until shutdown is triggered (by a `SHUTDOWN`
    /// frame or [`ServerHandle::shutdown`]); returns after in-flight
    /// connections drain.
    pub fn run(self) -> Result<()> {
        let Server { listener, hub, max_conns, idle_timeout, state } = self;
        let metrics = hub.metrics();
        let mut handlers: Vec<JoinHandle<()>> = Vec::new();
        loop {
            let (mut stream, _peer) = match listener.accept() {
                Ok(pair) => pair,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    if state.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    // Persistent accept failures (e.g. EMFILE during a
                    // connection storm) must not busy-spin the
                    // acceptor hot — back off briefly so handlers can
                    // release fds.
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    continue;
                }
            };
            if state.shutdown.load(Ordering::SeqCst) {
                break; // the shutdown wake-up connection lands here
            }
            // Reap finished handler threads so the list stays bounded
            // by the connection cap, not the server's lifetime.
            handlers.retain(|h| !h.is_finished());
            if state.active.load(Ordering::SeqCst) >= max_conns {
                metrics.net_conns_rejected.fetch_add(1, Ordering::Relaxed);
                let _ = protocol::write_frame(
                    &mut stream,
                    &Frame::error(
                        ErrorCode::Overloaded,
                        format!("server at its connection cap ({max_conns}); retry later"),
                    ),
                );
                continue; // dropped: explicit rejection, never a stall
            }
            // A connection that cannot be registered for the shutdown
            // wake (clone failure under fd pressure) must not be
            // served — its blocked reader would hang the drain.
            let read_half = match stream.try_clone() {
                Ok(half) => half,
                Err(_) => {
                    metrics.net_conns_rejected.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            };
            let id = state.next_conn.fetch_add(1, Ordering::Relaxed);
            state.conns_lock().insert(id, read_half);
            if state.shutdown.load(Ordering::SeqCst) {
                // begin_shutdown's half-close sweep may have run
                // between the flag check above and the insert; this
                // connection would then block in read_frame forever
                // and hang the drain. Half-close it ourselves —
                // SeqCst ordering guarantees one of the two sides
                // sees the other.
                if let Some(stream) = state.conns_lock().get(&id) {
                    let _ = stream.shutdown(Shutdown::Read);
                }
            }
            state.active.fetch_add(1, Ordering::SeqCst);
            let guard = ConnGuard { state: Arc::clone(&state), id };
            let hub = Arc::clone(&hub);
            let conn_state = Arc::clone(&state);
            let conn_metrics = Arc::clone(&metrics);
            let spawned = std::thread::Builder::new()
                .name(format!("lrbi-conn-{id}"))
                .spawn(move || {
                    let _guard = guard;
                    handle_conn(stream, &hub, &conn_state, &conn_metrics, idle_timeout);
                });
            match spawned {
                Ok(handle) => {
                    // Counted accepted only once a handler actually
                    // serves it, so a shed connection is never both
                    // accepted and rejected in STATS.
                    metrics.net_conns_accepted.fetch_add(1, Ordering::Relaxed);
                    handlers.push(handle);
                }
                Err(_) => {
                    // Thread exhaustion (EAGAIN/ENOMEM) must shed this
                    // connection, not panic the acceptor: dropping the
                    // un-run closure closes the stream and runs the
                    // guard's cleanup.
                    metrics.net_conns_rejected.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        drop(listener); // stop accepting before draining handlers
        for handle in handlers {
            let _ = handle.join();
        }
        Ok(())
    }
}

/// Per-connection request loop: read frames, dispatch, write replies.
fn handle_conn(
    stream: TcpStream,
    hub: &ModelHub,
    state: &ServerState,
    metrics: &Metrics,
    idle_timeout: Duration,
) {
    use crate::util::fault::{self, FaultPoint};
    let _ = stream.set_nodelay(true);
    // Socket options are shared with the read-half clones below, so
    // both directions get bounded before any clone is used. A
    // connection whose timeouts cannot be armed is *closed*, never
    // served untimed: an untimed reader would hold its `--max-conns`
    // slot forever once the peer goes silent.
    for (dir, res) in [
        ("read", stream.set_read_timeout(Some(idle_timeout))),
        ("write", stream.set_write_timeout(Some(CONN_WRITE_TIMEOUT))),
    ] {
        if let Err(e) = res {
            metrics.net_timeout_config_errors.fetch_add(1, Ordering::Relaxed);
            crate::lrbi_log!(
                Level::Warn,
                "closing connection: cannot arm {dir} timeout ({e}); refusing to serve untimed"
            );
            return;
        }
    }
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    loop {
        // Fault-plan hooks (no-ops unless `LRBI_FAULT` names them; one
        // relaxed atomic load when disabled — see util::fault).
        if let Some(a) = fault::fire(FaultPoint::ReadStall) {
            fault::stall(&a);
        }
        if fault::fire(FaultPoint::ConnClose).is_some() {
            break; // simulate the transport dying mid-conversation
        }
        let (frame, decode_ns) = match protocol::read_frame_timed(&mut reader) {
            Ok(Some(pair)) => pair,
            Ok(None) => break, // client closed cleanly
            Err(ReadError::Io(_)) => break,
            Err(ReadError::Wire(e)) => {
                metrics.net_protocol_errors.fetch_add(1, Ordering::Relaxed);
                // Some wire errors leave unread payload on the stream
                // (oversized prefix, a peer silent mid-frame — the
                // slow-loris case): those cannot be re-synced, so
                // reply and close. Every other decode error consumed
                // exactly one frame; the connection stays usable.
                let fatal = e.unsyncable();
                let _ = protocol::write_frame(
                    &mut writer,
                    &Frame::Error { code: e.code, message: e.message },
                );
                if fatal {
                    break;
                }
                continue;
            }
        };
        if fault::fire(FaultPoint::ReadTruncate).is_some() {
            // Pretend the frame arrived torn: answer the typed error a
            // real truncation would get; the connection stays usable.
            metrics.net_protocol_errors.fetch_add(1, Ordering::Relaxed);
            let reply = Frame::error(ErrorCode::BadFrame, "injected truncated frame (fault plan)");
            if protocol::write_frame(&mut writer, &reply).is_err() {
                break;
            }
            continue;
        }
        if let Some(a) = fault::fire(FaultPoint::WriteStall) {
            fault::stall(&a);
        }
        let reply = match frame {
            Frame::Infer { key, batch, deadline_us } => {
                // The budget is measured from decode: the clock starts
                // the moment the server understood the request.
                let deadline =
                    deadline_us.map(|us| Instant::now() + Duration::from_micros(us));
                metrics.net_requests.fetch_add(1, Ordering::Relaxed);
                metrics.telemetry.record_stage(Stage::Decode, decode_ns);
                let trace = metrics.telemetry.next_trace_id();
                let t_req = Instant::now();
                let (reply, stages, request_hist) = if state.shutdown.load(Ordering::SeqCst) {
                    (Frame::error(ErrorCode::ShuttingDown, "server is shutting down"), None, None)
                } else if fault::fire(FaultPoint::InferOverload).is_some() {
                    // Simulate transient admission-control rejection:
                    // exactly what a real full queue answers, so client
                    // retry paths can be exercised deterministically.
                    metrics.net_rejected_overload.fetch_add(1, Ordering::Relaxed);
                    (
                        Frame::error(
                            ErrorCode::Overloaded,
                            "injected transient overload (fault plan); retry with backoff",
                        ),
                        None,
                        None,
                    )
                } else {
                    match hub.get(&key) {
                        None => (
                            Frame::error(
                                ErrorCode::UnknownModel,
                                format!("no model '{key}' (available: {})", hub.keys().join(", ")),
                            ),
                            None,
                            None,
                        ),
                        Some(slot) => {
                            let hist = slot.request_hist.clone();
                            match slot.infer_batch(&batch, deadline) {
                                Ok((logits, st)) => (Frame::Logits(logits), Some(st), hist),
                                Err(e) => {
                                    if e.code == ErrorCode::Overloaded {
                                        metrics
                                            .net_rejected_overload
                                            .fetch_add(1, Ordering::Relaxed);
                                    }
                                    (Frame::Error { code: e.code, message: e.message }, None, hist)
                                }
                            }
                        }
                    }
                };
                // The INFER path writes its own reply so encode+write
                // lands in the trace as the `write` stage.
                let t_write = Instant::now();
                let write_ok = protocol::write_frame(&mut writer, &reply).is_ok();
                let write_ns = t_write.elapsed().as_nanos() as u64;
                metrics.telemetry.record_stage(Stage::Write, write_ns);
                let total_ns = decode_ns.saturating_add(t_req.elapsed().as_nanos() as u64);
                if let Some(hist) = request_hist {
                    hist.record(total_ns);
                }
                if let Some(mut st) = stages {
                    st.decode = decode_ns;
                    st.write = write_ns;
                    if total_ns >= slow_request_threshold_ns() {
                        crate::lrbi_log!(
                            Level::Info,
                            "slow request trace={trace} model='{key}' total={total_ns}ns {}",
                            st.breakdown()
                        );
                    }
                }
                if write_ok {
                    continue;
                }
                break;
            }
            Frame::StatsRequest => Frame::Stats(
                metrics
                    .snapshot()
                    .named_counters()
                    .into_iter()
                    .map(|(name, value)| (name.to_string(), value))
                    .collect(),
            ),
            Frame::Stats2Request => {
                let counters = metrics
                    .snapshot()
                    .named_counters()
                    .into_iter()
                    .map(|(name, value)| (name.to_string(), value))
                    .collect();
                let histograms = metrics
                    .telemetry
                    .export()
                    .into_iter()
                    .map(|s| {
                        let (p50, p95, p99) = s.hist.percentiles();
                        HistSummary {
                            name: s.name.to_string(),
                            labels: s.label_string(),
                            count: s.hist.count,
                            sum: s.hist.sum,
                            p50,
                            p95,
                            p99,
                        }
                    })
                    .collect();
                Frame::Stats2 { counters, histograms }
            }
            Frame::Scatter { key, col_start, col_end, batch, deadline_us } => {
                // Worker half of the router tier (docs/CLUSTER.md):
                // run the full forward pass, reply with only the
                // requested output columns. Slicing happens after
                // inference, so the PARTIAL is bitwise equal to those
                // columns of an unsharded INFER of the same batch.
                let deadline =
                    deadline_us.map(|us| Instant::now() + Duration::from_micros(us));
                metrics.net_requests.fetch_add(1, Ordering::Relaxed);
                metrics.telemetry.record_stage(Stage::Decode, decode_ns);
                if state.shutdown.load(Ordering::SeqCst) {
                    Frame::error(ErrorCode::ShuttingDown, "server is shutting down")
                } else {
                    match hub.get(&key) {
                        None => Frame::error(
                            ErrorCode::UnknownModel,
                            format!("no model '{key}' (available: {})", hub.keys().join(", ")),
                        ),
                        Some(slot) => {
                            if col_start > col_end || col_end as usize > slot.classes() {
                                Frame::error(
                                    ErrorCode::BadShape,
                                    format!(
                                        "scatter columns {col_start}..{col_end} out of range \
                                         for a {}-column model",
                                        slot.classes()
                                    ),
                                )
                            } else {
                                match slot.infer_batch(&batch, deadline) {
                                    Ok((logits, _stages)) => {
                                        match shard::slice_columns(&logits, col_start, col_end) {
                                            Ok(part) => {
                                                if let Some(a) =
                                                    fault::fire(FaultPoint::PartialStall)
                                                {
                                                    fault::stall(&a);
                                                }
                                                Frame::Partial { col_start, col_end, batch: part }
                                            }
                                            Err(e) => Frame::error(ErrorCode::Internal, e),
                                        }
                                    }
                                    Err(e) => {
                                        if e.code == ErrorCode::Overloaded {
                                            metrics
                                                .net_rejected_overload
                                                .fetch_add(1, Ordering::Relaxed);
                                        }
                                        Frame::Error { code: e.code, message: e.message }
                                    }
                                }
                            }
                        }
                    }
                }
            }
            // Liveness probe (router supervisor, docs/CLUSTER.md):
            // answered inline without touching the hub, so probes
            // never inflate `net_requests` or any latency series. A
            // pre-PING server falls through to the catch-all below and
            // answers `bad-frame` — which a prober may still read as
            // "alive, but old".
            Frame::Ping => Frame::Pong,
            Frame::Swap { key } => match hub.swap(&key) {
                Ok(message) => Frame::Ok { message },
                Err(e) => Frame::error(ErrorCode::Internal, e),
            },
            Frame::Shutdown => {
                let _ = protocol::write_frame(
                    &mut writer,
                    &Frame::Ok { message: "shutting down".into() },
                );
                state.begin_shutdown();
                break;
            }
            other => {
                metrics.net_protocol_errors.fetch_add(1, Ordering::Relaxed);
                Frame::error(
                    ErrorCode::BadFrame,
                    format!("unexpected {} frame from a client", other.type_name()),
                )
            }
        };
        if protocol::write_frame(&mut writer, &reply).is_err() {
            break;
        }
    }
}

/// Client-side retry policy for transient failures: `overloaded` and
/// `unavailable` replies and timeout / connection-reset I/O errors are retried with
/// capped exponential backoff plus equal jitter (deterministic per
/// `seed`, so tests and the loadgen bench are reproducible). Anything
/// typed — bad shape, unknown model, deadline exceeded — is never
/// retried: the same request would fail the same way.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = fail fast).
    pub max_retries: u32,
    /// Backoff before retry `n` is `base_backoff * 2^n`, capped at
    /// `max_backoff`, then jittered into `[cap/2, cap]`.
    pub base_backoff: Duration,
    /// Upper bound on any single backoff sleep.
    pub max_backoff: Duration,
    /// Jitter RNG seed (same seed ⇒ same backoff schedule).
    pub seed: u64,
}

impl RetryPolicy {
    /// No retries: every transient failure surfaces immediately
    /// (the pre-PR-8 client behavior).
    pub fn none() -> Self {
        RetryPolicy {
            max_retries: 0,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
            seed: 0,
        }
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
            seed: 0x7E7,
        }
    }
}

/// Connection/resilience knobs for [`NetClient::connect_with`].
#[derive(Debug, Clone, Copy)]
pub struct ClientOptions {
    /// Bound on TCP connect (and reconnect) time; `None` blocks on
    /// the OS default.
    pub connect_timeout: Option<Duration>,
    /// Socket read/write timeout per frame; a server stalled longer
    /// surfaces as a timed-out I/O error (retryable under `retry`).
    pub io_timeout: Option<Duration>,
    /// Retry policy for transient failures.
    pub retry: RetryPolicy,
    /// Default per-call budget for [`NetClient::infer`]: bounds the
    /// whole attempt+retry loop client-side and rides the wire as the
    /// INFER frame's `deadline_us`, so the server sheds work the
    /// client has already given up on.
    pub deadline: Option<Duration>,
}

impl Default for ClientOptions {
    /// Defaults preserve the original client behavior exactly: no
    /// timeouts, no retries, no deadline.
    fn default() -> Self {
        ClientOptions {
            connect_timeout: None,
            io_timeout: None,
            retry: RetryPolicy::none(),
            deadline: None,
        }
    }
}

/// Blocking client for the wire protocol — used by the CLI example,
/// the `perf_serve_loadgen` bench, and the integration tests.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Resolved peer, kept so a retry can reconnect after an I/O
    /// failure left the old stream in an unknown framing state.
    addr: SocketAddr,
    opts: ClientOptions,
}

/// I/O failures worth retrying: the peer (or network) hiccuped in a
/// way a fresh connection may survive. Everything else — refused,
/// unreachable, permission — fails the same way again immediately.
fn transient_io(kind: std::io::ErrorKind) -> bool {
    use std::io::ErrorKind::*;
    matches!(
        kind,
        TimedOut | WouldBlock | ConnectionReset | ConnectionAborted | BrokenPipe | UnexpectedEof
    )
}

/// Backoff before retry `attempt`: `base * 2^attempt` capped at
/// `max_backoff`, equal-jittered into `[cap/2, cap]` so synchronized
/// clients do not re-stampede the server on the same tick.
pub(crate) fn backoff_with_jitter(
    policy: &RetryPolicy,
    attempt: u32,
    rng: &mut crate::util::rng::Rng,
) -> Duration {
    let cap = policy
        .base_backoff
        .saturating_mul(1u32.checked_shl(attempt.min(20)).unwrap_or(u32::MAX))
        .min(policy.max_backoff);
    let half = cap / 2;
    let span_ns = (cap - half).as_nanos().min(u64::MAX as u128) as u64;
    let jitter = if span_ns == 0 { 0 } else { rng.next_range(span_ns + 1) };
    half + Duration::from_nanos(jitter)
}

/// Turn a server reply into the expected payload: error frames and
/// unexpected types both become typed [`Error::Protocol`]s.
fn expect_reply<T>(
    reply: Frame,
    want: &str,
    extract: impl FnOnce(Frame) -> std::result::Result<T, Frame>,
) -> Result<T> {
    match reply {
        Frame::Error { code, message } => {
            Err(Error::Protocol(format!("{}: {message}", code.name())))
        }
        other => extract(other)
            .map_err(|got| Error::Protocol(format!("expected {want}, got {}", got.type_name()))),
    }
}

impl NetClient {
    /// Connect to a running `lrbi serve --listen` frontend with the
    /// plain (no timeout, no retry) options.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient> {
        Self::connect_with(addr, ClientOptions::default())
    }

    /// Connect with explicit resilience options. Every resolved
    /// address is tried in order; the last error is returned if none
    /// accepts.
    pub fn connect_with(addr: impl ToSocketAddrs, opts: ClientOptions) -> Result<NetClient> {
        let mut last: Option<std::io::Error> = None;
        for sock in addr.to_socket_addrs()? {
            match Self::open_stream(sock, &opts) {
                Ok(stream) => {
                    let reader = BufReader::new(stream.try_clone()?);
                    return Ok(NetClient { reader, writer: stream, addr: sock, opts });
                }
                Err(e) => last = Some(e),
            }
        }
        Err(match last {
            Some(e) => Error::Io(e),
            None => Error::invalid("address resolved to nothing"),
        })
    }

    fn open_stream(addr: SocketAddr, opts: &ClientOptions) -> std::io::Result<TcpStream> {
        let stream = match opts.connect_timeout {
            Some(t) => TcpStream::connect_timeout(&addr, t)?,
            None => TcpStream::connect(addr)?,
        };
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(opts.io_timeout)?;
        stream.set_write_timeout(opts.io_timeout)?;
        Ok(stream)
    }

    /// Drop the (possibly desynced) stream and dial the peer again
    /// with the same options.
    fn reconnect(&mut self) -> Result<()> {
        let stream = Self::open_stream(self.addr, &self.opts)?;
        self.reader = BufReader::new(stream.try_clone()?);
        self.writer = stream;
        Ok(())
    }

    /// Send one frame, read one reply (the protocol is strictly
    /// request/response per connection).
    pub fn call(&mut self, frame: &Frame) -> Result<Frame> {
        protocol::write_frame(&mut self.writer, frame)?;
        match protocol::read_frame(&mut self.reader) {
            Ok(Some(reply)) => Ok(reply),
            Ok(None) => Err(Error::Protocol("server closed the connection".into())),
            Err(ReadError::Io(e)) => Err(Error::Io(e)),
            Err(ReadError::Wire(e)) => Err(e.into()),
        }
    }

    /// Run a row batch through the model named `key` ("" = default);
    /// an error frame becomes a typed [`Error::Protocol`]. Honors the
    /// client's configured retry policy and default deadline: see
    /// [`NetClient::infer_with_deadline`].
    pub fn infer(&mut self, key: &str, batch: RowBatch) -> Result<RowBatch> {
        self.infer_with_deadline(key, batch, self.opts.deadline)
    }

    /// Run a row batch with an explicit per-call budget.
    ///
    /// The budget bounds the **whole** attempt+retry loop: each
    /// attempt sends the *remaining* budget as the frame's
    /// `deadline_us` (so the server never works on a request the
    /// client has abandoned), and a retry whose backoff would
    /// overshoot the budget returns the last failure instead of
    /// sleeping past it. Retries fire on `overloaded` and
    /// `unavailable` replies (a router shard mid-failover) and on
    /// transient I/O (timeout, reset, broken pipe — the connection is
    /// re-dialed first, since a half-read frame cannot be re-synced);
    /// every retry is counted in the process-wide
    /// `net_retries_observed` metric.
    pub fn infer_with_deadline(
        &mut self,
        key: &str,
        batch: RowBatch,
        budget: Option<Duration>,
    ) -> Result<RowBatch> {
        let deadline = budget.map(|b| Instant::now() + b);
        let policy = self.opts.retry;
        let mut rng = crate::util::rng::Rng::new(policy.seed);
        let mut attempt: u32 = 0;
        loop {
            let deadline_us = match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(Error::Deadline(
                            "client budget exhausted before send".into(),
                        ));
                    }
                    Some((d - now).as_micros().min(u64::MAX as u128) as u64)
                }
                None => None,
            };
            let result = self.call(&Frame::Infer {
                key: key.to_string(),
                batch: batch.clone(),
                deadline_us,
            });
            let (retryable, reconnect) = match &result {
                Ok(Frame::Error {
                    code: ErrorCode::Overloaded | ErrorCode::Unavailable, ..
                }) => (true, false),
                Err(Error::Io(e)) if transient_io(e.kind()) => (true, true),
                _ => (false, false),
            };
            if !retryable || attempt >= policy.max_retries {
                return expect_reply(result?, "LOGITS", |frame| match frame {
                    Frame::Logits(logits) => Ok(logits),
                    other => Err(other),
                });
            }
            let sleep = backoff_with_jitter(&policy, attempt, &mut rng);
            if let Some(d) = deadline {
                if Instant::now() + sleep >= d {
                    // No budget left to retry inside — surface the
                    // last failure rather than sleeping past the
                    // deadline.
                    return expect_reply(result?, "LOGITS", |frame| match frame {
                        Frame::Logits(logits) => Ok(logits),
                        other => Err(other),
                    });
                }
            }
            crate::coordinator::metrics::record_net_retry();
            std::thread::sleep(sleep);
            if reconnect {
                self.reconnect()?;
            }
            attempt += 1;
        }
    }

    /// Fetch the server's metrics snapshot as named counters.
    pub fn stats(&mut self) -> Result<Vec<(String, u64)>> {
        let reply = self.call(&Frame::StatsRequest)?;
        expect_reply(reply, "STATS", |frame| match frame {
            Frame::Stats(entries) => Ok(entries),
            other => Err(other),
        })
    }

    /// Fetch the v2 stats: the same named counters plus a summary
    /// (count/sum/p50/p95/p99) of every telemetry histogram series.
    pub fn stats_v2(&mut self) -> Result<(Vec<(String, u64)>, Vec<HistSummary>)> {
        let reply = self.call(&Frame::Stats2Request)?;
        expect_reply(reply, "STATS2", |frame| match frame {
            Frame::Stats2 { counters, histograms } => Ok((counters, histograms)),
            other => Err(other),
        })
    }

    /// Liveness probe: send `PING`, expect `PONG`. Deliberately does
    /// not retry — the caller (the router's supervisor) owns the
    /// failure policy, and a probe that needs retries *is* the signal.
    pub fn ping(&mut self) -> Result<()> {
        let reply = self.call(&Frame::Ping)?;
        expect_reply(reply, "PONG", |frame| match frame {
            Frame::Pong => Ok(()),
            other => Err(other),
        })
    }

    /// Hot-swap the registry artifact `name` into the server.
    pub fn swap(&mut self, name: &str) -> Result<String> {
        let reply = self.call(&Frame::Swap { key: name.to_string() })?;
        expect_reply(reply, "OK", |frame| match frame {
            Frame::Ok { message } => Ok(message),
            other => Err(other),
        })
    }

    /// Ask the server to shut down gracefully.
    pub fn shutdown_server(&mut self) -> Result<String> {
        let reply = self.call(&Frame::Shutdown)?;
        expect_reply(reply, "OK", |frame| match frame {
            Frame::Ok { message } => Ok(message),
            other => Err(other),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::GEOMETRY;
    use crate::serve::engine::MlpParams;
    use crate::serve::kernels::KernelFormat;
    use crate::util::bits::BitMatrix;
    use crate::util::rng::Rng;

    fn small_hub() -> Arc<ModelHub> {
        let g = GEOMETRY;
        let params = MlpParams::init(3);
        let mut rng = Rng::new(4);
        let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.25));
        let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.25));
        let backend =
            NativeBackend::with_format(params, KernelFormat::DenseMasked, &ip, &iz).unwrap();
        Arc::new(ModelHub::from_backend(
            "default",
            backend,
            BatchPolicy::default(),
            64,
            Arc::new(Metrics::new()),
        ))
    }

    #[test]
    fn hub_resolves_default_and_unknown_keys() {
        let hub = small_hub();
        assert!(hub.get("").is_some(), "empty key selects the default");
        assert!(hub.get("default").is_some());
        assert!(hub.get("nope").is_none());
        assert_eq!(hub.keys(), vec!["default".to_string()]);
        assert_eq!(hub.default_key(), "default");
        let err = hub.swap("default").unwrap_err();
        assert!(err.to_string().contains("--registry"), "{err}");
    }

    #[test]
    fn slot_rejects_bad_shape_and_serves_empty_batches() {
        let hub = small_hub();
        let slot = hub.get("").unwrap();
        let bad = RowBatch::new(1, slot.input_dim() + 1, vec![0.0; slot.input_dim() + 1]).unwrap();
        let err = slot.infer_batch(&bad, None).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadShape);
        let empty = RowBatch::new(0, 0, vec![]).unwrap();
        let (logits, stages) = slot.infer_batch(&empty, None).unwrap();
        assert_eq!((logits.rows(), logits.cols()), (0, slot.classes()));
        assert_eq!(stages, StageNanos::default(), "no rows ran, no stages timed");
        assert!(slot.request_hist.is_some(), "hub-installed slots get a request series");
    }

    #[test]
    fn expired_deadline_is_shed_before_admission() {
        let hub = small_hub();
        let slot = hub.get("").unwrap();
        let row = RowBatch::new(1, slot.input_dim(), vec![0.0; slot.input_dim()]).unwrap();
        let past = Instant::now() - Duration::from_millis(5);
        let err = slot.infer_batch(&row, Some(past)).unwrap_err();
        assert_eq!(err.code, ErrorCode::DeadlineExceeded);
        let snap = hub.metrics().snapshot();
        assert_eq!(snap.net_deadline_exceeded, 1, "shed counted at admission");
        assert_eq!(snap.kernel_spmms, 0, "no row may reach spmm");
        // A generous deadline serves normally on the same slot.
        let (logits, _) = slot.infer_batch(&row, Some(Instant::now() + Duration::from_secs(30))).unwrap();
        assert_eq!(logits.rows(), 1);
    }

    #[test]
    fn backoff_is_deterministic_capped_and_jittered() {
        let policy = RetryPolicy {
            max_retries: 5,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(80),
            seed: 42,
        };
        let mut a = Rng::new(policy.seed);
        let mut b = Rng::new(policy.seed);
        for attempt in 0..6 {
            let x = backoff_with_jitter(&policy, attempt, &mut a);
            let y = backoff_with_jitter(&policy, attempt, &mut b);
            assert_eq!(x, y, "same seed, same schedule");
            let cap = (Duration::from_millis(10) * 2u32.pow(attempt)).min(Duration::from_millis(80));
            assert!(x >= cap / 2 && x <= cap, "attempt {attempt}: {x:?} outside [{:?}, {cap:?}]", cap / 2);
        }
        // RetryPolicy::none never sleeps.
        let none = RetryPolicy::none();
        assert_eq!(backoff_with_jitter(&none, 3, &mut a), Duration::ZERO);
    }

    #[test]
    fn transient_io_kinds_are_the_retryable_set() {
        use std::io::ErrorKind::*;
        for kind in [TimedOut, WouldBlock, ConnectionReset, ConnectionAborted, BrokenPipe, UnexpectedEof] {
            assert!(transient_io(kind), "{kind:?}");
        }
        for kind in [ConnectionRefused, NotFound, PermissionDenied, InvalidData] {
            assert!(!transient_io(kind), "{kind:?}");
        }
    }

    #[test]
    fn bound_server_reports_resolved_addr_and_handle_state() {
        let hub = small_hub();
        let server = Server::bind("127.0.0.1:0", hub, &ServeOptions::default()).unwrap();
        let addr = server.local_addr();
        assert_ne!(addr.port(), 0, "port 0 must resolve to a real port");
        let handle = server.handle();
        assert!(!handle.is_shutdown());
        assert_eq!(handle.active_connections(), 0);
        let runner = std::thread::spawn(move || server.run());
        handle.shutdown();
        runner.join().unwrap().unwrap();
        assert!(handle.is_shutdown());
    }
}
