//! The `lrbi` wire protocol: a small, versioned, length-prefixed
//! binary framing for network inference (`lrbi serve --listen`).
//!
//! Every message is one **frame**:
//!
//! ```text
//! offset  size  field
//! 0       4     payload length (u32 LE) — bytes after this field
//! 4       1     protocol version (currently 1)
//! 5       1     frame type
//! 6       ...   body (frame-type specific)
//! ```
//!
//! Clients send [`Frame::Infer`] (model key + an f32 row batch) and
//! receive [`Frame::Logits`] or a typed [`Frame::Error`] carrying an
//! [`ErrorCode`] — overload is an *explicit rejection frame*
//! ([`ErrorCode::Overloaded`]), never a silent stall. `STATS`, `SWAP`
//! and `SHUTDOWN` frames expose the server's metrics snapshot,
//! registry hot-swap, and graceful shutdown over the same socket.
//!
//! Decoding is strict: unknown frame types, version mismatches,
//! truncated or trailing bytes, and oversized length prefixes all
//! surface as typed [`WireError`]s (the server answers them with an
//! error frame; they never panic). The normative byte-level spec —
//! including a worked hex example — lives in `docs/PROTOCOL.md`; this
//! module is its reference implementation, and `tests/server.rs` pins
//! round-trip and corruption behavior.

use crate::util::error::Error;
use std::io::{Read, Write};
use std::time::Instant;

/// Protocol version carried in every frame (byte 4 on the wire).
pub const PROTOCOL_VERSION: u8 = 1;

/// Upper bound on a frame's payload length. A length prefix above
/// this is rejected with [`ErrorCode::TooLarge`] *before* the payload
/// is read, so a malicious or corrupt prefix cannot trigger a 4 GiB
/// allocation.
pub const MAX_FRAME: u32 = 1 << 24; // 16 MiB

// Frame type bytes (wire values; pinned by tests).
const FT_INFER: u8 = 0x01;
const FT_LOGITS: u8 = 0x02;
const FT_ERROR: u8 = 0x03;
const FT_STATS_REQ: u8 = 0x04;
const FT_STATS: u8 = 0x05;
const FT_SWAP: u8 = 0x06;
const FT_OK: u8 = 0x07;
const FT_SHUTDOWN: u8 = 0x08;
const FT_STATS2_REQ: u8 = 0x09;
const FT_STATS2: u8 = 0x0A;
const FT_SCATTER: u8 = 0x0B;
const FT_PARTIAL: u8 = 0x0C;
const FT_PING: u8 = 0x0D;
const FT_PONG: u8 = 0x0E;

/// Typed error codes carried by [`Frame::Error`] (wire values are
/// stable; see `docs/PROTOCOL.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Frame version byte differs from [`PROTOCOL_VERSION`].
    BadVersion = 1,
    /// Malformed frame: unknown type, truncated or trailing body,
    /// bad UTF-8, or a shape/length field that contradicts the body.
    BadFrame = 2,
    /// Length prefix exceeds [`MAX_FRAME`]; the connection is closed
    /// after this error because the stream can no longer be re-synced.
    TooLarge = 3,
    /// The request's model key names no registered model.
    UnknownModel = 4,
    /// Row width does not match the model's input dimension.
    BadShape = 5,
    /// Admission control rejected the request: the bounded request
    /// queue is full or the server is at `--max-conns`.
    Overloaded = 6,
    /// The backend failed while executing the request.
    Internal = 7,
    /// The server is shutting down and no longer admits requests.
    ShuttingDown = 8,
    /// The request's deadline budget expired before (or during)
    /// execution, or admission control predicted the request could
    /// not finish inside its remaining budget — the request was shed
    /// without running spmm (see `docs/ROBUSTNESS.md`). Retrying is
    /// only useful with a larger budget.
    DeadlineExceeded = 9,
    /// A router could not reach any worker replica for some shard of
    /// the model (or the shard group is degraded mid-swap), so the
    /// request cannot be served right now. Transient: clients retry
    /// this like [`ErrorCode::Overloaded`] (see `docs/CLUSTER.md`).
    Unavailable = 10,
}

impl ErrorCode {
    /// Every code, in wire order.
    pub const ALL: [ErrorCode; 10] = [
        ErrorCode::BadVersion,
        ErrorCode::BadFrame,
        ErrorCode::TooLarge,
        ErrorCode::UnknownModel,
        ErrorCode::BadShape,
        ErrorCode::Overloaded,
        ErrorCode::Internal,
        ErrorCode::ShuttingDown,
        ErrorCode::DeadlineExceeded,
        ErrorCode::Unavailable,
    ];

    /// Decode a wire byte.
    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        ErrorCode::ALL.into_iter().find(|c| *c as u8 == b)
    }

    /// Stable lowercase name (used in error messages and docs).
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::BadVersion => "bad-version",
            ErrorCode::BadFrame => "bad-frame",
            ErrorCode::TooLarge => "too-large",
            ErrorCode::UnknownModel => "unknown-model",
            ErrorCode::BadShape => "bad-shape",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Internal => "internal",
            ErrorCode::ShuttingDown => "shutting-down",
            ErrorCode::DeadlineExceeded => "deadline-exceeded",
            ErrorCode::Unavailable => "unavailable",
        }
    }
}

/// A typed protocol failure: what the server answers with an error
/// frame, and what strict decoding returns on malformed input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    /// Machine-readable code (also the error frame's code byte).
    pub code: ErrorCode,
    /// Human-readable context.
    pub message: String,
}

impl WireError {
    /// Build from a code + displayable context.
    pub fn new(code: ErrorCode, message: impl std::fmt::Display) -> Self {
        WireError { code, message: message.to_string() }
    }

    /// True when the stream can no longer be re-synced after this
    /// error and the server must close the connection (an oversized
    /// length prefix, or a peer that went silent mid-frame). All other
    /// wire errors are answered with an error frame and the connection
    /// stays usable.
    pub fn unsyncable(&self) -> bool {
        self.code == ErrorCode::TooLarge || self.message.starts_with("stream timed out inside")
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.name(), self.message)
    }
}

impl From<WireError> for Error {
    fn from(e: WireError) -> Self {
        Error::Protocol(e.to_string())
    }
}

/// Why [`read_frame`] failed: transport I/O vs protocol violation.
/// I/O failures end the connection; wire errors are answered with a
/// typed error frame (and, for [`ErrorCode::TooLarge`], also end the
/// connection, since the stream cannot be re-synced).
#[derive(Debug)]
pub enum ReadError {
    /// Transport failure (socket reset, read error).
    Io(std::io::Error),
    /// Protocol violation with its typed code.
    Wire(WireError),
}

/// A dense batch of `rows × cols` f32 values, row-major — the payload
/// of [`Frame::Infer`] (model inputs) and [`Frame::Logits`] (outputs).
#[derive(Debug, Clone, PartialEq)]
pub struct RowBatch {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl RowBatch {
    /// Build from shape + row-major data; rejects mismatched lengths
    /// and batches too large for one frame.
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> crate::util::error::Result<Self> {
        if rows.checked_mul(cols) != Some(data.len()) {
            return Err(Error::Protocol(format!(
                "row batch {rows}x{cols} vs {} values",
                data.len()
            )));
        }
        // body = 8 bytes of shape + 4 per value, plus header and — for
        // Infer — a u16-length key; budget the worst-case key (64 KiB)
        // so a client-validated batch always encodes under MAX_FRAME.
        if 16 + 4 * data.len() as u64 + (u16::MAX as u64 + 2) > MAX_FRAME as u64 {
            return Err(Error::Protocol(format!(
                "row batch {rows}x{cols} does not fit one frame (max {MAX_FRAME} bytes)"
            )));
        }
        Ok(RowBatch { rows, cols, data })
    }

    /// Build from a slice of equal-length rows.
    pub fn from_rows(rows: &[Vec<f32>]) -> crate::util::error::Result<Self> {
        let cols = rows.first().map(|r| r.len()).unwrap_or(0);
        if rows.iter().any(|r| r.len() != cols) {
            return Err(Error::Protocol("ragged row batch".into()));
        }
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            data.extend_from_slice(r);
        }
        RowBatch::new(rows.len(), cols, data)
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Row width.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// All values, row-major.
    pub fn data(&self) -> &[f32] {
        &self.data
    }
}

/// One latency-histogram summary inside [`Frame::Stats2`]: the series
/// identity plus its count, exact nanosecond sum, and the p50/p95/p99
/// triple (see `docs/PROTOCOL.md` for the field table).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSummary {
    /// Metric name (e.g. `stage_ns`).
    pub name: String,
    /// Label pairs as a `k=v,k=v` string ("" when unlabeled).
    pub labels: String,
    /// Samples recorded.
    pub count: u64,
    /// Exact sum of recorded values.
    pub sum: u64,
    /// 50th-percentile value (bucket midpoint; 0 when empty).
    pub p50: u64,
    /// 95th-percentile value.
    pub p95: u64,
    /// 99th-percentile value.
    pub p99: u64,
}

/// One protocol message. `Infer`, `StatsRequest`, `Stats2Request`,
/// `Swap` and `Shutdown` flow client → server; `Logits`, `Error`,
/// `Stats`, `Stats2` and `Ok` flow server → client.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Run a row batch through the model named `key` (empty key =
    /// server default model).
    Infer {
        /// Model key (registry name; empty selects the default).
        key: String,
        /// Input rows, each `input_dim` wide.
        batch: RowBatch,
        /// Optional deadline budget in **microseconds**, measured by
        /// the server from the moment it decodes the frame (a relative
        /// budget needs no clock sync). `None` encodes byte-identically
        /// to the original INFER layout, so pre-deadline clients keep
        /// working unchanged; `Some(0)` is an already-expired request
        /// (useful to probe shedding). Expired or unaffordable
        /// requests are answered with [`ErrorCode::DeadlineExceeded`].
        deadline_us: Option<u64>,
    },
    /// Per-row logits answering an `Infer`.
    Logits(RowBatch),
    /// Typed failure answering any request.
    Error {
        /// Machine-readable code.
        code: ErrorCode,
        /// Human-readable context.
        message: String,
    },
    /// Ask for the server's metrics snapshot.
    StatsRequest,
    /// Named counters answering a `StatsRequest`
    /// (`MetricsSnapshot::named_counters` order).
    Stats(Vec<(String, u64)>),
    /// Hot-swap the registry artifact named `key` into the running
    /// server (in-flight batches finish on the old kernel).
    Swap {
        /// Registry artifact name.
        key: String,
    },
    /// Success acknowledgement for `Swap` / `Shutdown`.
    Ok {
        /// Human-readable detail.
        message: String,
    },
    /// Ask the server to shut down gracefully (stop accepting, finish
    /// in-flight requests, exit).
    Shutdown,
    /// Ask for the v2 stats snapshot: counters *and* histogram
    /// summaries. A v1 client that never sends this byte sees no
    /// change — `STATS` framing is untouched.
    Stats2Request,
    /// Counters + latency-histogram summaries answering a
    /// `Stats2Request`.
    Stats2 {
        /// Named counters (`MetricsSnapshot::named_counters` order —
        /// identical content to [`Frame::Stats`]).
        counters: Vec<(String, u64)>,
        /// One summary per registered histogram series, in
        /// registration order.
        histograms: Vec<HistSummary>,
    },
    /// Router → worker: run a row batch through the model named `key`
    /// and return only the contiguous output columns
    /// `col_start..col_end` as a [`Frame::Partial`]. The worker
    /// computes the full forward pass (every output column is produced
    /// by the same kernel arithmetic as single-process serving) and
    /// slices afterwards, so a fixed-order gather of disjoint partials
    /// is bit-identical to an unsharded `INFER` (see
    /// `docs/CLUSTER.md`).
    Scatter {
        /// Model key on the worker (empty selects the worker default).
        key: String,
        /// First output column of the requested slice (inclusive).
        col_start: u32,
        /// One past the last output column of the slice (exclusive).
        col_end: u32,
        /// Input rows, each `input_dim` wide.
        batch: RowBatch,
        /// Optional deadline budget in **microseconds** with the same
        /// trailing-bytes encoding and semantics as
        /// [`Frame::Infer::deadline_us`].
        deadline_us: Option<u64>,
    },
    /// Worker → router: the output-column slice answering a
    /// [`Frame::Scatter`] — `rows × (col_end - col_start)` logits.
    Partial {
        /// First output column covered (inclusive), echoed back so the
        /// router can verify the gather order.
        col_start: u32,
        /// One past the last covered column (exclusive).
        col_end: u32,
        /// Per-row logits for exactly those columns.
        batch: RowBatch,
    },
    /// Liveness probe (empty body). A router's health supervisor sends
    /// this instead of an empty `INFER` so probes never ride the
    /// inference path or inflate `net_requests` / `request_ns` (see
    /// `docs/CLUSTER.md`). Any v1 server with this frame compiled in
    /// answers [`Frame::Pong`]; a pre-PING server answers `bad-frame`,
    /// which a prober treats as "alive but old".
    Ping,
    /// Liveness reply to [`Frame::Ping`] (empty body).
    Pong,
}

impl Frame {
    /// Convenience error-frame constructor.
    pub fn error(code: ErrorCode, message: impl std::fmt::Display) -> Frame {
        Frame::Error { code, message: message.to_string() }
    }

    /// The frame's wire type byte.
    pub fn type_byte(&self) -> u8 {
        match self {
            Frame::Infer { .. } => FT_INFER,
            Frame::Logits(_) => FT_LOGITS,
            Frame::Error { .. } => FT_ERROR,
            Frame::StatsRequest => FT_STATS_REQ,
            Frame::Stats(_) => FT_STATS,
            Frame::Swap { .. } => FT_SWAP,
            Frame::Ok { .. } => FT_OK,
            Frame::Shutdown => FT_SHUTDOWN,
            Frame::Stats2Request => FT_STATS2_REQ,
            Frame::Stats2 { .. } => FT_STATS2,
            Frame::Scatter { .. } => FT_SCATTER,
            Frame::Partial { .. } => FT_PARTIAL,
            Frame::Ping => FT_PING,
            Frame::Pong => FT_PONG,
        }
    }

    /// Stable frame-type name (logs and docs).
    pub fn type_name(&self) -> &'static str {
        match self {
            Frame::Infer { .. } => "INFER",
            Frame::Logits(_) => "LOGITS",
            Frame::Error { .. } => "ERROR",
            Frame::StatsRequest => "STATS_REQ",
            Frame::Stats(_) => "STATS",
            Frame::Swap { .. } => "SWAP",
            Frame::Ok { .. } => "OK",
            Frame::Shutdown => "SHUTDOWN",
            Frame::Stats2Request => "STATS2_REQ",
            Frame::Stats2 { .. } => "STATS2",
            Frame::Scatter { .. } => "SCATTER",
            Frame::Partial { .. } => "PARTIAL",
            Frame::Ping => "PING",
            Frame::Pong => "PONG",
        }
    }
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Longest prefix of `s` that fits `max` bytes without splitting a
/// UTF-8 code point — every length-prefixed string field truncates
/// through this so `encode` can never emit a frame its own decoder
/// rejects as invalid UTF-8.
fn utf8_prefix(s: &str, max: usize) -> &[u8] {
    if s.len() <= max {
        return s.as_bytes();
    }
    let mut end = max;
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    &s.as_bytes()[..end]
}

fn put_short_str(out: &mut Vec<u8>, s: &str) {
    // u16-length strings; oversized input is truncated at a char
    // boundary (keys and messages are short in practice).
    let bytes = utf8_prefix(s, u16::MAX as usize);
    put_u16(out, bytes.len() as u16);
    out.extend_from_slice(bytes);
}

fn put_batch(out: &mut Vec<u8>, b: &RowBatch) {
    put_u32(out, b.rows as u32);
    put_u32(out, b.cols as u32);
    for v in &b.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// u8-length-prefixed string (counter/series names and label strings).
fn put_tiny_str(out: &mut Vec<u8>, s: &str) {
    let bytes = utf8_prefix(s, u8::MAX as usize);
    out.push(bytes.len() as u8);
    out.extend_from_slice(bytes);
}

/// The counter list layout shared by `STATS` and `STATS2`: u16 count,
/// then per entry a u8-length name and a u64 LE value.
fn put_counters(out: &mut Vec<u8>, entries: &[(String, u64)]) {
    let count = entries.len().min(u16::MAX as usize);
    put_u16(out, count as u16);
    for (name, value) in entries.iter().take(count) {
        put_tiny_str(out, name);
        out.extend_from_slice(&value.to_le_bytes());
    }
}

/// Encode a frame to its full wire bytes (length prefix included).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut payload = Vec::with_capacity(64);
    payload.push(PROTOCOL_VERSION);
    payload.push(frame.type_byte());
    match frame {
        Frame::Infer { key, batch, deadline_us } => {
            put_short_str(&mut payload, key);
            put_batch(&mut payload, batch);
            // Optional trailing deadline (minor protocol revision):
            // omitted entirely for `None`, so deadline-free frames stay
            // byte-identical to the original INFER layout.
            if let Some(us) = deadline_us {
                payload.extend_from_slice(&us.to_le_bytes());
            }
        }
        Frame::Logits(batch) => put_batch(&mut payload, batch),
        Frame::Error { code, message } => {
            payload.push(*code as u8);
            put_short_str(&mut payload, message);
        }
        Frame::StatsRequest | Frame::Shutdown | Frame::Stats2Request | Frame::Ping
        | Frame::Pong => {}
        Frame::Stats(entries) => put_counters(&mut payload, entries),
        Frame::Stats2 { counters, histograms } => {
            put_counters(&mut payload, counters);
            let count = histograms.len().min(u16::MAX as usize);
            put_u16(&mut payload, count as u16);
            for h in histograms.iter().take(count) {
                put_tiny_str(&mut payload, &h.name);
                put_tiny_str(&mut payload, &h.labels);
                for v in [h.count, h.sum, h.p50, h.p95, h.p99] {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        Frame::Swap { key } => put_short_str(&mut payload, key),
        Frame::Ok { message } => put_short_str(&mut payload, message),
        Frame::Scatter { key, col_start, col_end, batch, deadline_us } => {
            put_short_str(&mut payload, key);
            put_u32(&mut payload, *col_start);
            put_u32(&mut payload, *col_end);
            put_batch(&mut payload, batch);
            // Same optional trailing deadline as INFER: omitted for
            // `None`, 8 LE bytes for `Some`.
            if let Some(us) = deadline_us {
                payload.extend_from_slice(&us.to_le_bytes());
            }
        }
        Frame::Partial { col_start, col_end, batch } => {
            put_u32(&mut payload, *col_start);
            put_u32(&mut payload, *col_end);
            put_batch(&mut payload, batch);
        }
    }
    let mut wire = Vec::with_capacity(4 + payload.len());
    wire.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    wire.extend_from_slice(&payload);
    wire
}

/// Strict byte cursor over a frame payload.
struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        if self.off + n > self.b.len() {
            return Err(WireError::new(
                ErrorCode::BadFrame,
                format!("truncated frame: {what} needs {n} bytes"),
            ));
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, WireError> {
        let s = self.take(2, what)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32, WireError> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        let s = self.take(8, what)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    fn short_str(&mut self, what: &str) -> Result<String, WireError> {
        let len = self.u16(what)? as usize;
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::new(ErrorCode::BadFrame, format!("{what}: invalid UTF-8")))
    }

    fn tiny_str(&mut self, what: &str) -> Result<String, WireError> {
        let len = self.u8(what)? as usize;
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::new(ErrorCode::BadFrame, format!("{what}: invalid UTF-8")))
    }

    /// The counter list layout shared by `STATS` and `STATS2`.
    fn counters(&mut self) -> Result<Vec<(String, u64)>, WireError> {
        let count = self.u16("stats count")? as usize;
        let mut entries = Vec::with_capacity(count.min(1024));
        for _ in 0..count {
            let name = self.tiny_str("stats name")?;
            let value = self.u64("stats value")?;
            entries.push((name, value));
        }
        Ok(entries)
    }

    fn batch(&mut self) -> Result<RowBatch, WireError> {
        let rows = self.u32("batch rows")? as usize;
        let cols = self.u32("batch cols")? as usize;
        let bytes_len = rows
            .checked_mul(cols)
            .and_then(|v| v.checked_mul(4))
            .ok_or_else(|| WireError::new(ErrorCode::BadFrame, "batch shape overflows"))?;
        let bytes = self.take(bytes_len, "batch values")?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(RowBatch { rows, cols, data })
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.off
    }

    fn done(self, what: &str) -> Result<(), WireError> {
        if self.off != self.b.len() {
            return Err(WireError::new(
                ErrorCode::BadFrame,
                format!("{what}: {} trailing bytes", self.b.len() - self.off),
            ));
        }
        Ok(())
    }
}

/// Decode one frame payload (the bytes *after* the length prefix).
/// Strict: version must match, the type byte must be known, and the
/// body must be exactly consumed.
pub fn decode_payload(payload: &[u8]) -> Result<Frame, WireError> {
    let mut cur = Cur { b: payload, off: 0 };
    let version = cur.u8("version")?;
    if version != PROTOCOL_VERSION {
        return Err(WireError::new(
            ErrorCode::BadVersion,
            format!("got version {version}, this server speaks {PROTOCOL_VERSION}"),
        ));
    }
    let ftype = cur.u8("frame type")?;
    let frame = match ftype {
        FT_INFER => {
            let key = cur.short_str("model key")?;
            let batch = cur.batch()?;
            // Optional trailing deadline: exactly 8 more bytes means a
            // deadline-carrying client; 0 means a legacy frame. Any
            // other residue falls through to the strict trailing-bytes
            // check in `done`.
            let deadline_us =
                if cur.remaining() == 8 { Some(cur.u64("deadline")?) } else { None };
            Frame::Infer { key, batch, deadline_us }
        }
        FT_LOGITS => Frame::Logits(cur.batch()?),
        FT_ERROR => {
            let code_byte = cur.u8("error code")?;
            let code = ErrorCode::from_u8(code_byte).ok_or_else(|| {
                WireError::new(ErrorCode::BadFrame, format!("unknown error code {code_byte}"))
            })?;
            let message = cur.short_str("error message")?;
            Frame::Error { code, message }
        }
        FT_STATS_REQ => Frame::StatsRequest,
        FT_STATS => Frame::Stats(cur.counters()?),
        FT_STATS2_REQ => Frame::Stats2Request,
        FT_STATS2 => {
            let counters = cur.counters()?;
            let count = cur.u16("histogram count")? as usize;
            let mut histograms = Vec::with_capacity(count.min(1024));
            for _ in 0..count {
                let name = cur.tiny_str("histogram name")?;
                let labels = cur.tiny_str("histogram labels")?;
                let count = cur.u64("histogram count")?;
                let sum = cur.u64("histogram sum")?;
                let p50 = cur.u64("histogram p50")?;
                let p95 = cur.u64("histogram p95")?;
                let p99 = cur.u64("histogram p99")?;
                histograms.push(HistSummary { name, labels, count, sum, p50, p95, p99 });
            }
            Frame::Stats2 { counters, histograms }
        }
        FT_SWAP => Frame::Swap { key: cur.short_str("swap key")? },
        FT_OK => Frame::Ok { message: cur.short_str("ok message")? },
        FT_SHUTDOWN => Frame::Shutdown,
        FT_SCATTER => {
            let key = cur.short_str("model key")?;
            let col_start = cur.u32("scatter col_start")?;
            let col_end = cur.u32("scatter col_end")?;
            let batch = cur.batch()?;
            // Optional trailing deadline, exactly as in INFER.
            let deadline_us =
                if cur.remaining() == 8 { Some(cur.u64("deadline")?) } else { None };
            Frame::Scatter { key, col_start, col_end, batch, deadline_us }
        }
        FT_PARTIAL => {
            let col_start = cur.u32("partial col_start")?;
            let col_end = cur.u32("partial col_end")?;
            let batch = cur.batch()?;
            Frame::Partial { col_start, col_end, batch }
        }
        FT_PING => Frame::Ping,
        FT_PONG => Frame::Pong,
        other => {
            return Err(WireError::new(
                ErrorCode::BadFrame,
                format!("unknown frame type {other:#04x}"),
            ));
        }
    };
    cur.done(frame.type_name())?;
    Ok(frame)
}

/// Whether an I/O error is a read-timeout expiry. Timeouts surface as
/// `WouldBlock` or `TimedOut` depending on platform.
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Write one frame to a stream.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&encode(frame))?;
    w.flush()
}

/// Read one frame from a stream. Returns `Ok(None)` on clean EOF at a
/// frame boundary; a stream ending mid-frame is a typed
/// [`ErrorCode::BadFrame`], and a length prefix above [`MAX_FRAME`] is
/// [`ErrorCode::TooLarge`] (rejected before any payload allocation).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, ReadError> {
    read_frame_timed(r).map(|opt| opt.map(|(frame, _)| frame))
}

/// [`read_frame`] plus the nanoseconds spent *decoding* the payload —
/// parse CPU time only, deliberately excluding the socket wait (which
/// would otherwise dominate every idle connection's `decode` stage).
pub fn read_frame_timed(r: &mut impl Read) -> Result<Option<(Frame, u64)>, ReadError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None); // clean EOF between frames
                }
                return Err(ReadError::Wire(WireError::new(
                    ErrorCode::BadFrame,
                    "stream ended inside a length prefix",
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) && got > 0 => {
                // Slow-loris: the peer opened a frame and went silent.
                // Mid-frame silence is a protocol violation (the stream
                // can no longer be re-synced), unlike an idle timeout
                // at a frame boundary (`got == 0`), which stays a plain
                // I/O close below.
                return Err(ReadError::Wire(WireError::new(
                    ErrorCode::BadFrame,
                    "stream timed out inside a length prefix",
                )));
            }
            Err(e) => return Err(ReadError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(ReadError::Wire(WireError::new(
            ErrorCode::TooLarge,
            format!("frame payload {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        )));
    }
    let mut payload = vec![0u8; len as usize];
    match r.read_exact(&mut payload) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Err(ReadError::Wire(WireError::new(
                ErrorCode::BadFrame,
                "stream ended inside a frame payload",
            )));
        }
        Err(e) if is_timeout(&e) => {
            return Err(ReadError::Wire(WireError::new(
                ErrorCode::BadFrame,
                "stream timed out inside a frame payload",
            )));
        }
        Err(e) => return Err(ReadError::Io(e)),
    }
    // Checked only after the payload was consumed, so an undersized
    // frame leaves the stream synced at the next frame boundary.
    if len < 2 {
        return Err(ReadError::Wire(WireError::new(
            ErrorCode::BadFrame,
            "frame payload shorter than version + type",
        )));
    }
    let t0 = Instant::now();
    let frame = decode_payload(&payload).map_err(ReadError::Wire)?;
    Ok(Some((frame, t0.elapsed().as_nanos() as u64)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(frame: &Frame) -> Frame {
        let wire = encode(frame);
        let mut r = &wire[..];
        let got = read_frame(&mut r).expect("decode").expect("some frame");
        assert_eq!(r.len(), 0, "frame fully consumed");
        got
    }

    #[test]
    fn every_frame_kind_round_trips() {
        let batch = RowBatch::new(2, 3, vec![1.0, -2.5, 0.0, 3.25, f32::MIN, f32::MAX]).unwrap();
        let frames = [
            Frame::Infer { key: "k16".into(), batch: batch.clone(), deadline_us: None },
            Frame::Infer { key: "k16".into(), batch: batch.clone(), deadline_us: Some(1500) },
            Frame::Infer { key: "k16".into(), batch: batch.clone(), deadline_us: Some(0) },
            Frame::Infer {
                key: String::new(),
                batch: RowBatch::new(0, 0, vec![]).unwrap(),
                deadline_us: Some(u64::MAX),
            },
            Frame::Logits(batch),
            Frame::error(ErrorCode::Overloaded, "queue full"),
            Frame::StatsRequest,
            Frame::Stats(vec![("requests".into(), 42), ("spmm_shards".into(), u64::MAX)]),
            Frame::Swap { key: "v2".into() },
            Frame::Ok { message: "swapped".into() },
            Frame::Shutdown,
            Frame::Stats2Request,
            Frame::Ping,
            Frame::Pong,
            Frame::Stats2 { counters: vec![], histograms: vec![] },
            Frame::Stats2 {
                counters: vec![("requests".into(), 42)],
                histograms: vec![
                    HistSummary {
                        name: "stage_ns".into(),
                        labels: "stage=spmm".into(),
                        count: 100,
                        sum: 123_456,
                        p50: 1_000,
                        p95: 2_000,
                        p99: u64::MAX,
                    },
                    HistSummary {
                        name: "spmm_shard_ns".into(),
                        labels: String::new(),
                        count: 0,
                        sum: 0,
                        p50: 0,
                        p95: 0,
                        p99: 0,
                    },
                ],
            },
        ];
        for f in &frames {
            assert_eq!(&roundtrip(f), f, "{}", f.type_name());
        }
    }

    #[test]
    fn scatter_and_partial_round_trip() {
        let batch = RowBatch::new(2, 3, vec![1.0, -2.5, 0.0, 3.25, f32::MIN, f32::MAX]).unwrap();
        let frames = [
            Frame::Scatter {
                key: "model-a".into(),
                col_start: 0,
                col_end: 5,
                batch: batch.clone(),
                deadline_us: None,
            },
            Frame::Scatter {
                key: String::new(),
                col_start: 5,
                col_end: 10,
                batch: batch.clone(),
                deadline_us: Some(1500),
            },
            Frame::Scatter {
                key: "m".into(),
                col_start: u32::MAX - 1,
                col_end: u32::MAX,
                batch: RowBatch::new(0, 0, vec![]).unwrap(),
                deadline_us: Some(0),
            },
            Frame::Partial { col_start: 0, col_end: 3, batch },
            Frame::Partial {
                col_start: 7,
                col_end: 7,
                batch: RowBatch::new(0, 0, vec![]).unwrap(),
            },
        ];
        for f in &frames {
            assert_eq!(&roundtrip(f), f, "{}", f.type_name());
        }
    }

    #[test]
    fn scatter_partial_trailing_deadline_is_rejected() {
        let batch = RowBatch::new(1, 1, vec![0.5]).unwrap();
        let mut wire = encode(&Frame::Scatter {
            key: "k".into(),
            col_start: 0,
            col_end: 1,
            batch,
            deadline_us: Some(42),
        });
        // chop 3 of the 8 deadline bytes and fix up the length prefix
        wire.truncate(wire.len() - 3);
        let plen = (wire.len() - 4) as u32;
        wire[..4].copy_from_slice(&plen.to_le_bytes());
        let mut r = &wire[..];
        match read_frame(&mut r) {
            Err(ReadError::Wire(e)) => {
                assert_eq!(e.code, ErrorCode::BadFrame);
                assert!(e.message.contains("trailing"), "{}", e.message);
            }
            other => panic!("expected BadFrame, got {other:?}"),
        }
    }

    #[test]
    fn ping_pong_are_empty_bodied_and_reject_any_payload() {
        // The 6-byte wire image is the whole frame: length 2, version,
        // type. Pin it byte-for-byte so PING stays cheap forever.
        assert_eq!(encode(&Frame::Ping), vec![2, 0, 0, 0, PROTOCOL_VERSION, 0x0D]);
        assert_eq!(encode(&Frame::Pong), vec![2, 0, 0, 0, PROTOCOL_VERSION, 0x0E]);
        // Truncation fuzz: every strict prefix of the wire image fails
        // to decode as a complete frame, and any trailing byte is a
        // typed bad-frame — an empty body is *exactly* empty.
        for ft in [0x0Du8, 0x0E] {
            let wire = vec![2, 0, 0, 0, PROTOCOL_VERSION, ft];
            for cut in 1..wire.len() {
                let mut r = &wire[..cut];
                match read_frame(&mut r) {
                    Err(ReadError::Wire(e)) => assert_eq!(e.code, ErrorCode::BadFrame),
                    Ok(Some(_)) => panic!("prefix of {cut} bytes decoded as a frame"),
                    // a bare length prefix with no payload is mid-frame EOF
                    other => panic!("cut={cut}: unexpected {other:?}"),
                }
            }
            let mut fat = vec![3, 0, 0, 0, PROTOCOL_VERSION, ft, 0xAA];
            let mut r = &fat[..];
            match read_frame(&mut r) {
                Err(ReadError::Wire(e)) => {
                    assert_eq!(e.code, ErrorCode::BadFrame);
                    assert!(e.message.contains("trailing"), "{}", e.message);
                }
                other => panic!("expected BadFrame on trailing byte, got {other:?}"),
            }
            // and a wrong version byte is still caught first
            fat[4] = PROTOCOL_VERSION + 1;
            fat.truncate(6);
            fat[0] = 2;
            let mut r = &fat[..];
            match read_frame(&mut r) {
                Err(ReadError::Wire(e)) => assert_eq!(e.code, ErrorCode::BadVersion),
                other => panic!("expected BadVersion, got {other:?}"),
            }
        }
    }

    #[test]
    fn timed_read_reports_decode_nanos() {
        let wire = encode(&Frame::Stats(vec![("requests".into(), 1)]));
        let mut r = &wire[..];
        let (frame, _decode_ns) = read_frame_timed(&mut r).unwrap().unwrap();
        assert_eq!(frame.type_name(), "STATS");
        // decode_ns is CPU parse time — can legitimately round to 0 on
        // a coarse clock, so only the framing is asserted here
    }

    #[test]
    fn type_bytes_are_stable() {
        assert_eq!(
            Frame::Infer {
                key: String::new(),
                batch: RowBatch::new(0, 0, vec![]).unwrap(),
                deadline_us: None,
            }
            .type_byte(),
            0x01
        );
        assert_eq!(Frame::Shutdown.type_byte(), 0x08);
        assert_eq!(Frame::Stats2Request.type_byte(), 0x09);
        assert_eq!(Frame::Stats2 { counters: vec![], histograms: vec![] }.type_byte(), 0x0A);
        let empty = || RowBatch::new(0, 0, vec![]).unwrap();
        assert_eq!(
            Frame::Scatter {
                key: String::new(),
                col_start: 0,
                col_end: 0,
                batch: empty(),
                deadline_us: None,
            }
            .type_byte(),
            0x0B
        );
        assert_eq!(
            Frame::Partial { col_start: 0, col_end: 0, batch: empty() }.type_byte(),
            0x0C
        );
        assert_eq!(Frame::Ping.type_byte(), 0x0D);
        assert_eq!(Frame::Pong.type_byte(), 0x0E);
        assert_eq!(Frame::Ping.type_name(), "PING");
        assert_eq!(Frame::Pong.type_name(), "PONG");
        assert_eq!(ErrorCode::DeadlineExceeded as u8, 9);
        assert_eq!(ErrorCode::DeadlineExceeded.name(), "deadline-exceeded");
        assert_eq!(ErrorCode::Unavailable as u8, 10);
        assert_eq!(ErrorCode::Unavailable.name(), "unavailable");
        for code in ErrorCode::ALL {
            assert_eq!(ErrorCode::from_u8(code as u8), Some(code));
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::from_u8(99), None);
    }

    #[test]
    fn deadline_free_infer_is_byte_identical_to_the_v0_layout() {
        // Handcraft the original (pre-deadline) INFER encoding and pin
        // that `deadline_us: None` still produces exactly those bytes —
        // the "old clients keep working unchanged" guarantee.
        let batch = RowBatch::new(1, 2, vec![1.0, -2.0]).unwrap();
        let mut payload = vec![PROTOCOL_VERSION, 0x01];
        payload.extend_from_slice(&(3u16).to_le_bytes());
        payload.extend_from_slice(b"key");
        payload.extend_from_slice(&(1u32).to_le_bytes());
        payload.extend_from_slice(&(2u32).to_le_bytes());
        payload.extend_from_slice(&1.0f32.to_le_bytes());
        payload.extend_from_slice(&(-2.0f32).to_le_bytes());
        let mut legacy = (payload.len() as u32).to_le_bytes().to_vec();
        legacy.extend_from_slice(&payload);

        let frame = Frame::Infer { key: "key".into(), batch, deadline_us: None };
        assert_eq!(encode(&frame), legacy);
        // and a deadline adds exactly the 8 trailing bytes
        let Frame::Infer { key, batch, .. } = frame else { unreachable!() };
        let with = encode(&Frame::Infer { key, batch, deadline_us: Some(7) });
        assert_eq!(with.len(), legacy.len() + 8);
    }

    #[test]
    fn partial_trailing_deadline_is_rejected() {
        let batch = RowBatch::new(1, 1, vec![0.5]).unwrap();
        let mut wire =
            encode(&Frame::Infer { key: "k".into(), batch, deadline_us: Some(42) });
        // chop 3 of the 8 deadline bytes and fix up the length prefix
        wire.truncate(wire.len() - 3);
        let plen = (wire.len() - 4) as u32;
        wire[..4].copy_from_slice(&plen.to_le_bytes());
        let mut r = &wire[..];
        match read_frame(&mut r) {
            Err(ReadError::Wire(e)) => {
                assert_eq!(e.code, ErrorCode::BadFrame);
                assert!(e.message.contains("trailing"), "{}", e.message);
            }
            other => panic!("expected BadFrame, got {other:?}"),
        }
    }

    #[test]
    fn midframe_timeout_is_a_typed_wire_error() {
        // A reader that yields some bytes then times out — the
        // slow-loris shape. Mid-prefix and mid-payload silences must
        // both be typed (unsyncable) wire errors, not silent I/O ends;
        // a timeout at a frame boundary stays plain I/O.
        struct Loris {
            bytes: Vec<u8>,
            off: usize,
        }
        impl std::io::Read for Loris {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.off >= self.bytes.len() {
                    return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "timeout"));
                }
                let n = buf.len().min(self.bytes.len() - self.off);
                buf[..n].copy_from_slice(&self.bytes[self.off..self.off + n]);
                self.off += n;
                Ok(n)
            }
        }
        let wire = encode(&Frame::StatsRequest);

        // 2 of 4 length-prefix bytes, then silence
        let mut r = Loris { bytes: wire[..2].to_vec(), off: 0 };
        match read_frame(&mut r) {
            Err(ReadError::Wire(e)) => {
                assert_eq!(e.code, ErrorCode::BadFrame);
                assert!(e.unsyncable(), "mid-prefix timeout must close the conn");
            }
            other => panic!("expected BadFrame, got {other:?}"),
        }

        // full prefix + 1 payload byte, then silence
        let mut r = Loris { bytes: wire[..5].to_vec(), off: 0 };
        match read_frame(&mut r) {
            Err(ReadError::Wire(e)) => {
                assert_eq!(e.code, ErrorCode::BadFrame);
                assert!(e.unsyncable(), "mid-payload timeout must close the conn");
            }
            other => panic!("expected BadFrame, got {other:?}"),
        }

        // frame-boundary timeout: plain I/O, caller reaps silently
        let mut r = Loris { bytes: vec![], off: 0 };
        match read_frame(&mut r) {
            Err(ReadError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::WouldBlock);
            }
            other => panic!("expected Io, got {other:?}"),
        }

        // plain truncation (TooLarge) is still flagged unsyncable,
        // ordinary bad frames are not
        assert!(WireError::new(ErrorCode::TooLarge, "x").unsyncable());
        assert!(!WireError::new(ErrorCode::BadFrame, "trailing bytes").unsyncable());
    }

    #[test]
    fn version_byte_is_checked() {
        let mut wire = encode(&Frame::StatsRequest);
        wire[4] = PROTOCOL_VERSION + 1;
        let mut r = &wire[..];
        match read_frame(&mut r) {
            Err(ReadError::Wire(e)) => assert_eq!(e.code, ErrorCode::BadVersion),
            other => panic!("expected BadVersion, got {other:?}"),
        }
    }

    #[test]
    fn oversized_length_prefix_rejected_as_too_large() {
        let mut wire = (MAX_FRAME + 1).to_le_bytes().to_vec();
        wire.extend_from_slice(&[0u8; 8]);
        let mut r = &wire[..];
        match read_frame(&mut r) {
            Err(ReadError::Wire(e)) => assert_eq!(e.code, ErrorCode::TooLarge),
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn row_batch_validates_shape_and_size() {
        assert!(RowBatch::new(2, 3, vec![0.0; 5]).is_err());
        assert!(RowBatch::new(1 << 20, 1 << 20, vec![]).is_err(), "shape overflow");
        assert!(RowBatch::from_rows(&[vec![1.0, 2.0], vec![3.0]]).is_err(), "ragged");
        let b = RowBatch::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!((b.rows(), b.cols()), (2, 2));
        assert_eq!(b.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn wire_error_converts_to_typed_crate_error() {
        let e: Error = WireError::new(ErrorCode::Overloaded, "q full").into();
        let msg = e.to_string();
        assert!(msg.contains("overloaded"), "{msg}");
        assert!(msg.contains("protocol error"), "{msg}");
    }
}
