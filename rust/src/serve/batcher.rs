//! Dynamic request batcher (vLLM-router-style): accumulate requests up
//! to `max_batch` or until `max_wait` elapses, then flush as one
//! execution. Callers block on a per-request response channel. With
//! metrics attached, every flush records into
//! `Metrics::{batch_flush_count, batch_size_sum}` so the batch-size
//! distribution the policy actually achieves is observable.

use crate::coordinator::metrics::Metrics;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many requests are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// One queued request: input vector + response channel.
pub struct Request<T, R> {
    /// Request payload.
    pub input: T,
    /// Channel the batch executor answers on.
    pub reply: mpsc::SyncSender<R>,
    /// When the request entered the submit queue — the executor turns
    /// this into the `queue` stage (submit → dequeue wall time).
    pub enqueued: Instant,
    /// Absolute expiry of the request's deadline budget, if it carried
    /// one. The batcher flushes no later than the earliest pending
    /// deadline, and the executor sheds expired requests at dequeue
    /// instead of running them (see `docs/ROBUSTNESS.md`).
    pub deadline: Option<Instant>,
}

/// Collects requests into batches per the policy. The executor thread
/// calls [`DynamicBatcher::next_batch`] in a loop.
pub struct DynamicBatcher<T, R> {
    rx: mpsc::Receiver<Request<T, R>>,
    policy: BatchPolicy,
    pending: Vec<Request<T, R>>,
    /// Drained batch vector handed back by [`DynamicBatcher::recycle`]
    /// — becomes the next `pending`, so steady-state flushes never
    /// allocate the request buffer.
    spare: Vec<Request<T, R>>,
    metrics: Option<Arc<Metrics>>,
    /// Formation window of the last flushed batch (first request
    /// received → flush) — the `batch` stage of every request that
    /// rode in it, read by the executor via
    /// [`DynamicBatcher::last_flush_wait_ns`].
    last_flush_wait_ns: u64,
}

/// Client handle for submitting requests.
pub struct BatcherClient<T, R> {
    tx: mpsc::SyncSender<Request<T, R>>,
}

// manual impl: #[derive(Clone)] would wrongly require T: Clone, R: Clone
impl<T, R> Clone for BatcherClient<T, R> {
    fn clone(&self) -> Self {
        BatcherClient { tx: self.tx.clone() }
    }
}

/// Why a non-blocking submit ([`BatcherClient::try_submit`]) was
/// refused — the admission-control signal the network frontend turns
/// into an explicit overload rejection frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded submit queue is full (over-offered load).
    Overloaded,
    /// The batcher has shut down.
    Closed,
}

impl<T, R> BatcherClient<T, R> {
    /// Submit a request and block for the reply. Returns None if the
    /// batcher shut down. Blocks while the submit queue is full —
    /// see [`BatcherClient::try_submit`] for the non-blocking,
    /// overload-rejecting path.
    pub fn call(&self, input: T) -> Option<R> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request { input, reply: reply_tx, enqueued: Instant::now(), deadline: None })
            .ok()?;
        reply_rx.recv().ok()
    }

    /// Submit without blocking: on success returns the reply channel
    /// (recv it for the result); a full queue is refused with
    /// [`SubmitError::Overloaded`] instead of stalling the caller —
    /// bounded queues must reject, not silently queue-build.
    pub fn try_submit(&self, input: T) -> std::result::Result<mpsc::Receiver<R>, SubmitError> {
        self.try_submit_with(input, None)
    }

    /// [`BatcherClient::try_submit`] with a deadline: the request is
    /// shed (not executed) if `deadline` passes before the executor
    /// dequeues it, and its arrival pulls the flush window forward to
    /// no later than the deadline.
    pub fn try_submit_with(
        &self,
        input: T,
        deadline: Option<Instant>,
    ) -> std::result::Result<mpsc::Receiver<R>, SubmitError> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        match self.tx.try_send(Request {
            input,
            reply: reply_tx,
            enqueued: Instant::now(),
            deadline,
        }) {
            Ok(()) => Ok(reply_rx),
            Err(mpsc::TrySendError::Full(_)) => Err(SubmitError::Overloaded),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }
}

impl<T, R> DynamicBatcher<T, R> {
    /// Create a batcher + client pair. `queue_cap` bounds the submit
    /// queue (backpressure for over-offered load).
    pub fn new(policy: BatchPolicy, queue_cap: usize) -> (Self, BatcherClient<T, R>) {
        let (tx, rx) = mpsc::sync_channel(queue_cap);
        (
            DynamicBatcher {
                rx,
                policy,
                pending: Vec::new(),
                spare: Vec::new(),
                metrics: None,
                last_flush_wait_ns: 0,
            },
            BatcherClient { tx },
        )
    }

    /// Attach metrics: every flushed batch records its size into
    /// `batch_flush_count` / `batch_size_sum`.
    pub fn attach_metrics(&mut self, metrics: Arc<Metrics>) {
        self.metrics = Some(metrics);
    }

    /// Block until a batch is ready (or the channel closed and the
    /// backlog drained). Returns None on shutdown with nothing left.
    pub fn next_batch(&mut self) -> Option<Vec<Request<T, R>>> {
        // collect into the recycled buffer, not a fresh allocation
        if self.pending.is_empty() && self.pending.capacity() < self.spare.capacity() {
            std::mem::swap(&mut self.pending, &mut self.spare);
        }
        // wait for the first request (blocking)
        if self.pending.is_empty() {
            match self.rx.recv() {
                Ok(r) => self.pending.push(r),
                Err(_) => return None,
            }
        }
        // the formation window (the `batch` stage) starts once the
        // first request is in hand — idle blocking above is not
        // batching latency
        let formed = Instant::now();
        // earliest-deadline flush: the window closes at max_wait or at
        // the earliest pending request deadline, whichever comes first,
        // so a tight-budget request is never held for stragglers (and
        // an already-expired one reaches the executor's shed path
        // immediately)
        let mut flush_at = formed + self.policy.max_wait;
        for r in &self.pending {
            if let Some(d) = r.deadline {
                flush_at = flush_at.min(d);
            }
        }
        while self.pending.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= flush_at {
                break;
            }
            match self.rx.recv_timeout(flush_at - now) {
                Ok(r) => {
                    if let Some(d) = r.deadline {
                        flush_at = flush_at.min(d);
                    }
                    self.pending.push(r);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        self.last_flush_wait_ns = formed.elapsed().as_nanos() as u64;
        if let Some(m) = &self.metrics {
            m.record_batch_flush(self.pending.len());
            m.telemetry
                .record_stage(crate::coordinator::telemetry::Stage::Batch, self.last_flush_wait_ns);
        }
        Some(std::mem::take(&mut self.pending))
    }

    /// Formation window (ns) of the most recently flushed batch —
    /// the `batch` stage every request in that flush shares.
    pub fn last_flush_wait_ns(&self) -> u64 {
        self.last_flush_wait_ns
    }

    /// Hand a **drained** batch vector back for reuse: its allocation
    /// becomes the next flush's `pending` buffer, so a steady-state
    /// executor loop (`next_batch` → drain → `recycle`) never grows or
    /// re-allocates request storage — each accepted buffer counts into
    /// `Metrics::batch_buffer_reuse`. Requests still inside the vector
    /// are dropped (their callers see a closed reply channel).
    pub fn recycle(&mut self, mut buf: Vec<Request<T, R>>) {
        buf.clear();
        if buf.capacity() > self.spare.capacity() {
            self.spare = buf;
            if let Some(m) = &self.metrics {
                m.batch_buffer_reuse.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        }
    }

    /// Capacity of the buffer the next flush will collect into (for
    /// the no-per-flush-growth regression test).
    pub fn pending_capacity(&self) -> usize {
        self.pending.capacity().max(self.spare.capacity())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn batches_up_to_max() {
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
            64,
        );
        let exec = thread::spawn(move || {
            let mut sizes = Vec::new();
            while let Some(batch) = b.next_batch() {
                sizes.push(batch.len());
                for r in batch {
                    let _ = r.reply.send(r.input * 2);
                }
            }
            sizes
        });
        let clients: Vec<_> = (0..8)
            .map(|i| {
                let c = client.clone();
                thread::spawn(move || c.call(i).unwrap())
            })
            .collect();
        let mut results: Vec<u32> = clients.into_iter().map(|h| h.join().unwrap()).collect();
        results.sort_unstable();
        assert_eq!(results, (0..8).map(|i| i * 2).collect::<Vec<_>>());
        drop(client);
        let sizes = exec.join().unwrap();
        assert!(sizes.iter().all(|&s| s <= 4));
        assert_eq!(sizes.iter().sum::<usize>(), 8);
    }

    #[test]
    fn flushes_on_timeout_with_partial_batch() {
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(10) },
            8,
        );
        let exec = thread::spawn(move || b.next_batch().map(|batch| batch.len()));
        let c = client.clone();
        let caller = thread::spawn(move || c.call(7));
        let size = exec.join().unwrap();
        assert_eq!(size, Some(1));
        // caller is still blocked on reply; drop its channel by ending scope
        drop(client);
        // answer was never sent -> caller gets None
        assert_eq!(caller.join().unwrap(), None);
    }

    #[test]
    fn try_submit_rejects_when_queue_full_and_when_closed() {
        // No executor drains the queue, so the bounded channel fills.
        let (b, client) = DynamicBatcher::<u32, u32>::new(BatchPolicy::default(), 2);
        assert!(client.try_submit(1).is_ok());
        assert!(client.try_submit(2).is_ok());
        assert_eq!(client.try_submit(3).unwrap_err(), SubmitError::Overloaded);
        drop(b);
        assert_eq!(client.try_submit(4).unwrap_err(), SubmitError::Closed);
    }

    #[test]
    fn try_submit_reply_arrives_on_receiver() {
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
            4,
        );
        let rx = client.try_submit(21).unwrap();
        let batch = b.next_batch().unwrap();
        for r in batch {
            let _ = r.reply.send(r.input * 2);
        }
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn recycled_buffers_stop_per_flush_capacity_growth() {
        // Regression: next_batch used to hand out a freshly grown Vec
        // every flush; with recycle() the same allocation must cycle.
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            16,
        );
        let metrics = Arc::new(Metrics::new());
        b.attach_metrics(Arc::clone(&metrics));
        let mut warm_cap = 0usize;
        for round in 0..10 {
            let receivers: Vec<_> =
                (0..4).map(|i| client.try_submit(i).unwrap()).collect();
            let mut batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 4);
            for r in batch.drain(..) {
                let _ = r.reply.send(r.input);
            }
            b.recycle(batch);
            for rx in receivers {
                rx.recv().unwrap();
            }
            if round == 1 {
                warm_cap = b.pending_capacity();
                assert!(warm_cap >= 4);
            } else if round > 1 {
                assert_eq!(b.pending_capacity(), warm_cap, "round {round} grew the buffer");
            }
        }
        assert!(
            metrics.snapshot().batch_buffer_reuse >= 9,
            "recycles recorded: {}",
            metrics.snapshot().batch_buffer_reuse
        );
    }

    #[test]
    fn earliest_deadline_pulls_the_flush_window_forward() {
        // max_wait is far (1 s); a request with a ~10 ms deadline must
        // flush near its deadline, not the window.
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(
            BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(1) },
            16,
        );
        let _rx = client
            .try_submit_with(1, Some(Instant::now() + Duration::from_millis(10)))
            .unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch.len(), 1);
        assert!(batch[0].deadline.is_some());
        assert!(waited < Duration::from_millis(500), "flushed at deadline, not max_wait");
    }

    #[test]
    fn expired_deadline_flushes_immediately() {
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(
            BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(1) },
            16,
        );
        let _rx = client.try_submit_with(1, Some(Instant::now())).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn plain_submits_carry_no_deadline() {
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
            4,
        );
        let _rx = client.try_submit(5).unwrap();
        let batch = b.next_batch().unwrap();
        assert!(batch[0].deadline.is_none());
    }

    #[test]
    fn shutdown_returns_none() {
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(BatchPolicy::default(), 4);
        drop(client);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn flushes_record_batch_size_distribution() {
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(50) },
            16,
        );
        let metrics = Arc::new(Metrics::new());
        b.attach_metrics(Arc::clone(&metrics));
        let exec = thread::spawn(move || {
            while let Some(batch) = b.next_batch() {
                for r in batch {
                    let _ = r.reply.send(r.input);
                }
            }
        });
        let callers: Vec<_> = (0..6)
            .map(|i| {
                let c = client.clone();
                thread::spawn(move || c.call(i).unwrap())
            })
            .collect();
        for h in callers {
            h.join().unwrap();
        }
        drop(client);
        exec.join().unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.batch_size_sum, 6, "every request counted once");
        assert!(snap.batch_flush_count >= 3, "max_batch 2 forces >= 3 flushes");
        assert!(snap.mean_flush_size() <= 2.0);
        // every flush also lands one sample in the `batch` stage histogram
        let batch_stage = metrics.telemetry.stage(crate::coordinator::telemetry::Stage::Batch);
        assert_eq!(batch_stage.count(), snap.batch_flush_count);
    }

    #[test]
    fn flush_wait_is_observable() {
        let (mut b, client) = DynamicBatcher::<u32, u32>::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
            16,
        );
        assert_eq!(b.last_flush_wait_ns(), 0);
        let _rx = client.try_submit(1).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        // the lone request forced a timeout flush: the window is ~max_wait
        assert!(b.last_flush_wait_ns() >= Duration::from_millis(4).as_nanos() as u64);
    }
}
