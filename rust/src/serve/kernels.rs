//! Sparse-execution kernels: the masked-layer matmul executed
//! *directly on* each index representation.
//!
//! The paper's observation is that "computations using sparse matrices
//! obtained by pruning parameters exhibit vastly different parallelism
//! depending on the index representation scheme" — so the serving
//! engine must not erase the distinction by decoding every format to a
//! dense mask first. Each [`SparseKernel`] implementation computes
//! `x · (W ⊙ I)` using the traversal its format affords:
//!
//! | format          | execution strategy                                  |
//! |-----------------|-----------------------------------------------------|
//! | dense-masked    | pre-mask `W` once, dense matmul (baseline)          |
//! | CSR (16-bit)    | gather-accumulate over `IA`/`JA` + packed values    |
//! | relative (5-bit)| stream the gap entries, fusing decode with compute  |
//! | fused low-rank  | expand `I_p ⊗ I_z` one packed row at a time         |
//! | viterbi         | shift-register walk regenerates 5 mask bits/input bit |
//! | dCSR (4-bit)    | stream the nibble deltas, decode fused with compute |
//!
//! The fused low-rank kernel never materialises the full `m × n` mask:
//! it ORs the packed `u64` rows of `I_z` selected by row `i` of `I_p`
//! into a single `n/64`-word tile, consumes it, and reuses the buffer
//! for the next row — the in-register analogue of the paper's on-chip
//! decompressor.
//!
//! Every kernel compiles an **execution plan** at build time (see
//! `serve::plan`): a one-time analysis of its index that
//! partitions the work into conflict-free, cache-sized shards, which
//! `spmm` then runs across the shared
//! [`ExecCtx`](crate::coordinator::pool::ExecCtx). Shard partitions
//! depend only on the index — never on the thread count — and every
//! reduction keeps a fixed shard→merge order, so parallel output is
//! bit-identical to `threads = 1` (pinned by `tests/kernels.rs`).
//!
//! Inner loops dispatch to the runtime-probed SIMD micro-kernels of
//! [`tensor::simd`](crate::tensor::simd) (AVX2 / NEON / scalar,
//! `LRBI_SIMD=off` pins scalar). Vectorization is strictly
//! lane-owns-output — each lane accumulates one output element in the
//! scalar order with non-fused mul+add — so output is also
//! byte-identical across tiers (see `docs/PERFORMANCE.md`). The hot
//! entry point is [`SparseKernel::spmm_into`]: callers hand in a
//! persistent output matrix, plan scratch comes from the context's
//! pool, and steady-state serving allocates nothing
//! (`Metrics::spmm_alloc_bytes` / `scratch_reuse`).

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::ExecCtx;
use crate::formats::csr::Csr16;
use crate::formats::dcsr::{DcsrIndex, ESCAPE};
use crate::formats::relative::{Csr5Relative, MAX_GAP};
use crate::formats::viterbi::ViterbiIndex;
use crate::formats::StoredIndex;
use crate::serve::plan::{
    lock_tile_scratch, shard_ranges, tile_col_shards, CscPlan, OutCell, RelShard, RelativePlan,
    RowShards, TileColShard, MAX_SHARDS, REDUCE_COLS_FACTOR, SHARD_COLS, SHARD_NNZ,
};
use crate::tensor::simd;
use crate::tensor::Matrix;
use crate::tiling::TiledLowRankIndex;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Metrics slots per kernel: indexes into the telemetry
/// `spmm_ns{kernel=...}` series (and the derived
/// `MetricsSnapshot::spmm_kernel_ns` totals), matching
/// `coordinator::metrics::SPMM_KERNEL_NAMES` (pinned by a test below).
const SLOT_DENSE: usize = 0;
const SLOT_CSR: usize = 1;
const SLOT_RELATIVE: usize = 2;
const SLOT_LOWRANK: usize = 3;
const SLOT_TILED: usize = 4;
const SLOT_VITERBI: usize = 5;
const SLOT_DCSR: usize = 6;

/// A sparse-execution strategy for the masked layer.
///
/// `spmm` computes `x · (W ⊙ I)` where `W` (m × n) and the pruning
/// mask `I` were captured at construction; `x` is `(batch, m)` and the
/// result is `(batch, n)`. All implementations are numerically
/// equivalent (same products, possibly reassociated) — see the
/// cross-format property test in `tests/kernels.rs`.
pub trait SparseKernel: Send {
    /// Kernel name as reported in metrics/benches.
    fn name(&self) -> &'static str;
    /// `x (batch × m)` → `x · (W ⊙ I)` written into `out`, which is
    /// re-shaped in place to `(batch × n)`
    /// ([`Matrix::reset_zero`]) — the serving hot path: a persistent
    /// `out` plus the kernel's pooled plan scratch make steady-state
    /// calls allocation-free (`Metrics::spmm_alloc_bytes` /
    /// `scratch_reuse`).
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) -> Result<()>;
    /// Allocating convenience wrapper over [`SparseKernel::spmm_into`].
    fn spmm(&self, x: &Matrix) -> Result<Matrix> {
        let mut out = Matrix::zeros(0, 0);
        self.spmm_into(x, &mut out)?;
        Ok(out)
    }
    /// Bytes of index metadata this kernel executes from.
    fn index_bytes(&self) -> usize;
    /// Mask rows `m` (the layer's input width).
    fn rows(&self) -> usize;
    /// Mask cols `n` (the layer's output width).
    fn cols(&self) -> usize;
    /// Conflict-free shards this kernel's execution plan partitions
    /// `spmm` into (1 = effectively sequential).
    fn plan_shards(&self) -> usize {
        1
    }
}

/// Which [`SparseKernel`] the serving engine runs — selected per
/// format at startup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFormat {
    /// Decode the mask once, pre-mask `W`, dense matmul (baseline).
    DenseMasked,
    /// CSR with 16-bit column indices, gather-accumulate.
    Csr,
    /// 5-bit relative (gap) stream, decode fused with compute.
    Relative,
    /// Fused low-rank: `I_p ⊗ I_z` expanded tile-by-tile from packed
    /// words, never materialising the dense mask.
    LowRankFused,
    /// Viterbi: the stored input bit-stream drives the rate-1/5
    /// shift-register encoder per row, regenerating mask words on the
    /// fly — the dense mask never exists. Mask-shaping: the executed
    /// mask is the trellis's nearest emittable approximation of
    /// `I_p ⊗ I_z`, not the product itself.
    Viterbi,
    /// dCSR: 4-bit delta stream (Trommer 2021), decode fused with
    /// compute over skip-pointer segments.
    Dcsr,
}

impl KernelFormat {
    /// Every selectable kernel, baseline first.
    pub const ALL: [KernelFormat; 6] = [
        KernelFormat::DenseMasked,
        KernelFormat::Csr,
        KernelFormat::Relative,
        KernelFormat::LowRankFused,
        KernelFormat::Viterbi,
        KernelFormat::Dcsr,
    ];

    /// Stable CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            KernelFormat::DenseMasked => "dense",
            KernelFormat::Csr => "csr",
            KernelFormat::Relative => "relative",
            KernelFormat::LowRankFused => "lowrank",
            KernelFormat::Viterbi => "viterbi",
            KernelFormat::Dcsr => "dcsr",
        }
    }

    /// Parse a CLI/report name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dense" | "dense-masked" => Ok(KernelFormat::DenseMasked),
            "csr" => Ok(KernelFormat::Csr),
            "relative" | "csr5" => Ok(KernelFormat::Relative),
            "lowrank" | "low-rank" | "fused" => Ok(KernelFormat::LowRankFused),
            "viterbi" => Ok(KernelFormat::Viterbi),
            "dcsr" => Ok(KernelFormat::Dcsr),
            other => Err(Error::invalid(format!(
                "unknown kernel format '{other}' (want dense|csr|relative|lowrank|viterbi|dcsr)"
            ))),
        }
    }
}

fn check_factor_shapes(w: &Matrix, ip: &BitMatrix, iz: &BitMatrix) -> Result<()> {
    if ip.rows() != w.rows() || iz.cols() != w.cols() || ip.cols() != iz.rows() {
        return Err(Error::shape(format!(
            "kernel factors: W {}x{}, I_p {}x{}, I_z {}x{}",
            w.rows(),
            w.cols(),
            ip.rows(),
            ip.cols(),
            iz.rows(),
            iz.cols()
        )));
    }
    Ok(())
}

fn check_mask_shape(w: &Matrix, mask: &BitMatrix) -> Result<()> {
    if mask.rows() != w.rows() || mask.cols() != w.cols() {
        return Err(Error::shape(format!(
            "kernel mask {}x{} vs W {}x{}",
            mask.rows(),
            mask.cols(),
            w.rows(),
            w.cols()
        )));
    }
    Ok(())
}

fn check_input(x: &Matrix, m: usize) -> Result<()> {
    if x.cols() != m {
        return Err(Error::shape(format!("spmm input {}x{} vs m={m}", x.rows(), x.cols())));
    }
    Ok(())
}

/// Build the kernel for `format` over layer weights `w` and the
/// factorized index `(I_p, I_z)`, executing single-threaded (the
/// [`ExecCtx::single`] context). When `metrics` is given, the build
/// (the per-format decode/encode step) is counted into
/// `kernel_decodes` / `kernel_decode_ns`.
pub fn build_kernel(
    format: KernelFormat,
    w: &Matrix,
    ip: &BitMatrix,
    iz: &BitMatrix,
    metrics: Option<&Metrics>,
) -> Result<Box<dyn SparseKernel>> {
    build_kernel_exec(format, w, ip, iz, &ExecCtx::single(), metrics)
}

/// [`build_kernel`] with an explicit execution context: the kernel's
/// plan shards run across `ctx`'s worker pool. The plan itself is
/// identical for every context (shard partitions depend only on the
/// index), so the same factors + weights produce bit-identical `spmm`
/// output at any thread count.
pub fn build_kernel_exec(
    format: KernelFormat,
    w: &Matrix,
    ip: &BitMatrix,
    iz: &BitMatrix,
    ctx: &Arc<ExecCtx>,
    metrics: Option<&Metrics>,
) -> Result<Box<dyn SparseKernel>> {
    check_factor_shapes(w, ip, iz)?;
    let t0 = Instant::now();
    let kernel: Box<dyn SparseKernel> = match format {
        KernelFormat::DenseMasked => Box::new(
            DenseMaskedKernel::from_mask(w, &ip.bool_product(iz))?.with_exec(Arc::clone(ctx)),
        ),
        KernelFormat::Csr => {
            Box::new(CsrKernel::new(w, &ip.bool_product(iz))?.with_exec(Arc::clone(ctx)))
        }
        KernelFormat::Relative => {
            Box::new(RelativeKernel::new(w, &ip.bool_product(iz))?.with_exec(Arc::clone(ctx)))
        }
        KernelFormat::LowRankFused => {
            Box::new(LowRankFusedKernel::new(w, ip, iz)?.with_exec(Arc::clone(ctx)))
        }
        KernelFormat::Viterbi => {
            // Mask-shaping: re-encode I_p ⊗ I_z as the trellis's
            // nearest emittable mask (the same deterministic encode
            // `StoredIndex::from_factors("viterbi", ..)` performs, so
            // factor and stored construction stay bitwise identical).
            let index = ViterbiIndex::shape_mask(&ip.bool_product(iz));
            Box::new(ViterbiKernel::new(w, index)?.with_exec(Arc::clone(ctx)))
        }
        KernelFormat::Dcsr => {
            Box::new(DcsrKernel::new(w, &ip.bool_product(iz))?.with_exec(Arc::clone(ctx)))
        }
    };
    if let Some(m) = metrics {
        m.kernel_decodes.fetch_add(1, Ordering::Relaxed);
        m.kernel_decode_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
    Ok(kernel)
}

/// Build the kernel for a *stored* index (the artifact load path).
/// Each variant goes straight from its serialized representation to
/// the kernel that executes it — CSR and relative streams feed their
/// kernels without reconstructing the dense mask, low-rank and tiled
/// factors stay factors. The dense-bitmap variant's decode *is* its
/// format semantics (the bitmap is the mask).
pub fn build_kernel_from_stored(
    stored: &StoredIndex,
    w: &Matrix,
    metrics: Option<&Metrics>,
) -> Result<Box<dyn SparseKernel>> {
    build_kernel_from_stored_exec(stored, w, &ExecCtx::single(), metrics)
}

/// [`build_kernel_from_stored`] with an explicit execution context
/// (see [`build_kernel_exec`] for the determinism contract).
pub fn build_kernel_from_stored_exec(
    stored: &StoredIndex,
    w: &Matrix,
    ctx: &Arc<ExecCtx>,
    metrics: Option<&Metrics>,
) -> Result<Box<dyn SparseKernel>> {
    let t0 = Instant::now();
    let kernel: Box<dyn SparseKernel> = match stored {
        StoredIndex::Binary(b) => {
            Box::new(DenseMaskedKernel::from_mask(w, &b.decode())?.with_exec(Arc::clone(ctx)))
        }
        StoredIndex::Csr(c) => Box::new(CsrKernel::from_encoded(w, c)?.with_exec(Arc::clone(ctx))),
        StoredIndex::Relative(r) => {
            Box::new(RelativeKernel::from_stream(w, r)?.with_exec(Arc::clone(ctx)))
        }
        StoredIndex::LowRank(l) => {
            let (ip, iz) = l.factors()?;
            Box::new(LowRankFusedKernel::new(w, &ip, &iz)?.with_exec(Arc::clone(ctx)))
        }
        StoredIndex::Tiled(t) => Box::new(TiledLowRankKernel::new(w, t)?.with_exec(Arc::clone(ctx))),
        StoredIndex::Viterbi(v) => {
            Box::new(ViterbiKernel::new(w, v.clone())?.with_exec(Arc::clone(ctx)))
        }
        StoredIndex::Dcsr(d) => Box::new(DcsrKernel::from_stream(w, d)?.with_exec(Arc::clone(ctx))),
    };
    if let Some(m) = metrics {
        m.kernel_decodes.fetch_add(1, Ordering::Relaxed);
        m.kernel_decode_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
    Ok(kernel)
}

/// Baseline: the mask is decoded to dense once and burned into a
/// pre-masked copy of `W`, which the plan stores **panel-packed**
/// (B-transposed, [`simd::PANEL`]-column lane interleave — see
/// `tensor::simd::pack_bt_panels`) so `spmm` runs the
/// runtime-dispatched vector micro-kernel
/// (`tensor::simd::matmul_packed_cols`) over output-column shards
/// with zero per-call packing — an honest dense baseline that scales
/// with the same `ExecCtx` the sparse kernels use. Each output
/// element is a single ascending-`k` dot product computed entirely by
/// one shard lane, so neither sharding nor the SIMD tier changes a
/// bit.
pub struct DenseMaskedKernel {
    m: usize,
    n: usize,
    /// The pre-masked weight, transposed and packed into
    /// lane-interleaved panels at build time — the only copy the
    /// kernel keeps.
    packed: Vec<f32>,
    /// Output-column shard ranges (~[`SHARD_COLS`] columns each).
    shards: Vec<(usize, usize)>,
    index_bytes: usize,
    ctx: Arc<ExecCtx>,
}

impl DenseMaskedKernel {
    /// Build from weights + a pre-decoded mask.
    pub fn from_mask(w: &Matrix, mask: &BitMatrix) -> Result<Self> {
        check_mask_shape(w, mask)?;
        let w_masked = crate::pruning::prune_with_mask(w, mask)?;
        let wt = w_masked.transpose();
        let packed = simd::pack_bt_panels(wt.data(), w_masked.cols(), w_masked.rows());
        let shards = shard_ranges(w_masked.cols(), SHARD_COLS);
        Ok(DenseMaskedKernel {
            m: w_masked.rows(),
            n: w_masked.cols(),
            packed,
            shards,
            index_bytes: mask.index_bytes(),
            ctx: ExecCtx::single(),
        })
    }

    /// Attach the execution context the plan shards run on.
    pub fn with_exec(mut self, ctx: Arc<ExecCtx>) -> Self {
        self.ctx = ctx;
        self
    }
}

impl SparseKernel for DenseMaskedKernel {
    fn name(&self) -> &'static str {
        "dense"
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        let (m, n) = (self.m, self.n);
        check_input(x, m)?;
        let batch = x.rows();
        out.reset_zero(batch, n);
        let t0 = Instant::now();
        let t = simd::tier();
        let cell = OutCell::new(out.data_mut());
        let xd = x.data();
        self.ctx.run(self.shards.len(), |s| {
            // SAFETY: shards own disjoint output-column ranges.
            unsafe {
                simd::matmul_packed_cols(
                    t,
                    xd,
                    &self.packed,
                    cell.at(0),
                    (batch, m, n),
                    self.shards[s],
                )
            };
        })?;
        self.ctx.record_plan_spmm(SLOT_DENSE, self.shards.len() as u64, t0);
        Ok(())
    }
    fn index_bytes(&self) -> usize {
        self.index_bytes
    }
    fn rows(&self) -> usize {
        self.m
    }
    fn cols(&self) -> usize {
        self.n
    }
    fn plan_shards(&self) -> usize {
        self.shards.len().max(1)
    }
}

/// CSR executed output-stationary: at build, the freshly-gathered
/// `IA`/`JA`/values are transposed once to CSC (the plan), so shards
/// own disjoint output-column ranges and threads never contend on an
/// output row — each output element is one register-accumulated dot
/// product over its column's survivors. Work stays O(batch · nnz).
pub struct CsrKernel {
    m: usize,
    n: usize,
    plan: CscPlan,
    index_bytes: usize,
    ctx: Arc<ExecCtx>,
}

impl CsrKernel {
    /// Encode the mask as CSR, gather the surviving weights, and
    /// compile the CSC execution plan.
    pub fn new(w: &Matrix, mask: &BitMatrix) -> Result<Self> {
        check_mask_shape(w, mask)?;
        let csr = Csr16::encode(mask)?;
        Self::from_encoded(w, &csr)
    }

    /// Build directly from an already-encoded CSR index (the artifact
    /// load path, where the index is borrowed from the artifact) —
    /// gathers surviving weights without touching a dense mask. The
    /// gather and transpose order is identical to [`CsrKernel::new`],
    /// so the two construction paths produce bit-identical `spmm`
    /// output.
    pub fn from_encoded(w: &Matrix, csr: &Csr16) -> Result<Self> {
        let vals = gather_csr_vals(w, csr)?;
        Ok(CsrKernel {
            m: csr.rows(),
            n: csr.cols(),
            plan: CscPlan::build(csr.rows(), csr.cols(), &csr.ia, &csr.ja, &vals),
            index_bytes: csr.index_bytes(),
            ctx: ExecCtx::single(),
        })
    }

    /// Attach the execution context the plan shards run on.
    pub fn with_exec(mut self, ctx: Arc<ExecCtx>) -> Self {
        self.ctx = ctx;
        self
    }

    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.plan.nnz()
    }
}

impl SparseKernel for CsrKernel {
    fn name(&self) -> &'static str {
        "csr"
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        check_input(x, self.m)?;
        out.reset_zero(x.rows(), self.n);
        let t0 = Instant::now();
        self.plan.execute(x, out, &self.ctx)?;
        self.ctx.record_plan_spmm(SLOT_CSR, self.plan.shard_count() as u64, t0);
        Ok(())
    }
    fn index_bytes(&self) -> usize {
        self.index_bytes
    }
    fn rows(&self) -> usize {
        self.m
    }
    fn cols(&self) -> usize {
        self.n
    }
    fn plan_shards(&self) -> usize {
        self.plan.shard_count().max(1)
    }
}

/// Shape-check a CSR index against `w` and gather the surviving
/// weights in `IA`/`JA` order (shared by both `CsrKernel`
/// constructors so their gather order — and thus `spmm` bit pattern —
/// is identical).
fn gather_csr_vals(w: &Matrix, csr: &Csr16) -> Result<Vec<f32>> {
    if csr.rows() != w.rows() || csr.cols() != w.cols() {
        return Err(Error::shape(format!(
            "CSR index {}x{} vs W {}x{}",
            csr.rows(),
            csr.cols(),
            w.rows(),
            w.cols()
        )));
    }
    let mut vals = Vec::with_capacity(csr.nnz());
    for i in 0..csr.rows() {
        let (a, b) = (csr.ia[i] as usize, csr.ia[i + 1] as usize);
        if b < a || b > csr.ja.len() {
            return Err(Error::store(format!("corrupt CSR IA at row {i}")));
        }
        for &j in &csr.ja[a..b] {
            if (j as usize) >= csr.cols() {
                return Err(Error::store(format!("CSR JA out of range: {j}")));
            }
            vals.push(w.get(i, j as usize));
        }
    }
    Ok(vals)
}

/// Relative-index streaming: the 5-bit gap stream of
/// [`Csr5Relative`] is walked entry-by-entry, decode fused with the
/// accumulate — the mask is never expanded, matching how Deep
/// Compression's decompressor consumes the stream. The stream is
/// sequential *per cursor* (each position depends on the running
/// cursor — the paper's §1 parallelism complaint), but the gather
/// walk at build time records **skip pointers** (stream offset +
/// value index + running cursor, `plan::RelShard`) at cache-sized
/// intervals, and with them the stream decodes shard-parallel:
/// per-shard partials merge in fixed shard order, so output stays
/// bit-identical to the sequential walk at any thread count. That a
/// one-pass index of `3 · usize` per ~2048 entries converts
/// Deep Compression's sequential-decode format into a parallel one is
/// itself a measurable observation — see the `perf_spmm_scaling`
/// bench.
pub struct RelativeKernel {
    m: usize,
    n: usize,
    entries: Vec<u8>,
    /// Surviving weights in stream order (fillers carry no value).
    vals: Vec<f32>,
    plan: RelativePlan,
    index_bytes: usize,
    ctx: Arc<ExecCtx>,
}

impl RelativeKernel {
    /// Encode the mask as a gap stream, gather surviving weights in
    /// stream order, and record the skip pointers — one fused walk.
    /// The freshly-encoded entry stream is *moved* into the kernel —
    /// no copy on the factor path.
    pub fn new(w: &Matrix, mask: &BitMatrix) -> Result<Self> {
        check_mask_shape(w, mask)?;
        let stream = Csr5Relative::encode(mask);
        let (vals, plan) = gather_stream_vals(w, &stream)?;
        let (m, n, index_bytes) = (stream.rows(), stream.cols(), stream.index_bytes());
        Ok(RelativeKernel {
            m,
            n,
            entries: stream.into_entries(),
            vals,
            plan,
            index_bytes,
            ctx: ExecCtx::single(),
        })
    }

    /// Build directly from an already-encoded gap stream (the artifact
    /// load path, where the stream is borrowed from the artifact): the
    /// stream is walked once to gather surviving weights and record
    /// skip pointers, fusing the only decode this kernel ever does
    /// with the value gather — the mask is never expanded.
    pub fn from_stream(w: &Matrix, stream: &Csr5Relative) -> Result<Self> {
        let (vals, plan) = gather_stream_vals(w, stream)?;
        Ok(RelativeKernel {
            m: stream.rows(),
            n: stream.cols(),
            entries: stream.entries().to_vec(),
            vals,
            plan,
            index_bytes: stream.index_bytes(),
            ctx: ExecCtx::single(),
        })
    }

    /// Attach the execution context the plan shards run on.
    pub fn with_exec(mut self, ctx: Arc<ExecCtx>) -> Self {
        self.ctx = ctx;
        self
    }
}

/// Shape-check a gap stream against `w`, gather the surviving weights
/// in stream order, and record the skip-pointer plan — one walk,
/// shared by both `RelativeKernel` constructors so gather order *and*
/// shard partition are identical on both construction paths. A shard
/// closes after ~[`SHARD_NNZ`] surviving weights (at least
/// `nnz / MAX_SHARDS`, keeping the count capped); its successor
/// starts at the entry right after the closing non-zero, so any
/// filler run stays with the non-zero it precedes.
fn gather_stream_vals(w: &Matrix, stream: &Csr5Relative) -> Result<(Vec<f32>, RelativePlan)> {
    if stream.rows() != w.rows() || stream.cols() != w.cols() {
        return Err(Error::shape(format!(
            "relative index {}x{} vs W {}x{}",
            stream.rows(),
            stream.cols(),
            w.rows(),
            w.cols()
        )));
    }
    gather_delta_vals(w, stream.entries(), stream.nnz(), MAX_GAP, "relative")
}

/// The same fused gather walk for the 4-bit dCSR stream (escape 15) —
/// shared by both `DcsrKernel` constructors.
fn gather_dcsr_vals(w: &Matrix, stream: &DcsrIndex) -> Result<(Vec<f32>, RelativePlan)> {
    if stream.rows() != w.rows() || stream.cols() != w.cols() {
        return Err(Error::shape(format!(
            "dcsr index {}x{} vs W {}x{}",
            stream.rows(),
            stream.cols(),
            w.rows(),
            w.cols()
        )));
    }
    gather_delta_vals(w, stream.entries(), stream.nnz(), ESCAPE, "dcsr")
}

/// Walk a delta stream (entries equal to `escape` advance `escape`
/// positions without a weight; anything else advances `entry + 1` and
/// places one), gathering surviving weights in stream order and
/// recording the skip-pointer plan. A shard closes after ~[`SHARD_NNZ`]
/// surviving weights (at least `nnz / MAX_SHARDS`, keeping the count
/// capped); its successor starts at the entry right after the closing
/// non-zero, so any escape run stays with the non-zero it precedes.
fn gather_delta_vals(
    w: &Matrix,
    entries: &[u8],
    nnz: usize,
    escape: u32,
    what: &str,
) -> Result<(Vec<f32>, RelativePlan)> {
    let n = w.cols();
    let total = w.rows() * n;
    // Shard size: cache-sized, capped in count, and at least
    // REDUCE_COLS_FACTOR·n non-zeros so the ordered partial merge
    // (2·batch·n streamed ops per shard) stays a small fraction of
    // the shard's own work.
    let per = nnz
        .div_ceil(MAX_SHARDS)
        .max(SHARD_NNZ)
        .max(REDUCE_COLS_FACTOR * n);
    let mut vals = Vec::with_capacity(nnz);
    let mut shards = Vec::new();
    let (mut e0, mut v0, mut pos0) = (0usize, 0usize, 0usize);
    let mut run_start = 0usize; // first entry after the last non-zero
    let mut pos = 0usize;
    let mut pending = 0u32;
    for (idx, &e) in entries.iter().enumerate() {
        if e as u32 == escape {
            pending += escape;
            continue;
        }
        let p = pos + (pending + e as u32) as usize;
        pending = 0;
        if p >= total {
            return Err(Error::store(format!(
                "{what} stream runs past the {total}-element mask"
            )));
        }
        if !vals.is_empty() && vals.len() % per == 0 {
            shards.push(RelShard { e0, e1: run_start, v0, pos0 });
            e0 = run_start;
            v0 = vals.len();
            pos0 = pos;
        }
        vals.push(w.get(p / n, p % n));
        pos = p + 1;
        run_start = idx + 1;
    }
    if e0 < entries.len() {
        shards.push(RelShard { e0, e1: entries.len(), v0, pos0 });
    }
    Ok((vals, RelativePlan { shards, escape }))
}

impl SparseKernel for RelativeKernel {
    fn name(&self) -> &'static str {
        "relative"
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        check_input(x, self.m)?;
        out.reset_zero(x.rows(), self.n);
        let t0 = Instant::now();
        // Stream outer, batch inner within each shard: every decoded
        // (i, j) is applied to all batch rows while it is hot.
        self.plan.execute(&self.entries, &self.vals, self.n, x, out, &self.ctx)?;
        self.ctx.record_plan_spmm(SLOT_RELATIVE, self.plan.shard_count() as u64, t0);
        Ok(())
    }
    fn index_bytes(&self) -> usize {
        self.index_bytes
    }
    fn rows(&self) -> usize {
        self.m
    }
    fn cols(&self) -> usize {
        self.n
    }
    fn plan_shards(&self) -> usize {
        self.plan.shard_count().max(1)
    }
}

/// dCSR streaming (Trommer 2021): the 4-bit delta stream of
/// [`DcsrIndex`] is walked entry-by-entry with decode fused into the
/// accumulate, exactly like [`RelativeKernel`] — same skip-pointer
/// segment shards ([`RelShard`]), same fixed merge order, same
/// `rel_entry_axpy` vector inner loop — but at half the entry width
/// and with escape value 15. Decode cost per entry is identical
/// (nibble unpack happens at load, the in-memory stream is one byte
/// per entry); the format trades more escape entries at extreme
/// sparsity for a denser index stream everywhere else, and the shared
/// kernel structure is what makes the head-to-head in
/// `perf_spmm_scaling` a pure index-representation comparison.
pub struct DcsrKernel {
    m: usize,
    n: usize,
    entries: Vec<u8>,
    /// Surviving weights in stream order (escapes carry no value).
    vals: Vec<f32>,
    plan: RelativePlan,
    index_bytes: usize,
    ctx: Arc<ExecCtx>,
}

impl DcsrKernel {
    /// Encode the mask as a 4-bit delta stream, gather surviving
    /// weights in stream order, and record the skip pointers. The
    /// freshly-encoded entry stream is *moved* into the kernel.
    pub fn new(w: &Matrix, mask: &BitMatrix) -> Result<Self> {
        check_mask_shape(w, mask)?;
        let stream = DcsrIndex::encode(mask);
        let (vals, plan) = gather_dcsr_vals(w, &stream)?;
        let (m, n, index_bytes) = (stream.rows(), stream.cols(), stream.index_bytes());
        Ok(DcsrKernel {
            m,
            n,
            entries: stream.into_entries(),
            vals,
            plan,
            index_bytes,
            ctx: ExecCtx::single(),
        })
    }

    /// Build directly from an already-encoded delta stream (the
    /// artifact load path): one walk gathers surviving weights and
    /// records skip pointers — the mask is never expanded, and the
    /// gather order matches [`DcsrKernel::new`] so both construction
    /// paths produce bit-identical `spmm` output.
    pub fn from_stream(w: &Matrix, stream: &DcsrIndex) -> Result<Self> {
        let (vals, plan) = gather_dcsr_vals(w, stream)?;
        Ok(DcsrKernel {
            m: stream.rows(),
            n: stream.cols(),
            entries: stream.entries().to_vec(),
            vals,
            plan,
            index_bytes: stream.index_bytes(),
            ctx: ExecCtx::single(),
        })
    }

    /// Attach the execution context the plan shards run on.
    pub fn with_exec(mut self, ctx: Arc<ExecCtx>) -> Self {
        self.ctx = ctx;
        self
    }
}

impl SparseKernel for DcsrKernel {
    fn name(&self) -> &'static str {
        "dcsr"
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        check_input(x, self.m)?;
        out.reset_zero(x.rows(), self.n);
        let t0 = Instant::now();
        self.plan.execute(&self.entries, &self.vals, self.n, x, out, &self.ctx)?;
        self.ctx.record_plan_spmm(SLOT_DCSR, self.plan.shard_count() as u64, t0);
        Ok(())
    }
    fn index_bytes(&self) -> usize {
        self.index_bytes
    }
    fn rows(&self) -> usize {
        self.m
    }
    fn cols(&self) -> usize {
        self.n
    }
    fn plan_shards(&self) -> usize {
        self.plan.shard_count().max(1)
    }
}

/// Fused low-rank execution: for each weight row `i`, the mask row is
/// reconstructed as the word-wise OR of the packed `I_z` rows selected
/// by the set bits of `I_p` row `i` — one `n/64`-word tile that lives
/// in a reused buffer — and is consumed immediately by walking its set
/// bits against row `i` of `W`. The dense `m × n` mask never exists;
/// peak extra memory is one row tile regardless of layer size, and
/// every row's expansion is independent (the parallelism the paper
/// claims for the format).
pub struct LowRankFusedKernel {
    w: Matrix,
    ip: BitMatrix,
    iz: BitMatrix,
    /// Row-range reduction shards with persistent per-shard scratch
    /// tiles — every row's expansion is independent (the parallelism
    /// the paper claims for the format), so rows shard freely and
    /// per-shard partials merge in fixed shard order.
    row_shards: RowShards,
    ctx: Arc<ExecCtx>,
}

impl LowRankFusedKernel {
    /// Capture weights + packed factors and partition the mask rows
    /// into the plan's shards; no decode happens here.
    pub fn new(w: &Matrix, ip: &BitMatrix, iz: &BitMatrix) -> Result<Self> {
        check_factor_shapes(w, ip, iz)?;
        let (m, n, k) = (w.rows(), w.cols(), ip.cols());
        // Estimate the expanded mask's non-zeros from the factor
        // densities (independence approximation) and size row shards
        // so each carries ≥ REDUCE_COLS_FACTOR·n of them — keeping the
        // ordered partial merge a small fraction of shard work. The
        // estimate depends only on the index, so the partition stays
        // identical across construction paths and thread counts.
        let density = if k == 0 || m == 0 || n == 0 {
            0.0
        } else {
            let dp = ip.count_ones() as f64 / (m * k) as f64;
            let dz = iz.count_ones() as f64 / (k * n) as f64;
            1.0 - (1.0 - dp * dz).powi(k as i32)
        };
        let est_nnz = ((m * n) as f64 * density) as usize;
        let target_rows = if est_nnz == 0 {
            m.max(1) // effectively empty mask: one shard, no merge
        } else {
            (REDUCE_COLS_FACTOR * n * m).div_ceil(est_nnz)
        };
        let row_shards = RowShards::new(m, n.div_ceil(64), target_rows);
        Ok(LowRankFusedKernel {
            w: w.clone(),
            ip: ip.clone(),
            iz: iz.clone(),
            row_shards,
            ctx: ExecCtx::single(),
        })
    }

    /// Attach the execution context the plan shards run on.
    pub fn with_exec(mut self, ctx: Arc<ExecCtx>) -> Self {
        self.ctx = ctx;
        self
    }

    /// Rank of the factorization.
    pub fn rank(&self) -> usize {
        self.ip.cols()
    }
}

impl SparseKernel for LowRankFusedKernel {
    fn name(&self) -> &'static str {
        "lowrank"
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        let (m, n, k) = (self.w.rows(), self.w.cols(), self.ip.cols());
        check_input(x, m)?;
        let batch = x.rows();
        out.reset_zero(batch, n);
        let t0 = Instant::now();
        let tier = simd::tier();
        self.row_shards.execute(batch, n, out, &self.ctx, |(r0, r1), tile, part| {
            for i in r0..r1 {
                // Expand mask row i: OR the I_z rows named by I_p row i.
                tile.fill(0);
                let mut any = false;
                for (wi, &w) in self.ip.row_words(i).iter().enumerate() {
                    let mut bits = w;
                    while bits != 0 {
                        let l = wi * 64 + bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        if l >= k {
                            break;
                        }
                        for (t, &z) in tile.iter_mut().zip(self.iz.row_words(l)) {
                            *t |= z;
                        }
                        any = true;
                    }
                }
                if !any {
                    continue; // fully pruned row
                }
                // Consume the tile against W row i for every batch
                // row: one masked vector axpy per 64-column word.
                let wrow = self.w.row(i);
                for b in 0..batch {
                    let xv = x.get(b, i);
                    if xv == 0.0 {
                        continue;
                    }
                    let orow = &mut part[b * n..(b + 1) * n];
                    for (wi, &word) in tile.iter().enumerate() {
                        if word == 0 {
                            continue;
                        }
                        // SAFETY: set bits of `word` only name columns
                        // < n - wi*64 (BitMatrix keeps padding bits
                        // clear), and this shard exclusively owns
                        // `part`.
                        unsafe {
                            simd::masked_axpy(
                                tier,
                                word,
                                xv,
                                wrow.as_ptr().add(wi * 64),
                                orow.as_mut_ptr().add(wi * 64),
                            )
                        };
                    }
                }
            }
        })?;
        self.ctx
            .record_plan_spmm(SLOT_LOWRANK, self.row_shards.shard_count() as u64, t0);
        Ok(())
    }
    fn index_bytes(&self) -> usize {
        (self.ip.cols() * (self.ip.rows() + self.iz.cols())).div_ceil(8)
    }
    fn rows(&self) -> usize {
        self.w.rows()
    }
    fn cols(&self) -> usize {
        self.w.cols()
    }
    fn plan_shards(&self) -> usize {
        self.row_shards.shard_count().max(1)
    }
}

/// Viterbi fused execution: for each weight row `i`, the stored input
/// bit-stream drives the rate-1/5 shift-register encoder
/// ([`ViterbiIndex::decode_row_words`]), regenerating the row's mask
/// as packed `u64` words in a reused tile — 5 mask bits per input bit,
/// the in-register analogue of the paper's [14] on-chip decompressor —
/// which is consumed immediately by the same `masked_axpy` vector
/// inner loop the low-rank kernel uses. The dense `m × n` mask never
/// exists; peak extra memory is one `n/64`-word tile per shard. Rows
/// decode independently (each restarts the register at state 0 — the
/// paper's hardware-parallelism argument), so mask rows shard freely
/// via [`RowShards`] and per-shard partials merge in fixed shard
/// order.
pub struct ViterbiKernel {
    w: Matrix,
    index: ViterbiIndex,
    /// Row-range reduction shards with persistent per-shard scratch
    /// tiles, sized from the index's exact decoded non-zero count so
    /// the partition depends only on the index.
    row_shards: RowShards,
    ctx: Arc<ExecCtx>,
}

impl ViterbiKernel {
    /// Capture weights + the compressed index and partition the mask
    /// rows into the plan's shards. The one-time `nnz` count walks the
    /// same per-row regeneration the hot loop runs; no dense mask is
    /// built.
    pub fn new(w: &Matrix, index: ViterbiIndex) -> Result<Self> {
        if index.rows() != w.rows() || index.cols() != w.cols() {
            return Err(Error::shape(format!(
                "viterbi index {}x{} vs W {}x{}",
                index.rows(),
                index.cols(),
                w.rows(),
                w.cols()
            )));
        }
        let (m, n) = (w.rows(), w.cols());
        let nnz = index.nnz();
        let target_rows = if nnz == 0 {
            m.max(1) // empty mask: one shard, no merge
        } else {
            (REDUCE_COLS_FACTOR * n * m).div_ceil(nnz)
        };
        let row_shards = RowShards::new(m, n.div_ceil(64), target_rows);
        Ok(ViterbiKernel { w: w.clone(), index, row_shards, ctx: ExecCtx::single() })
    }

    /// Attach the execution context the plan shards run on.
    pub fn with_exec(mut self, ctx: Arc<ExecCtx>) -> Self {
        self.ctx = ctx;
        self
    }
}

impl SparseKernel for ViterbiKernel {
    fn name(&self) -> &'static str {
        "viterbi"
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        let (m, n) = (self.w.rows(), self.w.cols());
        check_input(x, m)?;
        let batch = x.rows();
        out.reset_zero(batch, n);
        let t0 = Instant::now();
        let tier = simd::tier();
        self.row_shards.execute(batch, n, out, &self.ctx, |(r0, r1), tile, part| {
            for i in r0..r1 {
                // Regenerate mask row i from the input bits: the
                // shift-register walk emits RATE bits per input bit
                // straight into the packed tile.
                self.index.decode_row_words(i, tile);
                // Consume the tile against W row i for every batch
                // row: one masked vector axpy per 64-column word.
                let wrow = self.w.row(i);
                for b in 0..batch {
                    let xv = x.get(b, i);
                    if xv == 0.0 {
                        continue;
                    }
                    let orow = &mut part[b * n..(b + 1) * n];
                    for (wi, &word) in tile.iter().enumerate() {
                        if word == 0 {
                            continue;
                        }
                        // SAFETY: set bits of `word` only name columns
                        // < n - wi*64 (decode_row_words masks the
                        // truncated final step), and this shard
                        // exclusively owns `part`.
                        unsafe {
                            simd::masked_axpy(
                                tier,
                                word,
                                xv,
                                wrow.as_ptr().add(wi * 64),
                                orow.as_mut_ptr().add(wi * 64),
                            )
                        };
                    }
                }
            }
        })?;
        self.ctx
            .record_plan_spmm(SLOT_VITERBI, self.row_shards.shard_count() as u64, t0);
        Ok(())
    }
    fn index_bytes(&self) -> usize {
        self.index.index_bytes()
    }
    fn rows(&self) -> usize {
        self.w.rows()
    }
    fn cols(&self) -> usize {
        self.w.cols()
    }
    fn plan_shards(&self) -> usize {
        self.row_shards.shard_count().max(1)
    }
}

/// Tiled fused low-rank execution — the tiled analogue of
/// [`LowRankFusedKernel`]. Each tile's mask rows are expanded
/// independently (OR of that tile's packed `I_z` rows into a
/// tile-width buffer) and consumed against the tile's column range of
/// `W`; the full `m × n` mask never exists, and every (tile, row)
/// expansion is independent — exactly the bounded-buffer, parallel
/// decode §3.1 claims for tiling.
pub struct TiledLowRankKernel {
    w: Matrix,
    specs: Vec<crate::tiling::TileSpec>,
    tiles: Vec<crate::tiling::TileFactors>,
    /// Tile-column shards: every tile's contribution lands only in
    /// its own column range, so tiles sharing a column range form one
    /// shard (executed in tile-row order) and shards own disjoint
    /// output columns — conflict-free, no merge step, and the same
    /// accumulation order as sequential tile-id execution.
    col_shards: Vec<TileColShard>,
    index_bytes: usize,
    ctx: Arc<ExecCtx>,
}

impl TiledLowRankKernel {
    /// Capture weights + per-tile factors and group tiles into
    /// tile-column shards; no mask assembly happens.
    pub fn new(w: &Matrix, index: &TiledLowRankIndex) -> Result<Self> {
        if index.m != w.rows() || index.n != w.cols() {
            return Err(Error::shape(format!(
                "tiled index {}x{} vs W {}x{}",
                index.m,
                index.n,
                w.rows(),
                w.cols()
            )));
        }
        // One validation pass yields the specs the kernel executes
        // with; the factors are cloned once, for ownership only.
        let specs = index.validated_specs()?;
        let col_shards = tile_col_shards(&specs);
        Ok(TiledLowRankKernel {
            w: w.clone(),
            col_shards,
            specs,
            index_bytes: index.index_bytes(),
            tiles: index.tiles.clone(),
            ctx: ExecCtx::single(),
        })
    }

    /// Attach the execution context the plan shards run on.
    pub fn with_exec(mut self, ctx: Arc<ExecCtx>) -> Self {
        self.ctx = ctx;
        self
    }

    /// Number of tiles executed.
    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }
}

impl SparseKernel for TiledLowRankKernel {
    fn name(&self) -> &'static str {
        "tiled"
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        let (m, n) = (self.w.rows(), self.w.cols());
        check_input(x, m)?;
        let batch = x.rows();
        out.reset_zero(batch, n);
        let t0 = Instant::now();
        let tier = simd::tier();
        let cell = OutCell::new(out.data_mut());
        self.ctx.run(self.col_shards.len(), |s| {
            let shard = &self.col_shards[s];
            let mut scratch = lock_tile_scratch(shard);
            let tile = scratch.as_mut_slice();
            for &ti in &shard.tiles {
                let (spec, f) = (&self.specs[ti], &self.tiles[ti]);
                let words = spec.cols().div_ceil(64);
                for li in 0..spec.rows() {
                    let i = spec.r0 + li;
                    // Expand this tile's mask row li into the buffer.
                    tile[..words].fill(0);
                    let mut any = false;
                    for (wi, &pw) in f.ip.row_words(li).iter().enumerate() {
                        let mut bits = pw;
                        while bits != 0 {
                            let l = wi * 64 + bits.trailing_zeros() as usize;
                            bits &= bits - 1;
                            if l >= f.rank {
                                break;
                            }
                            for (t, &z) in tile[..words].iter_mut().zip(f.iz.row_words(l)) {
                                *t |= z;
                            }
                            any = true;
                        }
                    }
                    if !any {
                        continue; // fully pruned tile row
                    }
                    // Consume against W row i, columns [c0, c1): one
                    // masked vector axpy per 64-column tile word.
                    let wrow = self.w.row(i);
                    for b in 0..batch {
                        let xv = x.get(b, i);
                        if xv == 0.0 {
                            continue;
                        }
                        for (wi, &word) in tile[..words].iter().enumerate() {
                            if word == 0 {
                                continue;
                            }
                            let j0 = spec.c0 + wi * 64;
                            // SAFETY: this shard exclusively owns
                            // output columns [spec.c0, spec.c1), and
                            // set bits of `word` only name columns
                            // < spec.c1 - j0 (BitMatrix keeps padding
                            // bits clear).
                            unsafe {
                                simd::masked_axpy(
                                    tier,
                                    word,
                                    xv,
                                    wrow.as_ptr().add(j0),
                                    cell.at(b * n + j0),
                                )
                            };
                        }
                    }
                }
            }
        })?;
        self.ctx
            .record_plan_spmm(SLOT_TILED, self.col_shards.len() as u64, t0);
        Ok(())
    }
    fn index_bytes(&self) -> usize {
        self.index_bytes
    }
    fn rows(&self) -> usize {
        self.w.rows()
    }
    fn cols(&self) -> usize {
        self.w.cols()
    }
    fn plan_shards(&self) -> usize {
        self.col_shards.len().max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(seed: u64, m: usize, n: usize, k: usize) -> (Matrix, BitMatrix, BitMatrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut rng);
        let ip = BitMatrix::from_fn(m, k, |_, _| rng.bernoulli(0.3));
        let iz = BitMatrix::from_fn(k, n, |_, _| rng.bernoulli(0.3));
        (w, ip, iz)
    }

    fn reference(w: &Matrix, ip: &BitMatrix, iz: &BitMatrix, x: &Matrix) -> Matrix {
        let wm = crate::pruning::prune_with_mask(w, &ip.bool_product(iz)).unwrap();
        x.matmul(&wm).unwrap()
    }

    #[test]
    fn all_kernels_match_reference() {
        let (w, ip, iz) = setup(1, 70, 130, 6);
        let mut rng = Rng::new(9);
        let x = Matrix::gaussian(4, 70, 0.0, 1.0, &mut rng);
        let want = reference(&w, &ip, &iz, &x);
        // viterbi is mask-shaping: its reference is the dense matmul
        // over its own regenerated mask, not over I_p ⊗ I_z.
        let vit_mask = ViterbiIndex::shape_mask(&ip.bool_product(&iz)).decode();
        let want_vit = x
            .matmul(&crate::pruning::prune_with_mask(&w, &vit_mask).unwrap())
            .unwrap();
        for fmt in KernelFormat::ALL {
            let kern = build_kernel(fmt, &w, &ip, &iz, None).unwrap();
            assert_eq!(kern.name(), fmt.name());
            assert_eq!((kern.rows(), kern.cols()), (70, 130));
            let got = kern.spmm(&x).unwrap();
            let oracle = if fmt == KernelFormat::Viterbi { &want_vit } else { &want };
            for (a, b) in got.data().iter().zip(oracle.data()) {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "{}: {a} vs {b}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn lowrank_index_is_factor_bits_not_mask_bits() {
        let (w, ip, iz) = setup(2, 96, 200, 4);
        let kern = LowRankFusedKernel::new(&w, &ip, &iz).unwrap();
        assert_eq!(kern.index_bytes(), (4 * (96 + 200)).div_ceil(8));
        let dense = DenseMaskedKernel::from_mask(&w, &ip.bool_product(&iz)).unwrap();
        assert!(kern.index_bytes() < dense.index_bytes());
    }

    #[test]
    fn shape_mismatches_rejected() {
        let (w, ip, iz) = setup(3, 20, 30, 4);
        let bad_ip = BitMatrix::zeros(21, 4);
        assert!(build_kernel(KernelFormat::Csr, &w, &bad_ip, &iz, None).is_err());
        let kern = build_kernel(KernelFormat::LowRankFused, &w, &ip, &iz, None).unwrap();
        assert!(kern.spmm(&Matrix::zeros(2, 19)).is_err());
    }

    #[test]
    fn format_parse_roundtrip() {
        for fmt in KernelFormat::ALL {
            assert_eq!(KernelFormat::parse(fmt.name()).unwrap(), fmt);
        }
        assert!(KernelFormat::parse("nope").is_err());
    }

    #[test]
    fn build_records_decode_metrics() {
        let (w, ip, iz) = setup(4, 30, 40, 4);
        let metrics = Metrics::new();
        build_kernel(KernelFormat::LowRankFused, &w, &ip, &iz, Some(&metrics)).unwrap();
        build_kernel(KernelFormat::Csr, &w, &ip, &iz, Some(&metrics)).unwrap();
        assert_eq!(metrics.snapshot().kernel_decodes, 2);
    }

    #[test]
    fn stored_construction_matches_factor_construction_bitwise() {
        use crate::formats::StoredIndex;
        let (w, ip, iz) = setup(5, 66, 140, 5);
        let mut rng = Rng::new(10);
        let x = Matrix::gaussian(3, 66, 0.0, 1.0, &mut rng);
        for (fmt, name) in [
            (KernelFormat::DenseMasked, "dense"),
            (KernelFormat::Csr, "csr"),
            (KernelFormat::Relative, "relative"),
            (KernelFormat::LowRankFused, "lowrank"),
            (KernelFormat::Viterbi, "viterbi"),
            (KernelFormat::Dcsr, "dcsr"),
        ] {
            let direct = build_kernel(fmt, &w, &ip, &iz, None).unwrap();
            let stored = StoredIndex::from_factors(name, &ip, &iz).unwrap();
            let loaded = build_kernel_from_stored(&stored, &w, None).unwrap();
            assert_eq!(loaded.name(), direct.name());
            assert_eq!(loaded.index_bytes(), direct.index_bytes(), "{name}");
            // identical construction order ⇒ bit-identical output
            assert_eq!(
                loaded.spmm(&x).unwrap().data(),
                direct.spmm(&x).unwrap().data(),
                "{name}"
            );
        }
    }

    #[test]
    fn metrics_slots_match_kernel_names() {
        use crate::coordinator::metrics::SPMM_KERNEL_NAMES;
        assert_eq!(SPMM_KERNEL_NAMES[SLOT_DENSE], "dense");
        assert_eq!(SPMM_KERNEL_NAMES[SLOT_CSR], "csr");
        assert_eq!(SPMM_KERNEL_NAMES[SLOT_RELATIVE], "relative");
        assert_eq!(SPMM_KERNEL_NAMES[SLOT_LOWRANK], "lowrank");
        assert_eq!(SPMM_KERNEL_NAMES[SLOT_TILED], "tiled");
        assert_eq!(SPMM_KERNEL_NAMES[SLOT_VITERBI], "viterbi");
        assert_eq!(SPMM_KERNEL_NAMES[SLOT_DCSR], "dcsr");
    }

    #[test]
    fn plans_shard_large_layers_and_record_metrics() {
        // Large enough that every format's plan splits into > 1 shard.
        let (w, ip, iz) = setup(8, 300, 260, 6);
        let mut rng = Rng::new(11);
        let x = Matrix::gaussian(2, 300, 0.0, 1.0, &mut rng);
        let metrics = Arc::new(Metrics::new());
        let ctx = ExecCtx::new(4, Some(Arc::clone(&metrics)));
        for fmt in KernelFormat::ALL {
            let kern = build_kernel_exec(fmt, &w, &ip, &iz, &ctx, None).unwrap();
            assert!(
                kern.plan_shards() > 1,
                "{} plan should shard a 300x260 layer, got {}",
                fmt.name(),
                kern.plan_shards()
            );
            kern.spmm(&x).unwrap();
        }
        let snap = metrics.snapshot();
        assert!(snap.spmm_shards > 4, "shards recorded: {}", snap.spmm_shards);
        for (slot, ns) in snap.spmm_kernel_ns.iter().enumerate() {
            if slot == SLOT_TILED {
                continue; // only constructible from a stored index
            }
            assert!(*ns > 0, "slot {slot} got no time");
        }
    }

    #[test]
    fn spmm_with_empty_batch_returns_empty_matrix() {
        // Regression: the multi-shard merge path must tolerate batch 0
        // (merge_partials would otherwise hit chunks_exact(0)).
        let (w, ip, iz) = setup(9, 310, 270, 6); // large enough to shard
        let x = Matrix::zeros(0, 310);
        for fmt in KernelFormat::ALL {
            let kern = build_kernel(fmt, &w, &ip, &iz, None).unwrap();
            assert!(kern.plan_shards() > 1, "{}", fmt.name());
            let out = kern.spmm(&x).unwrap();
            assert_eq!((out.rows(), out.cols()), (0, 270), "{}", fmt.name());
        }
    }

    #[test]
    fn exec_ctx_kernels_match_single_threaded_bitwise() {
        let (w, ip, iz) = setup(6, 150, 170, 5);
        let mut rng = Rng::new(12);
        let x = Matrix::gaussian(3, 150, 0.0, 1.0, &mut rng);
        let ctx = ExecCtx::new(3, None);
        for fmt in KernelFormat::ALL {
            let single = build_kernel(fmt, &w, &ip, &iz, None).unwrap();
            let pooled = build_kernel_exec(fmt, &w, &ip, &iz, &ctx, None).unwrap();
            assert_eq!(
                single.plan_shards(),
                pooled.plan_shards(),
                "{}: plan must not depend on the context",
                fmt.name()
            );
            assert_eq!(
                pooled.spmm(&x).unwrap().data(),
                single.spmm(&x).unwrap().data(),
                "{}",
                fmt.name()
            );
        }
    }

    #[test]
    fn tiled_kernel_matches_assembled_mask_reference() {
        use crate::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
        let mut rng = Rng::new(12);
        let (m, n) = (50, 135); // 2x3 plan with non-divisible extents
        let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut rng);
        let plan = TilePlan::new(2, 3);
        let specs = plan.tiles(m, n).unwrap();
        let tiles: Vec<TileFactors> = specs
            .iter()
            .map(|s| {
                let k = 3 + s.id % 2; // mixed per-tile ranks
                TileFactors {
                    rank: k,
                    ip: BitMatrix::from_fn(s.rows(), k, |_, _| rng.bernoulli(0.3)),
                    iz: BitMatrix::from_fn(k, s.cols(), |_, _| rng.bernoulli(0.3)),
                }
            })
            .collect();
        let index = TiledLowRankIndex::new(m, n, plan, tiles).unwrap();
        let kern = TiledLowRankKernel::new(&w, &index).unwrap();
        assert_eq!(kern.name(), "tiled");
        assert_eq!(kern.tile_count(), 6);
        assert_eq!(kern.index_bytes(), index.index_bytes());
        let x = Matrix::gaussian(4, m, 0.0, 1.0, &mut rng);
        let got = kern.spmm(&x).unwrap();
        let wm =
            crate::pruning::prune_with_mask(&w, &index.decode_mask().unwrap()).unwrap();
        let want = x.matmul(&wm).unwrap();
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        // shape mismatch rejected
        assert!(TiledLowRankKernel::new(&Matrix::zeros(m, n + 1), &index).is_err());
        assert!(kern.spmm(&Matrix::zeros(2, m + 1)).is_err());
    }
}
