//! Sparse-execution kernels: the masked-layer matmul executed
//! *directly on* each index representation.
//!
//! The paper's observation is that "computations using sparse matrices
//! obtained by pruning parameters exhibit vastly different parallelism
//! depending on the index representation scheme" — so the serving
//! engine must not erase the distinction by decoding every format to a
//! dense mask first. Each [`SparseKernel`] implementation computes
//! `x · (W ⊙ I)` using the traversal its format affords:
//!
//! | format          | execution strategy                                  |
//! |-----------------|-----------------------------------------------------|
//! | dense-masked    | pre-mask `W` once, dense matmul (baseline)          |
//! | CSR (16-bit)    | gather-accumulate over `IA`/`JA` + packed values    |
//! | relative (5-bit)| stream the gap entries, fusing decode with compute  |
//! | fused low-rank  | expand `I_p ⊗ I_z` one packed row at a time         |
//!
//! The fused low-rank kernel never materialises the full `m × n` mask:
//! it ORs the packed `u64` rows of `I_z` selected by row `i` of `I_p`
//! into a single `n/64`-word tile, consumes it, and reuses the buffer
//! for the next row — the in-register analogue of the paper's on-chip
//! decompressor.

use crate::coordinator::metrics::Metrics;
use crate::formats::csr::Csr16;
use crate::formats::relative::{Csr5Relative, MAX_GAP};
use crate::formats::StoredIndex;
use crate::tensor::Matrix;
use crate::tiling::TiledLowRankIndex;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};
use std::sync::atomic::Ordering;
use std::time::Instant;

/// A sparse-execution strategy for the masked layer.
///
/// `spmm` computes `x · (W ⊙ I)` where `W` (m × n) and the pruning
/// mask `I` were captured at construction; `x` is `(batch, m)` and the
/// result is `(batch, n)`. All implementations are numerically
/// equivalent (same products, possibly reassociated) — see the
/// cross-format property test in `tests/kernels.rs`.
pub trait SparseKernel: Send {
    /// Kernel name as reported in metrics/benches.
    fn name(&self) -> &'static str;
    /// `x (batch × m)` → `x · (W ⊙ I)` of shape `(batch × n)`.
    fn spmm(&self, x: &Matrix) -> Result<Matrix>;
    /// Bytes of index metadata this kernel executes from.
    fn index_bytes(&self) -> usize;
    /// Mask rows `m` (the layer's input width).
    fn rows(&self) -> usize;
    /// Mask cols `n` (the layer's output width).
    fn cols(&self) -> usize;
}

/// Which [`SparseKernel`] the serving engine runs — selected per
/// format at startup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFormat {
    /// Decode the mask once, pre-mask `W`, dense matmul (baseline).
    DenseMasked,
    /// CSR with 16-bit column indices, gather-accumulate.
    Csr,
    /// 5-bit relative (gap) stream, decode fused with compute.
    Relative,
    /// Fused low-rank: `I_p ⊗ I_z` expanded tile-by-tile from packed
    /// words, never materialising the dense mask.
    LowRankFused,
}

impl KernelFormat {
    /// Every selectable kernel, baseline first.
    pub const ALL: [KernelFormat; 4] = [
        KernelFormat::DenseMasked,
        KernelFormat::Csr,
        KernelFormat::Relative,
        KernelFormat::LowRankFused,
    ];

    /// Stable CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            KernelFormat::DenseMasked => "dense",
            KernelFormat::Csr => "csr",
            KernelFormat::Relative => "relative",
            KernelFormat::LowRankFused => "lowrank",
        }
    }

    /// Parse a CLI/report name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dense" | "dense-masked" => Ok(KernelFormat::DenseMasked),
            "csr" => Ok(KernelFormat::Csr),
            "relative" | "csr5" => Ok(KernelFormat::Relative),
            "lowrank" | "low-rank" | "fused" => Ok(KernelFormat::LowRankFused),
            other => Err(Error::invalid(format!(
                "unknown kernel format '{other}' (want dense|csr|relative|lowrank)"
            ))),
        }
    }
}

fn check_factor_shapes(w: &Matrix, ip: &BitMatrix, iz: &BitMatrix) -> Result<()> {
    if ip.rows() != w.rows() || iz.cols() != w.cols() || ip.cols() != iz.rows() {
        return Err(Error::shape(format!(
            "kernel factors: W {}x{}, I_p {}x{}, I_z {}x{}",
            w.rows(),
            w.cols(),
            ip.rows(),
            ip.cols(),
            iz.rows(),
            iz.cols()
        )));
    }
    Ok(())
}

fn check_mask_shape(w: &Matrix, mask: &BitMatrix) -> Result<()> {
    if mask.rows() != w.rows() || mask.cols() != w.cols() {
        return Err(Error::shape(format!(
            "kernel mask {}x{} vs W {}x{}",
            mask.rows(),
            mask.cols(),
            w.rows(),
            w.cols()
        )));
    }
    Ok(())
}

fn check_input(x: &Matrix, m: usize) -> Result<()> {
    if x.cols() != m {
        return Err(Error::shape(format!("spmm input {}x{} vs m={m}", x.rows(), x.cols())));
    }
    Ok(())
}

/// Build the kernel for `format` over layer weights `w` and the
/// factorized index `(I_p, I_z)`. When `metrics` is given, the build
/// (the per-format decode/encode step) is counted into
/// `kernel_decodes` / `kernel_decode_ns`.
pub fn build_kernel(
    format: KernelFormat,
    w: &Matrix,
    ip: &BitMatrix,
    iz: &BitMatrix,
    metrics: Option<&Metrics>,
) -> Result<Box<dyn SparseKernel>> {
    check_factor_shapes(w, ip, iz)?;
    let t0 = Instant::now();
    let kernel: Box<dyn SparseKernel> = match format {
        KernelFormat::DenseMasked => {
            Box::new(DenseMaskedKernel::from_mask(w, &ip.bool_product(iz))?)
        }
        KernelFormat::Csr => Box::new(CsrKernel::new(w, &ip.bool_product(iz))?),
        KernelFormat::Relative => Box::new(RelativeKernel::new(w, &ip.bool_product(iz))?),
        KernelFormat::LowRankFused => Box::new(LowRankFusedKernel::new(w, ip, iz)?),
    };
    if let Some(m) = metrics {
        m.kernel_decodes.fetch_add(1, Ordering::Relaxed);
        m.kernel_decode_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
    Ok(kernel)
}

/// Build the kernel for a *stored* index (the artifact load path).
/// Each variant goes straight from its serialized representation to
/// the kernel that executes it — CSR and relative streams feed their
/// kernels without reconstructing the dense mask, low-rank and tiled
/// factors stay factors. The dense-bitmap variant's decode *is* its
/// format semantics (the bitmap is the mask).
pub fn build_kernel_from_stored(
    stored: &StoredIndex,
    w: &Matrix,
    metrics: Option<&Metrics>,
) -> Result<Box<dyn SparseKernel>> {
    let t0 = Instant::now();
    let kernel: Box<dyn SparseKernel> = match stored {
        StoredIndex::Binary(b) => Box::new(DenseMaskedKernel::from_mask(w, &b.decode())?),
        StoredIndex::Csr(c) => Box::new(CsrKernel::from_encoded(w, c)?),
        StoredIndex::Relative(r) => Box::new(RelativeKernel::from_stream(w, r)?),
        StoredIndex::LowRank(l) => {
            let (ip, iz) = l.factors()?;
            Box::new(LowRankFusedKernel::new(w, &ip, &iz)?)
        }
        StoredIndex::Tiled(t) => Box::new(TiledLowRankKernel::new(w, t)?),
    };
    if let Some(m) = metrics {
        m.kernel_decodes.fetch_add(1, Ordering::Relaxed);
        m.kernel_decode_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
    Ok(kernel)
}

/// Baseline: the mask is decoded to dense once and burned into a
/// pre-masked copy of `W`; `spmm` is a plain dense matmul. This is
/// exactly what the engine did before the kernel layer existed, kept
/// as the reference point every other kernel is measured against.
pub struct DenseMaskedKernel {
    w_masked: Matrix,
    index_bytes: usize,
}

impl DenseMaskedKernel {
    /// Build from weights + a pre-decoded mask.
    pub fn from_mask(w: &Matrix, mask: &BitMatrix) -> Result<Self> {
        check_mask_shape(w, mask)?;
        let w_masked = crate::pruning::prune_with_mask(w, mask)?;
        Ok(DenseMaskedKernel { w_masked, index_bytes: mask.index_bytes() })
    }

    /// The pre-masked weight (for oracles in tests/benches).
    pub fn weights(&self) -> &Matrix {
        &self.w_masked
    }
}

impl SparseKernel for DenseMaskedKernel {
    fn name(&self) -> &'static str {
        "dense"
    }
    fn spmm(&self, x: &Matrix) -> Result<Matrix> {
        x.matmul(&self.w_masked)
    }
    fn index_bytes(&self) -> usize {
        self.index_bytes
    }
    fn rows(&self) -> usize {
        self.w_masked.rows()
    }
    fn cols(&self) -> usize {
        self.w_masked.cols()
    }
}

/// CSR gather-accumulate: `JA` column indices walk each weight row's
/// survivors; the surviving weights are packed contiguously in `vals`
/// (the gather happens once at build), so `spmm` touches only live
/// entries — work is O(batch · nnz), not O(batch · m · n).
pub struct CsrKernel {
    m: usize,
    n: usize,
    ia: Vec<u32>,
    ja: Vec<u16>,
    vals: Vec<f32>,
    index_bytes: usize,
}

impl CsrKernel {
    /// Encode the mask as CSR and gather the surviving weights. The
    /// freshly-encoded `IA`/`JA` arrays are *moved* into the kernel —
    /// no copy on the factor path.
    pub fn new(w: &Matrix, mask: &BitMatrix) -> Result<Self> {
        check_mask_shape(w, mask)?;
        let csr = Csr16::encode(mask);
        let vals = gather_csr_vals(w, &csr)?;
        Ok(CsrKernel {
            m: csr.rows(),
            n: csr.cols(),
            index_bytes: csr.index_bytes(),
            ia: csr.ia,
            ja: csr.ja,
            vals,
        })
    }

    /// Build directly from an already-encoded CSR index (the artifact
    /// load path, where the index is borrowed from the artifact) —
    /// gathers surviving weights without touching a dense mask. The
    /// gather order is identical to [`CsrKernel::new`], so the two
    /// construction paths produce bit-identical `spmm` output.
    pub fn from_encoded(w: &Matrix, csr: &Csr16) -> Result<Self> {
        let vals = gather_csr_vals(w, csr)?;
        Ok(CsrKernel {
            m: csr.rows(),
            n: csr.cols(),
            ia: csr.ia.clone(),
            ja: csr.ja.clone(),
            vals,
            index_bytes: csr.index_bytes(),
        })
    }

    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }
}

impl SparseKernel for CsrKernel {
    fn name(&self) -> &'static str {
        "csr"
    }
    fn spmm(&self, x: &Matrix) -> Result<Matrix> {
        check_input(x, self.m)?;
        let batch = x.rows();
        let mut out = Matrix::zeros(batch, self.n);
        for b in 0..batch {
            let xrow = x.row(b);
            let orow = &mut out.data_mut()[b * self.n..(b + 1) * self.n];
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let (a, e) = (self.ia[i] as usize, self.ia[i + 1] as usize);
                for (j, v) in self.ja[a..e].iter().zip(&self.vals[a..e]) {
                    orow[*j as usize] += xv * v;
                }
            }
        }
        Ok(out)
    }
    fn index_bytes(&self) -> usize {
        self.index_bytes
    }
    fn rows(&self) -> usize {
        self.m
    }
    fn cols(&self) -> usize {
        self.n
    }
}

/// Shape-check a CSR index against `w` and gather the surviving
/// weights in `IA`/`JA` order (shared by both `CsrKernel`
/// constructors so their gather order — and thus `spmm` bit pattern —
/// is identical).
fn gather_csr_vals(w: &Matrix, csr: &Csr16) -> Result<Vec<f32>> {
    if csr.rows() != w.rows() || csr.cols() != w.cols() {
        return Err(Error::shape(format!(
            "CSR index {}x{} vs W {}x{}",
            csr.rows(),
            csr.cols(),
            w.rows(),
            w.cols()
        )));
    }
    let mut vals = Vec::with_capacity(csr.nnz());
    for i in 0..csr.rows() {
        let (a, b) = (csr.ia[i] as usize, csr.ia[i + 1] as usize);
        if b < a || b > csr.ja.len() {
            return Err(Error::store(format!("corrupt CSR IA at row {i}")));
        }
        for &j in &csr.ja[a..b] {
            if (j as usize) >= csr.cols() {
                return Err(Error::store(format!("CSR JA out of range: {j}")));
            }
            vals.push(w.get(i, j as usize));
        }
    }
    Ok(vals)
}

/// Relative-index streaming: the 5-bit gap stream of
/// [`Csr5Relative`] is walked entry-by-entry, decode fused with the
/// accumulate — the mask is never expanded, matching how Deep
/// Compression's decompressor consumes the stream. Work is inherently
/// sequential per stream (each position depends on the running cursor),
/// which is exactly the parallelism limitation the paper's low-rank
/// format removes.
pub struct RelativeKernel {
    m: usize,
    n: usize,
    entries: Vec<u8>,
    /// Surviving weights in stream order (fillers carry no value).
    vals: Vec<f32>,
    index_bytes: usize,
}

impl RelativeKernel {
    /// Encode the mask as a gap stream and gather surviving weights in
    /// stream order. The freshly-encoded entry stream is *moved* into
    /// the kernel — no copy on the factor path.
    pub fn new(w: &Matrix, mask: &BitMatrix) -> Result<Self> {
        check_mask_shape(w, mask)?;
        let stream = Csr5Relative::encode(mask);
        let vals = gather_stream_vals(w, &stream)?;
        let (m, n, index_bytes) = (stream.rows(), stream.cols(), stream.index_bytes());
        Ok(RelativeKernel { m, n, entries: stream.into_entries(), vals, index_bytes })
    }

    /// Build directly from an already-encoded gap stream (the artifact
    /// load path, where the stream is borrowed from the artifact): the
    /// stream is walked once to gather surviving weights, fusing the
    /// only decode this kernel ever does with the value gather — the
    /// mask is never expanded.
    pub fn from_stream(w: &Matrix, stream: &Csr5Relative) -> Result<Self> {
        let vals = gather_stream_vals(w, stream)?;
        Ok(RelativeKernel {
            m: stream.rows(),
            n: stream.cols(),
            entries: stream.entries().to_vec(),
            vals,
            index_bytes: stream.index_bytes(),
        })
    }
}

/// Shape-check a gap stream against `w` and gather the surviving
/// weights in stream order (shared by both `RelativeKernel`
/// constructors so their gather order is identical).
fn gather_stream_vals(w: &Matrix, stream: &Csr5Relative) -> Result<Vec<f32>> {
    if stream.rows() != w.rows() || stream.cols() != w.cols() {
        return Err(Error::shape(format!(
            "relative index {}x{} vs W {}x{}",
            stream.rows(),
            stream.cols(),
            w.rows(),
            w.cols()
        )));
    }
    let n = stream.cols();
    let total = stream.rows() * n;
    let mut vals = Vec::with_capacity(stream.nnz());
    let mut pos = 0usize;
    let mut pending = 0u32;
    for &e in stream.entries() {
        if e as u32 == MAX_GAP {
            pending += MAX_GAP;
            continue;
        }
        pos += (pending + e as u32) as usize;
        pending = 0;
        if pos >= total {
            return Err(Error::store(format!(
                "relative stream runs past the {total}-element mask"
            )));
        }
        vals.push(w.get(pos / n, pos % n));
        pos += 1;
    }
    Ok(vals)
}

impl SparseKernel for RelativeKernel {
    fn name(&self) -> &'static str {
        "relative"
    }
    fn spmm(&self, x: &Matrix) -> Result<Matrix> {
        check_input(x, self.m)?;
        let batch = x.rows();
        let n = self.n;
        let mut out = Matrix::zeros(batch, n);
        // Stream outer, batch inner: the sequential cursor decode runs
        // once per call, and every decoded (i, j) is applied to all
        // batch rows while it is hot.
        let mut pos = 0usize;
        let mut pending = 0u32;
        let mut vi = 0usize;
        for &e in &self.entries {
            if e as u32 == MAX_GAP {
                pending += MAX_GAP;
                continue;
            }
            pos += (pending + e as u32) as usize;
            pending = 0;
            let (i, j) = (pos / n, pos % n);
            let v = self.vals[vi];
            let odata = out.data_mut();
            for b in 0..batch {
                odata[b * n + j] += x.get(b, i) * v;
            }
            vi += 1;
            pos += 1;
        }
        Ok(out)
    }
    fn index_bytes(&self) -> usize {
        self.index_bytes
    }
    fn rows(&self) -> usize {
        self.m
    }
    fn cols(&self) -> usize {
        self.n
    }
}

/// Fused low-rank execution: for each weight row `i`, the mask row is
/// reconstructed as the word-wise OR of the packed `I_z` rows selected
/// by the set bits of `I_p` row `i` — one `n/64`-word tile that lives
/// in a reused buffer — and is consumed immediately by walking its set
/// bits against row `i` of `W`. The dense `m × n` mask never exists;
/// peak extra memory is one row tile regardless of layer size, and
/// every row's expansion is independent (the parallelism the paper
/// claims for the format).
pub struct LowRankFusedKernel {
    w: Matrix,
    ip: BitMatrix,
    iz: BitMatrix,
}

impl LowRankFusedKernel {
    /// Capture weights + packed factors; no decode happens here.
    pub fn new(w: &Matrix, ip: &BitMatrix, iz: &BitMatrix) -> Result<Self> {
        check_factor_shapes(w, ip, iz)?;
        Ok(LowRankFusedKernel { w: w.clone(), ip: ip.clone(), iz: iz.clone() })
    }

    /// Rank of the factorization.
    pub fn rank(&self) -> usize {
        self.ip.cols()
    }
}

impl SparseKernel for LowRankFusedKernel {
    fn name(&self) -> &'static str {
        "lowrank"
    }
    fn spmm(&self, x: &Matrix) -> Result<Matrix> {
        let (m, n, k) = (self.w.rows(), self.w.cols(), self.ip.cols());
        check_input(x, m)?;
        let batch = x.rows();
        let mut out = Matrix::zeros(batch, n);
        let words = n.div_ceil(64);
        let mut tile = vec![0u64; words];
        for i in 0..m {
            // Expand mask row i: OR the I_z rows named by I_p row i.
            tile.fill(0);
            let mut any = false;
            for (wi, &w) in self.ip.row_words(i).iter().enumerate() {
                let mut bits = w;
                while bits != 0 {
                    let l = wi * 64 + bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    if l >= k {
                        break;
                    }
                    for (t, &z) in tile.iter_mut().zip(self.iz.row_words(l)) {
                        *t |= z;
                    }
                    any = true;
                }
            }
            if !any {
                continue; // fully pruned row
            }
            // Consume the tile against W row i for every batch row.
            let wrow = self.w.row(i);
            for b in 0..batch {
                let xv = x.get(b, i);
                if xv == 0.0 {
                    continue;
                }
                let orow = &mut out.data_mut()[b * n..(b + 1) * n];
                for (wi, &word) in tile.iter().enumerate() {
                    let mut bits = word;
                    while bits != 0 {
                        let j = wi * 64 + bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        orow[j] += xv * wrow[j];
                    }
                }
            }
        }
        Ok(out)
    }
    fn index_bytes(&self) -> usize {
        (self.ip.cols() * (self.ip.rows() + self.iz.cols())).div_ceil(8)
    }
    fn rows(&self) -> usize {
        self.w.rows()
    }
    fn cols(&self) -> usize {
        self.w.cols()
    }
}

/// Tiled fused low-rank execution — the tiled analogue of
/// [`LowRankFusedKernel`]. Each tile's mask rows are expanded
/// independently (OR of that tile's packed `I_z` rows into a
/// tile-width buffer) and consumed against the tile's column range of
/// `W`; the full `m × n` mask never exists, and every (tile, row)
/// expansion is independent — exactly the bounded-buffer, parallel
/// decode §3.1 claims for tiling.
pub struct TiledLowRankKernel {
    w: Matrix,
    specs: Vec<crate::tiling::TileSpec>,
    tiles: Vec<crate::tiling::TileFactors>,
    index_bytes: usize,
}

impl TiledLowRankKernel {
    /// Capture weights + per-tile factors; no mask assembly happens.
    pub fn new(w: &Matrix, index: &TiledLowRankIndex) -> Result<Self> {
        if index.m != w.rows() || index.n != w.cols() {
            return Err(Error::shape(format!(
                "tiled index {}x{} vs W {}x{}",
                index.m,
                index.n,
                w.rows(),
                w.cols()
            )));
        }
        // One validation pass yields the specs the kernel executes
        // with; the factors are cloned once, for ownership only.
        let specs = index.validated_specs()?;
        Ok(TiledLowRankKernel {
            w: w.clone(),
            specs,
            index_bytes: index.index_bytes(),
            tiles: index.tiles.clone(),
        })
    }

    /// Number of tiles executed.
    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }
}

impl SparseKernel for TiledLowRankKernel {
    fn name(&self) -> &'static str {
        "tiled"
    }
    fn spmm(&self, x: &Matrix) -> Result<Matrix> {
        let (m, n) = (self.w.rows(), self.w.cols());
        check_input(x, m)?;
        let batch = x.rows();
        let mut out = Matrix::zeros(batch, n);
        let max_words = self
            .specs
            .iter()
            .map(|s| s.cols().div_ceil(64))
            .max()
            .unwrap_or(0);
        let mut tile = vec![0u64; max_words];
        for (spec, f) in self.specs.iter().zip(&self.tiles) {
            let words = spec.cols().div_ceil(64);
            for li in 0..spec.rows() {
                let i = spec.r0 + li;
                // Expand this tile's mask row li into the tile buffer.
                tile[..words].fill(0);
                let mut any = false;
                for (wi, &pw) in f.ip.row_words(li).iter().enumerate() {
                    let mut bits = pw;
                    while bits != 0 {
                        let l = wi * 64 + bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        if l >= f.rank {
                            break;
                        }
                        for (t, &z) in tile[..words].iter_mut().zip(f.iz.row_words(l)) {
                            *t |= z;
                        }
                        any = true;
                    }
                }
                if !any {
                    continue; // fully pruned tile row
                }
                // Consume against W row i, columns [c0, c1).
                let wrow = self.w.row(i);
                for b in 0..batch {
                    let xv = x.get(b, i);
                    if xv == 0.0 {
                        continue;
                    }
                    let orow = &mut out.data_mut()[b * n..(b + 1) * n];
                    for (wi, &word) in tile[..words].iter().enumerate() {
                        let mut bits = word;
                        while bits != 0 {
                            let lj = wi * 64 + bits.trailing_zeros() as usize;
                            bits &= bits - 1;
                            let j = spec.c0 + lj;
                            orow[j] += xv * wrow[j];
                        }
                    }
                }
            }
        }
        Ok(out)
    }
    fn index_bytes(&self) -> usize {
        self.index_bytes
    }
    fn rows(&self) -> usize {
        self.w.rows()
    }
    fn cols(&self) -> usize {
        self.w.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(seed: u64, m: usize, n: usize, k: usize) -> (Matrix, BitMatrix, BitMatrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut rng);
        let ip = BitMatrix::from_fn(m, k, |_, _| rng.bernoulli(0.3));
        let iz = BitMatrix::from_fn(k, n, |_, _| rng.bernoulli(0.3));
        (w, ip, iz)
    }

    fn reference(w: &Matrix, ip: &BitMatrix, iz: &BitMatrix, x: &Matrix) -> Matrix {
        let wm = crate::pruning::prune_with_mask(w, &ip.bool_product(iz)).unwrap();
        x.matmul(&wm).unwrap()
    }

    #[test]
    fn all_kernels_match_reference() {
        let (w, ip, iz) = setup(1, 70, 130, 6);
        let mut rng = Rng::new(9);
        let x = Matrix::gaussian(4, 70, 0.0, 1.0, &mut rng);
        let want = reference(&w, &ip, &iz, &x);
        for fmt in KernelFormat::ALL {
            let kern = build_kernel(fmt, &w, &ip, &iz, None).unwrap();
            assert_eq!(kern.name(), fmt.name());
            assert_eq!((kern.rows(), kern.cols()), (70, 130));
            let got = kern.spmm(&x).unwrap();
            for (a, b) in got.data().iter().zip(want.data()) {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "{}: {a} vs {b}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn lowrank_index_is_factor_bits_not_mask_bits() {
        let (w, ip, iz) = setup(2, 96, 200, 4);
        let kern = LowRankFusedKernel::new(&w, &ip, &iz).unwrap();
        assert_eq!(kern.index_bytes(), (4 * (96 + 200)).div_ceil(8));
        let dense = DenseMaskedKernel::from_mask(&w, &ip.bool_product(&iz)).unwrap();
        assert!(kern.index_bytes() < dense.index_bytes());
    }

    #[test]
    fn shape_mismatches_rejected() {
        let (w, ip, iz) = setup(3, 20, 30, 4);
        let bad_ip = BitMatrix::zeros(21, 4);
        assert!(build_kernel(KernelFormat::Csr, &w, &bad_ip, &iz, None).is_err());
        let kern = build_kernel(KernelFormat::LowRankFused, &w, &ip, &iz, None).unwrap();
        assert!(kern.spmm(&Matrix::zeros(2, 19)).is_err());
    }

    #[test]
    fn format_parse_roundtrip() {
        for fmt in KernelFormat::ALL {
            assert_eq!(KernelFormat::parse(fmt.name()).unwrap(), fmt);
        }
        assert!(KernelFormat::parse("nope").is_err());
    }

    #[test]
    fn build_records_decode_metrics() {
        let (w, ip, iz) = setup(4, 30, 40, 4);
        let metrics = Metrics::new();
        build_kernel(KernelFormat::LowRankFused, &w, &ip, &iz, Some(&metrics)).unwrap();
        build_kernel(KernelFormat::Csr, &w, &ip, &iz, Some(&metrics)).unwrap();
        assert_eq!(metrics.snapshot().kernel_decodes, 2);
    }

    #[test]
    fn stored_construction_matches_factor_construction_bitwise() {
        use crate::formats::StoredIndex;
        let (w, ip, iz) = setup(5, 66, 140, 5);
        let mut rng = Rng::new(10);
        let x = Matrix::gaussian(3, 66, 0.0, 1.0, &mut rng);
        for (fmt, name) in [
            (KernelFormat::DenseMasked, "dense"),
            (KernelFormat::Csr, "csr"),
            (KernelFormat::Relative, "relative"),
            (KernelFormat::LowRankFused, "lowrank"),
        ] {
            let direct = build_kernel(fmt, &w, &ip, &iz, None).unwrap();
            let stored = StoredIndex::from_factors(name, &ip, &iz).unwrap();
            let loaded = build_kernel_from_stored(&stored, &w, None).unwrap();
            assert_eq!(loaded.name(), direct.name());
            assert_eq!(loaded.index_bytes(), direct.index_bytes(), "{name}");
            // identical construction order ⇒ bit-identical output
            assert_eq!(
                loaded.spmm(&x).unwrap().data(),
                direct.spmm(&x).unwrap().data(),
                "{name}"
            );
        }
    }

    #[test]
    fn tiled_kernel_matches_assembled_mask_reference() {
        use crate::tiling::{TileFactors, TilePlan, TiledLowRankIndex};
        let mut rng = Rng::new(12);
        let (m, n) = (50, 135); // 2x3 plan with non-divisible extents
        let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut rng);
        let plan = TilePlan::new(2, 3);
        let specs = plan.tiles(m, n).unwrap();
        let tiles: Vec<TileFactors> = specs
            .iter()
            .map(|s| {
                let k = 3 + s.id % 2; // mixed per-tile ranks
                TileFactors {
                    rank: k,
                    ip: BitMatrix::from_fn(s.rows(), k, |_, _| rng.bernoulli(0.3)),
                    iz: BitMatrix::from_fn(k, s.cols(), |_, _| rng.bernoulli(0.3)),
                }
            })
            .collect();
        let index = TiledLowRankIndex::new(m, n, plan, tiles).unwrap();
        let kern = TiledLowRankKernel::new(&w, &index).unwrap();
        assert_eq!(kern.name(), "tiled");
        assert_eq!(kern.tile_count(), 6);
        assert_eq!(kern.index_bytes(), index.index_bytes());
        let x = Matrix::gaussian(4, m, 0.0, 1.0, &mut rng);
        let got = kern.spmm(&x).unwrap();
        let wm =
            crate::pruning::prune_with_mask(&w, &index.decode_mask().unwrap()).unwrap();
        let want = x.matmul(&wm).unwrap();
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        // shape mismatch rejected
        assert!(TiledLowRankKernel::new(&Matrix::zeros(m, n + 1), &index).is_err());
        assert!(kern.spmm(&Matrix::zeros(2, m + 1)).is_err());
    }
}
