//! The inference engine: fixed-batch backends (PJRT artifact or native
//! fallback) behind a dynamic batcher. The native backend's masked
//! layer executes through a pluggable [`SparseKernel`] selected by
//! index format at startup, so the request path runs directly on the
//! compressed representation instead of always decoding to dense.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::ExecCtx;
use crate::coordinator::telemetry::{Stage, StageNanos};
use crate::runtime::artifacts::GEOMETRY;
use crate::runtime::client::{literal_matrix, matrix_literal, Runtime};
use crate::serve::batcher::{BatchPolicy, BatcherClient, DynamicBatcher};
use crate::formats::StoredIndex;
use crate::serve::kernels::{
    build_kernel_exec, build_kernel_from_stored_exec, DenseMaskedKernel, KernelFormat,
    SparseKernel,
};
use crate::store::Artifact;
use crate::tensor::Matrix;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A fixed-geometry classifier backend.
///
/// Backends need not be `Send` (the PJRT client is `!Send`); the
/// serving engine constructs the backend *inside* its executor thread
/// via the factory passed to [`ServingEngine::start_with`].
pub trait InferenceBackend {
    /// Fixed batch size the backend executes.
    fn batch(&self) -> usize;
    /// Input feature dimension.
    fn input_dim(&self) -> usize;
    /// Output classes.
    fn classes(&self) -> usize;
    /// Run one full batch into a caller-owned output buffer: `x` is
    /// (batch, input_dim); `out` is re-shaped in place to
    /// (batch, classes). The serving executor passes one persistent
    /// `out` across flushes, so a backend that also reuses its
    /// internal buffers (like [`NativeBackend`]) makes the whole
    /// predict path allocation-free after the first flush.
    fn predict_into(&mut self, x: &Matrix, out: &mut Matrix) -> Result<()>;
    /// Allocating convenience wrapper over
    /// [`InferenceBackend::predict_into`].
    fn predict(&mut self, x: &Matrix) -> Result<Matrix> {
        let mut out = Matrix::zeros(0, 0);
        self.predict_into(x, &mut out)?;
        Ok(out)
    }
    /// Nanoseconds the last `predict_into` spent inside the sparse
    /// kernel's `spmm` — the `spmm` stage of every request in that
    /// flush. Backends that don't time themselves report 0 (the
    /// executor then skips the stage rather than recording zeros).
    fn last_spmm_ns(&self) -> u64 {
        0
    }
    /// Drain the partial-merge nanoseconds accumulated since the last
    /// call (reduction-sharded plans only) — the `merge` stage.
    /// Backends without plan execution report 0.
    fn take_last_merge_ns(&mut self) -> u64 {
        0
    }
}

/// Model parameters for the LeNet-FC classifier (mirrors model.py).
/// `PartialEq` is derived (not hand-rolled field comparison) so that
/// equality keeps covering every field if the struct grows — the
/// hot-swap path relies on it to decide whether cached kernels must
/// be flushed.
#[derive(Debug, Clone, PartialEq)]
pub struct MlpParams {
    /// FC0 weight (input_dim × hidden0).
    pub w0: Matrix,
    /// FC0 bias.
    pub b0: Vec<f32>,
    /// FC1 weight (hidden0 × hidden1) — the masked layer.
    pub w1: Matrix,
    /// FC1 bias.
    pub b1: Vec<f32>,
    /// FC2 weight (hidden1 × classes).
    pub w2: Matrix,
    /// FC2 bias.
    pub b2: Vec<f32>,
}

impl MlpParams {
    /// He-initialised parameters.
    pub fn init(seed: u64) -> Self {
        let g = GEOMETRY;
        let mut rng = crate::util::rng::Rng::new(seed);
        let he = |rng: &mut crate::util::rng::Rng, fan_in: usize, r: usize, c: usize| {
            Matrix::gaussian(r, c, 0.0, (2.0 / fan_in as f32).sqrt(), rng)
        };
        MlpParams {
            w0: he(&mut rng, g.input_dim, g.input_dim, g.hidden0),
            b0: vec![0.0; g.hidden0],
            w1: he(&mut rng, g.hidden0, g.hidden0, g.hidden1),
            b1: vec![0.0; g.hidden1],
            w2: he(&mut rng, g.hidden1, g.hidden1, g.classes),
            b2: vec![0.0; g.classes],
        }
    }
}

fn relu_inplace(m: &mut Matrix) {
    m.map_inplace(|v| v.max(0.0));
}

fn add_bias(m: &mut Matrix, b: &[f32]) {
    let cols = m.cols();
    for (idx, v) in m.data_mut().iter_mut().enumerate() {
        *v += b[idx % cols];
    }
}

/// Pure-Rust backend: the masked FC1 matmul runs through a
/// [`SparseKernel`] built once at construction (or factor update) —
/// the serving analogue of the paper's on-chip decompressor, with the
/// execution strategy chosen by [`KernelFormat`].
pub struct NativeBackend {
    params: MlpParams,
    format: KernelFormat,
    kernel: Box<dyn SparseKernel>,
    batch: usize,
    metrics: Option<Arc<Metrics>>,
    /// Execution context the kernel's plan shards run on; shared with
    /// any kernel rebuilt by `update_factors`.
    ctx: Arc<ExecCtx>,
    /// Persistent hidden-layer activation buffers, re-shaped in place
    /// every predict — after the first batch the forward pass
    /// allocates nothing.
    h0: Matrix,
    h1: Matrix,
    /// `spmm` wall time of the last `predict_into` (the executor reads
    /// it back as the flush's `spmm` stage).
    last_spmm_ns: u64,
}

impl NativeBackend {
    /// Build from params + binary factors with the dense-masked
    /// baseline kernel (the pre-kernel-layer behavior).
    pub fn new(params: MlpParams, ip: &BitMatrix, iz: &BitMatrix) -> Result<Self> {
        Self::with_format(params, KernelFormat::DenseMasked, ip, iz)
    }

    /// Build from params + binary factors, executing the masked layer
    /// with the kernel for `format` (single-threaded plans; see
    /// [`NativeBackend::with_format_exec`] for the parallel path).
    pub fn with_format(
        params: MlpParams,
        format: KernelFormat,
        ip: &BitMatrix,
        iz: &BitMatrix,
    ) -> Result<Self> {
        Self::with_format_exec(params, format, ip, iz, ExecCtx::single())
    }

    /// [`NativeBackend::with_format`] with an explicit execution
    /// context: the masked layer's plan shards run across `ctx`'s
    /// worker pool (`lrbi serve --threads N`). Output is
    /// bit-identical to the single-threaded build.
    pub fn with_format_exec(
        params: MlpParams,
        format: KernelFormat,
        ip: &BitMatrix,
        iz: &BitMatrix,
        ctx: Arc<ExecCtx>,
    ) -> Result<Self> {
        let kernel = build_kernel_exec(format, &params.w1, ip, iz, &ctx, None)?;
        Ok(NativeBackend {
            params,
            format,
            kernel,
            batch: GEOMETRY.batch,
            metrics: None,
            ctx,
            h0: Matrix::zeros(0, 0),
            h1: Matrix::zeros(0, 0),
            last_spmm_ns: 0,
        })
    }

    /// Build from a loaded `.lrbi` artifact: the stored index decodes
    /// straight into the kernel for its own representation (CSR,
    /// relative, low-rank, tiled, Viterbi, and dCSR never materialize
    /// the dense mask), and the artifact's dense params become the model —
    /// Algorithm 1 is not re-run.
    pub fn from_artifact(artifact: &Artifact) -> Result<Self> {
        Self::from_artifact_exec(artifact, ExecCtx::single())
    }

    /// [`NativeBackend::from_artifact`] with an explicit execution
    /// context for the kernel's plan shards.
    pub fn from_artifact_exec(artifact: &Artifact, ctx: Arc<ExecCtx>) -> Result<Self> {
        let kernel =
            build_kernel_from_stored_exec(&artifact.index, &artifact.params.w1, &ctx, None)?;
        // The nearest selectable format, used only if factors are
        // later swapped in via `update_factors`.
        let format = match &artifact.index {
            StoredIndex::Binary(_) => KernelFormat::DenseMasked,
            StoredIndex::Csr(_) => KernelFormat::Csr,
            StoredIndex::Relative(_) => KernelFormat::Relative,
            StoredIndex::LowRank(_) | StoredIndex::Tiled(_) => KernelFormat::LowRankFused,
            StoredIndex::Viterbi(_) => KernelFormat::Viterbi,
            StoredIndex::Dcsr(_) => KernelFormat::Dcsr,
        };
        Ok(NativeBackend {
            params: artifact.params.clone(),
            format,
            kernel,
            batch: GEOMETRY.batch,
            metrics: None,
            ctx,
            h0: Matrix::zeros(0, 0),
            h1: Matrix::zeros(0, 0),
            last_spmm_ns: 0,
        })
    }

    /// Build from params + a pre-decoded mask (dense-masked kernel —
    /// the only format constructible without factors).
    pub fn with_mask(params: MlpParams, mask: &BitMatrix) -> Result<Self> {
        let kernel = Box::new(DenseMaskedKernel::from_mask(&params.w1, mask)?);
        Ok(NativeBackend {
            params,
            format: KernelFormat::DenseMasked,
            kernel,
            batch: GEOMETRY.batch,
            metrics: None,
            ctx: ExecCtx::single(),
            h0: Matrix::zeros(0, 0),
            h1: Matrix::zeros(0, 0),
            last_spmm_ns: 0,
        })
    }

    /// Attach metrics: kernel compute time is recorded per predict,
    /// and factor updates count as kernel decodes.
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Name of the active sparse kernel.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// The active sparse kernel (for oracles in tests/benches).
    pub fn kernel(&self) -> &dyn SparseKernel {
        self.kernel.as_ref()
    }

    /// Swap in new factors (e.g. after a re-compression): rebuilds the
    /// kernel once, keeping the configured format and execution
    /// context.
    pub fn update_factors(&mut self, ip: &BitMatrix, iz: &BitMatrix) -> Result<()> {
        self.kernel = build_kernel_exec(
            self.format,
            &self.params.w1,
            ip,
            iz,
            &self.ctx,
            self.metrics.as_deref(),
        )?;
        Ok(())
    }
}

impl InferenceBackend for NativeBackend {
    fn batch(&self) -> usize {
        self.batch
    }
    fn input_dim(&self) -> usize {
        self.params.w0.rows()
    }
    fn classes(&self) -> usize {
        self.params.w2.cols()
    }
    fn predict_into(&mut self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        x.matmul_into(&self.params.w0, &mut self.h0)?;
        add_bias(&mut self.h0, &self.params.b0);
        relu_inplace(&mut self.h0);
        let t0 = Instant::now();
        self.kernel.spmm_into(&self.h0, &mut self.h1)?;
        // measure once; the counter and the `spmm` stage histogram
        // (recorded by the executor) see the same number
        self.last_spmm_ns = t0.elapsed().as_nanos() as u64;
        if let Some(m) = &self.metrics {
            m.record_spmm_ns(self.last_spmm_ns);
        }
        add_bias(&mut self.h1, &self.params.b1);
        relu_inplace(&mut self.h1);
        self.h1.matmul_into(&self.params.w2, out)?;
        add_bias(out, &self.params.b2);
        Ok(())
    }
    fn last_spmm_ns(&self) -> u64 {
        self.last_spmm_ns
    }
    fn take_last_merge_ns(&mut self) -> u64 {
        self.ctx.take_last_merge_ns()
    }
}

/// PJRT backend: executes the `predict` artifact; the mask decode is
/// *inside* the lowered graph (the L1 Pallas kernel), so the request
/// path exercises the paper's binary-matmul decompression directly.
pub struct PjrtBackend {
    runtime: Runtime,
    inputs: Vec<xla::Literal>, // params + factors, reused every call
}

impl PjrtBackend {
    /// Build from a runtime, params, and float {0,1} factor matrices.
    pub fn new(mut runtime: Runtime, params: &MlpParams, ip: &Matrix, iz: &Matrix) -> Result<Self> {
        runtime.load("predict")?;
        let g = GEOMETRY;
        if ip.rows() != g.hidden0 || ip.cols() != g.rank || iz.rows() != g.rank {
            return Err(Error::shape("factor shapes must match artifact geometry"));
        }
        let inputs = vec![
            matrix_literal(&params.w0)?,
            xla::Literal::vec1(&params.b0),
            matrix_literal(&params.w1)?,
            xla::Literal::vec1(&params.b1),
            matrix_literal(&params.w2)?,
            xla::Literal::vec1(&params.b2),
            matrix_literal(ip)?,
            matrix_literal(iz)?,
        ];
        Ok(PjrtBackend { runtime, inputs })
    }
}

impl InferenceBackend for PjrtBackend {
    fn batch(&self) -> usize {
        GEOMETRY.batch
    }
    fn input_dim(&self) -> usize {
        GEOMETRY.input_dim
    }
    fn classes(&self) -> usize {
        GEOMETRY.classes
    }
    fn predict_into(&mut self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(9);
        for lit in &self.inputs {
            inputs.push(lit.clone());
        }
        inputs.push(matrix_literal(x)?);
        let res = self.runtime.execute("predict", &inputs)?;
        *out = literal_matrix(&res[0], GEOMETRY.batch, GEOMETRY.classes)?;
        Ok(())
    }
}

/// The engine's reply payload: logits plus the per-stage timing the
/// executor assembled for the request (`decode`/`write` are zero here
/// — the network frontend fills them before logging/recording).
pub type TracedLogits = (Vec<f32>, StageNanos);

/// A running serving engine: executor thread + batcher client.
pub struct ServingEngine {
    client: BatcherClient<Vec<f32>, Result<TracedLogits>>,
    handle: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

impl ServingEngine {
    /// Submit-queue bound used by [`ServingEngine::start`] /
    /// [`ServingEngine::start_with`]; the network frontend passes an
    /// explicit `--max-queue` via [`ServingEngine::start_bounded`].
    pub const DEFAULT_QUEUE_CAP: usize = 1024;

    /// Start the executor thread over an already-built `Send` backend.
    pub fn start(
        backend: impl InferenceBackend + Send + 'static,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::start_bounded(backend, policy, Self::DEFAULT_QUEUE_CAP, metrics)
    }

    /// [`ServingEngine::start`] with an explicit submit-queue bound:
    /// the admission-control knob. Blocking callers
    /// ([`ServingEngine::infer`]) stall when the queue is full;
    /// non-blocking submitters (`BatcherClient::try_submit`, used by
    /// the TCP frontend) are refused with an overload signal instead.
    pub fn start_bounded(
        backend: impl InferenceBackend + Send + 'static,
        policy: BatchPolicy,
        queue_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::start_with_bounded(move || Ok(backend), policy, queue_cap, metrics)
    }

    /// Start the executor thread, constructing the backend inside it.
    /// Required for `!Send` backends such as [`PjrtBackend`]. If the
    /// factory fails, every request is answered with the error.
    pub fn start_with<B: InferenceBackend + 'static>(
        factory: impl FnOnce() -> Result<B> + Send + 'static,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::start_with_bounded(factory, policy, Self::DEFAULT_QUEUE_CAP, metrics)
    }

    /// [`ServingEngine::start_with`] with an explicit submit-queue
    /// bound (see [`ServingEngine::start_bounded`]).
    pub fn start_with_bounded<B: InferenceBackend + 'static>(
        factory: impl FnOnce() -> Result<B> + Send + 'static,
        policy: BatchPolicy,
        queue_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        let (mut batcher, client) =
            DynamicBatcher::<Vec<f32>, Result<TracedLogits>>::new(policy, queue_cap.max(1));
        batcher.attach_metrics(Arc::clone(&metrics));
        let m = Arc::clone(&metrics);
        let handle = std::thread::Builder::new()
            .name("lrbi-serving".into())
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => b,
                    Err(e) => {
                        let msg = e.to_string();
                        while let Some(batch) = batcher.next_batch() {
                            for req in batch {
                                let _ = req.reply.send(Err(Error::Runtime(msg.clone())));
                            }
                        }
                        return;
                    }
                };
                let bsz = backend.batch();
                let dim = backend.input_dim();
                let classes = backend.classes();
                // Steady-state buffers, reused across flushes: the
                // padded input batch, the logits, and the per-slot
                // validity flags all stop allocating after flush 1
                // (the request *vector* is recycled through the
                // batcher — `Metrics::batch_buffer_reuse`).
                let mut x = Matrix::zeros(bsz, dim);
                let mut logits = Matrix::zeros(0, 0);
                let mut bad: Vec<bool> = Vec::new();
                let mut shed: Vec<bool> = Vec::new();
                // per-request queue wait of the current flush; cleared
                // and refilled each flush, so it stops allocating once
                // capacity covers max_batch
                let mut queue_ns: Vec<u64> = Vec::new();
                while let Some(mut batch) = batcher.next_batch() {
                    let dequeued = Instant::now();
                    m.batches.fetch_add(1, Ordering::Relaxed);
                    m.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
                    // assemble padded batch
                    x.reset_zero(bsz, dim);
                    bad.clear();
                    bad.resize(batch.len(), false);
                    shed.clear();
                    shed.resize(batch.len(), false);
                    queue_ns.clear();
                    for (slot, req) in batch.iter().enumerate() {
                        // submit → dequeue (includes the formation
                        // window; see docs/OBSERVABILITY.md)
                        let ns = dequeued.duration_since(req.enqueued).as_nanos() as u64;
                        m.telemetry.record_stage(Stage::Queue, ns);
                        queue_ns.push(ns);
                        // deadline check at dequeue: an expired request
                        // is shed *before* its row is padded into the
                        // batch, so it never enters spmm
                        if req.deadline.is_some_and(|d| dequeued >= d) {
                            shed[slot] = true;
                            m.net_deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        if slot < bsz {
                            if req.input.len() == dim {
                                for (j, &v) in req.input.iter().enumerate() {
                                    x.set(slot, j, v);
                                }
                            } else {
                                bad[slot] = true;
                            }
                        }
                    }
                    // skip the backend entirely when every slot was
                    // shed or invalid — an all-expired flush must not
                    // run (or count) an spmm
                    let live = (0..batch.len().min(bsz)).any(|s| !bad[s] && !shed[s]);
                    let result =
                        if live { backend.predict_into(&x, &mut logits) } else { Ok(()) };
                    // flush-level stages, shared by every request that
                    // rode in this batch (0 = the backend doesn't time
                    // that stage / nothing ran — not recorded)
                    let spmm_ns = if live { backend.last_spmm_ns() } else { 0 };
                    let merge_ns = if live { backend.take_last_merge_ns() } else { 0 };
                    if result.is_ok() {
                        if spmm_ns > 0 {
                            m.telemetry.record_stage(Stage::Spmm, spmm_ns);
                        }
                        if merge_ns > 0 {
                            m.telemetry.record_stage(Stage::Merge, merge_ns);
                        }
                    }
                    let stages_base = StageNanos {
                        batch: batcher.last_flush_wait_ns(),
                        spmm: spmm_ns,
                        merge: merge_ns,
                        ..Default::default()
                    };
                    for (slot, req) in batch.drain(..).enumerate() {
                        let reply = if shed[slot] {
                            Err(Error::Deadline(
                                "budget expired before execution; request shed".into(),
                            ))
                        } else if slot >= bsz {
                            Err(Error::Coordinator("batch overflow".into()))
                        } else if bad[slot] {
                            Err(Error::shape("bad input dimension"))
                        } else {
                            match &result {
                                Ok(()) => {
                                    let mut stages = stages_base;
                                    stages.queue = queue_ns[slot];
                                    Ok((logits.row(slot)[..classes].to_vec(), stages))
                                }
                                Err(e) => Err(Error::Runtime(e.to_string())),
                            }
                        };
                        let _ = req.reply.send(reply);
                    }
                    batcher.recycle(batch);
                }
            })
            .expect("spawn serving thread");
        ServingEngine { client, handle: Some(handle), metrics }
    }

    /// Blocking single-request inference.
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_traced(input).map(|(logits, _)| logits)
    }

    /// Blocking single-request inference with the request's per-stage
    /// timing (`decode`/`write` are zero at this layer).
    pub fn infer_traced(&self, input: Vec<f32>) -> Result<TracedLogits> {
        self.client
            .call(input)
            .ok_or_else(|| Error::Coordinator("serving engine stopped".into()))?
    }

    /// A cloneable client handle for concurrent load generators.
    pub fn client(&self) -> BatcherClient<Vec<f32>, Result<TracedLogits>> {
        self.client.clone()
    }

    /// Engine metrics.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        // The executor thread exits once every BatcherClient clone is
        // dropped (the submit channel closes). Detach rather than join:
        // outstanding clones held by load generators must not deadlock
        // engine teardown.
        let _ = self.handle.take();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn dense_factors() -> (BitMatrix, BitMatrix) {
        let g = GEOMETRY;
        (
            BitMatrix::from_fn(g.hidden0, g.rank, |_, _| true),
            BitMatrix::from_fn(g.rank, g.hidden1, |_, _| true),
        )
    }

    #[test]
    fn native_backend_masks_fc1() {
        let params = MlpParams::init(1);
        let g = GEOMETRY;
        let mut rng = Rng::new(2);
        let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.2));
        let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.2));
        // Masked entries must not contribute: spmm of a one-hot input
        // row reads out the (masked) FC1 row directly.
        let be = NativeBackend::new(params.clone(), &ip, &iz).unwrap();
        let mask = ip.bool_product(&iz);
        let mut x = Matrix::zeros(1, g.hidden0);
        x.set(0, 3, 1.0);
        let row = be.kernel().spmm(&x).unwrap();
        for j in 0..g.hidden1 {
            if mask.get(3, j) {
                assert_eq!(row.get(0, j), params.w1.get(3, j));
            } else {
                assert_eq!(row.get(0, j), 0.0);
            }
        }
    }

    #[test]
    fn every_kernel_format_serves_identical_logits() {
        let params = MlpParams::init(7);
        let g = GEOMETRY;
        let mut rng = Rng::new(8);
        let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.25));
        let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.25));
        let x = Matrix::gaussian(GEOMETRY.batch, g.input_dim, 0.0, 1.0, &mut rng);
        let mut baseline = NativeBackend::new(params.clone(), &ip, &iz).unwrap();
        let want = baseline.predict(&x).unwrap();
        for fmt in crate::serve::kernels::KernelFormat::ALL {
            let mut be = NativeBackend::with_format(params.clone(), fmt, &ip, &iz).unwrap();
            assert_eq!(be.kernel_name(), fmt.name());
            let got = be.predict(&x).unwrap();
            // Viterbi is mask-shaping: its kernel serves the nearest
            // Viterbi-representable mask, so its oracle is the dense
            // kernel over that same decoded mask — every other format
            // is mask-exact and compares against the shared baseline.
            let want_fmt;
            let oracle = if fmt == KernelFormat::Viterbi {
                let mask = crate::formats::viterbi::ViterbiIndex::shape_mask(&ip.bool_product(&iz))
                    .decode();
                let mut shaped = NativeBackend::with_mask(params.clone(), &mask).unwrap();
                want_fmt = shaped.predict(&x).unwrap();
                &want_fmt
            } else {
                &want
            };
            for (a, b) in got.data().iter().zip(oracle.data()) {
                assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{}: {a} vs {b}", fmt.name());
            }
        }
    }

    #[test]
    fn artifact_backend_matches_in_memory_backend_bitwise() {
        let params = MlpParams::init(21);
        let g = GEOMETRY;
        let mut rng = Rng::new(22);
        let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.25));
        let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.25));
        let x = Matrix::gaussian(2, g.input_dim, 0.0, 1.0, &mut rng);
        for (fmt, name) in [
            (KernelFormat::DenseMasked, "dense"),
            (KernelFormat::Csr, "csr"),
            (KernelFormat::Relative, "relative"),
            (KernelFormat::LowRankFused, "lowrank"),
            (KernelFormat::Viterbi, "viterbi"),
            (KernelFormat::Dcsr, "dcsr"),
        ] {
            let mut mem = NativeBackend::with_format(params.clone(), fmt, &ip, &iz).unwrap();
            let art =
                Artifact::pack_factors(params.clone(), name, &ip, &iz, "engine test").unwrap();
            let mut loaded = NativeBackend::from_artifact(&art).unwrap();
            assert_eq!(loaded.kernel_name(), mem.kernel_name());
            // Same kernel construction order ⇒ bit-identical logits.
            assert_eq!(
                loaded.predict(&x).unwrap().data(),
                mem.predict(&x).unwrap().data(),
                "{name}"
            );
        }
    }

    #[test]
    fn engine_serves_batched_requests() {
        let params = MlpParams::init(3);
        let (ip, iz) = dense_factors();
        let backend = NativeBackend::new(params, &ip, &iz).unwrap();
        let metrics = Arc::new(Metrics::new());
        let engine = ServingEngine::start(
            backend,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
            Arc::clone(&metrics),
        );
        let client = engine.client();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let x = vec![0.01 * i as f32; GEOMETRY.input_dim];
                    c.call(x).unwrap().unwrap()
                })
            })
            .collect();
        for h in handles {
            let (logits, stages) = h.join().unwrap();
            assert_eq!(logits.len(), GEOMETRY.classes);
            assert!(logits.iter().all(|v| v.is_finite()));
            assert!(stages.spmm > 0, "native backend times its spmm");
            assert_eq!(stages.decode, 0, "decode/write belong to the net frontend");
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 16);
        // every request landed a queue-stage sample; every flush an
        // spmm-stage sample
        let t = &metrics.telemetry;
        assert_eq!(t.stage(crate::coordinator::telemetry::Stage::Queue).count(), 16);
        assert_eq!(t.stage(crate::coordinator::telemetry::Stage::Spmm).count(), snap.batches);
        assert!(snap.batches >= 2, "expected batching, got {} batches", snap.batches);
        // the batcher-side distribution counters agree with the
        // engine-side totals
        assert_eq!(snap.batch_size_sum, 16);
        assert_eq!(snap.batch_flush_count, snap.batches);
        assert!(snap.mean_flush_size() > 1.0, "batching should coalesce requests");
    }

    #[test]
    fn exec_backend_serves_identical_logits_to_single_threaded() {
        let params = MlpParams::init(33);
        let g = GEOMETRY;
        let mut rng = Rng::new(34);
        let ip = BitMatrix::from_fn(g.hidden0, g.rank, |_, _| rng.bernoulli(0.25));
        let iz = BitMatrix::from_fn(g.rank, g.hidden1, |_, _| rng.bernoulli(0.25));
        let x = Matrix::gaussian(2, g.input_dim, 0.0, 1.0, &mut rng);
        for fmt in KernelFormat::ALL {
            let mut single = NativeBackend::with_format(params.clone(), fmt, &ip, &iz).unwrap();
            let ctx = crate::coordinator::pool::ExecCtx::new(4, None);
            let mut pooled =
                NativeBackend::with_format_exec(params.clone(), fmt, &ip, &iz, ctx).unwrap();
            assert_eq!(
                pooled.predict(&x).unwrap().data(),
                single.predict(&x).unwrap().data(),
                "{}",
                fmt.name()
            );
        }
    }

    #[test]
    fn expired_deadline_is_shed_at_dequeue_without_running_spmm() {
        let params = MlpParams::init(5);
        let (ip, iz) = dense_factors();
        let metrics = Arc::new(Metrics::new());
        let backend =
            NativeBackend::new(params, &ip, &iz).unwrap().with_metrics(Arc::clone(&metrics));
        let engine = ServingEngine::start(
            backend,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
            Arc::clone(&metrics),
        );
        let client = engine.client();
        // a deadline already in the past: the batcher flushes it
        // immediately and the executor sheds it at dequeue
        let rx = client
            .try_submit_with(
                vec![0.0; GEOMETRY.input_dim],
                Some(std::time::Instant::now() - Duration::from_millis(1)),
            )
            .unwrap();
        let reply = rx.recv().unwrap();
        assert!(
            matches!(reply, Err(Error::Deadline(_))),
            "expected a deadline shed, got {reply:?}"
        );
        let snap = metrics.snapshot();
        assert_eq!(snap.net_deadline_exceeded, 1);
        assert_eq!(snap.kernel_spmms, 0, "shed rows must never enter spmm");
        // the engine is still healthy: a deadline-free request serves
        let (logits, _) = client.call(vec![0.0; GEOMETRY.input_dim]).unwrap().unwrap();
        assert_eq!(logits.len(), GEOMETRY.classes);
        assert!(metrics.snapshot().kernel_spmms >= 1);
    }

    #[test]
    fn unexpired_deadline_serves_normally() {
        let params = MlpParams::init(6);
        let (ip, iz) = dense_factors();
        let backend = NativeBackend::new(params, &ip, &iz).unwrap();
        let metrics = Arc::new(Metrics::new());
        let engine = ServingEngine::start(
            backend,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
            Arc::clone(&metrics),
        );
        let rx = engine
            .client()
            .try_submit_with(
                vec![0.0; GEOMETRY.input_dim],
                Some(std::time::Instant::now() + Duration::from_secs(30)),
            )
            .unwrap();
        let (logits, _) = rx.recv().unwrap().unwrap();
        assert_eq!(logits.len(), GEOMETRY.classes);
        assert_eq!(metrics.snapshot().net_deadline_exceeded, 0);
    }

    #[test]
    fn engine_rejects_bad_dims() {
        let params = MlpParams::init(4);
        let (ip, iz) = dense_factors();
        let backend = NativeBackend::new(params, &ip, &iz).unwrap();
        let engine = ServingEngine::start(
            backend,
            BatchPolicy::default(),
            Arc::new(Metrics::new()),
        );
        let err = engine.infer(vec![1.0; 3]);
        assert!(err.is_err());
    }
}
