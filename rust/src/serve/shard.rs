//! Output-column shard math for router/worker serving.
//!
//! The cluster tier (see `docs/CLUSTER.md`) splits a model's **final
//! output columns** (classes) into contiguous, disjoint ranges — one
//! per worker shard. Each worker runs the *full* forward pass with the
//! same kernel arithmetic as a single-process server and returns only
//! its column slice; the router concatenates the slices in fixed shard
//! order. Because every output column is computed independently (one
//! dot product against the last weight column), slicing after the fact
//! reorders **nothing**: the gathered batch is bit-identical to an
//! unsharded [`Frame::Infer`](crate::serve::protocol::Frame) at any
//! shard count. This is the same output-disjoint discipline
//! `serve::plan` uses in-process ("no merge step exists, so there is
//! nothing to reorder"), lifted over the network.
//!
//! The alternative — sharding the *hidden* layer and summing partial
//! products on the router — was rejected: a split reduction
//! reassociates f32 partial sums (`(a+b)+(c+d) != ((a+b)+c)+d`), which
//! breaks the repo-wide bit-identity contract. `tests/cluster.rs` pins
//! the slice/assemble path against the unsharded kernel output.

use crate::serve::protocol::RowBatch;
use crate::util::error::{Error, Result};

/// Split `classes` output columns into `shards` contiguous ranges
/// `[(start, end), ...]` covering `0..classes` exactly, in ascending
/// order, sized as evenly as possible (first ranges get the remainder;
/// deterministic in both inputs). Asking for more shards than columns
/// yields one range per column — empty ranges are never produced.
pub fn shard_cols(classes: usize, shards: usize) -> Vec<(u32, u32)> {
    if classes == 0 {
        return Vec::new();
    }
    let count = shards.clamp(1, classes);
    let per = classes / count;
    let extra = classes % count;
    let mut out = Vec::with_capacity(count);
    let mut start = 0usize;
    for i in 0..count {
        let width = per + usize::from(i < extra);
        out.push((start as u32, (start + width) as u32));
        start += width;
    }
    debug_assert_eq!(start, classes);
    out
}

/// Extract columns `col_start..col_end` of every row into a new batch
/// (what a worker does to its full-width logits before replying with a
/// `PARTIAL`). Pure copying — no arithmetic touches the values, so the
/// slice is bitwise equal to the same columns of the source.
pub fn slice_columns(batch: &RowBatch, col_start: u32, col_end: u32) -> Result<RowBatch> {
    let (start, end) = (col_start as usize, col_end as usize);
    if start > end || end > batch.cols() {
        return Err(Error::Protocol(format!(
            "column slice {col_start}..{col_end} out of range for a {}-column batch",
            batch.cols()
        )));
    }
    let width = end - start;
    let mut data = Vec::with_capacity(batch.rows() * width);
    for r in 0..batch.rows() {
        data.extend_from_slice(&batch.row(r)[start..end]);
    }
    RowBatch::new(batch.rows(), width, data)
}

/// Reassemble gathered partials into the full `rows × classes` batch
/// (what the router does after scattering). `parts` must arrive in
/// ascending shard order and tile `0..classes` exactly — ranges are
/// validated, never trusted — and every part must carry `rows` rows of
/// exactly its declared width. Pure copying in fixed order: no
/// floating-point operation runs here, so the result is bit-identical
/// to the unsharded logits the partials were sliced from.
pub fn assemble(rows: usize, classes: usize, parts: &[(u32, u32, RowBatch)]) -> Result<RowBatch> {
    let mut expected_start = 0u32;
    for (start, end, batch) in parts {
        if *start != expected_start || end < start {
            return Err(Error::Protocol(format!(
                "partials do not tile the output: got columns {start}..{end}, \
                 expected a slice starting at {expected_start}"
            )));
        }
        if batch.rows() != rows || batch.cols() != (end - start) as usize {
            return Err(Error::Protocol(format!(
                "partial {start}..{end} is {}x{}, expected {rows}x{}",
                batch.rows(),
                batch.cols(),
                end - start
            )));
        }
        expected_start = *end;
    }
    if expected_start as usize != classes {
        return Err(Error::Protocol(format!(
            "partials cover columns 0..{expected_start}, model has {classes}"
        )));
    }
    let mut data = Vec::with_capacity(rows * classes);
    for r in 0..rows {
        for (_, _, batch) in parts {
            data.extend_from_slice(batch.row(r));
        }
    }
    RowBatch::new(rows, classes, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn shard_cols_tiles_exactly_and_evenly() {
        assert_eq!(shard_cols(10, 1), vec![(0, 10)]);
        assert_eq!(shard_cols(10, 2), vec![(0, 5), (5, 10)]);
        assert_eq!(shard_cols(10, 4), vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
        assert_eq!(shard_cols(4, 4), vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        // more shards than columns clamps to one column per shard
        assert_eq!(shard_cols(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(shard_cols(0, 4), Vec::<(u32, u32)>::new());
        assert_eq!(shard_cols(7, 0), vec![(0, 7)]);
    }

    #[test]
    fn shard_cols_property_contiguous_cover() {
        prop::check("shard_cols tiles 0..classes", 200, |rng| {
            let classes = prop::dim(rng, 1, 64);
            let shards = prop::dim(rng, 1, 12);
            let ranges = shard_cols(classes, shards);
            assert!(!ranges.is_empty());
            assert!(ranges.len() <= shards.min(classes));
            let mut next = 0u32;
            for (s, e) in &ranges {
                assert_eq!(*s, next, "contiguous");
                assert!(e > s, "non-empty");
                next = *e;
            }
            assert_eq!(next as usize, classes, "full cover");
            // near-even: widths differ by at most one
            let widths: Vec<u32> = ranges.iter().map(|(s, e)| e - s).collect();
            let (min, max) = (widths.iter().min().unwrap(), widths.iter().max().unwrap());
            assert!(max - min <= 1, "uneven split {widths:?}");
        });
    }

    #[test]
    fn slice_then_assemble_is_identity() {
        prop::check("slice/assemble round-trips any batch", 100, |rng| {
            let rows = prop::dim(rng, 0, 6);
            let classes = prop::dim(rng, 1, 24);
            let shards = prop::dim(rng, 1, 6);
            let data: Vec<f32> = (0..rows * classes).map(|_| rng.next_f32() - 0.5).collect();
            let full = RowBatch::new(rows, classes, data).unwrap();
            let parts: Vec<(u32, u32, RowBatch)> = shard_cols(classes, shards)
                .into_iter()
                .map(|(s, e)| (s, e, slice_columns(&full, s, e).unwrap()))
                .collect();
            let got = assemble(rows, classes, &parts).unwrap();
            assert_eq!(got, full, "bitwise identity");
        });
    }

    #[test]
    fn slice_columns_rejects_bad_ranges() {
        let b = RowBatch::new(2, 4, vec![0.0; 8]).unwrap();
        assert!(slice_columns(&b, 2, 1).is_err(), "inverted");
        assert!(slice_columns(&b, 0, 5).is_err(), "past the end");
        assert_eq!(slice_columns(&b, 4, 4).unwrap().cols(), 0, "empty tail slice ok");
    }

    #[test]
    fn assemble_rejects_gaps_overlaps_and_bad_shapes() {
        let full = RowBatch::new(1, 4, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let part = |s: u32, e: u32| (s, e, slice_columns(&full, s, e).unwrap());
        // gap: 0..2 then 3..4
        assert!(assemble(1, 4, &[part(0, 2), part(3, 4)]).is_err());
        // overlap: 0..3 then 2..4
        assert!(assemble(1, 4, &[part(0, 3), part(2, 4)]).is_err());
        // short cover: 0..3 only
        assert!(assemble(1, 4, &[part(0, 3)]).is_err());
        // wrong row count
        assert!(assemble(2, 4, &[part(0, 4)]).is_err());
        // wrong declared width
        let lying = (0u32, 4u32, slice_columns(&full, 0, 2).unwrap());
        assert!(assemble(1, 4, &[lying]).is_err());
        // exact cover succeeds
        assert_eq!(assemble(1, 4, &[part(0, 2), part(2, 4)]).unwrap(), full);
    }
}
