//! `SpmmPlan` infrastructure: build-time analysis that partitions each
//! kernel's index into conflict-free, cache-sized shards, executed
//! across the shared [`ExecCtx`] (the coordinator's worker pool).
//!
//! Two shard disciplines cover all kernels:
//!
//! - **Output-disjoint shards** own exclusive output-column ranges and
//!   write the shared output directly ([`CscPlan`] for CSR, the dense
//!   kernel's column blocks, the tiled kernel's tile-column shards
//!   from [`tile_col_shards`]). No merge step exists, so there is
//!   nothing to reorder.
//! - **Reduction shards** split the reduction axis (mask rows for the
//!   fused low-rank and Viterbi kernels via [`RowShards`], stream
//!   segments for the relative and dCSR kernels via [`RelativePlan`]);
//!   each shard accumulates into a private partial buffer and partials
//!   merge in **fixed shard order**.
//!
//! Determinism contract (pinned by
//! `tests/kernels.rs::parallel_spmm_bit_identical_across_thread_counts`):
//! the shard partition depends only on the index — never on the thread
//! count — and every floating-point accumulation order is fixed by the
//! plan, so `spmm` output is bit-identical for any `threads`. The same
//! holds across SIMD tiers: inner loops dispatch to the lane-owns-output
//! micro-kernels of `tensor::simd`, whose per-element operation
//! sequence is exactly the scalar order (see `docs/PERFORMANCE.md`).
//!
//! Steady-state executions are allocation-free: partial buffers and
//! input transposes are checked out of the shared
//! [`ExecCtx`] scratch pool (`take_scratch`/`put_scratch`) and
//! returned after the merge, observable through the
//! `spmm_alloc_bytes`/`scratch_reuse` metrics pair.

use crate::coordinator::pool::ExecCtx;
use crate::tensor::simd::{self, SimdTier};
use crate::tensor::Matrix;
use crate::util::error::Result;
use std::sync::Mutex;
use std::time::Instant;

/// Cap on reduction shards per plan: bounds partial-buffer memory at
/// `MAX_SHARDS · batch · n` floats regardless of layer size.
pub(crate) const MAX_SHARDS: usize = 32;
/// Target non-zeros per CSR-column / relative-stream shard — a few
/// L1-sized index+value blocks of work per shard.
pub(crate) const SHARD_NNZ: usize = 2048;
/// Target mask rows per low-rank row shard.
pub(crate) const SHARD_ROWS: usize = 32;
/// Target output columns per dense shard (micro-kernel panel width).
pub(crate) const SHARD_COLS: usize = 64;
/// Floor on a *reduction* shard's non-zeros as a multiple of the
/// output width `n`: every partial costs `2·batch·n` streamed ops
/// (zero-init + ordered merge), so requiring ≥ `REDUCE_COLS_FACTOR·n`
/// non-zeros per shard bounds that overhead at `2/REDUCE_COLS_FACTOR`
/// of the shard's own scattered MACs — the desk-check argument that
/// single-threaded plan execution stays within a few percent of the
/// old direct scalar loops (output-disjoint plans have no merge and
/// pay nothing).
pub(crate) const REDUCE_COLS_FACTOR: usize = 8;

/// Raw shared pointer into an output buffer that shards write
/// disjointly — the plan layer's analogue of `pool::SliceCell`.
pub(crate) struct OutCell(*mut f32);
// SAFETY: shards address provably disjoint index sets (disjoint
// columns, or disjoint partial-buffer ranges), so concurrent writes
// never alias; the cell never outlives the borrowed buffer.
unsafe impl Send for OutCell {}
unsafe impl Sync for OutCell {}

impl OutCell {
    /// Wrap a buffer for disjoint shard writes.
    pub(crate) fn new(s: &mut [f32]) -> Self {
        OutCell(s.as_mut_ptr())
    }

    /// Pointer to element `off`.
    ///
    /// # Safety
    /// `off` must be in bounds and the addressed elements must not be
    /// concurrently accessed by any other shard.
    pub(crate) unsafe fn at(&self, off: usize) -> *mut f32 {
        unsafe { self.0.add(off) }
    }

    /// `*self[off] += v`.
    ///
    /// # Safety
    /// Same contract as [`OutCell::at`].
    pub(crate) unsafe fn add(&self, off: usize, v: f32) {
        unsafe { *self.0.add(off) += v };
    }
}

/// Split `0..total` into contiguous ranges of ~`target` items, capped
/// at [`MAX_SHARDS`]. Deterministic in `(total, target)` only.
pub(crate) fn shard_ranges(total: usize, target: usize) -> Vec<(usize, usize)> {
    if total == 0 {
        return Vec::new();
    }
    let count = total.div_ceil(target.max(1)).clamp(1, MAX_SHARDS);
    let per = total.div_ceil(count);
    (0..count)
        .map(|s| (s * per, ((s + 1) * per).min(total)))
        .filter(|(a, b)| a < b)
        .collect()
}

/// Merge `partials` (one `len`-sized buffer per shard, concatenated)
/// into `out` in ascending shard order — the fixed merge order that
/// keeps reduction-sharded output independent of thread count.
pub(crate) fn merge_partials(out: &mut [f32], partials: &[f32]) {
    if out.is_empty() {
        return; // batch 0: nothing to merge (chunks_exact(0) would panic)
    }
    for part in partials.chunks_exact(out.len()) {
        for (o, p) in out.iter_mut().zip(part) {
            *o += *p;
        }
    }
}

/// Output-stationary CSC execution plan for the CSR kernel: `IA`/`JA`
/// and the gathered values are transposed to CSC once at build, so
/// each shard owns a disjoint output-column range and every output
/// element is a register-accumulated dot product over that column's
/// entries (rows ascending) — threads never contend on an output row,
/// and the accumulation order per element is fixed by the plan.
pub(crate) struct CscPlan {
    m: usize,
    n: usize,
    /// Column pointers, len `n + 1`.
    cp: Vec<u32>,
    /// Row index per entry, ascending within each column.
    ri: Vec<u32>,
    /// Value per entry, CSC order.
    vals: Vec<f32>,
    /// Output-column ranges with ~[`SHARD_NNZ`] entries each.
    shards: Vec<(usize, usize)>,
}

impl CscPlan {
    /// Transpose a CSR index (+ gathered values in `IA`/`JA` order)
    /// to the column-major plan. The counting transpose is stable, so
    /// rows appear in ascending order within each column no matter
    /// which construction path supplied the CSR arrays.
    pub(crate) fn build(m: usize, n: usize, ia: &[u32], ja: &[u16], vals: &[f32]) -> Self {
        let nnz = vals.len();
        let mut cp = vec![0u32; n + 1];
        for &j in ja {
            cp[j as usize + 1] += 1;
        }
        for j in 0..n {
            cp[j + 1] += cp[j];
        }
        let mut cursor: Vec<u32> = cp[..n].to_vec();
        let mut ri = vec![0u32; nnz];
        let mut cv = vec![0f32; nnz];
        for i in 0..m {
            for p in ia[i] as usize..ia[i + 1] as usize {
                let j = ja[p] as usize;
                let dst = cursor[j] as usize;
                cursor[j] += 1;
                ri[dst] = i as u32;
                cv[dst] = vals[p];
            }
        }
        let shards = Self::col_shards(&cp, n, nnz);
        CscPlan { m, n, cp, ri, vals: cv, shards }
    }

    /// Greedy column ranges accumulating ~`SHARD_NNZ` entries each
    /// (at least `nnz / MAX_SHARDS`, so the shard count stays capped).
    fn col_shards(cp: &[u32], n: usize, nnz: usize) -> Vec<(usize, usize)> {
        if nnz == 0 || n == 0 {
            return Vec::new();
        }
        let per = nnz.div_ceil(MAX_SHARDS).max(SHARD_NNZ);
        let mut shards = Vec::new();
        let mut c0 = 0usize;
        let mut acc = 0usize;
        for j in 0..n {
            acc += (cp[j + 1] - cp[j]) as usize;
            if acc >= per {
                shards.push((c0, j + 1));
                c0 = j + 1;
                acc = 0;
            }
        }
        if c0 < n {
            shards.push((c0, n));
        }
        shards
    }

    /// Shards in the plan.
    pub(crate) fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Stored non-zeros.
    pub(crate) fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Run the plan: `out += x · (sparse)` with `out` pre-zeroed.
    ///
    /// On a SIMD tier the input is transposed once into a pooled
    /// scratch buffer (batch-contiguous layout) and each column runs
    /// the batch-lane vector kernel; the scalar tier keeps the
    /// row-major register walk. Both accumulate every `(b, j)` element
    /// in ascending entry order, so the bytes are identical.
    pub(crate) fn execute(&self, x: &Matrix, out: &mut Matrix, ctx: &ExecCtx) -> Result<()> {
        let batch = x.rows();
        let (m, n) = (self.m, self.n);
        let xd = x.data();
        let cell = OutCell::new(out.data_mut());
        let t = simd::tier();
        if t == SimdTier::Scalar || batch == 0 {
            return ctx.run(self.shards.len(), |s| {
                let (c0, c1) = self.shards[s];
                for b in 0..batch {
                    let xrow = &xd[b * m..(b + 1) * m];
                    for j in c0..c1 {
                        let (a, e) = (self.cp[j] as usize, self.cp[j + 1] as usize);
                        if a == e {
                            continue;
                        }
                        let mut acc = 0f32;
                        for (r, v) in self.ri[a..e].iter().zip(&self.vals[a..e]) {
                            acc += xrow[*r as usize] * v;
                        }
                        // SAFETY: shard `s` exclusively owns columns
                        // [c0, c1) of every output row.
                        unsafe { cell.add(b * n + j, acc) };
                    }
                }
            });
        }
        let mut xt = ctx.take_scratch_uninit(m * batch);
        simd::transpose_into(xd, batch, m, &mut xt);
        let xt_ref = &xt[..];
        let res = ctx.run(self.shards.len(), |s| {
            let (c0, c1) = self.shards[s];
            for j in c0..c1 {
                let (a, e) = (self.cp[j] as usize, self.cp[j + 1] as usize);
                if a == e {
                    continue;
                }
                // SAFETY: shard `s` exclusively owns columns [c0, c1)
                // of every output row; the kernel writes only offsets
                // `b * n` from `cell.at(j)`.
                unsafe {
                    simd::csc_column_accum(
                        t,
                        xt_ref,
                        batch,
                        &self.ri[a..e],
                        &self.vals[a..e],
                        cell.at(j),
                        n,
                    )
                };
            }
        });
        ctx.put_scratch(xt);
        res
    }
}

/// One relative-stream shard: a skip pointer into the gap stream —
/// entry range `[e0, e1)`, the index `v0` of its first surviving
/// weight, and the running flat-position cursor `pos0` (the position
/// one past the previous shard's last non-zero). Recorded during the
/// gather walk, these let the nominally sequential 5-bit stream resume
/// decoding from any shard boundary — the observation that makes
/// Deep-Compression-style relative indexing row-parallel after all.
#[derive(Debug, Clone, Copy)]
pub(crate) struct RelShard {
    /// First stream entry of the shard.
    pub e0: usize,
    /// One past the last stream entry of the shard.
    pub e1: usize,
    /// Index into the gathered values at `e0`.
    pub v0: usize,
    /// Flat mask position the cursor resumes from.
    pub pos0: usize,
}

/// Skip-pointer plan over a delta-index stream — either the 5-bit
/// [`Csr5Relative`](crate::formats::relative) gap stream (`escape` =
/// its `MAX_GAP`, 31) or the 4-bit [`DcsrIndex`](crate::formats::dcsr)
/// stream (`escape` = 15). The walk is identical: an entry equal to
/// `escape` advances the cursor `escape` positions without placing a
/// weight; anything else advances `entry + 1` and places one. Shards
/// split the reduction (the stream), so execution accumulates into
/// per-shard partials merged in shard order.
pub(crate) struct RelativePlan {
    pub(crate) shards: Vec<RelShard>,
    /// Escape/filler sentinel value of the stream's entry width.
    pub(crate) escape: u32,
}

impl RelativePlan {
    /// Shards in the plan.
    pub(crate) fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Run the plan: decode each shard's stream segment from its skip
    /// pointer, fused with the accumulate, into a private partial;
    /// merge partials in fixed shard order. With a single shard the
    /// partial *is* the output buffer (merging one partial into zeros
    /// is the identity, so this is bit-identical, just cheaper).
    /// Partials (and, on a SIMD tier, the batch-contiguous input
    /// transpose the vector accumulate reads) come from the context's
    /// scratch pool — steady-state executions allocate nothing.
    pub(crate) fn execute(
        &self,
        entries: &[u8],
        vals: &[f32],
        n: usize,
        x: &Matrix,
        out: &mut Matrix,
        ctx: &ExecCtx,
    ) -> Result<()> {
        let batch = x.rows();
        let t = simd::tier();
        let mut xt_buf: Option<Vec<f32>> = None;
        if t != SimdTier::Scalar && batch > 0 {
            let m = x.cols();
            let mut xt = ctx.take_scratch_uninit(m * batch);
            simd::transpose_into(x.data(), batch, m, &mut xt);
            xt_buf = Some(xt);
        }
        let xt = xt_buf.as_deref().map(|s| (t, s));
        let res = if self.shards.len() <= 1 {
            if let Some(sh) = self.shards.first() {
                decode_rel_shard(sh, self.escape, entries, vals, n, x, xt, out.data_mut());
            }
            Ok(())
        } else {
            let bn = batch * n;
            let mut partials = ctx.take_scratch(self.shards.len() * bn);
            let cell = OutCell::new(&mut partials);
            let run = ctx.run(self.shards.len(), |s| {
                // SAFETY: shard `s` exclusively owns partial range
                // [s*bn, (s+1)*bn).
                let part = unsafe { std::slice::from_raw_parts_mut(cell.at(s * bn), bn) };
                decode_rel_shard(&self.shards[s], self.escape, entries, vals, n, x, xt, part);
            });
            if run.is_ok() {
                let t_merge = Instant::now();
                merge_partials(out.data_mut(), &partials);
                ctx.record_merge(t_merge);
            }
            ctx.put_scratch(partials);
            run
        };
        if let Some(buf) = xt_buf {
            ctx.put_scratch(buf);
        }
        res
    }
}

/// Decode one stream segment from its skip pointer, accumulating
/// `x[b][i] * v` into `out[b*n + j]` for every non-zero `(i, j)` it
/// places — the same fused decode-compute loop the kernel always ran,
/// now restartable mid-stream. When `xt` carries the SIMD tier and
/// the batch-contiguous input transpose, the per-entry batch loop
/// runs the vector axpy (`tensor::simd::rel_entry_axpy`) — same
/// per-element mul+add in the same entry order, so the bytes match
/// the scalar walk.
#[allow(clippy::too_many_arguments)]
fn decode_rel_shard(
    sh: &RelShard,
    escape: u32,
    entries: &[u8],
    vals: &[f32],
    n: usize,
    x: &Matrix,
    xt: Option<(SimdTier, &[f32])>,
    out: &mut [f32],
) {
    let batch = x.rows();
    let mut pos = sh.pos0;
    let mut pending = 0u32;
    let mut vi = sh.v0;
    for &e in &entries[sh.e0..sh.e1] {
        if e as u32 == escape {
            pending += escape;
            continue;
        }
        pos += (pending + e as u32) as usize;
        pending = 0;
        let (i, j) = (pos / n, pos % n);
        let v = vals[vi];
        match xt {
            Some((t, xt)) => {
                // SAFETY: this call exclusively owns `out`, and the
                // kernel touches only offsets `j + b*n < batch*n`.
                unsafe {
                    simd::rel_entry_axpy(
                        t,
                        &xt[i * batch..(i + 1) * batch],
                        v,
                        out.as_mut_ptr().add(j),
                        n,
                    )
                };
            }
            None => {
                for b in 0..batch {
                    out[b * n + j] += x.get(b, i) * v;
                }
            }
        }
        vi += 1;
        pos += 1;
    }
}

/// Row-range reduction shards for the fused low-rank kernel, each with
/// a persistent scratch tile (`n/64` packed words) so per-call
/// execution never allocates the expansion buffer — the in-register
/// decompressor's working set lives in the plan.
pub(crate) struct RowShards {
    shards: Vec<(usize, usize)>,
    scratch: Vec<Mutex<Vec<u64>>>,
}

impl RowShards {
    /// Partition `m` mask rows into shards of ≥ `target_rows` rows
    /// (the caller sizes the target so each shard carries enough
    /// non-zeros to amortize its merge — see [`REDUCE_COLS_FACTOR`]),
    /// each owning a zeroed `words`-long scratch tile.
    pub(crate) fn new(m: usize, words: usize, target_rows: usize) -> Self {
        let shards = shard_ranges(m, target_rows.max(SHARD_ROWS));
        let scratch = shards.iter().map(|_| Mutex::new(vec![0u64; words])).collect();
        RowShards { shards, scratch }
    }

    /// Shards in the plan.
    pub(crate) fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Run `body(rows, scratch, partial)` per shard and merge partials
    /// in fixed shard order (single shard: straight into `out`). The
    /// partial buffer comes from the context's scratch pool, so
    /// steady-state executions allocate nothing.
    pub(crate) fn execute(
        &self,
        batch: usize,
        n: usize,
        out: &mut Matrix,
        ctx: &ExecCtx,
        body: impl Fn((usize, usize), &mut [u64], &mut [f32]) + Sync,
    ) -> Result<()> {
        let k = self.shards.len();
        if k == 0 {
            return Ok(());
        }
        if k == 1 {
            let mut scratch = lock_scratch(&self.scratch[0]);
            body(self.shards[0], scratch.as_mut_slice(), out.data_mut());
            return Ok(());
        }
        let bn = batch * n;
        let mut partials = ctx.take_scratch(k * bn);
        let cell = OutCell::new(&mut partials);
        let run = ctx.run(k, |s| {
            // SAFETY: shard `s` exclusively owns partial range
            // [s*bn, (s+1)*bn); its scratch Mutex is locked by exactly
            // one shard.
            let part = unsafe { std::slice::from_raw_parts_mut(cell.at(s * bn), bn) };
            let mut scratch = lock_scratch(&self.scratch[s]);
            body(self.shards[s], scratch.as_mut_slice(), part);
        });
        if run.is_ok() {
            let t_merge = Instant::now();
            merge_partials(out.data_mut(), &partials);
            ctx.record_merge(t_merge);
        }
        ctx.put_scratch(partials);
        run
    }
}

/// Lock a shard's scratch tile, ignoring poison: the tile is
/// re-zeroed before every use, so content after a panicked shard is
/// irrelevant, and refusing the lock would wedge the kernel forever.
fn lock_scratch(m: &Mutex<Vec<u64>>) -> std::sync::MutexGuard<'_, Vec<u64>> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// One tile-column shard of the tiled low-rank plan: the tiles (in
/// ascending tile-row order) that share an output-column range, plus a
/// persistent scratch tile sized for the widest of them. Because a
/// tile's contributions land only in its own column range, tile-column
/// shards own disjoint output columns — conflict-free with no merge
/// step, and the within-column accumulation order (tile-rows
/// ascending) matches sequential tile-id execution exactly.
pub(crate) struct TileColShard {
    /// Output-column range `[c0, c1)` this shard exclusively owns.
    pub cols: (usize, usize),
    /// Tile ids in ascending tile-row order.
    pub tiles: Vec<usize>,
    /// Persistent expansion buffer (widest member tile's words).
    pub scratch: Mutex<Vec<u64>>,
}

/// Group tile specs into tile-column shards (specs are in row-major
/// tile-id order, so ids within a group stay in tile-row order).
pub(crate) fn tile_col_shards(specs: &[crate::tiling::TileSpec]) -> Vec<TileColShard> {
    let mut shards: Vec<(usize, usize, Vec<usize>, usize)> = Vec::new();
    for (idx, spec) in specs.iter().enumerate() {
        let words = spec.cols().div_ceil(64);
        match shards.iter().position(|(c0, c1, _, _)| (*c0, *c1) == (spec.c0, spec.c1)) {
            Some(at) => {
                shards[at].2.push(idx);
                shards[at].3 = shards[at].3.max(words);
            }
            None => shards.push((spec.c0, spec.c1, vec![idx], words)),
        }
    }
    shards
        .into_iter()
        .map(|(c0, c1, tiles, words)| TileColShard {
            cols: (c0, c1),
            tiles,
            scratch: Mutex::new(vec![0u64; words]),
        })
        .collect()
}

/// Lock a tile-column shard's scratch (poison-tolerant, like
/// [`RowShards`]' scratch).
pub(crate) fn lock_tile_scratch(sh: &TileColShard) -> std::sync::MutexGuard<'_, Vec<u64>> {
    lock_scratch(&sh.scratch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_exactly() {
        for (total, target) in [(0usize, 4usize), (1, 4), (7, 3), (100, 9), (5000, 7)] {
            let shards = shard_ranges(total, target);
            assert!(shards.len() <= MAX_SHARDS);
            let mut expect = 0usize;
            for &(a, b) in &shards {
                assert_eq!(a, expect);
                assert!(b > a);
                expect = b;
            }
            assert_eq!(expect, total, "ranges must tile 0..{total}");
        }
    }

    #[test]
    fn merge_partials_is_ordered_sum() {
        let mut out = vec![1.0f32, 2.0];
        merge_partials(&mut out, &[10.0, 20.0, 100.0, 200.0]);
        assert_eq!(out, vec![111.0, 222.0]);
    }

    #[test]
    fn csc_plan_transposes_and_shards() {
        // 3x4, entries: (0,1)=a (0,3)=b (2,0)=c (2,1)=d
        let ia = vec![0u32, 2, 2, 4];
        let ja = vec![1u16, 3, 0, 1];
        let vals = vec![1.0f32, 2.0, 3.0, 4.0];
        let plan = CscPlan::build(3, 4, &ia, &ja, &vals);
        assert_eq!(plan.cp, vec![0, 1, 3, 3, 4]);
        assert_eq!(plan.ri, vec![2, 0, 2, 0]);
        assert_eq!(plan.vals, vec![3.0, 1.0, 4.0, 2.0]);
        assert_eq!(plan.shard_count(), 1, "4 nnz is one cache shard");
        // empty index → no shards, execute is a no-op
        let empty = CscPlan::build(2, 3, &[0, 0, 0], &[], &[]);
        assert_eq!(empty.shard_count(), 0);
    }

    #[test]
    fn tile_col_shards_group_by_column_range() {
        use crate::tiling::TilePlan;
        let specs = TilePlan::new(3, 2).tiles(9, 10).unwrap();
        let shards = tile_col_shards(&specs);
        assert_eq!(shards.len(), 2, "one shard per tile column");
        assert_eq!(shards[0].tiles, vec![0, 2, 4], "tile-row order");
        assert_eq!(shards[1].tiles, vec![1, 3, 5]);
        assert_eq!(shards[0].cols, (0, 5));
        assert_eq!(shards[1].cols, (5, 10));
    }
}
