//! Router tier: scatter/gather over output-column worker shards.
//!
//! A [`ShardGroup`] is the router-side handle to one served model. It
//! holds a fixed list of shards, each a fail-over chain of replica
//! workers (ordinary `lrbi serve --worker` processes speaking the
//! versioned wire protocol). On every request the router scatters the
//! *full input batch* to one live replica per shard as a `SCATTER`
//! frame, each worker runs the complete forward pass and answers a
//! `PARTIAL` carrying only its contiguous slice of output columns, and
//! the router reassembles the slices in fixed shard order with
//! [`shard::assemble`]. No arithmetic runs on the router, so the
//! gathered logits are bit-identical to a single-process
//! `NativeBackend` — `tests/cluster.rs` pins this for every kernel
//! format at shard counts {1, 2, 4}.
//!
//! Failure discipline (see `docs/CLUSTER.md`):
//! - **Deterministic request errors** (bad shape, unknown model,
//!   deadline exceeded, malformed frame) would fail identically on any
//!   replica, so they propagate immediately without fail-over.
//! - **Transient errors** (worker overloaded / shutting down / I/O
//!   failure) advance to the next replica of the same shard; the dead
//!   connection is dropped and re-dialled lazily on a later request.
//! - When every replica of a shard fails, the request gets a typed
//!   `unavailable` error — clients retry it like `overloaded`.
//! - A rolling [`ShardGroup::rolling_swap`] walks the replicas in
//!   fixed order under an exclusive lock (scatters hold it shared). If
//!   any worker refuses the swap, the group is marked *degraded* and
//!   answers `unavailable` until a later swap succeeds end-to-end —
//!   the router never gathers logits from mixed artifact versions.
//!
//! Supervision (PR 10, see `docs/CLUSTER.md`): the group is
//! *self-healing*. Every replica carries a [`CircuitBreaker`]
//! (closed → open on consecutive failures → half-open after a cooldown
//! → closed again after `breaker_successes` probe successes), so the
//! scatter path skips a dead worker without paying its dial/IO
//! timeout; lazy re-dials back off exponentially with equal jitter
//! (reusing [`RetryPolicy`]) instead of connect-storming a rebooting
//! worker; a [`start_supervisor`] thread probes every replica with
//! dedicated `PING`/`PONG` frames on a jittered interval (probes never
//! ride the `INFER` path, so they pollute no request counters), closes
//! breakers only after the *artifact re-probe* agrees on the output
//! width (a worker that slept through a rolling swap must not rejoin
//! serving stale bytes — counted in `net_reintegrations`), and retries
//! a degraded group's swap until it un-degrades without operator
//! action. Scatters *hedge*: if a shard's partial is still outstanding
//! after the hedge cut (`--hedge-ms`, or adaptively the live
//! `worker_ns` p95), the same `SCATTER` is fired at the next healthy
//! replica and the first reply wins — replicas are bit-identical by
//! construction, so the winner cannot change the output.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::telemetry::LatencyHistogram;
use crate::serve::protocol::{ErrorCode, Frame, RowBatch, WireError};
use crate::serve::server::{backoff_with_jitter, ClientOptions, NetClient, RetryPolicy};
use crate::serve::shard;
use crate::util::error::{Error, Result};
use crate::util::fault::{self, FaultPoint};
use crate::util::log::Level;
use crate::util::rng::Rng;

/// Parse a worker topology spec: `,` separates shards, `|` separates
/// replicas within a shard. `"a:1|b:1,c:2"` is two shards — the first
/// with replicas `a:1` and `b:1`, the second with the single worker
/// `c:2`. Whitespace around addresses is trimmed; empty entries are
/// rejected.
pub fn parse_workers(spec: &str) -> Result<Vec<Vec<String>>> {
    let mut shards = Vec::new();
    for (i, group) in spec.split(',').enumerate() {
        let replicas: Vec<String> = group
            .split('|')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        if replicas.is_empty() {
            return Err(Error::InvalidArg(format!(
                "worker spec '{spec}': shard {i} has no replicas \
                 (expected HOST:PORT[|HOST:PORT...][,HOST:PORT...])"
            )));
        }
        shards.push(replicas);
    }
    if shards.is_empty() {
        return Err(Error::InvalidArg(
            "worker spec is empty; expected HOST:PORT[|replica...][,shard...]".into(),
        ));
    }
    Ok(shards)
}

/// When (if ever) a scatter fires a second attempt at the next healthy
/// replica of the same shard while the first is still outstanding.
#[derive(Debug, Clone, Copy)]
pub enum HedgePolicy {
    /// Never hedge (`--hedge-ms 0`).
    Disabled,
    /// Hedge after a fixed wait (`--hedge-ms N`).
    Fixed(Duration),
    /// Hedge after the primary replica's live `worker_ns` p95 (clamped
    /// to [1ms, 1s]); a cold series (< 32 samples) never hedges, so an
    /// idle cluster cannot hedge off noise.
    Adaptive,
}

/// Supervision knobs for a [`ShardGroup`]: health probing, circuit
/// breaking, hedging, and dial backoff. All deterministic given
/// `seed` (jitter reuses the seeded [`Rng`]).
#[derive(Debug, Clone, Copy)]
pub struct SupervisorOptions {
    /// Cadence of the background health prober
    /// (`--health-interval-ms`); `ZERO` disables the thread entirely.
    /// Each tick sleeps a jittered `[interval/2, interval]` so a fleet
    /// of routers never probes in lockstep.
    pub health_interval: Duration,
    /// Hedged-scatter policy (`--hedge-ms`).
    pub hedge: HedgePolicy,
    /// Consecutive failures that open a replica's breaker
    /// (`--breaker-failures`).
    pub breaker_failures: u32,
    /// How long an open breaker rejects attempts before probing again
    /// (half-open) (`--breaker-cooldown-ms`).
    pub breaker_cooldown: Duration,
    /// Successful probes a half-open replica must pass — *plus* the
    /// artifact re-probe — before it rejoins serving
    /// (`--breaker-successes`).
    pub breaker_successes: u32,
    /// Backoff schedule for lazy re-dials of an unreachable worker
    /// (the PR 8 retry policy, reused: capped exponential with equal
    /// jitter), so a dead worker is not connect-stormed once per
    /// request.
    pub dial_backoff: RetryPolicy,
    /// Seed for probe-interval and dial-backoff jitter.
    pub seed: u64,
}

impl Default for SupervisorOptions {
    fn default() -> Self {
        SupervisorOptions {
            health_interval: Duration::from_millis(1000),
            hedge: HedgePolicy::Adaptive,
            breaker_failures: 3,
            breaker_cooldown: Duration::from_millis(1000),
            breaker_successes: 2,
            dial_backoff: RetryPolicy::default(),
            seed: 0xC1AD,
        }
    }
}

/// Circuit-breaker states (docs/CLUSTER.md has the diagram).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: requests flow, consecutive failures are counted.
    Closed,
    /// Quarantined: every attempt is skipped without dialing until the
    /// cooldown elapses.
    Open,
    /// Trial: attempts are admitted; successes accumulate toward
    /// close, any failure re-opens.
    HalfOpen,
}

/// Per-replica circuit breaker. Pure state machine — every method
/// takes `now` explicitly, so tests drive it with a synthetic clock —
/// counting its transitions into the shared [`Metrics`]
/// (`net_breaker_opens` / `net_breaker_half_opens` /
/// `net_breaker_closes`).
pub struct CircuitBreaker {
    state: BreakerState,
    failures: u32,
    successes: u32,
    opened_at: Option<Instant>,
    fail_threshold: u32,
    cooldown: Duration,
    close_after: u32,
}

impl CircuitBreaker {
    /// `fail_threshold` consecutive failures open the breaker; after
    /// `cooldown` it half-opens; `close_after` gated successes close it.
    pub fn new(fail_threshold: u32, cooldown: Duration, close_after: u32) -> Self {
        CircuitBreaker {
            state: BreakerState::Closed,
            failures: 0,
            successes: 0,
            opened_at: None,
            fail_threshold: fail_threshold.max(1),
            cooldown,
            close_after: close_after.max(1),
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// May an attempt proceed at `now`? An open breaker whose cooldown
    /// has elapsed transitions to half-open here (counted) and admits
    /// the trial.
    pub fn admit(&mut self, now: Instant, metrics: &Metrics) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                let elapsed = self
                    .opened_at
                    .map(|t| now.saturating_duration_since(t) >= self.cooldown)
                    .unwrap_or(true);
                if elapsed {
                    self.state = BreakerState::HalfOpen;
                    self.successes = 0;
                    metrics.net_breaker_half_opens.fetch_add(1, Ordering::Relaxed);
                }
                elapsed
            }
        }
    }

    /// Record a failed attempt/probe. Opens from closed at the
    /// threshold; re-opens instantly from half-open (a trial that
    /// fails restarts the cooldown).
    pub fn record_failure(&mut self, now: Instant, metrics: &Metrics) {
        match self.state {
            BreakerState::Closed => {
                self.failures = self.failures.saturating_add(1);
                if self.failures >= self.fail_threshold {
                    self.state = BreakerState::Open;
                    self.opened_at = Some(now);
                    metrics.net_breaker_opens.fetch_add(1, Ordering::Relaxed);
                }
            }
            BreakerState::HalfOpen => {
                self.state = BreakerState::Open;
                self.opened_at = Some(now);
                self.successes = 0;
                metrics.net_breaker_opens.fetch_add(1, Ordering::Relaxed);
            }
            BreakerState::Open => {}
        }
    }

    /// Record a successful attempt/probe; returns `true` when this
    /// success closed the breaker. Closing from half-open requires
    /// `close_gate` — the caller's confirmation that reintegration
    /// preconditions hold (the supervisor passes it only after the
    /// artifact re-probe agrees), so ordinary scatter successes can
    /// never sneak a stale worker back in.
    pub fn record_success(&mut self, close_gate: bool, metrics: &Metrics) -> bool {
        match self.state {
            BreakerState::Closed => {
                self.failures = 0;
                false
            }
            BreakerState::HalfOpen => {
                let next = self.successes.saturating_add(1);
                if close_gate && next >= self.close_after {
                    self.state = BreakerState::Closed;
                    self.failures = 0;
                    self.successes = 0;
                    metrics.net_breaker_closes.fetch_add(1, Ordering::Relaxed);
                    true
                } else {
                    // Without the gate, successes saturate one short of
                    // the closing count: the gated caller still decides.
                    self.successes =
                        if close_gate { next } else { next.min(self.close_after - 1) };
                    false
                }
            }
            BreakerState::Open => false,
        }
    }

    /// Would one more *gated* success close the breaker? The
    /// supervisor runs the (non-free) artifact re-probe only when this
    /// is true.
    pub fn pending_close(&self) -> bool {
        self.state == BreakerState::HalfOpen
            && self.successes.saturating_add(1) >= self.close_after
    }
}

/// One worker endpoint. The connection is lazy: dropped on any
/// transport error and re-dialled on the next attempt — but only after
/// the replica's jittered dial backoff elapses, so a dead worker costs
/// a bounded number of dials, not one per request.
struct Replica {
    addr: String,
    conn: Option<NetClient>,
    /// `worker_ns{worker=<addr>}` — full scatter round-trip latency.
    hist: Arc<LatencyHistogram>,
    /// `replica_healthy{worker=<addr>}` — 1 per successful probe,
    /// 0 per failure (p50 tracks state; sum/count = success ratio).
    health: Arc<LatencyHistogram>,
    breaker: CircuitBreaker,
    /// Consecutive dial failures (drives the backoff exponent).
    dial_failures: u32,
    /// No re-dial before this instant.
    next_dial: Option<Instant>,
}

enum Attempt {
    /// The same request would fail the same way on any replica.
    Fatal(WireError),
    /// Worth trying the next replica of this shard.
    Transient(WireError),
    /// Not attempted at all (breaker open / dial backoff): advance to
    /// the next replica without counting a worker failure.
    Skipped(WireError),
}

/// Router-side handle to one model served by a fixed shard topology.
pub struct ShardGroup {
    /// Model key sent to workers (may be `""` for the worker default).
    key: String,
    classes: usize,
    ranges: Vec<(u32, u32)>,
    shards: Vec<Vec<Arc<Mutex<Replica>>>>,
    /// Scatters take this shared; a rolling swap takes it exclusive so
    /// no request can observe half-swapped workers.
    swap_lock: RwLock<()>,
    /// Set when a rolling swap aborts partway: workers may disagree on
    /// the artifact, so infers answer `unavailable` until a swap
    /// completes end-to-end (or the supervisor's retry succeeds).
    degraded: AtomicBool,
    /// Name of the last requested rolling swap, so a degraded group's
    /// supervisor can retry it without operator action.
    last_swap: Mutex<Option<String>>,
    /// Total TCP dials attempted (probe + scatter + swap paths);
    /// observable so tests can pin the connect-storm fix. Arc'd so
    /// detached scatter-attempt threads can hold it past the request.
    dials: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
    opts: ClientOptions,
    sup: SupervisorOptions,
}

impl ShardGroup {
    /// Dial the topology in `spec` (see [`parse_workers`]), probe every
    /// shard for the model's output width with an empty `INFER`, and
    /// split the columns with [`shard::shard_cols`]. Fails if any shard
    /// is unreachable on all replicas, if shards disagree on the output
    /// width, or if there are more shards than output columns.
    pub fn connect(
        spec: &str,
        key: &str,
        opts: ClientOptions,
        metrics: Arc<Metrics>,
    ) -> Result<ShardGroup> {
        Self::connect_with(spec, key, opts, SupervisorOptions::default(), metrics)
    }

    /// [`ShardGroup::connect`] with explicit supervision knobs
    /// (breaker thresholds, hedge policy, dial backoff, probe
    /// interval) — the `--router` CLI path.
    pub fn connect_with(
        spec: &str,
        key: &str,
        opts: ClientOptions,
        sup: SupervisorOptions,
        metrics: Arc<Metrics>,
    ) -> Result<ShardGroup> {
        let groups = parse_workers(spec)?;
        let mut shards: Vec<Vec<Arc<Mutex<Replica>>>> = Vec::with_capacity(groups.len());
        let mut classes: Option<usize> = None;
        for (si, addrs) in groups.iter().enumerate() {
            let mut replicas: Vec<Replica> = addrs
                .iter()
                .map(|a| Replica {
                    addr: a.clone(),
                    conn: None,
                    hist: metrics.telemetry.worker_histogram(a),
                    health: metrics.telemetry.replica_health_histogram(a),
                    breaker: CircuitBreaker::new(
                        sup.breaker_failures,
                        sup.breaker_cooldown,
                        sup.breaker_successes,
                    ),
                    dial_failures: 0,
                    next_dial: None,
                })
                .collect();
            let c = probe_shard(&mut replicas, key, &opts).map_err(|e| {
                Error::Coordinator(format!(
                    "cannot probe shard {si} ({}): {e}",
                    addrs.join("|")
                ))
            })?;
            match classes {
                None => classes = Some(c),
                Some(prev) if prev != c => {
                    return Err(Error::Coordinator(format!(
                        "workers disagree on output width: shard 0 reports {prev} \
                         columns, shard {si} ({}) reports {c}",
                        addrs.join("|")
                    )));
                }
                Some(_) => {}
            }
            shards.push(replicas.into_iter().map(|r| Arc::new(Mutex::new(r))).collect());
        }
        let classes = classes.unwrap_or(0);
        if classes == 0 {
            return Err(Error::Coordinator(
                "workers report a zero-column model; nothing to shard".into(),
            ));
        }
        if shards.len() > classes {
            return Err(Error::InvalidArg(format!(
                "{} shards requested but the model has only {classes} output \
                 column(s); use at most {classes}",
                shards.len()
            )));
        }
        let ranges = shard::shard_cols(classes, shards.len());
        Ok(ShardGroup {
            key: key.to_string(),
            classes,
            ranges,
            shards,
            swap_lock: RwLock::new(()),
            degraded: AtomicBool::new(false),
            last_swap: Mutex::new(None),
            dials: Arc::new(AtomicU64::new(0)),
            metrics,
            opts,
            sup,
        })
    }

    /// Total TCP dials attempted so far (tests pin the connect-storm
    /// fix: a dead replica must cost a bounded number of dials, not
    /// one per request).
    pub fn dial_attempts(&self) -> u64 {
        self.dials.load(Ordering::Relaxed)
    }

    /// Output width discovered from the workers at connect time.
    pub fn classes(&self) -> usize {
        self.classes
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub(crate) fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// One-line topology summary for the startup banner.
    pub fn describe(&self) -> String {
        self.ranges
            .iter()
            .zip(&self.shards)
            .enumerate()
            .map(|(i, ((s, e), reps))| format!("shard {i} cols {s}..{e} x{}", reps.len()))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Scatter `batch` to one live replica per shard, gather the
    /// partials, and reassemble the full logits. Pure data movement —
    /// bit-identical to an unsharded infer of the same batch.
    pub(crate) fn scatter_gather(
        &self,
        batch: &RowBatch,
        deadline: Option<Instant>,
    ) -> std::result::Result<RowBatch, WireError> {
        let _serving = self.swap_lock.read().unwrap_or_else(|p| p.into_inner());
        if self.degraded.load(Ordering::SeqCst) {
            self.metrics
                .net_worker_unavailable
                .fetch_add(1, Ordering::Relaxed);
            return Err(WireError::new(
                ErrorCode::Unavailable,
                "shard group degraded by a failed rolling swap; retry after the \
                 next successful SWAP",
            ));
        }
        let mut parts = Vec::with_capacity(self.shards.len());
        for (i, replicas) in self.shards.iter().enumerate() {
            let (cs, ce) = self.ranges[i];
            let part = self.scatter_one(i, replicas, cs, ce, batch, deadline)?;
            parts.push((cs, ce, part));
        }
        shard::assemble(batch.rows(), self.classes, &parts)
            .map_err(|e| WireError::new(ErrorCode::Internal, e.to_string()))
    }

    /// The hedge cut for one shard: how long the primary's partial may
    /// stay outstanding before the same scatter fires at the next
    /// replica. `None` disables hedging (single replica, explicit
    /// `--hedge-ms 0`, or a cold adaptive series).
    fn hedge_delay(&self, replicas: &[Arc<Mutex<Replica>>]) -> Option<Duration> {
        if replicas.len() < 2 {
            return None;
        }
        match self.sup.hedge {
            HedgePolicy::Disabled => None,
            HedgePolicy::Fixed(d) if d.is_zero() => None,
            HedgePolicy::Fixed(d) => Some(d),
            HedgePolicy::Adaptive => {
                let hist = {
                    let r = replicas[0].lock().unwrap_or_else(|p| p.into_inner());
                    Arc::clone(&r.hist)
                };
                let snap = hist.snapshot();
                if snap.count < 32 {
                    return None;
                }
                let p95 = snap.quantile(0.95);
                Some(
                    Duration::from_nanos(p95)
                        .max(Duration::from_millis(1))
                        .min(Duration::from_secs(1)),
                )
            }
        }
    }

    /// Serve one shard: launch the first admissible replica, hedge to
    /// the next after [`ShardGroup::hedge_delay`] if the partial is
    /// still outstanding, take whichever `PARTIAL` lands first, and
    /// fail over sequentially past transient errors. Replicas are
    /// byte-identical, so the winner cannot change the gathered
    /// logits. Breaker-open and backoff-window replicas are skipped
    /// without dialing (and without inflating `net_worker_failures`).
    fn scatter_one(
        &self,
        shard_idx: usize,
        replicas: &[Arc<Mutex<Replica>>],
        col_start: u32,
        col_end: u32,
        batch: &RowBatch,
        deadline: Option<Instant>,
    ) -> std::result::Result<RowBatch, WireError> {
        type Outcome = (usize, std::result::Result<RowBatch, Attempt>);
        let n = replicas.len();
        let hedge_after = self.hedge_delay(replicas);
        let (tx, rx) = mpsc::channel::<Outcome>();
        // Attempts run on detached threads so a stalled replica cannot
        // pin the request: the first PARTIAL wins, losers finish into
        // a dropped receiver. Each thread owns clones of everything it
        // touches (the replica cell is Arc'd), so no borrow outlives
        // this call.
        let spawn_attempt = |idx: usize, is_primary: bool| {
            let cell = Arc::clone(&replicas[idx]);
            let key = self.key.clone();
            let opts = self.opts;
            let sup = self.sup;
            let metrics = Arc::clone(&self.metrics);
            let dials = Arc::clone(&self.dials);
            let batch = batch.clone();
            let txc = tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("lrbi-scatter-{shard_idx}-{idx}"))
                .spawn(move || {
                    let out = attempt_scatter(
                        &cell, &key, &opts, &sup, &metrics, &dials, col_start, col_end,
                        &batch, deadline, is_primary,
                    );
                    let _ = txc.send((idx, out));
                });
            if spawned.is_err() {
                // Thread exhaustion: report a transient failure so the
                // orchestrator advances instead of waiting forever.
                let _ = tx.send((
                    idx,
                    Err(Attempt::Transient(WireError::new(
                        ErrorCode::Internal,
                        "cannot spawn a scatter attempt thread",
                    ))),
                ));
            }
        };
        let forever = Duration::from_secs(86_400);
        let mut next = 0usize;
        let mut in_flight = 0usize;
        let mut hedged = false;
        let mut hedge_idx: Option<usize> = None;
        let mut last: Option<WireError> = None;
        spawn_attempt(next, true);
        next += 1;
        in_flight += 1;
        loop {
            let mut wait =
                if !hedged && next < n { hedge_after.unwrap_or(forever) } else { forever };
            if let Some(d) = deadline {
                let now = Instant::now();
                if d <= now {
                    return Err(WireError::new(
                        ErrorCode::DeadlineExceeded,
                        format!(
                            "scatter budget exhausted awaiting shard {shard_idx} \
                             (columns {col_start}..{col_end})"
                        ),
                    ));
                }
                wait = wait.min(d - now);
            }
            match rx.recv_timeout(wait) {
                Ok((idx, Ok(part))) => {
                    if hedge_idx == Some(idx) {
                        self.metrics.net_hedges_won.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(part);
                }
                Ok((_idx, Err(att))) => {
                    in_flight -= 1;
                    match att {
                        Attempt::Fatal(e) => return Err(e),
                        Attempt::Transient(e) => {
                            self.metrics.net_worker_failures.fetch_add(1, Ordering::Relaxed);
                            if in_flight > 0 || next < n {
                                self.metrics
                                    .net_worker_failovers
                                    .fetch_add(1, Ordering::Relaxed);
                                crate::lrbi_log!(
                                    Level::Warn,
                                    "shard {shard_idx} replica failed ({}); failing over \
                                     to the next replica",
                                    e.message
                                );
                            }
                            last = Some(e);
                        }
                        Attempt::Skipped(e) => last = Some(e),
                    }
                    if in_flight == 0 {
                        if next < n {
                            spawn_attempt(next, false);
                            next += 1;
                            in_flight += 1;
                        } else {
                            break;
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if !hedged && next < n && in_flight > 0 {
                        // The primary's partial is still outstanding
                        // past the hedge cut: race the next replica.
                        hedged = true;
                        hedge_idx = Some(next);
                        self.metrics.net_hedges_fired.fetch_add(1, Ordering::Relaxed);
                        spawn_attempt(next, false);
                        next += 1;
                        in_flight += 1;
                    } else if in_flight == 0 {
                        break;
                    }
                    // else: deadline-capped wait expired with work in
                    // flight; the loop re-checks the deadline above.
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        self.metrics
            .net_worker_unavailable
            .fetch_add(1, Ordering::Relaxed);
        let detail = last
            .map(|e| e.message)
            .unwrap_or_else(|| "shard has no replicas".to_string());
        Err(WireError::new(
            ErrorCode::Unavailable,
            format!(
                "no replica of shard {shard_idx} (columns {col_start}..{col_end}) \
                 could serve: {detail}; retry with backoff"
            ),
        ))
    }

    /// One supervision pass: retry a degraded group's swap, then
    /// health-probe every replica (dedicated `PING` frames — probes
    /// never touch `net_requests` or any request latency series).
    /// Public so tests can drive supervision deterministically without
    /// the background thread; [`start_supervisor`] calls it on a
    /// jittered interval.
    pub fn supervise_tick(&self) {
        if self.degraded.load(Ordering::SeqCst) {
            let pending =
                self.last_swap.lock().unwrap_or_else(|p| p.into_inner()).clone();
            if let Some(name) = pending {
                match self.rolling_swap(&name) {
                    Ok(msg) => {
                        crate::lrbi_log!(Level::Info, "supervisor retried swap: {msg}")
                    }
                    Err(e) => crate::lrbi_log!(
                        Level::Warn,
                        "supervisor swap retry failed (still degraded): {e}"
                    ),
                }
            }
        }
        for replicas in &self.shards {
            for cell in replicas {
                let mut r = cell.lock().unwrap_or_else(|p| p.into_inner());
                self.probe_replica(&mut r);
            }
        }
    }

    /// Health-probe one replica and feed its breaker. A quarantined
    /// replica (breaker not closed) rejoins only after
    /// `breaker_successes` consecutive probe successes *plus* the
    /// artifact re-probe: `PONG` proves liveness, but only
    /// class-agreement proves the worker did not sleep through a
    /// rolling swap. Each rejoin is counted in `net_reintegrations`.
    fn probe_replica(&self, r: &mut Replica) {
        let m = &*self.metrics;
        m.net_health_probes.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let mut ok = false;
        if fault::fire(FaultPoint::HealthProbeFail).is_none() {
            if ensure_conn(r, &self.opts, &self.sup, &self.dials).is_ok() {
                match r.conn.as_mut().expect("connected above").ping() {
                    Ok(()) => ok = true,
                    Err(_) => r.conn = None,
                }
            }
        }
        r.health.record(u64::from(ok));
        if !ok {
            r.breaker.record_failure(now, m);
            return;
        }
        let _ = r.breaker.admit(now, m);
        if r.breaker.pending_close() {
            let agreed = self.reprobe_classes(r);
            if agreed == Some(self.classes) {
                if r.breaker.record_success(true, m) {
                    m.net_reintegrations.fetch_add(1, Ordering::Relaxed);
                    crate::lrbi_log!(
                        Level::Info,
                        "worker {} reintegrated: consecutive health checks and the \
                         artifact re-probe all passed",
                        r.addr
                    );
                }
            } else {
                crate::lrbi_log!(
                    Level::Warn,
                    "worker {} answers PING but fails the artifact re-probe \
                     ({agreed:?} columns vs {} expected) — likely serving stale \
                     bytes; kept quarantined",
                    r.addr,
                    self.classes
                );
                r.breaker.record_failure(now, m);
                r.conn = None;
            }
        } else {
            let _ = r.breaker.record_success(false, m);
        }
    }

    /// The class-agreement re-probe: an empty `INFER` echoing the
    /// worker's output width (the same probe `connect` used). `None`
    /// means the probe itself failed.
    fn reprobe_classes(&self, r: &mut Replica) -> Option<usize> {
        let conn = r.conn.as_mut()?;
        let empty = RowBatch::new(0, 0, Vec::new()).ok()?;
        match conn.infer(&self.key, empty) {
            Ok(logits) => Some(logits.cols()),
            Err(_) => {
                r.conn = None;
                None
            }
        }
    }

    /// Coordinated rolling `SWAP name` across every worker replica, in
    /// fixed shard-then-replica order, exclusive with scatters. Aborts
    /// at the first refusal and degrades the group (infers answer
    /// `unavailable`) so mixed-artifact logits can never be gathered; a
    /// later swap that completes end-to-end clears the degradation.
    pub fn rolling_swap(&self, name: &str) -> Result<String> {
        let _excl = self.swap_lock.write().unwrap_or_else(|p| p.into_inner());
        // Remember the requested swap so a degraded group's supervisor
        // can retry it without operator action.
        *self.last_swap.lock().unwrap_or_else(|p| p.into_inner()) = Some(name.to_string());
        let mut stepped = 0usize;
        for replicas in &self.shards {
            for cell in replicas {
                let mut r = cell.lock().unwrap_or_else(|p| p.into_inner());
                let step: Result<String> = if let Some(action) = fault::fire(FaultPoint::WorkerSwapFail)
                {
                    fault::stall(&action);
                    Err(Error::Coordinator(format!(
                        "injected swap failure at worker {} (fault plan)",
                        r.addr
                    )))
                } else {
                    self.swap_replica(&mut r, name)
                };
                match step {
                    Ok(_) => {
                        self.metrics.net_worker_swaps.fetch_add(1, Ordering::Relaxed);
                        stepped += 1;
                    }
                    Err(e) => {
                        self.metrics
                            .net_worker_swap_failures
                            .fetch_add(1, Ordering::Relaxed);
                        self.degraded.store(true, Ordering::SeqCst);
                        return Err(Error::Coordinator(format!(
                            "rolling swap of '{name}' aborted at worker {} after \
                             {stepped} completed step(s): {e}; shard group is degraded \
                             (infers answer 'unavailable') until a SWAP succeeds",
                            r.addr
                        )));
                    }
                }
            }
        }
        self.degraded.store(false, Ordering::SeqCst);
        Ok(format!(
            "rolling swap of '{name}' complete across {stepped} worker replica(s); \
             in-flight batches finished on the old artifact"
        ))
    }

    fn swap_replica(&self, r: &mut Replica, name: &str) -> Result<String> {
        if r.conn.is_none() {
            // A swap is an explicit (operator or supervisor) action:
            // dial regardless of the lazy-path backoff window, but
            // still count the attempt and reset the schedule on
            // success.
            self.dials.fetch_add(1, Ordering::Relaxed);
            match NetClient::connect_with(r.addr.as_str(), self.opts) {
                Ok(c) => {
                    r.conn = Some(c);
                    r.dial_failures = 0;
                    r.next_dial = None;
                }
                Err(e) => return Err(e),
            }
        }
        match r.conn.as_mut().expect("connected above").swap(name) {
            Ok(msg) => Ok(msg),
            Err(e) => {
                r.conn = None;
                Err(e)
            }
        }
    }
}

/// One scatter attempt against one replica, run on its own thread so
/// the orchestrator can hedge past a stall. Consults the breaker and
/// the dial-backoff window before paying any network cost; feeds the
/// breaker with the outcome. Drops the connection on any transport or
/// protocol surprise so the next attempt re-dials.
#[allow(clippy::too_many_arguments)]
fn attempt_scatter(
    cell: &Mutex<Replica>,
    key: &str,
    opts: &ClientOptions,
    sup: &SupervisorOptions,
    metrics: &Metrics,
    dials: &AtomicU64,
    col_start: u32,
    col_end: u32,
    batch: &RowBatch,
    deadline: Option<Instant>,
    is_primary: bool,
) -> std::result::Result<RowBatch, Attempt> {
    let mut r = cell.lock().unwrap_or_else(|p| p.into_inner());
    // Supervised groups (a health prober exists) never route traffic
    // at a non-closed replica: reintegration belongs to the
    // supervisor's probe + artifact re-probe, and a stale worker must
    // not see a trial scatter it could answer with foreign bytes. An
    // unsupervised group has no prober, so the serving path itself
    // walks the half-open trial.
    let admitted = if sup.health_interval.is_zero() {
        r.breaker.admit(Instant::now(), metrics)
    } else {
        r.breaker.state() == BreakerState::Closed
    };
    if !admitted {
        return Err(Attempt::Skipped(WireError::new(
            ErrorCode::Unavailable,
            format!("worker {}: circuit open, skipped without dialing", r.addr),
        )));
    }
    if is_primary {
        // Router-side hedge exercise point: stalls only the primary
        // attempt, so a hedge deterministically fires and wins.
        if let Some(action) = fault::fire(FaultPoint::HedgeStall) {
            fault::stall(&action);
        }
    }
    if let Some(action) = fault::fire(FaultPoint::WorkerConnDrop) {
        fault::stall(&action);
        r.conn = None;
        r.breaker.record_failure(Instant::now(), metrics);
        return Err(Attempt::Transient(WireError::new(
            ErrorCode::Unavailable,
            format!("injected connection drop to worker {} (fault plan)", r.addr),
        )));
    }
    match ensure_conn(&mut r, opts, sup, dials) {
        Ok(()) => {}
        Err(att) => {
            if matches!(att, Attempt::Transient(_)) {
                r.breaker.record_failure(Instant::now(), metrics);
            }
            return Err(att);
        }
    }
    let deadline_us = deadline.map(|d| {
        let now = Instant::now();
        if d > now {
            (d - now).as_micros().min(u64::MAX as u128) as u64
        } else {
            0
        }
    });
    metrics.net_worker_requests.fetch_add(1, Ordering::Relaxed);
    let started = Instant::now();
    let reply = r.conn.as_mut().expect("connected above").call(&Frame::Scatter {
        key: key.to_string(),
        col_start,
        col_end,
        batch: batch.clone(),
        deadline_us,
    });
    match reply {
        Ok(Frame::Partial {
            col_start: got_s,
            col_end: got_e,
            batch: part,
        }) => {
            if got_s != col_start || got_e != col_end || part.rows() != batch.rows() {
                r.conn = None;
                r.breaker.record_failure(Instant::now(), metrics);
                return Err(Attempt::Transient(WireError::new(
                    ErrorCode::Internal,
                    format!(
                        "worker {} answered columns {got_s}..{got_e} ({} rows) to a \
                         scatter for {col_start}..{col_end} ({} rows)",
                        r.addr,
                        part.rows(),
                        batch.rows()
                    ),
                )));
            }
            r.hist.record_since(started);
            // Serving successes reset the closed breaker's failure run
            // but never close a half-open one: reintegration stays
            // gated on the supervisor's artifact re-probe.
            let _ = r.breaker.record_success(false, metrics);
            Ok(part)
        }
        Ok(Frame::Error { code, message }) => {
            let tagged = WireError::new(code, format!("worker {}: {message}", r.addr));
            match code {
                // The request itself is wrong (or out of time) — any
                // replica would refuse it identically. Not the
                // replica's fault: the breaker is untouched.
                ErrorCode::BadShape
                | ErrorCode::UnknownModel
                | ErrorCode::DeadlineExceeded
                | ErrorCode::BadFrame
                | ErrorCode::BadVersion
                | ErrorCode::TooLarge => Err(Attempt::Fatal(tagged)),
                // Overloaded / Internal / ShuttingDown / Unavailable:
                // this replica is struggling, another may not be.
                _ => {
                    r.breaker.record_failure(Instant::now(), metrics);
                    Err(Attempt::Transient(tagged))
                }
            }
        }
        Ok(other) => {
            r.conn = None;
            r.breaker.record_failure(Instant::now(), metrics);
            Err(Attempt::Transient(WireError::new(
                ErrorCode::Internal,
                format!(
                    "worker {} answered a scatter with an unexpected {} frame",
                    r.addr,
                    other.type_name()
                ),
            )))
        }
        Err(e) => {
            r.conn = None;
            r.breaker.record_failure(Instant::now(), metrics);
            Err(Attempt::Transient(WireError::new(
                ErrorCode::Unavailable,
                format!("worker {} transport error: {e}", r.addr),
            )))
        }
    }
}

/// Lazily (re)connect a replica, honoring its jittered dial-backoff
/// window: inside the window the attempt is [`Attempt::Skipped`]
/// (no dial, no failure counted); a failed dial schedules the next one
/// with the capped equal-jitter exponential from [`RetryPolicy`].
/// Breaker-free — callers decide whether a skip or failure feeds it.
fn ensure_conn(
    r: &mut Replica,
    opts: &ClientOptions,
    sup: &SupervisorOptions,
    dials: &AtomicU64,
) -> std::result::Result<(), Attempt> {
    if r.conn.is_some() {
        return Ok(());
    }
    let now = Instant::now();
    if let Some(at) = r.next_dial {
        if now < at {
            return Err(Attempt::Skipped(WireError::new(
                ErrorCode::Unavailable,
                format!(
                    "worker {} in dial backoff for another {}ms",
                    r.addr,
                    at.saturating_duration_since(now).as_millis()
                ),
            )));
        }
    }
    dials.fetch_add(1, Ordering::Relaxed);
    match NetClient::connect_with(r.addr.as_str(), *opts) {
        Ok(c) => {
            r.conn = Some(c);
            r.dial_failures = 0;
            r.next_dial = None;
            Ok(())
        }
        Err(e) => {
            // Deterministic jitter, decorrelated across replicas by
            // hashing the address into the seed.
            let mut rng =
                Rng::new(sup.dial_backoff.seed ^ addr_seed(&r.addr) ^ u64::from(r.dial_failures));
            let backoff =
                backoff_with_jitter(&sup.dial_backoff, r.dial_failures.min(16), &mut rng);
            r.dial_failures = r.dial_failures.saturating_add(1);
            r.next_dial = Some(now + backoff);
            Err(Attempt::Transient(WireError::new(
                ErrorCode::Unavailable,
                format!("cannot reach worker {}: {e}; next dial in {backoff:?}", r.addr),
            )))
        }
    }
}

/// FNV-1a hash of a worker address (dial-jitter decorrelation).
fn addr_seed(addr: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in addr.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Handle to a running supervisor thread; stopping (or dropping) it
/// signals the thread and joins it. The thread holds only a `Weak` to
/// the group, so an abandoned group shuts its supervisor down too.
pub struct SupervisorHandle {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl SupervisorHandle {
    /// Signal the prober loop and wait for it to exit.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SupervisorHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start the background supervisor for `group`: every jittered
/// `health_interval` (uniform in `[interval/2, interval]`, seeded, so
/// a fleet of routers never probes in lockstep) it runs one
/// [`ShardGroup::supervise_tick`] — health probes, breaker
/// transitions, reintegration re-probes, and degraded-swap retries. A
/// `ZERO` interval disables supervision: the handle is inert and no
/// thread is spawned.
pub fn start_supervisor(group: &Arc<ShardGroup>) -> SupervisorHandle {
    let stop = Arc::new(AtomicBool::new(false));
    let interval = group.sup.health_interval;
    if interval.is_zero() {
        return SupervisorHandle { stop, handle: None };
    }
    let seed = group.sup.seed;
    let weak: Weak<ShardGroup> = Arc::downgrade(group);
    let stop2 = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name("lrbi-supervisor".into())
        .spawn(move || {
            let mut rng = Rng::new(seed ^ 0x5EED_5EED);
            while !stop2.load(Ordering::SeqCst) {
                // Jittered sleep in short slices so stop() never waits
                // a whole interval.
                let half_ns = (interval.as_nanos() / 2).min(u64::MAX as u128) as u64;
                let sleep = Duration::from_nanos(half_ns + rng.next_range(half_ns + 1));
                let start = Instant::now();
                while start.elapsed() < sleep && !stop2.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(
                        10.min((sleep - start.elapsed().min(sleep)).as_millis() as u64).max(1),
                    ));
                }
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match weak.upgrade() {
                    Some(g) => g.supervise_tick(),
                    None => break,
                }
            }
        })
        .ok();
    SupervisorHandle { stop, handle }
}

/// Discover a shard's output width: an empty `INFER` (0 rows, 0 cols)
/// takes the server's empty-batch fast path and echoes a `0 × classes`
/// logits frame without touching a kernel. The probe connection is
/// kept as the replica's initial connection.
fn probe_shard(replicas: &mut [Replica], key: &str, opts: &ClientOptions) -> Result<usize> {
    let mut last: Option<Error> = None;
    for r in replicas.iter_mut() {
        let attempt = (|| -> Result<usize> {
            let mut conn = NetClient::connect_with(r.addr.as_str(), *opts)?;
            let empty = RowBatch::new(0, 0, Vec::new())?;
            let logits = conn.infer(key, empty)?;
            r.conn = Some(conn);
            Ok(logits.cols())
        })();
        match attempt {
            Ok(c) => return Ok(c),
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| Error::InvalidArg("shard has no replicas".into())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_workers_splits_shards_and_replicas() {
        assert_eq!(
            parse_workers("127.0.0.1:9000").unwrap(),
            vec![vec!["127.0.0.1:9000".to_string()]]
        );
        assert_eq!(
            parse_workers("a:1,b:2,c:3").unwrap(),
            vec![
                vec!["a:1".to_string()],
                vec!["b:2".to_string()],
                vec!["c:3".to_string()],
            ]
        );
        assert_eq!(
            parse_workers(" a:1 | b:1 , c:2 ").unwrap(),
            vec![
                vec!["a:1".to_string(), "b:1".to_string()],
                vec!["c:2".to_string()],
            ]
        );
    }

    #[test]
    fn parse_workers_rejects_empty_entries() {
        assert!(parse_workers("").is_err());
        assert!(parse_workers("a:1,,b:2").is_err());
        assert!(parse_workers("|").is_err());
        assert!(parse_workers(" , ").is_err());
    }

    /// The full breaker lifecycle under an injected clock: every
    /// `Instant` below derives from one origin, so the transitions are
    /// deterministic regardless of scheduler noise.
    #[test]
    fn breaker_walks_closed_open_half_open_closed() {
        let m = Metrics::new();
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut b = CircuitBreaker::new(3, Duration::from_millis(100), 2);
        assert_eq!(b.state(), BreakerState::Closed);
        // Two failures stay closed; an interleaved success resets the run.
        b.record_failure(at(0), &m);
        b.record_failure(at(1), &m);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(!b.record_success(false, &m));
        b.record_failure(at(2), &m);
        b.record_failure(at(3), &m);
        assert_eq!(b.state(), BreakerState::Closed, "success reset the failure run");
        // The third consecutive failure opens.
        b.record_failure(at(4), &m);
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(m.snapshot().net_breaker_opens, 1);
        // Inside the cooldown nothing is admitted (no dial, no timeout).
        assert!(!b.admit(at(50), &m));
        assert_eq!(b.state(), BreakerState::Open);
        // Cooldown elapsed: the next admit half-opens and admits the trial.
        assert!(b.admit(at(104), &m));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(m.snapshot().net_breaker_half_opens, 1);
        // First gated success is not enough (close_after = 2)…
        assert!(!b.pending_close());
        assert!(!b.record_success(true, &m));
        // …the second closes, and the counters carry the floor.
        assert!(b.pending_close());
        assert!(b.record_success(true, &m));
        assert_eq!(b.state(), BreakerState::Closed);
        let snap = m.snapshot();
        assert_eq!(
            (snap.net_breaker_opens, snap.net_breaker_half_opens, snap.net_breaker_closes),
            (1, 1, 1)
        );
    }

    /// A failed half-open trial re-opens immediately, and ungated
    /// successes (the scatter path) can never close the breaker — the
    /// supervisor's artifact re-probe owns reintegration.
    #[test]
    fn breaker_reopens_on_trial_failure_and_gates_closing() {
        let m = Metrics::new();
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut b = CircuitBreaker::new(1, Duration::from_millis(10), 2);
        b.record_failure(at(0), &m);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(b.admit(at(20), &m));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record_failure(at(21), &m);
        assert_eq!(b.state(), BreakerState::Open, "failed trial re-opens");
        assert!(!b.admit(at(25), &m), "cooldown restarted from the re-open");
        assert!(b.admit(at(35), &m));
        // Ungated successes saturate short of closing, forever.
        for _ in 0..10 {
            assert!(!b.record_success(false, &m));
        }
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(b.pending_close(), "saturated one short: next gated success closes");
        assert!(b.record_success(true, &m));
        assert_eq!(b.state(), BreakerState::Closed);
        let snap = m.snapshot();
        assert_eq!(snap.net_breaker_opens, 2);
        assert_eq!(snap.net_breaker_half_opens, 2);
        assert_eq!(snap.net_breaker_closes, 1);
    }

    #[test]
    fn addr_seed_decorrelates_and_is_stable() {
        assert_eq!(addr_seed("a:1"), addr_seed("a:1"));
        assert_ne!(addr_seed("a:1"), addr_seed("a:2"));
    }
}
