//! Router tier: scatter/gather over output-column worker shards.
//!
//! A [`ShardGroup`] is the router-side handle to one served model. It
//! holds a fixed list of shards, each a fail-over chain of replica
//! workers (ordinary `lrbi serve --worker` processes speaking the
//! versioned wire protocol). On every request the router scatters the
//! *full input batch* to one live replica per shard as a `SCATTER`
//! frame, each worker runs the complete forward pass and answers a
//! `PARTIAL` carrying only its contiguous slice of output columns, and
//! the router reassembles the slices in fixed shard order with
//! [`shard::assemble`]. No arithmetic runs on the router, so the
//! gathered logits are bit-identical to a single-process
//! `NativeBackend` — `tests/cluster.rs` pins this for every kernel
//! format at shard counts {1, 2, 4}.
//!
//! Failure discipline (see `docs/CLUSTER.md`):
//! - **Deterministic request errors** (bad shape, unknown model,
//!   deadline exceeded, malformed frame) would fail identically on any
//!   replica, so they propagate immediately without fail-over.
//! - **Transient errors** (worker overloaded / shutting down / I/O
//!   failure) advance to the next replica of the same shard; the dead
//!   connection is dropped and re-dialled lazily on a later request.
//! - When every replica of a shard fails, the request gets a typed
//!   `unavailable` error — clients retry it like `overloaded`.
//! - A rolling [`ShardGroup::rolling_swap`] walks the replicas in
//!   fixed order under an exclusive lock (scatters hold it shared). If
//!   any worker refuses the swap, the group is marked *degraded* and
//!   answers `unavailable` until a later swap succeeds end-to-end —
//!   the router never gathers logits from mixed artifact versions.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::telemetry::LatencyHistogram;
use crate::serve::protocol::{ErrorCode, Frame, RowBatch, WireError};
use crate::serve::server::{ClientOptions, NetClient};
use crate::serve::shard;
use crate::util::error::{Error, Result};
use crate::util::fault::{self, FaultPoint};
use crate::util::log::Level;

/// Parse a worker topology spec: `,` separates shards, `|` separates
/// replicas within a shard. `"a:1|b:1,c:2"` is two shards — the first
/// with replicas `a:1` and `b:1`, the second with the single worker
/// `c:2`. Whitespace around addresses is trimmed; empty entries are
/// rejected.
pub fn parse_workers(spec: &str) -> Result<Vec<Vec<String>>> {
    let mut shards = Vec::new();
    for (i, group) in spec.split(',').enumerate() {
        let replicas: Vec<String> = group
            .split('|')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        if replicas.is_empty() {
            return Err(Error::InvalidArg(format!(
                "worker spec '{spec}': shard {i} has no replicas \
                 (expected HOST:PORT[|HOST:PORT...][,HOST:PORT...])"
            )));
        }
        shards.push(replicas);
    }
    if shards.is_empty() {
        return Err(Error::InvalidArg(
            "worker spec is empty; expected HOST:PORT[|replica...][,shard...]".into(),
        ));
    }
    Ok(shards)
}

/// One worker endpoint. The connection is lazy: dropped on any
/// transport error and re-dialled on the next attempt, so a worker
/// restart heals without router intervention.
struct Replica {
    addr: String,
    conn: Option<NetClient>,
    /// `worker_ns{worker=<addr>}` — full scatter round-trip latency.
    hist: Arc<LatencyHistogram>,
}

enum Attempt {
    /// The same request would fail the same way on any replica.
    Fatal(WireError),
    /// Worth trying the next replica of this shard.
    Transient(WireError),
}

/// Router-side handle to one model served by a fixed shard topology.
pub struct ShardGroup {
    /// Model key sent to workers (may be `""` for the worker default).
    key: String,
    classes: usize,
    ranges: Vec<(u32, u32)>,
    shards: Vec<Vec<Mutex<Replica>>>,
    /// Scatters take this shared; a rolling swap takes it exclusive so
    /// no request can observe half-swapped workers.
    swap_lock: RwLock<()>,
    /// Set when a rolling swap aborts partway: workers may disagree on
    /// the artifact, so infers answer `unavailable` until a swap
    /// completes end-to-end.
    degraded: AtomicBool,
    metrics: Arc<Metrics>,
    opts: ClientOptions,
}

impl ShardGroup {
    /// Dial the topology in `spec` (see [`parse_workers`]), probe every
    /// shard for the model's output width with an empty `INFER`, and
    /// split the columns with [`shard::shard_cols`]. Fails if any shard
    /// is unreachable on all replicas, if shards disagree on the output
    /// width, or if there are more shards than output columns.
    pub fn connect(
        spec: &str,
        key: &str,
        opts: ClientOptions,
        metrics: Arc<Metrics>,
    ) -> Result<ShardGroup> {
        let groups = parse_workers(spec)?;
        let mut shards: Vec<Vec<Mutex<Replica>>> = Vec::with_capacity(groups.len());
        let mut classes: Option<usize> = None;
        for (si, addrs) in groups.iter().enumerate() {
            let mut replicas: Vec<Replica> = addrs
                .iter()
                .map(|a| Replica {
                    addr: a.clone(),
                    conn: None,
                    hist: metrics.telemetry.worker_histogram(a),
                })
                .collect();
            let c = probe_shard(&mut replicas, key, &opts).map_err(|e| {
                Error::Coordinator(format!(
                    "cannot probe shard {si} ({}): {e}",
                    addrs.join("|")
                ))
            })?;
            match classes {
                None => classes = Some(c),
                Some(prev) if prev != c => {
                    return Err(Error::Coordinator(format!(
                        "workers disagree on output width: shard 0 reports {prev} \
                         columns, shard {si} ({}) reports {c}",
                        addrs.join("|")
                    )));
                }
                Some(_) => {}
            }
            shards.push(replicas.into_iter().map(Mutex::new).collect());
        }
        let classes = classes.unwrap_or(0);
        if classes == 0 {
            return Err(Error::Coordinator(
                "workers report a zero-column model; nothing to shard".into(),
            ));
        }
        if shards.len() > classes {
            return Err(Error::InvalidArg(format!(
                "{} shards requested but the model has only {classes} output \
                 column(s); use at most {classes}",
                shards.len()
            )));
        }
        let ranges = shard::shard_cols(classes, shards.len());
        Ok(ShardGroup {
            key: key.to_string(),
            classes,
            ranges,
            shards,
            swap_lock: RwLock::new(()),
            degraded: AtomicBool::new(false),
            metrics,
            opts,
        })
    }

    /// Output width discovered from the workers at connect time.
    pub fn classes(&self) -> usize {
        self.classes
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub(crate) fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// One-line topology summary for the startup banner.
    pub fn describe(&self) -> String {
        self.ranges
            .iter()
            .zip(&self.shards)
            .enumerate()
            .map(|(i, ((s, e), reps))| format!("shard {i} cols {s}..{e} x{}", reps.len()))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Scatter `batch` to one live replica per shard, gather the
    /// partials, and reassemble the full logits. Pure data movement —
    /// bit-identical to an unsharded infer of the same batch.
    pub(crate) fn scatter_gather(
        &self,
        batch: &RowBatch,
        deadline: Option<Instant>,
    ) -> std::result::Result<RowBatch, WireError> {
        let _serving = self.swap_lock.read().unwrap_or_else(|p| p.into_inner());
        if self.degraded.load(Ordering::SeqCst) {
            self.metrics
                .net_worker_unavailable
                .fetch_add(1, Ordering::Relaxed);
            return Err(WireError::new(
                ErrorCode::Unavailable,
                "shard group degraded by a failed rolling swap; retry after the \
                 next successful SWAP",
            ));
        }
        let mut parts = Vec::with_capacity(self.shards.len());
        for (i, replicas) in self.shards.iter().enumerate() {
            let (cs, ce) = self.ranges[i];
            let part = self.scatter_one(i, replicas, cs, ce, batch, deadline)?;
            parts.push((cs, ce, part));
        }
        shard::assemble(batch.rows(), self.classes, &parts)
            .map_err(|e| WireError::new(ErrorCode::Internal, e.to_string()))
    }

    /// Try each replica of one shard in order until a `PARTIAL` lands.
    fn scatter_one(
        &self,
        shard_idx: usize,
        replicas: &[Mutex<Replica>],
        col_start: u32,
        col_end: u32,
        batch: &RowBatch,
        deadline: Option<Instant>,
    ) -> std::result::Result<RowBatch, WireError> {
        let mut last: Option<WireError> = None;
        for (ri, cell) in replicas.iter().enumerate() {
            let mut r = cell.lock().unwrap_or_else(|p| p.into_inner());
            match self.try_replica(&mut r, col_start, col_end, batch, deadline) {
                Ok(part) => return Ok(part),
                Err(Attempt::Fatal(e)) => return Err(e),
                Err(Attempt::Transient(e)) => {
                    self.metrics
                        .net_worker_failures
                        .fetch_add(1, Ordering::Relaxed);
                    if ri + 1 < replicas.len() {
                        self.metrics
                            .net_worker_failovers
                            .fetch_add(1, Ordering::Relaxed);
                        crate::lrbi_log!(
                            Level::Warn,
                            "shard {shard_idx} replica {} failed ({}); failing over \
                             to the next replica",
                            r.addr,
                            e.message
                        );
                    }
                    last = Some(e);
                }
            }
        }
        self.metrics
            .net_worker_unavailable
            .fetch_add(1, Ordering::Relaxed);
        let detail = last
            .map(|e| e.message)
            .unwrap_or_else(|| "shard has no replicas".to_string());
        Err(WireError::new(
            ErrorCode::Unavailable,
            format!(
                "no replica of shard {shard_idx} (columns {col_start}..{col_end}) \
                 could serve: {detail}; retry with backoff"
            ),
        ))
    }

    /// One scatter attempt against one replica. Drops the connection on
    /// any transport or protocol surprise so the next attempt re-dials.
    fn try_replica(
        &self,
        r: &mut Replica,
        col_start: u32,
        col_end: u32,
        batch: &RowBatch,
        deadline: Option<Instant>,
    ) -> std::result::Result<RowBatch, Attempt> {
        if let Some(action) = fault::fire(FaultPoint::WorkerConnDrop) {
            fault::stall(&action);
            r.conn = None;
            return Err(Attempt::Transient(WireError::new(
                ErrorCode::Unavailable,
                format!("injected connection drop to worker {} (fault plan)", r.addr),
            )));
        }
        if r.conn.is_none() {
            match NetClient::connect_with(r.addr.as_str(), self.opts) {
                Ok(c) => r.conn = Some(c),
                Err(e) => {
                    return Err(Attempt::Transient(WireError::new(
                        ErrorCode::Unavailable,
                        format!("cannot reach worker {}: {e}", r.addr),
                    )));
                }
            }
        }
        let deadline_us = deadline.map(|d| {
            let now = Instant::now();
            if d > now {
                (d - now).as_micros().min(u64::MAX as u128) as u64
            } else {
                0
            }
        });
        self.metrics
            .net_worker_requests
            .fetch_add(1, Ordering::Relaxed);
        let started = Instant::now();
        let reply = r.conn.as_mut().expect("connected above").call(&Frame::Scatter {
            key: self.key.clone(),
            col_start,
            col_end,
            batch: batch.clone(),
            deadline_us,
        });
        match reply {
            Ok(Frame::Partial {
                col_start: got_s,
                col_end: got_e,
                batch: part,
            }) => {
                if got_s != col_start || got_e != col_end || part.rows() != batch.rows() {
                    r.conn = None;
                    return Err(Attempt::Transient(WireError::new(
                        ErrorCode::Internal,
                        format!(
                            "worker {} answered columns {got_s}..{got_e} ({} rows) to a \
                             scatter for {col_start}..{col_end} ({} rows)",
                            r.addr,
                            part.rows(),
                            batch.rows()
                        ),
                    )));
                }
                r.hist.record_since(started);
                Ok(part)
            }
            Ok(Frame::Error { code, message }) => {
                let tagged = WireError::new(code, format!("worker {}: {message}", r.addr));
                match code {
                    // The request itself is wrong (or out of time) — any
                    // replica would refuse it identically.
                    ErrorCode::BadShape
                    | ErrorCode::UnknownModel
                    | ErrorCode::DeadlineExceeded
                    | ErrorCode::BadFrame
                    | ErrorCode::BadVersion
                    | ErrorCode::TooLarge => Err(Attempt::Fatal(tagged)),
                    // Overloaded / Internal / ShuttingDown / Unavailable:
                    // this replica is struggling, another may not be.
                    _ => Err(Attempt::Transient(tagged)),
                }
            }
            Ok(other) => {
                r.conn = None;
                Err(Attempt::Transient(WireError::new(
                    ErrorCode::Internal,
                    format!(
                        "worker {} answered a scatter with an unexpected {} frame",
                        r.addr,
                        other.type_name()
                    ),
                )))
            }
            Err(e) => {
                r.conn = None;
                Err(Attempt::Transient(WireError::new(
                    ErrorCode::Unavailable,
                    format!("worker {} transport error: {e}", r.addr),
                )))
            }
        }
    }

    /// Coordinated rolling `SWAP name` across every worker replica, in
    /// fixed shard-then-replica order, exclusive with scatters. Aborts
    /// at the first refusal and degrades the group (infers answer
    /// `unavailable`) so mixed-artifact logits can never be gathered; a
    /// later swap that completes end-to-end clears the degradation.
    pub fn rolling_swap(&self, name: &str) -> Result<String> {
        let _excl = self.swap_lock.write().unwrap_or_else(|p| p.into_inner());
        let mut stepped = 0usize;
        for replicas in &self.shards {
            for cell in replicas {
                let mut r = cell.lock().unwrap_or_else(|p| p.into_inner());
                let step: Result<String> = if let Some(action) = fault::fire(FaultPoint::WorkerSwapFail)
                {
                    fault::stall(&action);
                    Err(Error::Coordinator(format!(
                        "injected swap failure at worker {} (fault plan)",
                        r.addr
                    )))
                } else {
                    self.swap_replica(&mut r, name)
                };
                match step {
                    Ok(_) => {
                        self.metrics.net_worker_swaps.fetch_add(1, Ordering::Relaxed);
                        stepped += 1;
                    }
                    Err(e) => {
                        self.metrics
                            .net_worker_swap_failures
                            .fetch_add(1, Ordering::Relaxed);
                        self.degraded.store(true, Ordering::SeqCst);
                        return Err(Error::Coordinator(format!(
                            "rolling swap of '{name}' aborted at worker {} after \
                             {stepped} completed step(s): {e}; shard group is degraded \
                             (infers answer 'unavailable') until a SWAP succeeds",
                            r.addr
                        )));
                    }
                }
            }
        }
        self.degraded.store(false, Ordering::SeqCst);
        Ok(format!(
            "rolling swap of '{name}' complete across {stepped} worker replica(s); \
             in-flight batches finished on the old artifact"
        ))
    }

    fn swap_replica(&self, r: &mut Replica, name: &str) -> Result<String> {
        if r.conn.is_none() {
            r.conn = Some(NetClient::connect_with(r.addr.as_str(), self.opts)?);
        }
        match r.conn.as_mut().expect("connected above").swap(name) {
            Ok(msg) => Ok(msg),
            Err(e) => {
                r.conn = None;
                Err(e)
            }
        }
    }
}

/// Discover a shard's output width: an empty `INFER` (0 rows, 0 cols)
/// takes the server's empty-batch fast path and echoes a `0 × classes`
/// logits frame without touching a kernel. The probe connection is
/// kept as the replica's initial connection.
fn probe_shard(replicas: &mut [Replica], key: &str, opts: &ClientOptions) -> Result<usize> {
    let mut last: Option<Error> = None;
    for r in replicas.iter_mut() {
        let attempt = (|| -> Result<usize> {
            let mut conn = NetClient::connect_with(r.addr.as_str(), *opts)?;
            let empty = RowBatch::new(0, 0, Vec::new())?;
            let logits = conn.infer(key, empty)?;
            r.conn = Some(conn);
            Ok(logits.cols())
        })();
        match attempt {
            Ok(c) => return Ok(c),
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| Error::InvalidArg("shard has no replicas".into())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_workers_splits_shards_and_replicas() {
        assert_eq!(
            parse_workers("127.0.0.1:9000").unwrap(),
            vec![vec!["127.0.0.1:9000".to_string()]]
        );
        assert_eq!(
            parse_workers("a:1,b:2,c:3").unwrap(),
            vec![
                vec!["a:1".to_string()],
                vec!["b:2".to_string()],
                vec!["c:3".to_string()],
            ]
        );
        assert_eq!(
            parse_workers(" a:1 | b:1 , c:2 ").unwrap(),
            vec![
                vec!["a:1".to_string(), "b:1".to_string()],
                vec!["c:2".to_string()],
            ]
        );
    }

    #[test]
    fn parse_workers_rejects_empty_entries() {
        assert!(parse_workers("").is_err());
        assert!(parse_workers("a:1,,b:2").is_err());
        assert!(parse_workers("|").is_err());
        assert!(parse_workers(" , ").is_err());
    }
}
