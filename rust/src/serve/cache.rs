//! Small LRU cache (no external crates offline). Used as the decode
//! cache: reconstructed masks / masked weights keyed by layer+factors
//! version, so the binary-matmul decompression runs once per update,
//! not once per request.

use std::collections::HashMap;
use std::hash::Hash;

/// LRU cache with O(1) amortised get/put (hash map + monotonic clock).
#[derive(Debug)]
pub struct LruCache<K: Eq + Hash + Clone, V> {
    cap: usize,
    clock: u64,
    map: HashMap<K, (u64, V)>,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// Cache holding at most `cap` entries (cap >= 1).
    pub fn new(cap: usize) -> Self {
        LruCache { cap: cap.max(1), clock: 0, map: HashMap::new() }
    }

    /// Get and refresh recency.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        self.clock += 1;
        let clock = self.clock;
        match self.map.get_mut(k) {
            Some((t, v)) => {
                *t = clock;
                Some(&*v)
            }
            None => None,
        }
    }

    /// Insert, evicting the least-recently-used entry if full.
    pub fn put(&mut self, k: K, v: V) {
        self.clock += 1;
        if self.map.len() >= self.cap && !self.map.contains_key(&k) {
            if let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        self.map.insert(k, (self.clock, v));
    }

    /// Current entry count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Remove one entry (used to invalidate a hot-swapped variant's
    /// kernel without disturbing the rest of the cache).
    pub fn remove(&mut self, k: &K) -> Option<V> {
        self.map.remove(k).map(|(_, v)| v)
    }

    /// Remove everything.
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_get_put() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        assert_eq!(c.get(&"a"), Some(&1));
        assert_eq!(c.get(&"z"), None);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        let _ = c.get(&"a"); // refresh a
        c.put("c", 3); // evicts b
        assert_eq!(c.get(&"b"), None);
        assert_eq!(c.get(&"a"), Some(&1));
        assert_eq!(c.get(&"c"), Some(&3));
    }

    #[test]
    fn overwrite_does_not_evict() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        c.put("a", 10);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&"a"), Some(&10));
        assert_eq!(c.get(&"b"), Some(&2));
    }

    #[test]
    fn remove_evicts_single_key() {
        let mut c = LruCache::new(3);
        c.put("a", 1);
        c.put("b", 2);
        assert_eq!(c.remove(&"a"), Some(1));
        assert_eq!(c.remove(&"a"), None);
        assert_eq!(c.get(&"b"), Some(&2));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn cap_one_works() {
        let mut c = LruCache::new(1);
        c.put(1, "x");
        c.put(2, "y");
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&2), Some(&"y"));
    }
}
