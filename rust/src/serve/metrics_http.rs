//! `--metrics-addr`: a plaintext HTTP/1.0 metrics endpoint in
//! Prometheus text exposition format, built on `std::net` only (no
//! HTTP library). One scrape = one snapshot of every counter in
//! `MetricsSnapshot::named_counters` plus every telemetry histogram
//! series, rendered as summary-style metrics
//! (`lrbi_stage_ns{stage="spmm",quantile="0.5"} …` with `_sum` and
//! `_count` companions). Exposition details and example output live in
//! `docs/OBSERVABILITY.md`.
//!
//! The server is deliberately minimal: it answers **any** request on
//! the socket with the full metrics page (a real Prometheus scraper
//! sends `GET / HTTP/1.1`; path and headers are ignored), serves one
//! connection at a time on a background thread, and holds no
//! per-connection state. Scrapes read atomics — they never lock the
//! request path.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::telemetry::SeriesSnapshot;
use crate::util::error::{Error, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Escape a label value per the Prometheus text format (`\`, `"`, and
/// newlines).
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render one histogram series as a Prometheus summary: three
/// `quantile` samples plus `_sum` and `_count`.
fn render_series(out: &mut String, s: &SeriesSnapshot) {
    let base_labels: String = s
        .labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\",", escape_label(v)))
        .collect();
    let (p50, p95, p99) = s.hist.percentiles();
    for (q, v) in [("0.5", p50), ("0.95", p95), ("0.99", p99)] {
        out.push_str(&format!(
            "lrbi_{name}{{{base_labels}quantile=\"{q}\"}} {v}\n",
            name = s.name
        ));
    }
    let plain = if base_labels.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", base_labels.trim_end_matches(','))
    };
    out.push_str(&format!("lrbi_{}_sum{plain} {}\n", s.name, s.hist.sum));
    out.push_str(&format!("lrbi_{}_count{plain} {}\n", s.name, s.hist.count));
}

/// Render the full metrics page: every named counter (as a Prometheus
/// counter) followed by every histogram series (as a summary). One
/// `# TYPE` line per distinct metric name, as the format requires.
pub fn render_prometheus(metrics: &Metrics) -> String {
    let mut out = String::with_capacity(8 * 1024);
    for (name, value) in metrics.snapshot().named_counters() {
        out.push_str(&format!("# TYPE lrbi_{name} counter\n"));
        out.push_str(&format!("lrbi_{name} {value}\n"));
    }
    let mut last_name = "";
    for series in metrics.telemetry.export() {
        if series.name != last_name {
            out.push_str(&format!("# TYPE lrbi_{} summary\n", series.name));
            last_name = series.name;
        }
        render_series(&mut out, &series);
    }
    out
}

fn answer(stream: &mut TcpStream, body: &str) -> std::io::Result<()> {
    // drain whatever request line/headers arrived (best effort — the
    // reply does not depend on them), then answer and close
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut scratch = [0u8; 1024];
    let _ = stream.read(&mut scratch);
    let head = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// A running metrics endpoint: accept loop on a background thread,
/// one page per connection. Dropping the handle (or calling
/// [`MetricsServer::stop`]) shuts it down.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9095`; port 0 picks a free port)
    /// and start serving scrapes of `metrics`.
    pub fn bind(addr: &str, metrics: Arc<Metrics>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Coordinator(format!("metrics bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Coordinator(format!("metrics local_addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("lrbi-metrics".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(mut stream) = conn else { continue };
                    let body = render_prometheus(&metrics);
                    let _ = answer(&mut stream, &body);
                }
            })
            .map_err(|e| Error::Coordinator(format!("spawn metrics thread: {e}")))?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::telemetry::Stage;

    #[test]
    fn rendered_page_is_valid_prometheus_text() {
        let metrics = Metrics::new();
        metrics.net_requests.fetch_add(3, Ordering::Relaxed);
        metrics.telemetry.record_stage(Stage::Spmm, 1_500);
        metrics.telemetry.record_spmm_kernel(3, 2_000);
        metrics.telemetry.request_histogram("default").record(9_000);
        let page = render_prometheus(&metrics);
        assert!(page.contains("# TYPE lrbi_net_requests counter\n"));
        assert!(page.contains("lrbi_net_requests 3\n"));
        assert!(page.contains("# TYPE lrbi_stage_ns summary\n"));
        assert!(page.contains("lrbi_stage_ns{stage=\"spmm\",quantile=\"0.5\"}"));
        assert!(page.contains("lrbi_stage_ns_count{stage=\"spmm\"} 1\n"));
        assert!(page.contains("lrbi_stage_ns_sum{stage=\"spmm\"} 1500\n"));
        assert!(page.contains("lrbi_spmm_ns{kernel=\"lowrank\",quantile=\"0.99\"}"));
        assert!(page.contains("lrbi_request_ns{model=\"default\",quantile=\"0.95\"}"));
        assert!(page.contains("lrbi_spmm_shard_ns_count 0\n"), "unlabeled series render bare");
        // `# TYPE` appears once per metric name, not per series
        let stage_types = page.matches("# TYPE lrbi_stage_ns summary").count();
        assert_eq!(stage_types, 1);
        // every non-comment line is `name{...} value` or `name value`
        for line in page.lines().filter(|l| !l.starts_with('#')) {
            let (series, value) = line.rsplit_once(' ').expect("sample line");
            assert!(series.starts_with("lrbi_"), "{line}");
            assert!(value.parse::<u64>().is_ok(), "{line}");
        }
    }

    #[test]
    fn label_values_are_escaped() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn endpoint_serves_a_scrape_over_http() {
        let metrics = Arc::new(Metrics::new());
        metrics.telemetry.record_stage(Stage::Decode, 777);
        let mut server = MetricsServer::bind("127.0.0.1:0", Arc::clone(&metrics)).unwrap();
        let addr = server.local_addr();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut response = String::new();
        conn.read_to_string(&mut response).unwrap();
        assert!(response.starts_with("HTTP/1.0 200 OK\r\n"), "{response}");
        assert!(response.contains("Content-Type: text/plain"), "{response}");
        let body = response.split("\r\n\r\n").nth(1).expect("header/body split");
        assert!(body.contains("lrbi_stage_ns_count{stage=\"decode\"} 1\n"), "{body}");
        // Content-Length matches the body exactly
        let clen: usize = response
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_eq!(clen, body.len());
        server.stop();
        // stop is idempotent and the port is released
        server.stop();
    }
}
