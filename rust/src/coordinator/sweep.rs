//! Model-level compression orchestration: fan per-(layer, tile) jobs
//! across the pool, aggregate into a per-layer and per-model report.
//! This is the parallel counterpart of `tiling::compress_tiled` and
//! the entry point the CLI and Table-2 bench use.

use crate::bmf::algorithm1::{algorithm1, Algorithm1Config};
use crate::coordinator::jobs::{CompressionJob, JobResult};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::parallel_map;
use crate::models::{synthetic_weights, ModelSpec};
use crate::pruning::manip::ManipMethod;
use crate::tensor::Matrix;
use crate::tiling::TilePlan;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;
use std::time::Instant;

/// How to compress a model.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Target pruning rate `S` for every compressed layer.
    pub sparsity: f64,
    /// Rank for a layer group (indexed by `LayerSpec::group`, paper
    /// direction: entry 0 applies to the *largest* group).
    pub group_ranks: Vec<usize>,
    /// Tile plan applied to layers larger than `tile_threshold`.
    pub tile_plan: TilePlan,
    /// Layers with more parameters than this get tiled.
    pub tile_threshold: usize,
    /// Magnitude manipulation.
    pub manip: ManipMethod,
    /// Worker threads.
    pub threads: usize,
    /// Algorithm-1 template (rank overwritten per job).
    pub base: Algorithm1Config,
    /// Seed for synthetic weights.
    pub seed: u64,
}

impl SweepOptions {
    /// Reasonable defaults for a model at sparsity `s`.
    pub fn new(s: f64, rank: usize) -> Self {
        SweepOptions {
            sparsity: s,
            group_ranks: vec![rank, rank, rank],
            tile_plan: TilePlan::single(),
            tile_threshold: usize::MAX,
            manip: ManipMethod::None,
            threads: crate::tensor::matrix::available_threads(),
            base: Algorithm1Config::new(rank, s),
            seed: 0x5EED,
        }
    }
}

/// Per-layer compression outcome.
#[derive(Debug)]
pub struct LayerReport {
    /// Layer name.
    pub layer: String,
    /// Dense index bits (mn).
    pub dense_bits: usize,
    /// Low-rank index bits Σ k(m+n).
    pub index_bits: usize,
    /// Achieved sparsity of the assembled mask.
    pub sparsity: f64,
    /// Total Algorithm-1 cost.
    pub cost: f64,
    /// Assembled mask.
    pub mask: BitMatrix,
    /// Number of tiles used.
    pub tiles: usize,
}

impl LayerReport {
    /// Index compression ratio for this layer.
    pub fn compression_ratio(&self) -> f64 {
        self.dense_bits as f64 / self.index_bits as f64
    }
}

/// Whole-model compression outcome.
#[derive(Debug)]
pub struct ModelCompressionReport {
    /// Model name.
    pub model: String,
    /// Per compressed layer.
    pub layers: Vec<LayerReport>,
    /// Job-level results (diagnostics).
    pub jobs: Vec<JobResult>,
}

impl ModelCompressionReport {
    /// Aggregate compression ratio over compressed layers.
    pub fn compression_ratio(&self) -> f64 {
        let dense: usize = self.layers.iter().map(|l| l.dense_bits).sum();
        let lr: usize = self.layers.iter().map(|l| l.index_bits).sum();
        dense as f64 / lr as f64
    }

    /// Weighted mean sparsity across compressed layers.
    pub fn sparsity(&self) -> f64 {
        let total: usize = self.layers.iter().map(|l| l.dense_bits).sum();
        if total == 0 {
            return 0.0;
        }
        self.layers
            .iter()
            .map(|l| l.sparsity * l.dense_bits as f64)
            .sum::<f64>()
            / total as f64
    }

    /// Total Cost.
    pub fn cost(&self) -> f64 {
        self.layers.iter().map(|l| l.cost).sum()
    }
}

/// Compress every compressible layer of `model` (synthetic pretrained
/// weights) in parallel. The returned report drives Tables 2 and 4.
pub fn compress_model(
    model: &ModelSpec,
    opts: &SweepOptions,
    metrics: &Metrics,
) -> Result<ModelCompressionReport> {
    // materialise weights + jobs
    let mut rng = Rng::new(opts.seed);
    let mut layer_inputs: Vec<(String, Matrix, TilePlan, usize)> = Vec::new();
    for spec in model.compressible() {
        let w = synthetic_weights(spec, &mut rng);
        let plan = if spec.params() > opts.tile_threshold {
            opts.tile_plan
        } else {
            TilePlan::single()
        };
        let group = spec.group.min(opts.group_ranks.len() - 1);
        // paper direction: ranks[0] -> largest group (see models::resnet32)
        let rank = opts.group_ranks[opts.group_ranks.len() - 1 - group];
        layer_inputs.push((spec.name.clone(), w, plan, rank));
    }

    // flatten to (layer idx, tile spec) jobs
    let mut jobs: Vec<(usize, CompressionJob)> = Vec::new();
    for (li, (name, w, plan, rank)) in layer_inputs.iter().enumerate() {
        for tile in plan.tiles(w.rows(), w.cols())? {
            jobs.push((
                li,
                CompressionJob {
                    model: model.name.clone(),
                    layer: name.clone(),
                    tile,
                    rank: *rank,
                    sparsity: opts.sparsity,
                    manip: opts.manip,
                },
            ));
        }
    }

    // run the bag in parallel
    let results: Vec<JobResult> = parallel_map(&jobs, opts.threads, |(li, job)| {
        let started = Instant::now();
        let (_, w, _, _) = &layer_inputs[*li];
        let sub = w
            .submatrix(job.tile.r0, job.tile.r1, job.tile.c0, job.tile.c1)
            .expect("tile within bounds");
        let mut cfg = opts.base.clone();
        cfg.rank = job.rank;
        cfg.nmf.rank = job.rank;
        cfg.target_sparsity = job.sparsity;
        cfg.manip = job.manip;
        cfg.nmf.seed = opts.seed ^ (job.tile.id as u64).wrapping_mul(0x9E37_79B9);
        let out = algorithm1(&sub, &cfg);
        let elapsed_ns = started.elapsed().as_nanos() as u64;
        metrics.record_job(started, out.is_ok());
        match out {
            Ok(index) => JobResult { job: job.clone(), index: Some(index), error: None, elapsed_ns },
            Err(e) => JobResult {
                job: job.clone(),
                index: None,
                error: Some(e.to_string()),
                elapsed_ns,
            },
        }
    });

    // aggregate per layer
    let mut layers = Vec::new();
    for (li, (name, w, plan, _)) in layer_inputs.iter().enumerate() {
        let mut mask = BitMatrix::zeros(w.rows(), w.cols());
        let mut index_bits = 0usize;
        let mut cost = 0.0f64;
        let mut tiles = 0usize;
        for ((job_li, _), result) in jobs.iter().zip(&results) {
            if job_li != &li {
                continue;
            }
            let f = result.index.as_ref().ok_or_else(|| {
                Error::Coordinator(format!(
                    "job failed for layer {name}: {}",
                    result.error.as_deref().unwrap_or("unknown")
                ))
            })?;
            let t = result.job.tile;
            for i in 0..t.rows() {
                for j in 0..t.cols() {
                    if f.mask.get(i, j) {
                        mask.set(t.r0 + i, t.c0 + j, true);
                    }
                }
            }
            index_bits += f.index_bits();
            cost += f.cost;
            tiles += 1;
        }
        let _ = plan;
        layers.push(LayerReport {
            layer: name.clone(),
            dense_bits: w.rows() * w.cols(),
            index_bits,
            sparsity: mask.sparsity(),
            cost,
            mask,
            tiles,
        });
    }

    Ok(ModelCompressionReport { model: model.name.clone(), layers, jobs: results })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{LayerKind, LayerSpec};

    fn tiny_model() -> ModelSpec {
        ModelSpec {
            name: "tiny".into(),
            layers: vec![
                LayerSpec {
                    name: "a".into(),
                    rows: 40,
                    cols: 30,
                    kind: LayerKind::Fc,
                    group: 0,
                    compress: true,
                },
                LayerSpec {
                    name: "b".into(),
                    rows: 60,
                    cols: 20,
                    kind: LayerKind::Fc,
                    group: 1,
                    compress: true,
                },
                LayerSpec {
                    name: "skip".into(),
                    rows: 5,
                    cols: 5,
                    kind: LayerKind::Fc,
                    group: 0,
                    compress: false,
                },
            ],
        }
    }

    fn fast_opts() -> SweepOptions {
        let mut o = SweepOptions::new(0.85, 4);
        o.base.sp_grid = vec![0.3, 0.6];
        o.base.nmf.max_iters = 12;
        o.threads = 4;
        o
    }

    #[test]
    fn compresses_only_compressible_layers() {
        let m = tiny_model();
        let metrics = Metrics::new();
        let rep = compress_model(&m, &fast_opts(), &metrics).unwrap();
        let names: Vec<_> = rep.layers.iter().map(|l| l.layer.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
        assert_eq!(metrics.snapshot().jobs_done, 2);
    }

    #[test]
    fn report_math_consistent() {
        let m = tiny_model();
        let rep = compress_model(&m, &fast_opts(), &Metrics::new()).unwrap();
        for l in &rep.layers {
            assert!((l.sparsity - 0.85).abs() < 0.05, "{}: {}", l.layer, l.sparsity);
            assert!(l.compression_ratio() > 1.0);
        }
        assert!(rep.compression_ratio() > 1.0);
        assert!(rep.sparsity() > 0.8);
    }

    #[test]
    fn tiling_kicks_in_above_threshold() {
        let m = tiny_model();
        let mut o = fast_opts();
        o.tile_plan = TilePlan::new(2, 2);
        o.tile_threshold = 1000; // layer a (1200) and b (1200) both tile
        let rep = compress_model(&m, &o, &Metrics::new()).unwrap();
        assert!(rep.layers.iter().all(|l| l.tiles == 4));
        assert_eq!(rep.jobs.len(), 8);
    }

    #[test]
    fn deterministic_given_seed() {
        let m = tiny_model();
        let r1 = compress_model(&m, &fast_opts(), &Metrics::new()).unwrap();
        let r2 = compress_model(&m, &fast_opts(), &Metrics::new()).unwrap();
        for (a, b) in r1.layers.iter().zip(&r2.layers) {
            assert_eq!(a.mask, b.mask);
            assert_eq!(a.cost, b.cost);
        }
    }
}
