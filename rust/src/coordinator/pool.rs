//! Worker pool + data-parallel map (the substrate tokio would have
//! provided). Bounded injection queue gives backpressure: submitters
//! block when workers fall behind. [`ExecCtx`] packages a thread
//! budget plus a pool into the shared execution context the sparse
//! kernels' `SpmmPlan`s run their shards on.

use crate::coordinator::metrics::Metrics;
use crate::util::error::{Error, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool with a bounded job queue.
pub struct WorkerPool {
    tx: Option<mpsc::SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn `n` workers with a queue bound of `queue_cap` jobs.
    pub fn new(n: usize, queue_cap: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Job>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("lrbi-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool lock poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                queued.fetch_sub(1, Ordering::Relaxed);
                                // A panicking job must not kill the
                                // worker: the pool would silently lose
                                // capacity. Jobs that need the panic
                                // reported (e.g. run_indexed shards)
                                // catch and forward it themselves.
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { tx: Some(tx), workers, queued }
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .ok_or_else(|| Error::Coordinator("pool is shut down".into()))?
            .send(Box::new(job))
            .map_err(|_| {
                self.queued.fetch_sub(1, Ordering::Relaxed);
                Error::Coordinator("worker pool closed".into())
            })
    }

    /// Jobs submitted but not yet started.
    pub fn queue_depth(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Execute `f(0)`, `f(1)`, …, `f(n-1)` on the pool, blocking until
    /// every shard has finished. Panics inside `f` are caught on the
    /// worker (which survives) and surfaced as
    /// [`Error::Coordinator`] — *after* every other shard completed,
    /// so borrowed data is never left aliased by a still-running job.
    /// The naive wiring (submit + wait on per-job results) would hang
    /// forever on a panicking job's never-sent result; the
    /// catch-unwind + send-always protocol here is what makes a
    /// poisoned shard fail the call instead of deadlocking it.
    ///
    /// Must not be called from inside a pool job (the nested wait
    /// could starve the queue).
    pub fn run_indexed(&self, n: usize, f: &(dyn Fn(usize) + Sync)) -> Result<()> {
        let (tx, rx) = mpsc::channel::<std::thread::Result<()>>();
        // SAFETY: every submitted job sends exactly one result (the
        // catch_unwind guarantees the send runs even when `f` panics),
        // and we receive all of them below before returning — so no
        // job can outlive this call's borrow of `f`.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let mut submitted = 0usize;
        let mut first_err: Option<Error> = None;
        for i in 0..n {
            let tx = tx.clone();
            let res = self.submit(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f_static(i)));
                let _ = tx.send(r);
            });
            match res {
                Ok(()) => submitted += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        drop(tx);
        for _ in 0..submitted {
            match rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(payload)) => {
                    if first_err.is_none() {
                        let msg = payload
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "<non-string panic>".into());
                        first_err =
                            Some(Error::Coordinator(format!("parallel shard panicked: {msg}")));
                    }
                }
                // Unreachable while jobs hold sender clones; treat a
                // closed channel as a missing result.
                Err(_) => {
                    if first_err.is_none() {
                        first_err =
                            Some(Error::Coordinator("parallel shard result lost".into()));
                    }
                    break;
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers drain then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Shared execution context for the sparse kernels' `SpmmPlan`s: a
/// thread budget plus the [`WorkerPool`] that executes plan shards,
/// with optional [`Metrics`] so every plan execution lands in
/// `spmm_shards` / per-kernel nanosecond counters.
///
/// One `ExecCtx` is shared (via `Arc`) by every kernel a backend or
/// variant server builds, so all of them draw from one pool instead
/// of spawning per-call threads. `threads == 1` (the
/// [`ExecCtx::single`] default, and the default of every pre-existing
/// constructor) carries no pool and executes shards inline — plan
/// *structure* never depends on the context, only on the index, which
/// is what makes output bit-identical across thread counts.
pub struct ExecCtx {
    threads: usize,
    pool: Option<WorkerPool>,
    metrics: Option<Arc<Metrics>>,
    /// Recycled `f32` work buffers (SpMM partials, input transposes)
    /// checked out by [`ExecCtx::take_scratch`] — the context-level
    /// half of the serving path's zero-allocation steady state.
    scratch: Mutex<Vec<Vec<f32>>>,
    /// Nanoseconds spent merging reduction-shard partials since the
    /// last [`ExecCtx::take_last_merge_ns`] — accumulated by
    /// [`ExecCtx::record_merge`] from inside plan execution, drained
    /// by the serving engine into the `merge` stage histogram (the
    /// plans can't record the stage directly without double counting
    /// when a batch runs several layers).
    last_merge_ns: AtomicU64,
}

/// Cap on pooled scratch buffers per context: enough for every
/// concurrent buffer a plan execution checks out, small enough that a
/// burst of odd sizes cannot hoard memory.
const SCRATCH_POOL_CAP: usize = 8;

impl ExecCtx {
    /// Single-threaded context (no pool): shards run inline, in order.
    pub fn single() -> Arc<ExecCtx> {
        Arc::new(ExecCtx {
            threads: 1,
            pool: None,
            metrics: None,
            scratch: Mutex::new(Vec::new()),
            last_merge_ns: AtomicU64::new(0),
        })
    }

    /// Context with `threads` workers (clamped to ≥ 1; 1 means no
    /// pool). `metrics`, when given, receives `spmm_shards` and
    /// per-kernel spmm nanoseconds from every plan execution, plus the
    /// scratch-pool pair `spmm_alloc_bytes` / `scratch_reuse`.
    pub fn new(threads: usize, metrics: Option<Arc<Metrics>>) -> Arc<ExecCtx> {
        let threads = threads.max(1);
        let pool = (threads > 1).then(|| WorkerPool::new(threads, threads * 4));
        Arc::new(ExecCtx {
            threads,
            pool,
            metrics,
            scratch: Mutex::new(Vec::new()),
            last_merge_ns: AtomicU64::new(0),
        })
    }

    /// Check out a zeroed `len`-element work buffer, reusing a pooled
    /// allocation when one is large enough (best fit; falls back to
    /// growing the largest available). Return it with
    /// [`ExecCtx::put_scratch`] when done — after one warm-up
    /// execution per buffer shape, every subsequent `spmm` on this
    /// context is served entirely from the pool. With metrics
    /// attached, a satisfied checkout counts into
    /// `Metrics::scratch_reuse` and a growing one adds the fresh bytes
    /// to `Metrics::spmm_alloc_bytes` — the observable proof that the
    /// steady state allocates nothing (see `docs/PERFORMANCE.md`).
    pub fn take_scratch(&self, len: usize) -> Vec<f32> {
        let mut buf = self.checkout(len);
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// [`ExecCtx::take_scratch`] without the zero fill: the buffer has
    /// `len` elements but stale ones keep their previous contents —
    /// for checkouts the caller **fully overwrites** before reading
    /// (the SpMM input transposes), where the memset would be pure
    /// waste. Reduction partials must use the zeroed variant.
    pub fn take_scratch_uninit(&self, len: usize) -> Vec<f32> {
        let mut buf = self.checkout(len);
        // grow (zero-filling only the gap) or truncate to len; the
        // retained prefix is stale on purpose.
        buf.resize(len, 0.0);
        buf
    }

    /// Pop the best-fitting pooled buffer (smallest adequate capacity,
    /// else the largest available) and record the reuse/alloc metrics
    /// pair for a `len`-element checkout.
    fn checkout(&self, len: usize) -> Vec<f32> {
        let mut pool = self.scratch.lock().unwrap_or_else(|p| p.into_inner());
        let pos = pool
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= len)
            .min_by_key(|(_, b)| b.capacity())
            .or_else(|| pool.iter().enumerate().max_by_key(|(_, b)| b.capacity()))
            .map(|(i, _)| i);
        let buf = pos.map(|i| pool.swap_remove(i)).unwrap_or_default();
        drop(pool);
        if let Some(m) = &self.metrics {
            if len > 0 {
                if buf.capacity() >= len {
                    m.scratch_reuse.fetch_add(1, Ordering::Relaxed);
                } else {
                    m.spmm_alloc_bytes
                        .fetch_add((len * std::mem::size_of::<f32>()) as u64, Ordering::Relaxed);
                }
            }
        }
        buf
    }

    /// Return a buffer taken with [`ExecCtx::take_scratch`] to the
    /// pool (dropped silently once the pool is full or the buffer
    /// never allocated).
    pub fn put_scratch(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut pool = self.scratch.lock().unwrap_or_else(|p| p.into_inner());
        if pool.len() < SCRATCH_POOL_CAP {
            pool.push(buf);
        }
    }

    /// Configured worker count (1 = inline execution).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` over shard indices `0..shards`: inline when
    /// single-threaded (or when there is nothing to fan out), on the
    /// pool otherwise. Shard panics on the pool surface as
    /// [`Error::Coordinator`]; inline panics propagate normally.
    ///
    /// With metrics attached, each shard's wall time lands in the
    /// `spmm_shard_ns` histogram, and multi-shard runs additionally
    /// record the shard-imbalance gauge (`spmm_imbalance_pm`:
    /// `max_shard_ns / mean_shard_ns` in per-mille, 1000 = balanced)
    /// — the profiling signal the planned autotuner keys on. Timing
    /// is atomics-only: no allocation, no change to how `f` runs, so
    /// plan outputs stay bit-identical with telemetry on.
    pub fn run(&self, shards: usize, f: impl Fn(usize) + Sync) -> Result<()> {
        let Some(m) = &self.metrics else {
            return self.run_inner(shards, &f);
        };
        let max_ns = AtomicU64::new(0);
        let sum_ns = AtomicU64::new(0);
        let shard_hist = m.telemetry.shard();
        let timed = |s: usize| {
            let t0 = Instant::now();
            f(s);
            let ns = shard_hist.record_since(t0);
            max_ns.fetch_max(ns, Ordering::Relaxed);
            sum_ns.fetch_add(ns, Ordering::Relaxed);
        };
        let res = self.run_inner(shards, &timed);
        let sum = sum_ns.load(Ordering::Relaxed);
        if shards > 1 && sum > 0 {
            // max/mean in per-mille; u128 keeps ns * shards * 1000
            // from overflowing
            let pm = max_ns.load(Ordering::Relaxed) as u128 * shards as u128 * 1000
                / sum as u128;
            m.telemetry.imbalance().record(pm as u64);
        }
        res
    }

    fn run_inner(&self, shards: usize, f: &(dyn Fn(usize) + Sync)) -> Result<()> {
        match &self.pool {
            Some(pool) if shards > 1 => {
                // Chaos hooks (no-ops unless a fault plan is live; see
                // util::fault). Only shard 0 consults the plan, so one
                // plan execution consumes exactly one hit ordinal, and
                // only pooled runs inject the panic — run_indexed's
                // unwind fence turns it into a typed coordinator error
                // instead of killing the calling thread.
                let faulted = |s: usize| {
                    if s == 0 {
                        use crate::util::fault::{self, FaultPoint};
                        if let Some(a) = fault::fire(FaultPoint::SlowShard) {
                            fault::stall(&a);
                        }
                        if fault::fire(FaultPoint::ShardPanic).is_some() {
                            panic!("injected shard panic (fault plan)");
                        }
                    }
                    f(s);
                };
                pool.run_indexed(shards, &faulted)
            }
            _ => {
                for s in 0..shards {
                    f(s);
                }
                Ok(())
            }
        }
    }

    /// Record one plan-based spmm execution: `shards` into
    /// `Metrics::spmm_shards`, elapsed time into the per-kernel
    /// `spmm_ns{kernel=...}` histogram (slot order is
    /// `SPMM_KERNEL_NAMES`; out-of-range slots are ignored). No-op
    /// without attached metrics.
    pub fn record_plan_spmm(&self, slot: usize, shards: u64, started: Instant) {
        if let Some(m) = &self.metrics {
            m.spmm_shards.fetch_add(shards, Ordering::Relaxed);
            m.telemetry
                .record_spmm_kernel(slot, started.elapsed().as_nanos() as u64);
        }
    }

    /// Accumulate partial-merge time from inside a plan execution
    /// (reduction-sharded plans call this around `merge_partials`).
    /// Drained by [`ExecCtx::take_last_merge_ns`].
    pub fn record_merge(&self, started: Instant) {
        self.last_merge_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Drain the merge nanoseconds accumulated since the last call —
    /// the serving engine takes this once per batch and records it as
    /// the `merge` stage.
    pub fn take_last_merge_ns(&self) -> u64 {
        self.last_merge_ns.swap(0, Ordering::Relaxed)
    }
}

/// Deterministic data-parallel map over an indexable work list using
/// scoped threads and an atomic cursor (work stealing by index).
/// Results come back in input order regardless of completion order.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let cursor = AtomicUsize::new(0);
    let out_ptr = SliceCell(out.as_mut_ptr());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: each index i is claimed by exactly one thread
                // via the atomic cursor, and `out` outlives the scope.
                unsafe { out_ptr.write(i, r) };
            });
        }
    });
    out.into_iter().map(|o| o.expect("all indices written")).collect()
}

/// Send/Sync wrapper for disjoint writes into a results buffer.
struct SliceCell<R>(*mut Option<R>);
unsafe impl<R: Send> Send for SliceCell<R> {}
unsafe impl<R: Send> Sync for SliceCell<R> {}
impl<R> SliceCell<R> {
    unsafe fn write(&self, i: usize, v: R) {
        unsafe { *self.0.add(i) = Some(v) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = WorkerPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn backpressure_blocks_then_drains() {
        let pool = WorkerPool::new(1, 2);
        let gate = Arc::new(Mutex::new(()));
        let guard = gate.lock().unwrap();
        // first job blocks the single worker on the gate
        {
            let gate = Arc::clone(&gate);
            pool.submit(move || {
                let _g = gate.lock().unwrap();
            })
            .unwrap();
        }
        std::thread::sleep(Duration::from_millis(20));
        // queue up more; capacity 2 means these fit, depth grows
        pool.submit(|| {}).unwrap();
        pool.submit(|| {}).unwrap();
        assert!(pool.queue_depth() >= 2);
        drop(guard);
        drop(pool);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..500).collect();
        let got = parallel_map(&items, 8, |&x| x * x);
        let want: Vec<u64> = items.iter().map(|&x| x * x).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn parallel_map_more_threads_than_items() {
        let items = vec![1u32, 2, 3];
        assert_eq!(parallel_map(&items, 64, |&x| x), items);
    }

    #[test]
    fn run_indexed_executes_all_shards() {
        let pool = WorkerPool::new(4, 16);
        let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
        pool.run_indexed(37, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn poisoned_shard_fails_the_call_instead_of_deadlocking() {
        let pool = WorkerPool::new(2, 8);
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        let err = pool
            .run_indexed(8, &move |i| {
                if i == 3 {
                    panic!("shard {i} is poisoned");
                }
                d.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        assert_eq!(done.load(Ordering::Relaxed), 7, "other shards still ran");
        // the pool survives: workers caught the unwind and keep serving
        let ok = Arc::new(AtomicU64::new(0));
        let o = Arc::clone(&ok);
        pool.run_indexed(4, &move |_| {
            o.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(ok.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn exec_ctx_runs_inline_and_pooled() {
        for ctx in [ExecCtx::single(), ExecCtx::new(3, None)] {
            let hits: Vec<AtomicU64> = (0..11).map(|_| AtomicU64::new(0)).collect();
            ctx.run(11, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
        assert_eq!(ExecCtx::single().threads(), 1);
        assert_eq!(ExecCtx::new(0, None).threads(), 1, "clamped to >= 1");
        assert_eq!(ExecCtx::new(4, None).threads(), 4);
    }

    #[test]
    fn scratch_pool_reuses_buffers_and_records_the_alloc_pair() {
        let metrics = Arc::new(Metrics::new());
        let ctx = ExecCtx::new(1, Some(Arc::clone(&metrics)));
        // cold: both checkouts allocate
        let a = ctx.take_scratch(1000);
        let b = ctx.take_scratch(500);
        assert!(a.iter().all(|&v| v == 0.0) && a.len() == 1000);
        ctx.put_scratch(a);
        ctx.put_scratch(b);
        let cold = metrics.snapshot();
        assert_eq!(cold.spmm_alloc_bytes, 1500 * 4);
        assert_eq!(cold.scratch_reuse, 0);
        // warm: the same shapes are served from the pool, best fit
        // keeps the big buffer for the big request
        let b = ctx.take_scratch(500);
        let a = ctx.take_scratch(1000);
        assert_eq!((a.len(), b.len()), (1000, 500));
        ctx.put_scratch(a);
        ctx.put_scratch(b);
        let warm = metrics.snapshot();
        assert_eq!(warm.spmm_alloc_bytes, cold.spmm_alloc_bytes, "warm takes must not allocate");
        assert_eq!(warm.scratch_reuse, 2);
        // zero-length checkouts are free and uncounted
        let z = ctx.take_scratch(0);
        assert!(z.is_empty());
        ctx.put_scratch(z);
        assert_eq!(metrics.snapshot().scratch_reuse, 2);
    }

    #[test]
    fn scratch_pool_without_metrics_still_pools() {
        let ctx = ExecCtx::single();
        let a = ctx.take_scratch(64);
        let ptr = a.as_ptr();
        ctx.put_scratch(a);
        let b = ctx.take_scratch(64);
        assert_eq!(b.as_ptr(), ptr, "same allocation must come back");
        ctx.put_scratch(b);
    }

    #[test]
    fn exec_ctx_records_plan_metrics() {
        let metrics = Arc::new(Metrics::new());
        let ctx = ExecCtx::new(2, Some(Arc::clone(&metrics)));
        let t0 = Instant::now();
        ctx.run(6, |_| {}).unwrap();
        ctx.record_plan_spmm(1, 6, t0);
        let snap = metrics.snapshot();
        assert_eq!(snap.spmm_shards, 6);
        assert!(snap.spmm_kernel_ns[1] > 0);
        assert_eq!(snap.spmm_kernel_ns[0], 0);
        // out-of-range slot is ignored, shards still counted
        ctx.record_plan_spmm(99, 1, Instant::now());
        assert_eq!(metrics.snapshot().spmm_shards, 7);
    }

    #[test]
    fn run_times_shards_and_records_imbalance() {
        use crate::coordinator::telemetry::Stage;
        let metrics = Arc::new(Metrics::new());
        for ctx in [
            ExecCtx::new(1, Some(Arc::clone(&metrics))),
            ExecCtx::new(3, Some(Arc::clone(&metrics))),
        ] {
            ctx.run(5, |_| std::hint::black_box(())).unwrap();
        }
        let t = &metrics.telemetry;
        assert_eq!(t.shard().count(), 10, "every shard of both runs timed");
        assert_eq!(t.imbalance().count(), 2, "one gauge sample per multi-shard run");
        // per-mille ratio max/mean is >= 1000 by construction (mean is
        // exact — the quantiles are bucket midpoints)
        assert!(t.imbalance().snapshot().mean() >= 1000.0);
        // single-shard runs time the shard but skip the gauge
        let before = t.imbalance().count();
        ExecCtx::new(1, Some(Arc::clone(&metrics))).run(1, |_| {}).unwrap();
        assert_eq!(t.imbalance().count(), before);
        assert_eq!(t.stage(Stage::Merge).count(), 0, "run() itself never records stages");
        // without metrics, run() stays untimed and works
        ExecCtx::new(2, None).run(4, |_| {}).unwrap();
        assert_eq!(t.shard().count(), 11);
    }

    #[test]
    fn injected_shard_faults_degrade_gracefully() {
        use crate::util::fault::{self, FaultPlan};
        let _g = fault::test_guard();

        // slow shard: the run completes correctly, just later
        fault::install(FaultPlan::parse("slow_shard=1:5").unwrap());
        let ctx = ExecCtx::new(3, None);
        let hits: Vec<AtomicU64> = (0..6).map(|_| AtomicU64::new(0)).collect();
        ctx.run(6, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));

        // shard panic: typed coordinator error, pool survives
        fault::install(FaultPlan::parse("shard_panic=1").unwrap());
        let err = ctx.run(6, |_| {}).unwrap_err();
        assert!(
            err.to_string().contains("injected shard panic"),
            "want the fault surfaced as a typed error, got: {err}"
        );
        // hit 1 was consumed; the next run is clean on the same pool
        let ok: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        ctx.run(4, |i| {
            ok[i].fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert!(ok.iter().all(|h| h.load(Ordering::Relaxed) == 1));

        // inline contexts never consult the shard fault points
        fault::install(FaultPlan::parse("shard_panic=1+*").unwrap());
        ExecCtx::single().run(3, |_| {}).unwrap();
        fault::clear();
    }

    #[test]
    fn merge_ns_accumulates_then_drains() {
        let ctx = ExecCtx::single();
        assert_eq!(ctx.take_last_merge_ns(), 0);
        ctx.record_merge(Instant::now());
        ctx.record_merge(Instant::now());
        let drained = ctx.take_last_merge_ns();
        assert!(drained > 0, "two merges accumulated");
        assert_eq!(ctx.take_last_merge_ns(), 0, "drain resets");
    }
}
