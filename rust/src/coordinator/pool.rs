//! Worker pool + data-parallel map (the substrate tokio would have
//! provided). Bounded injection queue gives backpressure: submitters
//! block when workers fall behind.

use crate::util::error::{Error, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool with a bounded job queue.
pub struct WorkerPool {
    tx: Option<mpsc::SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn `n` workers with a queue bound of `queue_cap` jobs.
    pub fn new(n: usize, queue_cap: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Job>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("lrbi-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool lock poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                queued.fetch_sub(1, Ordering::Relaxed);
                                job();
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { tx: Some(tx), workers, queued }
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .ok_or_else(|| Error::Coordinator("pool is shut down".into()))?
            .send(Box::new(job))
            .map_err(|_| {
                self.queued.fetch_sub(1, Ordering::Relaxed);
                Error::Coordinator("worker pool closed".into())
            })
    }

    /// Jobs submitted but not yet started.
    pub fn queue_depth(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers drain then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Deterministic data-parallel map over an indexable work list using
/// scoped threads and an atomic cursor (work stealing by index).
/// Results come back in input order regardless of completion order.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let cursor = AtomicUsize::new(0);
    let out_ptr = SliceCell(out.as_mut_ptr());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: each index i is claimed by exactly one thread
                // via the atomic cursor, and `out` outlives the scope.
                unsafe { out_ptr.write(i, r) };
            });
        }
    });
    out.into_iter().map(|o| o.expect("all indices written")).collect()
}

/// Send/Sync wrapper for disjoint writes into a results buffer.
struct SliceCell<R>(*mut Option<R>);
unsafe impl<R: Send> Send for SliceCell<R> {}
unsafe impl<R: Send> Sync for SliceCell<R> {}
impl<R> SliceCell<R> {
    unsafe fn write(&self, i: usize, v: R) {
        unsafe { *self.0.add(i) = Some(v) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = WorkerPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn backpressure_blocks_then_drains() {
        let pool = WorkerPool::new(1, 2);
        let gate = Arc::new(Mutex::new(()));
        let guard = gate.lock().unwrap();
        // first job blocks the single worker on the gate
        {
            let gate = Arc::clone(&gate);
            pool.submit(move || {
                let _g = gate.lock().unwrap();
            })
            .unwrap();
        }
        std::thread::sleep(Duration::from_millis(20));
        // queue up more; capacity 2 means these fit, depth grows
        pool.submit(|| {}).unwrap();
        pool.submit(|| {}).unwrap();
        assert!(pool.queue_depth() >= 2);
        drop(guard);
        drop(pool);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..500).collect();
        let got = parallel_map(&items, 8, |&x| x * x);
        let want: Vec<u64> = items.iter().map(|&x| x * x).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn parallel_map_more_threads_than_items() {
        let items = vec![1u32, 2, 3];
        assert_eq!(parallel_map(&items, 64, |&x| x), items);
    }
}
