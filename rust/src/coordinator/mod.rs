//! L3 coordinator — the system half of the paper's contribution.
//!
//! Index compression is a bag of independent `(layer, tile, rank)`
//! factorization jobs with a cheap argmin reduce; serving is a stream
//! of requests over compressed weights. The coordinator owns both:
//! a work-stealing worker pool (no tokio offline), bounded queues with
//! backpressure, deterministic aggregation, and metrics.

pub mod jobs;
pub mod metrics;
pub mod pool;
pub mod sweep;
pub mod telemetry;

pub use jobs::{CompressionJob, JobResult};
pub use metrics::Metrics;
pub use pool::{parallel_map, ExecCtx, WorkerPool};
pub use telemetry::{
    HistogramSnapshot, LatencyHistogram, MetricRegistry, SeriesSnapshot, Stage, StageNanos,
    Telemetry, STAGE_NAMES,
};
pub use sweep::{compress_model, ModelCompressionReport, SweepOptions};
