//! Lightweight metrics: atomic counters + wall-time accounting,
//! snapshotted by the CLI/report layer.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Shared coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs completed.
    pub jobs_done: AtomicU64,
    /// Jobs failed.
    pub jobs_failed: AtomicU64,
    /// Cumulative busy nanoseconds across workers.
    pub busy_ns: AtomicU64,
    /// Requests served (serving path).
    pub requests: AtomicU64,
    /// Batches executed (serving path).
    pub batches: AtomicU64,
    /// Decode-cache hits.
    pub cache_hits: AtomicU64,
    /// Decode-cache misses.
    pub cache_misses: AtomicU64,
}

/// A point-in-time copy for reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Jobs completed.
    pub jobs_done: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Cumulative busy nanoseconds.
    pub busy_ns: u64,
    /// Requests served.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Decode-cache hits.
    pub cache_hits: u64,
    /// Decode-cache misses.
    pub cache_misses: u64,
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed job with its busy time.
    pub fn record_job(&self, started: Instant, ok: bool) {
        if ok {
            self.jobs_done.fetch_add(1, Ordering::Relaxed);
        } else {
            self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        }
        self.busy_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Copy out current values.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_done: self.jobs_done.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
        }
    }
}

impl MetricsSnapshot {
    /// Mean requests per batch (serving efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Decode-cache hit rate.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::new();
        m.record_job(Instant::now(), true);
        m.record_job(Instant::now(), false);
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.batches.fetch_add(4, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.jobs_done, 1);
        assert_eq!(s.jobs_failed, 1);
        assert!((s.mean_batch_size() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn hit_rate_handles_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.cache_hit_rate(), 0.0);
        assert_eq!(s.mean_batch_size(), 0.0);
    }
}
