//! Lightweight metrics: atomic counters + wall-time accounting,
//! snapshotted by the CLI/report layer.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Shared coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs completed.
    pub jobs_done: AtomicU64,
    /// Jobs failed.
    pub jobs_failed: AtomicU64,
    /// Cumulative busy nanoseconds across workers.
    pub busy_ns: AtomicU64,
    /// Requests served (serving path).
    pub requests: AtomicU64,
    /// Batches executed (serving path).
    pub batches: AtomicU64,
    /// Decode-cache hits.
    pub cache_hits: AtomicU64,
    /// Decode-cache misses.
    pub cache_misses: AtomicU64,
    /// Sparse-kernel builds (per-format decode/encode of the index).
    pub kernel_decodes: AtomicU64,
    /// Nanoseconds spent building sparse kernels.
    pub kernel_decode_ns: AtomicU64,
    /// Sparse-kernel `spmm` invocations (masked-layer matmuls).
    pub kernel_spmms: AtomicU64,
    /// Nanoseconds spent inside sparse-kernel `spmm`.
    pub kernel_spmm_ns: AtomicU64,
    /// `.lrbi` artifacts loaded from disk (read + CRC + decode).
    pub artifact_loads: AtomicU64,
    /// Nanoseconds spent loading artifacts.
    pub artifact_load_ns: AtomicU64,
    /// Variant hot-swaps applied to a running server.
    pub hot_swaps: AtomicU64,
}

/// A point-in-time copy for reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Jobs completed.
    pub jobs_done: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Cumulative busy nanoseconds.
    pub busy_ns: u64,
    /// Requests served.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Decode-cache hits.
    pub cache_hits: u64,
    /// Decode-cache misses.
    pub cache_misses: u64,
    /// Sparse-kernel builds.
    pub kernel_decodes: u64,
    /// Nanoseconds building sparse kernels.
    pub kernel_decode_ns: u64,
    /// Sparse-kernel `spmm` invocations.
    pub kernel_spmms: u64,
    /// Nanoseconds inside sparse-kernel `spmm`.
    pub kernel_spmm_ns: u64,
    /// `.lrbi` artifacts loaded from disk.
    pub artifact_loads: u64,
    /// Nanoseconds loading artifacts.
    pub artifact_load_ns: u64,
    /// Variant hot-swaps applied.
    pub hot_swaps: u64,
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed job with its busy time.
    pub fn record_job(&self, started: Instant, ok: bool) {
        if ok {
            self.jobs_done.fetch_add(1, Ordering::Relaxed);
        } else {
            self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        }
        self.busy_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Copy out current values.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_done: self.jobs_done.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            kernel_decodes: self.kernel_decodes.load(Ordering::Relaxed),
            kernel_decode_ns: self.kernel_decode_ns.load(Ordering::Relaxed),
            kernel_spmms: self.kernel_spmms.load(Ordering::Relaxed),
            kernel_spmm_ns: self.kernel_spmm_ns.load(Ordering::Relaxed),
            artifact_loads: self.artifact_loads.load(Ordering::Relaxed),
            artifact_load_ns: self.artifact_load_ns.load(Ordering::Relaxed),
            hot_swaps: self.hot_swaps.load(Ordering::Relaxed),
        }
    }

    /// Record one artifact load (disk read + decode) with wall time.
    pub fn record_artifact_load(&self, started: Instant) {
        self.artifact_loads.fetch_add(1, Ordering::Relaxed);
        self.artifact_load_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record one sparse-kernel `spmm` with its wall time.
    pub fn record_spmm(&self, started: Instant) {
        self.kernel_spmms.fetch_add(1, Ordering::Relaxed);
        self.kernel_spmm_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

impl MetricsSnapshot {
    /// Mean requests per batch (serving efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Decode-cache hit rate.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Mean sparse-kernel build (decode/encode) time in milliseconds.
    pub fn mean_decode_ms(&self) -> f64 {
        if self.kernel_decodes == 0 {
            0.0
        } else {
            self.kernel_decode_ns as f64 / self.kernel_decodes as f64 / 1e6
        }
    }

    /// Mean sparse-kernel `spmm` time in microseconds.
    pub fn mean_spmm_us(&self) -> f64 {
        if self.kernel_spmms == 0 {
            0.0
        } else {
            self.kernel_spmm_ns as f64 / self.kernel_spmms as f64 / 1e3
        }
    }

    /// Mean artifact cold-load time in milliseconds.
    pub fn mean_artifact_load_ms(&self) -> f64 {
        if self.artifact_loads == 0 {
            0.0
        } else {
            self.artifact_load_ns as f64 / self.artifact_loads as f64 / 1e6
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::new();
        m.record_job(Instant::now(), true);
        m.record_job(Instant::now(), false);
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.batches.fetch_add(4, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.jobs_done, 1);
        assert_eq!(s.jobs_failed, 1);
        assert!((s.mean_batch_size() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn hit_rate_handles_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.cache_hit_rate(), 0.0);
        assert_eq!(s.mean_batch_size(), 0.0);
        assert_eq!(s.mean_decode_ms(), 0.0);
        assert_eq!(s.mean_spmm_us(), 0.0);
    }

    #[test]
    fn kernel_counters_average() {
        let m = Metrics::new();
        m.kernel_decodes.fetch_add(2, Ordering::Relaxed);
        m.kernel_decode_ns.fetch_add(4_000_000, Ordering::Relaxed);
        m.record_spmm(Instant::now());
        let s = m.snapshot();
        assert!((s.mean_decode_ms() - 2.0).abs() < 1e-12);
        assert_eq!(s.kernel_spmms, 1);
    }

    #[test]
    fn artifact_counters_average() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().mean_artifact_load_ms(), 0.0);
        m.record_artifact_load(Instant::now());
        m.artifact_load_ns.store(3_000_000, Ordering::Relaxed);
        m.hot_swaps.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.artifact_loads, 1);
        assert_eq!(s.hot_swaps, 2);
        assert!((s.mean_artifact_load_ms() - 3.0).abs() < 1e-9);
    }
}
