//! Lightweight metrics: atomic counters + wall-time accounting,
//! snapshotted by the CLI/report layer, plus the embedded
//! [`Telemetry`] hub of labeled latency histograms
//! (`coordinator::telemetry`).

use crate::coordinator::telemetry::Telemetry;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Kernel names in `spmm_kernel_ns` slot order. Slot `i` of the
/// snapshot's array accumulates nanoseconds spent inside `spmm` of
/// the kernel named `SPMM_KERNEL_NAMES[i]` — pinned by a test in
/// `serve::kernels`. These are also the label values of the
/// `spmm_ns{kernel=...}` histogram series the totals are derived
/// from.
pub const SPMM_KERNEL_NAMES: [&str; 7] = [
    "dense", "csr", "relative", "lowrank", "tiled", "viterbi", "dcsr",
];

/// Counter names the per-kernel `spmm_kernel_ns` slots serialize
/// under in [`MetricsSnapshot::named_counters`] (same slot order as
/// [`SPMM_KERNEL_NAMES`]); the `STATS` wire frame and
/// `docs/SERVING.md` use these names verbatim.
pub const SPMM_NS_COUNTER_NAMES: [&str; 7] = [
    "spmm_ns_dense",
    "spmm_ns_csr",
    "spmm_ns_relative",
    "spmm_ns_lowrank",
    "spmm_ns_tiled",
    "spmm_ns_viterbi",
    "spmm_ns_dcsr",
];

/// Shared coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs completed.
    pub jobs_done: AtomicU64,
    /// Jobs failed.
    pub jobs_failed: AtomicU64,
    /// Cumulative busy nanoseconds across workers.
    pub busy_ns: AtomicU64,
    /// Requests served (serving path).
    pub requests: AtomicU64,
    /// Batches executed (serving path).
    pub batches: AtomicU64,
    /// Decode-cache hits.
    pub cache_hits: AtomicU64,
    /// Decode-cache misses.
    pub cache_misses: AtomicU64,
    /// Sparse-kernel builds (per-format decode/encode of the index).
    pub kernel_decodes: AtomicU64,
    /// Nanoseconds spent building sparse kernels.
    pub kernel_decode_ns: AtomicU64,
    /// Sparse-kernel `spmm` invocations (masked-layer matmuls).
    pub kernel_spmms: AtomicU64,
    /// Nanoseconds spent inside sparse-kernel `spmm`.
    pub kernel_spmm_ns: AtomicU64,
    /// `.lrbi` artifacts loaded from disk (read + CRC + decode).
    pub artifact_loads: AtomicU64,
    /// Nanoseconds spent loading artifacts.
    pub artifact_load_ns: AtomicU64,
    /// Variant hot-swaps applied to a running server.
    pub hot_swaps: AtomicU64,
    /// Execution-plan shards run across all plan-based `spmm` calls
    /// (`ExecCtx::record_plan_spmm`).
    pub spmm_shards: AtomicU64,
    /// Labeled latency histograms (per-stage, per-kernel, per-shard,
    /// per-model) — the `STATS` v2 / Prometheus exposition source.
    /// Replaces the old hand-grown `spmm_kernel_ns: [AtomicU64; 7]`
    /// array: per-kernel nanosecond totals are now derived from the
    /// `spmm_ns{kernel=...}` series' exact sums.
    pub telemetry: Telemetry,
    /// Dynamic-batcher flushes (batches handed to the executor).
    pub batch_flush_count: AtomicU64,
    /// Total requests across all flushed batches; together with
    /// `batch_flush_count` this makes the batch-size distribution's
    /// mean observable in `serve` reports.
    pub batch_size_sum: AtomicU64,
    /// TCP connections accepted by the network frontend.
    pub net_conns_accepted: AtomicU64,
    /// TCP connections rejected at accept time (`--max-conns`).
    pub net_conns_rejected: AtomicU64,
    /// Inference (`INFER`) requests received over the wire.
    pub net_requests: AtomicU64,
    /// Wire requests rejected with an `overloaded` error frame
    /// (bounded request queue full — admission control).
    pub net_rejected_overload: AtomicU64,
    /// Malformed/unexpected frames answered with a typed error frame.
    pub net_protocol_errors: AtomicU64,
    /// Bytes newly allocated for SpMM scratch (execution-plan
    /// partials, input transposes) by `ExecCtx::take_scratch`. Flat
    /// after the first batch ⇒ the hot path allocates nothing.
    pub spmm_alloc_bytes: AtomicU64,
    /// Scratch checkouts served from the pool without allocating —
    /// the other half of the zero-allocation proof.
    pub scratch_reuse: AtomicU64,
    /// Drained batch buffers accepted back for reuse by the dynamic
    /// batcher (`DynamicBatcher::recycle`) — one per steady-state
    /// flush, so flushes stop allocating request storage.
    pub batch_buffer_reuse: AtomicU64,
    /// Requests shed with a `deadline-exceeded` error frame because
    /// their budget expired before execution (at admission or at
    /// dequeue, before spmm ran).
    pub net_deadline_exceeded: AtomicU64,
    /// Requests shed at admission because predicted completion time
    /// (the p95 of the model's `request_ns` histogram) exceeded the
    /// remaining deadline budget — a subset of work that would have
    /// become `net_deadline_exceeded` later, refused early instead.
    pub net_shed_predicted: AtomicU64,
    /// Connections dropped because arming the idle/write socket
    /// timeout failed — a connection is never allowed to run
    /// untimed (see `docs/ROBUSTNESS.md`).
    pub net_timeout_config_errors: AtomicU64,
    /// `SCATTER` frames a router sent to worker replicas (one per
    /// shard per request attempt; see `docs/CLUSTER.md`).
    pub net_worker_requests: AtomicU64,
    /// Worker scatter attempts that failed (connect error, I/O error,
    /// or an error frame instead of a `PARTIAL`).
    pub net_worker_failures: AtomicU64,
    /// Failed scatter attempts that were recovered by failing over to
    /// another replica of the same shard.
    pub net_worker_failovers: AtomicU64,
    /// Worker swap steps completed during coordinated rolling swaps.
    pub net_worker_swaps: AtomicU64,
    /// Worker swap steps that failed (the rolling swap aborts and the
    /// shard group degrades until a later swap succeeds).
    pub net_worker_swap_failures: AtomicU64,
    /// Router requests answered with an `unavailable` error frame (no
    /// replica of some shard reachable, or the group is degraded).
    pub net_worker_unavailable: AtomicU64,
    /// `PING` health probes a router's supervisor sent to replicas
    /// (successful or not; see `docs/CLUSTER.md`).
    pub net_health_probes: AtomicU64,
    /// Circuit-breaker transitions closed → open (a replica was
    /// quarantined after consecutive failures).
    pub net_breaker_opens: AtomicU64,
    /// Circuit-breaker transitions open → half-open (cooldown expired,
    /// the replica is being re-probed).
    pub net_breaker_half_opens: AtomicU64,
    /// Circuit-breaker transitions half-open → closed (the replica
    /// passed its probation and serves traffic again).
    pub net_breaker_closes: AtomicU64,
    /// Hedged scatters fired: a shard's partial was still outstanding
    /// after the hedge cut, so the same `SCATTER` was sent to the next
    /// healthy replica.
    pub net_hedges_fired: AtomicU64,
    /// Hedged scatters where the hedge (not the primary) produced the
    /// reply that was used.
    pub net_hedges_won: AtomicU64,
    /// Replicas reintegrated into serving after quarantine (passed
    /// consecutive health probes plus the class-agreement re-probe).
    pub net_reintegrations: AtomicU64,
}

/// Client-side retries (`NetClient` backoff) observed in this process.
/// Process-global rather than a [`Metrics`] field because the client
/// has no server `Metrics` instance; in-process clients (tests, the
/// loadgen bench, `serve --connect`) surface through the snapshot's
/// `net_retries_observed` counter.
static NET_RETRIES: AtomicU64 = AtomicU64::new(0);

/// Record one client-side retry (a re-sent request, not the first
/// attempt).
pub fn record_net_retry() {
    NET_RETRIES.fetch_add(1, Ordering::Relaxed);
}

/// Total client-side retries observed in this process.
pub fn net_retries_total() -> u64 {
    NET_RETRIES.load(Ordering::Relaxed)
}

/// A point-in-time copy for reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Jobs completed.
    pub jobs_done: u64,
    /// Jobs failed.
    pub jobs_failed: u64,
    /// Cumulative busy nanoseconds.
    pub busy_ns: u64,
    /// Requests served.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Decode-cache hits.
    pub cache_hits: u64,
    /// Decode-cache misses.
    pub cache_misses: u64,
    /// Sparse-kernel builds.
    pub kernel_decodes: u64,
    /// Nanoseconds building sparse kernels.
    pub kernel_decode_ns: u64,
    /// Sparse-kernel `spmm` invocations.
    pub kernel_spmms: u64,
    /// Nanoseconds inside sparse-kernel `spmm`.
    pub kernel_spmm_ns: u64,
    /// `.lrbi` artifacts loaded from disk.
    pub artifact_loads: u64,
    /// Nanoseconds loading artifacts.
    pub artifact_load_ns: u64,
    /// Variant hot-swaps applied.
    pub hot_swaps: u64,
    /// Execution-plan shards run.
    pub spmm_shards: u64,
    /// Per-kernel plan-spmm nanoseconds ([`SPMM_KERNEL_NAMES`] order).
    pub spmm_kernel_ns: [u64; 7],
    /// Dynamic-batcher flushes.
    pub batch_flush_count: u64,
    /// Requests summed over flushed batches.
    pub batch_size_sum: u64,
    /// TCP connections accepted.
    pub net_conns_accepted: u64,
    /// TCP connections rejected at accept (`--max-conns`).
    pub net_conns_rejected: u64,
    /// Wire inference requests received.
    pub net_requests: u64,
    /// Wire requests rejected as overloaded (admission control).
    pub net_rejected_overload: u64,
    /// Malformed/unexpected frames answered with an error frame.
    pub net_protocol_errors: u64,
    /// Bytes newly allocated for SpMM scratch buffers.
    pub spmm_alloc_bytes: u64,
    /// Scratch checkouts served without allocating.
    pub scratch_reuse: u64,
    /// Batcher flushes served from a recycled request buffer.
    pub batch_buffer_reuse: u64,
    /// Requests shed with `deadline-exceeded` (expired budget).
    pub net_deadline_exceeded: u64,
    /// Requests shed at admission by predicted completion time.
    pub net_shed_predicted: u64,
    /// Connections closed because a socket timeout could not be armed.
    pub net_timeout_config_errors: u64,
    /// `SCATTER` frames sent to worker replicas.
    pub net_worker_requests: u64,
    /// Worker scatter attempts that failed.
    pub net_worker_failures: u64,
    /// Scatter failures recovered by replica failover.
    pub net_worker_failovers: u64,
    /// Worker swap steps completed in rolling swaps.
    pub net_worker_swaps: u64,
    /// Worker swap steps that failed (group degraded).
    pub net_worker_swap_failures: u64,
    /// Router requests answered `unavailable`.
    pub net_worker_unavailable: u64,
    /// Supervisor `PING` health probes sent.
    pub net_health_probes: u64,
    /// Breaker transitions closed → open.
    pub net_breaker_opens: u64,
    /// Breaker transitions open → half-open.
    pub net_breaker_half_opens: u64,
    /// Breaker transitions half-open → closed.
    pub net_breaker_closes: u64,
    /// Hedged scatters fired at a second replica.
    pub net_hedges_fired: u64,
    /// Hedged scatters won by the hedge.
    pub net_hedges_won: u64,
    /// Quarantined replicas reintegrated into serving.
    pub net_reintegrations: u64,
    /// Client-side retries observed in this process (process-global;
    /// see [`record_net_retry`]).
    pub net_retries_observed: u64,
    /// Faults injected by the process-global fault plan
    /// (`util::fault`; 0 unless `LRBI_FAULT` / a chaos test installed
    /// a plan).
    pub faults_injected: u64,
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed job with its busy time.
    pub fn record_job(&self, started: Instant, ok: bool) {
        if ok {
            self.jobs_done.fetch_add(1, Ordering::Relaxed);
        } else {
            self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        }
        self.busy_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Copy out current values.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_done: self.jobs_done.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            kernel_decodes: self.kernel_decodes.load(Ordering::Relaxed),
            kernel_decode_ns: self.kernel_decode_ns.load(Ordering::Relaxed),
            kernel_spmms: self.kernel_spmms.load(Ordering::Relaxed),
            kernel_spmm_ns: self.kernel_spmm_ns.load(Ordering::Relaxed),
            artifact_loads: self.artifact_loads.load(Ordering::Relaxed),
            artifact_load_ns: self.artifact_load_ns.load(Ordering::Relaxed),
            hot_swaps: self.hot_swaps.load(Ordering::Relaxed),
            spmm_shards: self.spmm_shards.load(Ordering::Relaxed),
            spmm_kernel_ns: self.telemetry.spmm_ns_totals(),
            batch_flush_count: self.batch_flush_count.load(Ordering::Relaxed),
            batch_size_sum: self.batch_size_sum.load(Ordering::Relaxed),
            net_conns_accepted: self.net_conns_accepted.load(Ordering::Relaxed),
            net_conns_rejected: self.net_conns_rejected.load(Ordering::Relaxed),
            net_requests: self.net_requests.load(Ordering::Relaxed),
            net_rejected_overload: self.net_rejected_overload.load(Ordering::Relaxed),
            net_protocol_errors: self.net_protocol_errors.load(Ordering::Relaxed),
            spmm_alloc_bytes: self.spmm_alloc_bytes.load(Ordering::Relaxed),
            scratch_reuse: self.scratch_reuse.load(Ordering::Relaxed),
            batch_buffer_reuse: self.batch_buffer_reuse.load(Ordering::Relaxed),
            net_deadline_exceeded: self.net_deadline_exceeded.load(Ordering::Relaxed),
            net_shed_predicted: self.net_shed_predicted.load(Ordering::Relaxed),
            net_timeout_config_errors: self.net_timeout_config_errors.load(Ordering::Relaxed),
            net_worker_requests: self.net_worker_requests.load(Ordering::Relaxed),
            net_worker_failures: self.net_worker_failures.load(Ordering::Relaxed),
            net_worker_failovers: self.net_worker_failovers.load(Ordering::Relaxed),
            net_worker_swaps: self.net_worker_swaps.load(Ordering::Relaxed),
            net_worker_swap_failures: self.net_worker_swap_failures.load(Ordering::Relaxed),
            net_worker_unavailable: self.net_worker_unavailable.load(Ordering::Relaxed),
            net_health_probes: self.net_health_probes.load(Ordering::Relaxed),
            net_breaker_opens: self.net_breaker_opens.load(Ordering::Relaxed),
            net_breaker_half_opens: self.net_breaker_half_opens.load(Ordering::Relaxed),
            net_breaker_closes: self.net_breaker_closes.load(Ordering::Relaxed),
            net_hedges_fired: self.net_hedges_fired.load(Ordering::Relaxed),
            net_hedges_won: self.net_hedges_won.load(Ordering::Relaxed),
            net_reintegrations: self.net_reintegrations.load(Ordering::Relaxed),
            net_retries_observed: net_retries_total(),
            faults_injected: crate::util::fault::injected_total(),
        }
    }

    /// Record one dynamic-batcher flush of `size` requests.
    pub fn record_batch_flush(&self, size: usize) {
        self.batch_flush_count.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record one artifact load (disk read + decode) with wall time.
    pub fn record_artifact_load(&self, started: Instant) {
        self.artifact_loads.fetch_add(1, Ordering::Relaxed);
        self.artifact_load_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record one sparse-kernel `spmm` with its wall time.
    pub fn record_spmm(&self, started: Instant) {
        self.record_spmm_ns(started.elapsed().as_nanos() as u64);
    }

    /// Record one sparse-kernel `spmm` whose duration was already
    /// measured (the engine measures once and feeds both this and the
    /// per-stage histogram, so the two never disagree).
    pub fn record_spmm_ns(&self, ns: u64) {
        self.kernel_spmms.fetch_add(1, Ordering::Relaxed);
        self.kernel_spmm_ns.fetch_add(ns, Ordering::Relaxed);
    }
}

impl MetricsSnapshot {
    /// Mean requests per batch (serving efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Decode-cache hit rate.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Mean sparse-kernel build (decode/encode) time in milliseconds.
    pub fn mean_decode_ms(&self) -> f64 {
        if self.kernel_decodes == 0 {
            0.0
        } else {
            self.kernel_decode_ns as f64 / self.kernel_decodes as f64 / 1e6
        }
    }

    /// Mean sparse-kernel `spmm` time in microseconds.
    pub fn mean_spmm_us(&self) -> f64 {
        if self.kernel_spmms == 0 {
            0.0
        } else {
            self.kernel_spmm_ns as f64 / self.kernel_spmms as f64 / 1e3
        }
    }

    /// Mean requests per *flushed* batch — the dynamic batcher's
    /// efficiency as measured at the flush point (unlike
    /// [`Self::mean_batch_size`], which uses the engine-side counts).
    pub fn mean_flush_size(&self) -> f64 {
        if self.batch_flush_count == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batch_flush_count as f64
        }
    }

    /// Every counter as a stable `(name, value)` list — the `STATS`
    /// wire frame's payload, in the exact order documented in
    /// `docs/SERVING.md`: the scalar counters in struct order, then
    /// the per-kernel `spmm` nanoseconds under
    /// [`SPMM_NS_COUNTER_NAMES`].
    pub fn named_counters(&self) -> Vec<(&'static str, u64)> {
        let mut out = vec![
            ("jobs_done", self.jobs_done),
            ("jobs_failed", self.jobs_failed),
            ("busy_ns", self.busy_ns),
            ("requests", self.requests),
            ("batches", self.batches),
            ("cache_hits", self.cache_hits),
            ("cache_misses", self.cache_misses),
            ("kernel_decodes", self.kernel_decodes),
            ("kernel_decode_ns", self.kernel_decode_ns),
            ("kernel_spmms", self.kernel_spmms),
            ("kernel_spmm_ns", self.kernel_spmm_ns),
            ("artifact_loads", self.artifact_loads),
            ("artifact_load_ns", self.artifact_load_ns),
            ("hot_swaps", self.hot_swaps),
            ("spmm_shards", self.spmm_shards),
            ("batch_flush_count", self.batch_flush_count),
            ("batch_size_sum", self.batch_size_sum),
            ("net_conns_accepted", self.net_conns_accepted),
            ("net_conns_rejected", self.net_conns_rejected),
            ("net_requests", self.net_requests),
            ("net_rejected_overload", self.net_rejected_overload),
            ("net_protocol_errors", self.net_protocol_errors),
            ("spmm_alloc_bytes", self.spmm_alloc_bytes),
            ("scratch_reuse", self.scratch_reuse),
            ("batch_buffer_reuse", self.batch_buffer_reuse),
            ("net_deadline_exceeded", self.net_deadline_exceeded),
            ("net_shed_predicted", self.net_shed_predicted),
            ("net_timeout_config_errors", self.net_timeout_config_errors),
            ("net_worker_requests", self.net_worker_requests),
            ("net_worker_failures", self.net_worker_failures),
            ("net_worker_failovers", self.net_worker_failovers),
            ("net_worker_swaps", self.net_worker_swaps),
            ("net_worker_swap_failures", self.net_worker_swap_failures),
            ("net_worker_unavailable", self.net_worker_unavailable),
            ("net_health_probes", self.net_health_probes),
            ("net_breaker_opens", self.net_breaker_opens),
            ("net_breaker_half_opens", self.net_breaker_half_opens),
            ("net_breaker_closes", self.net_breaker_closes),
            ("net_hedges_fired", self.net_hedges_fired),
            ("net_hedges_won", self.net_hedges_won),
            ("net_reintegrations", self.net_reintegrations),
            ("net_retries_observed", self.net_retries_observed),
            ("faults_injected", self.faults_injected),
        ];
        for (i, name) in SPMM_NS_COUNTER_NAMES.into_iter().enumerate() {
            out.push((name, self.spmm_kernel_ns[i]));
        }
        out
    }

    /// Mean artifact cold-load time in milliseconds.
    pub fn mean_artifact_load_ms(&self) -> f64 {
        if self.artifact_loads == 0 {
            0.0
        } else {
            self.artifact_load_ns as f64 / self.artifact_loads as f64 / 1e6
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::new();
        m.record_job(Instant::now(), true);
        m.record_job(Instant::now(), false);
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.batches.fetch_add(4, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.jobs_done, 1);
        assert_eq!(s.jobs_failed, 1);
        assert!((s.mean_batch_size() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn hit_rate_handles_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.cache_hit_rate(), 0.0);
        assert_eq!(s.mean_batch_size(), 0.0);
        assert_eq!(s.mean_decode_ms(), 0.0);
        assert_eq!(s.mean_spmm_us(), 0.0);
    }

    #[test]
    fn kernel_counters_average() {
        let m = Metrics::new();
        m.kernel_decodes.fetch_add(2, Ordering::Relaxed);
        m.kernel_decode_ns.fetch_add(4_000_000, Ordering::Relaxed);
        m.record_spmm(Instant::now());
        let s = m.snapshot();
        assert!((s.mean_decode_ms() - 2.0).abs() < 1e-12);
        assert_eq!(s.kernel_spmms, 1);
    }

    #[test]
    fn batch_flush_distribution_recorded() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().mean_flush_size(), 0.0);
        m.record_batch_flush(4);
        m.record_batch_flush(8);
        let s = m.snapshot();
        assert_eq!(s.batch_flush_count, 2);
        assert_eq!(s.batch_size_sum, 12);
        assert!((s.mean_flush_size() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn spmm_plan_counters_snapshot() {
        let m = Metrics::new();
        m.spmm_shards.fetch_add(5, Ordering::Relaxed);
        m.telemetry.record_spmm_kernel(2, 1234);
        let s = m.snapshot();
        assert_eq!(s.spmm_shards, 5);
        assert_eq!(s.spmm_kernel_ns, [0, 0, 1234, 0, 0, 0, 0]);
        assert_eq!(SPMM_KERNEL_NAMES[2], "relative");
    }

    #[test]
    fn named_counters_cover_every_field_with_unique_names() {
        let m = Metrics::new();
        m.net_requests.fetch_add(7, Ordering::Relaxed);
        m.telemetry.record_spmm_kernel(4, 99);
        let s = m.snapshot();
        let named = s.named_counters();
        // scalar fields + one entry per spmm kernel slot
        assert_eq!(named.len(), 43 + SPMM_NS_COUNTER_NAMES.len());
        let mut names: Vec<&str> = named.iter().map(|(n, _)| *n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), named.len(), "counter names must be unique");
        let get = |k: &str| named.iter().find(|(n, _)| *n == k).unwrap().1;
        assert_eq!(get("net_requests"), 7);
        assert_eq!(get("spmm_ns_tiled"), 99);
        assert_eq!(get("net_rejected_overload"), 0);
        assert_eq!(get("spmm_alloc_bytes"), 0);
        assert_eq!(get("batch_buffer_reuse"), 0);
        assert_eq!(get("net_deadline_exceeded"), 0);
        assert_eq!(get("net_shed_predicted"), 0);
        assert_eq!(get("net_timeout_config_errors"), 0);
        assert_eq!(get("net_worker_requests"), 0);
        assert_eq!(get("net_worker_failures"), 0);
        assert_eq!(get("net_worker_failovers"), 0);
        assert_eq!(get("net_worker_swaps"), 0);
        assert_eq!(get("net_worker_swap_failures"), 0);
        assert_eq!(get("net_worker_unavailable"), 0);
        assert_eq!(get("net_health_probes"), 0);
        assert_eq!(get("net_breaker_opens"), 0);
        assert_eq!(get("net_breaker_half_opens"), 0);
        assert_eq!(get("net_breaker_closes"), 0);
        assert_eq!(get("net_hedges_fired"), 0);
        assert_eq!(get("net_hedges_won"), 0);
        assert_eq!(get("net_reintegrations"), 0);
        // net_retries_observed / faults_injected are process-global
        // (other tests may have moved them) — presence is asserted by
        // the uniqueness sweep above, not a zero value.
    }

    #[test]
    fn deadline_and_retry_counters_snapshot() {
        let m = Metrics::new();
        m.net_deadline_exceeded.fetch_add(3, Ordering::Relaxed);
        m.net_shed_predicted.fetch_add(1, Ordering::Relaxed);
        m.net_timeout_config_errors.fetch_add(2, Ordering::Relaxed);
        let before = net_retries_total();
        record_net_retry();
        let s = m.snapshot();
        assert_eq!(s.net_deadline_exceeded, 3);
        assert_eq!(s.net_shed_predicted, 1);
        assert_eq!(s.net_timeout_config_errors, 2);
        assert!(s.net_retries_observed >= before + 1, "retry global is monotonic");
    }

    #[test]
    fn artifact_counters_average() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().mean_artifact_load_ms(), 0.0);
        m.record_artifact_load(Instant::now());
        m.artifact_load_ns.store(3_000_000, Ordering::Relaxed);
        m.hot_swaps.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.artifact_loads, 1);
        assert_eq!(s.hot_swaps, 2);
        assert!((s.mean_artifact_load_ms() - 3.0).abs() < 1e-9);
    }
}
