//! Job descriptors for the compression pipeline.

use crate::bmf::algorithm1::FactorizedIndex;
use crate::pruning::manip::ManipMethod;
use crate::tiling::TileSpec;

/// One tile-factorization work item.
#[derive(Debug, Clone)]
pub struct CompressionJob {
    /// Model name (reporting).
    pub model: String,
    /// Layer name.
    pub layer: String,
    /// Tile within the layer.
    pub tile: TileSpec,
    /// BMF rank for this tile.
    pub rank: usize,
    /// Target pruning rate.
    pub sparsity: f64,
    /// Magnitude manipulation method.
    pub manip: ManipMethod,
}

/// Outcome of one job.
#[derive(Debug)]
pub struct JobResult {
    /// The job that produced this.
    pub job: CompressionJob,
    /// Factorization output (None on failure).
    pub index: Option<FactorizedIndex>,
    /// Error text when failed.
    pub error: Option<String>,
    /// Wall time in nanoseconds.
    pub elapsed_ns: u64,
}

impl JobResult {
    /// Whether the job succeeded.
    pub fn ok(&self) -> bool {
        self.index.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_result_ok_logic() {
        let job = CompressionJob {
            model: "m".into(),
            layer: "l".into(),
            tile: TileSpec { id: 0, r0: 0, r1: 4, c0: 0, c1: 4 },
            rank: 2,
            sparsity: 0.5,
            manip: ManipMethod::None,
        };
        let r = JobResult { job, index: None, error: Some("x".into()), elapsed_ns: 1 };
        assert!(!r.ok());
    }
}
