//! Lock-free serving telemetry: log-bucketed latency histograms, a
//! name+label metric registry, and the per-stage trace vocabulary the
//! serving stack records into (see `docs/OBSERVABILITY.md`).
//!
//! Design constraints, in priority order:
//!
//! 1. **Nothing on the hot path but atomics.** A histogram is a
//!    preallocated `[AtomicU64; HIST_BUCKETS]`; recording a sample is
//!    three relaxed `fetch_add`s. Handles to the histograms the
//!    request path touches are resolved **once** at construction
//!    ([`Telemetry::new`] pre-registers fixed arrays indexed by
//!    [`Stage`] / kernel slot), so the registry's lock is never taken
//!    while serving — the PR 5 zero-allocation steady-state proof
//!    (`tests/serving.rs`) holds with telemetry on.
//! 2. **Bounded error.** Buckets are logarithmic with
//!    2^[`SUB_BITS`] = 8 sub-buckets per octave, so any reported
//!    quantile is within 12.5% of the true sample — plenty for p50/
//!    p95/p99 dashboards, at 496 buckets (≈4 KiB) per series.
//! 3. **Mergeable.** Snapshots add bucket-wise
//!    ([`HistogramSnapshot::merge`]), so per-worker histograms can be
//!    combined without losing quantile fidelity — the property the
//!    `STATS` v2 exposition relies on.

use crate::coordinator::metrics::SPMM_KERNEL_NAMES;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Sub-bucket resolution: 2^3 = 8 logarithmic sub-buckets per octave,
/// bounding a bucket's relative width at 1/8 = 12.5%.
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;

/// Total buckets: values `0..8` get exact unit buckets, then 8 per
/// octave for the remaining 61 octaves of `u64` — every nanosecond
/// count from 0 to `u64::MAX` maps to exactly one bucket.
pub const HIST_BUCKETS: usize = (SUB + (64 - SUB_BITS as u64 - 1) * SUB) as usize;

/// Bucket index for a sample (total order preserving: `a <= b` ⇒
/// `bucket_index(a) <= bucket_index(b)`).
pub fn bucket_index(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as u64; // >= SUB_BITS
    let sub = (v >> (msb - SUB_BITS as u64)) & (SUB - 1);
    (SUB + (msb - SUB_BITS as u64) * SUB + sub) as usize
}

/// Half-open value range `[lo, hi)` covered by bucket `idx` (the last
/// bucket's `hi` saturates at `u64::MAX`).
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    assert!(idx < HIST_BUCKETS, "bucket {idx} out of range");
    if (idx as u64) < SUB {
        return (idx as u64, idx as u64 + 1);
    }
    let octave = (idx as u64 - SUB) / SUB;
    let msb = octave + SUB_BITS as u64;
    let sub = (idx as u64 - SUB) % SUB;
    let width = 1u64 << (msb - SUB_BITS as u64);
    let lo = (1u64 << msb) + sub * width;
    let hi = lo.checked_add(width).unwrap_or(u64::MAX);
    (lo, hi)
}

/// A fixed-size log-bucketed latency histogram. Recording is lock-free
/// and allocation-free (three relaxed atomic adds); reading goes
/// through [`LatencyHistogram::snapshot`].
pub struct LatencyHistogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// A zeroed histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one sample (nanoseconds by convention, but any `u64`
    /// magnitude works — the shard-imbalance gauge records per-mille).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record the nanoseconds elapsed since `started`; returns them.
    pub fn record_since(&self, started: Instant) -> u64 {
        let ns = started.elapsed().as_nanos() as u64;
        self.record(ns);
        ns
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of recorded samples (not bucket-quantized).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Copy out a point-in-time snapshot for quantile extraction.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

impl std::fmt::Debug for LatencyHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatencyHistogram")
            .field("count", &self.count())
            .field("sum", &self.sum())
            .finish_non_exhaustive()
    }
}

/// A point-in-time copy of a [`LatencyHistogram`]: mergeable, with
/// nearest-rank quantile extraction.
#[derive(Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    buckets: [u64; HIST_BUCKETS],
    /// Samples recorded.
    pub count: u64,
    /// Exact sum of recorded samples.
    pub sum: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot { buckets: [0; HIST_BUCKETS], count: 0, sum: 0 }
    }
}

impl HistogramSnapshot {
    /// Add another snapshot bucket-wise: the result is exactly the
    /// histogram of the union of both sample sets.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Nearest-rank quantile (`q` in `[0, 1]`), reported as the
    /// midpoint of the bucket holding the rank-selected sample — so
    /// the result always lies within that bucket's bounds, i.e. within
    /// 12.5% of the true sample. Returns 0 on an empty snapshot.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = crate::util::stats::nearest_rank(self.count as usize, q) as u64;
        let mut cum = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum > rank {
                let (lo, hi) = bucket_bounds(idx);
                return lo + (hi - lo) / 2;
            }
        }
        // unreachable with count > 0; fall back to the top bucket
        bucket_bounds(HIST_BUCKETS - 1).0
    }

    /// Mean of recorded samples (exact, from the un-quantized sum).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The (p50, p95, p99) triple every exposition surface reports.
    pub fn percentiles(&self) -> (u64, u64, u64) {
        (self.quantile(0.50), self.quantile(0.95), self.quantile(0.99))
    }

    /// Count in bucket `idx` (bucket-scheme tests).
    pub fn bucket(&self, idx: usize) -> u64 {
        self.buckets[idx]
    }
}

impl std::fmt::Debug for HistogramSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HistogramSnapshot")
            .field("count", &self.count)
            .field("sum", &self.sum)
            .field("p50", &self.quantile(0.5))
            .finish_non_exhaustive()
    }
}

/// One registered series: a metric name, its label set, and the live
/// histogram behind it.
struct Series {
    name: &'static str,
    labels: Vec<(&'static str, String)>,
    hist: Arc<LatencyHistogram>,
}

/// A name+label registry of latency histograms. Registration
/// (`histogram`) takes a lock and is meant for startup / model
/// install; hot paths hold the returned `Arc` and never touch the
/// registry again.
#[derive(Default)]
pub struct MetricRegistry {
    series: Mutex<Vec<Series>>,
}

impl MetricRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create the histogram for `name` + `labels`. The same
    /// (name, labels) pair always returns the same histogram, so
    /// re-registration after a hot swap keeps accumulating into the
    /// existing series.
    pub fn histogram(
        &self,
        name: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<LatencyHistogram> {
        let mut series = self.series.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(s) = series.iter().find(|s| {
            s.name == name
                && s.labels.len() == labels.len()
                && s.labels.iter().zip(labels).all(|((k0, v0), (k1, v1))| k0 == k1 && v0 == v1)
        }) {
            return Arc::clone(&s.hist);
        }
        let hist = Arc::new(LatencyHistogram::new());
        series.push(Series {
            name,
            labels: labels.iter().map(|(k, v)| (*k, v.to_string())).collect(),
            hist: Arc::clone(&hist),
        });
        hist
    }

    /// Snapshot every registered series, in registration order.
    pub fn export(&self) -> Vec<SeriesSnapshot> {
        let series = self.series.lock().unwrap_or_else(|p| p.into_inner());
        series
            .iter()
            .map(|s| SeriesSnapshot {
                name: s.name,
                labels: s.labels.clone(),
                hist: s.hist.snapshot(),
            })
            .collect()
    }
}

impl std::fmt::Debug for MetricRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.series.lock().map(|s| s.len()).unwrap_or(0);
        f.debug_struct("MetricRegistry").field("series", &n).finish()
    }
}

/// A snapshot of one registered series (the `STATS` v2 / Prometheus
/// exposition unit).
#[derive(Debug, Clone)]
pub struct SeriesSnapshot {
    /// Metric name (e.g. `stage_ns`).
    pub name: &'static str,
    /// Label pairs (e.g. `[("stage", "decode")]`).
    pub labels: Vec<(&'static str, String)>,
    /// The histogram's state at export time.
    pub hist: HistogramSnapshot,
}

impl SeriesSnapshot {
    /// Labels as a stable `k=v,k=v` string ("" when unlabeled) — the
    /// wire form `STATS` v2 carries.
    pub fn label_string(&self) -> String {
        self.labels
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// `name{k=v,...}` (or the bare name), the registry's uniqueness
    /// key rendered for display.
    pub fn full_name(&self) -> String {
        let labels = self.label_string();
        if labels.is_empty() {
            self.name.to_string()
        } else {
            format!("{}{{{labels}}}", self.name)
        }
    }
}

/// The traced request stages, in pipeline order. Values index the
/// pre-registered `stage_ns` histogram array, so recording a stage is
/// a single array index away from the atomics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Wire-frame decode (`protocol::read_frame_timed`, CPU time of
    /// the payload parse — not the socket wait).
    Decode = 0,
    /// Submit → executor dequeue (includes batch-formation wait; see
    /// `docs/OBSERVABILITY.md` for the overlap note).
    Queue = 1,
    /// Batch-formation window: first request of the flush received →
    /// flush handed to the executor.
    Batch = 2,
    /// The sparse kernel's `spmm` inside `predict` (also split
    /// per-kernel under `spmm_ns{kernel=...}`).
    Spmm = 3,
    /// Ordered merge of reduction-shard partials (zero for
    /// output-disjoint plans, which have no merge step).
    Merge = 4,
    /// Reply encode + socket write.
    Write = 5,
}

/// Stage names in [`Stage`] discriminant order (label values of the
/// `stage_ns` series).
pub const STAGE_NAMES: [&str; 6] = ["decode", "queue", "batch", "spmm", "merge", "write"];

impl Stage {
    /// Every stage, in pipeline order.
    pub const ALL: [Stage; 6] =
        [Stage::Decode, Stage::Queue, Stage::Batch, Stage::Spmm, Stage::Merge, Stage::Write];

    /// Stable lowercase name (the `stage` label value).
    pub fn name(self) -> &'static str {
        STAGE_NAMES[self as usize]
    }
}

/// Per-request stage timings in nanoseconds — assembled along the
/// request path (server fills decode/write, the engine executor fills
/// queue/batch/spmm/merge) and carried back with each reply for the
/// slow-request log.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageNanos {
    /// Wire-frame decode.
    pub decode: u64,
    /// Submit → executor dequeue.
    pub queue: u64,
    /// Batch-formation window of the flush that carried this request.
    pub batch: u64,
    /// Sparse-kernel `spmm` of that flush.
    pub spmm: u64,
    /// Partial-merge time of that flush (0 for merge-free plans).
    pub merge: u64,
    /// Reply encode + socket write.
    pub write: u64,
}

impl StageNanos {
    /// Values in [`Stage::ALL`] order.
    pub fn as_array(&self) -> [u64; 6] {
        [self.decode, self.queue, self.batch, self.spmm, self.merge, self.write]
    }

    /// Per-stage maximum with `other` — how a multi-row wire request
    /// aggregates its rows' timings (the slowest row bounds the
    /// request).
    pub fn max_with(&mut self, other: &StageNanos) {
        self.decode = self.decode.max(other.decode);
        self.queue = self.queue.max(other.queue);
        self.batch = self.batch.max(other.batch);
        self.spmm = self.spmm.max(other.spmm);
        self.merge = self.merge.max(other.merge);
        self.write = self.write.max(other.write);
    }

    /// `stage=ns` breakdown for the slow-request log line.
    pub fn breakdown(&self) -> String {
        STAGE_NAMES
            .iter()
            .zip(self.as_array())
            .map(|(name, ns)| format!("{name}={ns}ns"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// The serving stack's telemetry hub, embedded in
/// [`Metrics`](crate::coordinator::metrics::Metrics). Pre-registers
/// every histogram the hot path records into as fixed arrays, so
/// request-path lookups are array indexing — never a registry lock.
pub struct Telemetry {
    registry: MetricRegistry,
    stages: [Arc<LatencyHistogram>; STAGE_NAMES.len()],
    spmm_kernels: [Arc<LatencyHistogram>; SPMM_KERNEL_NAMES.len()],
    shard: Arc<LatencyHistogram>,
    imbalance: Arc<LatencyHistogram>,
    next_trace: AtomicU64,
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

impl Telemetry {
    /// Build the hub with its fixed series pre-registered.
    pub fn new() -> Self {
        let registry = MetricRegistry::new();
        let stages =
            std::array::from_fn(|i| registry.histogram("stage_ns", &[("stage", STAGE_NAMES[i])]));
        let spmm_kernels = std::array::from_fn(|i| {
            registry.histogram("spmm_ns", &[("kernel", SPMM_KERNEL_NAMES[i])])
        });
        let shard = registry.histogram("spmm_shard_ns", &[]);
        let imbalance = registry.histogram("spmm_imbalance_pm", &[]);
        Telemetry {
            registry,
            stages,
            spmm_kernels,
            shard,
            imbalance,
            next_trace: AtomicU64::new(1),
        }
    }

    /// The histogram behind a pipeline stage.
    pub fn stage(&self, s: Stage) -> &LatencyHistogram {
        &self.stages[s as usize]
    }

    /// Record `ns` into a stage's histogram.
    pub fn record_stage(&self, s: Stage, ns: u64) {
        self.stages[s as usize].record(ns);
    }

    /// Record `ns` into the per-kernel `spmm_ns` series for `slot`
    /// ([`SPMM_KERNEL_NAMES`] order); out-of-range slots are ignored,
    /// matching the old array-counter semantics.
    pub fn record_spmm_kernel(&self, slot: usize, ns: u64) {
        if let Some(h) = self.spmm_kernels.get(slot) {
            h.record(ns);
        }
    }

    /// The per-kernel `spmm_ns` histogram for `slot`, if in range.
    pub fn spmm_kernel(&self, slot: usize) -> Option<&LatencyHistogram> {
        self.spmm_kernels.get(slot).map(|h| h.as_ref())
    }

    /// Exact per-kernel nanosecond totals in slot order — the source
    /// of the legacy `spmm_ns_*` counters
    /// ([`MetricsSnapshot::spmm_kernel_ns`]
    /// (crate::coordinator::metrics::MetricsSnapshot::spmm_kernel_ns)
    /// is derived from these sums, so the v1 `STATS` frame is
    /// unchanged).
    pub fn spmm_ns_totals(&self) -> [u64; SPMM_KERNEL_NAMES.len()] {
        std::array::from_fn(|i| self.spmm_kernels[i].sum())
    }

    /// Per-shard `spmm` execution-time histogram (`spmm_shard_ns`).
    pub fn shard(&self) -> &LatencyHistogram {
        &self.shard
    }

    /// Shard-imbalance gauge (`spmm_imbalance_pm`): per plan
    /// execution with > 1 shard, `max_shard_ns / mean_shard_ns` in
    /// per-mille — 1000 means perfectly balanced; 2000 means the
    /// slowest shard ran twice the mean. The profiling hook the
    /// autotuner (`ROADMAP.md`) will consume.
    pub fn imbalance(&self) -> &LatencyHistogram {
        &self.imbalance
    }

    /// Allocate the next request trace id (monotonic, starts at 1).
    pub fn next_trace_id(&self) -> u64 {
        self.next_trace.fetch_add(1, Ordering::Relaxed)
    }

    /// Get-or-create the per-model end-to-end latency series
    /// (`request_ns{model=...}`) — resolved once per model install,
    /// held by the model slot.
    pub fn request_histogram(&self, model: &str) -> Arc<LatencyHistogram> {
        self.registry.histogram("request_ns", &[("model", model)])
    }

    /// Get-or-create the per-worker scatter round-trip latency series
    /// (`worker_ns{worker=...}`) — resolved once per replica by the
    /// router's shard group, recorded on every `SCATTER`/`PARTIAL`
    /// exchange (see `docs/CLUSTER.md`).
    pub fn worker_histogram(&self, worker: &str) -> Arc<LatencyHistogram> {
        self.registry.histogram("worker_ns", &[("worker", worker)])
    }

    /// Get-or-create the per-replica health gauge series
    /// (`replica_healthy{worker=...}`). The router's supervisor
    /// records a `1` sample per successful health probe and a `0` per
    /// failure, so the series' p50 tracks the replica's recent state,
    /// `count` is the probe total, and `sum / count` is its success
    /// ratio — exported through `STATS2` and the Prometheus page like
    /// every other series (see `docs/CLUSTER.md`).
    pub fn replica_health_histogram(&self, worker: &str) -> Arc<LatencyHistogram> {
        self.registry.histogram("replica_healthy", &[("worker", worker)])
    }

    /// Snapshot every registered series (fixed + per-model).
    pub fn export(&self) -> Vec<SeriesSnapshot> {
        self.registry.export()
    }
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry").field("registry", &self.registry).finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_index_is_monotonic_and_bounds_contain_value() {
        let mut rng = Rng::new(0xB0C1);
        let mut samples: Vec<u64> = (0..4000).map(|_| rng.next_u64() >> (rng.next_u64() % 64)).collect();
        samples.extend([0, 1, 7, 8, 9, 15, 16, 255, 256, u64::MAX - 1, u64::MAX]);
        for &v in &samples {
            let idx = bucket_index(v);
            assert!(idx < HIST_BUCKETS);
            let (lo, hi) = bucket_bounds(idx);
            let contained = v >= lo && (v < hi || (v == u64::MAX && hi == u64::MAX));
            assert!(contained, "{v} not in [{lo}, {hi}) of bucket {idx}");
        }
        samples.sort_unstable();
        for w in samples.windows(2) {
            assert!(bucket_index(w[0]) <= bucket_index(w[1]), "{} vs {}", w[0], w[1]);
        }
        // buckets tile the axis: consecutive bounds meet exactly
        for idx in 0..HIST_BUCKETS - 1 {
            assert_eq!(bucket_bounds(idx).1, bucket_bounds(idx + 1).0, "gap after bucket {idx}");
        }
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn bucket_relative_width_is_bounded() {
        for idx in 0..HIST_BUCKETS {
            let (lo, hi) = bucket_bounds(idx);
            if lo >= SUB && hi > lo {
                let width = hi - lo;
                assert!(
                    width as f64 / lo as f64 <= 0.125 + 1e-12,
                    "bucket {idx} [{lo}, {hi}) wider than 12.5%"
                );
            }
        }
    }

    /// Property: a quantile of recorded values always lands inside the
    /// bounds of the bucket holding the true nearest-rank sample.
    #[test]
    fn quantile_lands_within_its_buckets_bounds() {
        let mut rng = Rng::new(0xD1CE);
        for case in 0..20 {
            let h = LatencyHistogram::new();
            let n = 1 + (rng.next_u64() % 300) as usize;
            let mut vals: Vec<u64> =
                (0..n).map(|_| rng.next_u64() >> (32 + case % 24)).collect();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_unstable();
            let snap = h.snapshot();
            assert_eq!(snap.count, n as u64);
            for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let got = snap.quantile(q);
                let truth = vals[crate::util::stats::nearest_rank(n, q)];
                let idx = bucket_index(truth);
                let (lo, hi) = bucket_bounds(idx);
                assert!(
                    got >= lo && got < hi.max(lo + 1),
                    "case {case} q={q}: got {got}, truth {truth} in bucket {idx} [{lo},{hi})"
                );
            }
        }
    }

    /// Property: merging two snapshots equals recording the union.
    #[test]
    fn merge_equals_recording_the_union() {
        let mut rng = Rng::new(0x11E6);
        for _ in 0..10 {
            let (a, b, u) = (LatencyHistogram::new(), LatencyHistogram::new(), LatencyHistogram::new());
            for _ in 0..(rng.next_u64() % 200) {
                let v = rng.next_u64() >> (rng.next_u64() % 50);
                a.record(v);
                u.record(v);
            }
            for _ in 0..(rng.next_u64() % 200) {
                let v = rng.next_u64() >> (rng.next_u64() % 50);
                b.record(v);
                u.record(v);
            }
            let mut merged = a.snapshot();
            merged.merge(&b.snapshot());
            assert_eq!(merged, u.snapshot());
        }
    }

    #[test]
    fn empty_snapshot_is_all_zero() {
        let snap = LatencyHistogram::new().snapshot();
        assert_eq!(snap.count, 0);
        assert_eq!(snap.quantile(0.5), 0);
        assert_eq!(snap.mean(), 0.0);
        assert_eq!(snap.percentiles(), (0, 0, 0));
    }

    #[test]
    fn concurrent_records_are_all_counted() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                })
            })
            .collect();
        for handle in handles {
            handle.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
        assert_eq!(h.snapshot().count, 4000);
    }

    #[test]
    fn registry_deduplicates_and_exports_unique_series_names() {
        let reg = MetricRegistry::new();
        let a = reg.histogram("stage_ns", &[("stage", "decode")]);
        let b = reg.histogram("stage_ns", &[("stage", "decode")]);
        let c = reg.histogram("stage_ns", &[("stage", "queue")]);
        let d = reg.histogram("request_ns", &[("model", "default")]);
        let bare = reg.histogram("spmm_shard_ns", &[]);
        a.record(7);
        assert_eq!(b.count(), 1, "same (name, labels) must share the histogram");
        c.record(1);
        d.record(2);
        bare.record(3);
        let export = reg.export();
        assert_eq!(export.len(), 4);
        let mut names: Vec<String> = export.iter().map(|s| s.full_name()).collect();
        names.sort();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before, "exported series names must be unique: {names:?}");
        assert!(names.contains(&"stage_ns{stage=decode}".to_string()));
        assert!(names.contains(&"spmm_shard_ns".to_string()));
    }

    #[test]
    fn telemetry_preregisters_every_fixed_series() {
        let t = Telemetry::new();
        let export = t.export();
        // 6 stages + 7 kernels + shard + imbalance
        assert_eq!(export.len(), STAGE_NAMES.len() + SPMM_KERNEL_NAMES.len() + 2);
        let mut names: Vec<String> = export.iter().map(|s| s.full_name()).collect();
        names.sort();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before, "series names unique: {names:?}");
        t.record_stage(Stage::Spmm, 1234);
        t.record_spmm_kernel(3, 500);
        t.record_spmm_kernel(99, 500); // ignored, like the old array
        assert_eq!(t.stage(Stage::Spmm).count(), 1);
        assert_eq!(t.spmm_ns_totals(), [0, 0, 0, 500, 0, 0, 0]);
        // per-model series registers on demand and persists
        let m = t.request_histogram("default");
        m.record(42);
        assert_eq!(t.request_histogram("default").count(), 1);
        assert_eq!(t.export().len(), export.len() + 1);
    }

    #[test]
    fn replica_health_gauge_registers_per_worker_and_deduplicates() {
        let t = Telemetry::new();
        let fixed = t.export().len();
        let h = t.replica_health_histogram("127.0.0.1:9001");
        h.record(1);
        h.record(1);
        h.record(0);
        // same address returns the same series; another address is new
        assert_eq!(t.replica_health_histogram("127.0.0.1:9001").count(), 3);
        t.replica_health_histogram("127.0.0.1:9002").record(1);
        assert_eq!(t.export().len(), fixed + 2);
        let snap = t.replica_health_histogram("127.0.0.1:9001").snapshot();
        assert_eq!((snap.count, snap.sum), (3, 2), "2 healthy of 3 probes");
        assert_eq!(snap.quantile(0.5), 1, "recent-majority health reads 1");
    }

    #[test]
    fn trace_ids_are_monotonic_from_one() {
        let t = Telemetry::new();
        assert_eq!(t.next_trace_id(), 1);
        assert_eq!(t.next_trace_id(), 2);
        assert_eq!(t.next_trace_id(), 3);
    }

    #[test]
    fn stage_nanos_aggregates_and_prints() {
        let mut a = StageNanos { decode: 5, queue: 10, ..Default::default() };
        let b = StageNanos { decode: 3, queue: 20, spmm: 7, ..Default::default() };
        a.max_with(&b);
        assert_eq!(a.as_array(), [5, 20, 0, 7, 0, 0]);
        let s = a.breakdown();
        assert!(s.contains("queue=20ns") && s.contains("spmm=7ns"), "{s}");
        assert_eq!(Stage::ALL.len(), STAGE_NAMES.len());
        for st in Stage::ALL {
            assert_eq!(STAGE_NAMES[st as usize], st.name());
        }
    }
}
