//! Training driver: pre-train → prune (Algorithm 1) → retrain with the
//! decoded low-rank mask, exactly the paper's §2.2 protocol, executed
//! through the AOT `train_step` artifact (Python never runs here).

pub mod data;
pub mod loop_;

pub use data::{Dataset, SyntheticDigits};
pub use loop_::{NativeTrainer, PjrtTrainer, TrainConfig, TrainLog};
