//! The train → prune → retrain loop (paper §2.2 protocol).
//!
//! Two interchangeable trainers:
//!
//! * [`NativeTrainer`] — pure-Rust fwd/bwd (the oracle; also what the
//!   Table-1 rank sweep uses, since the AOT artifact is traced at a
//!   fixed rank).
//! * [`PjrtTrainer`] — executes the AOT `train_step`/`predict`
//!   artifacts through PJRT; the L1 Pallas decode kernel runs inside
//!   every step. Ranks below the traced rank are zero-column-padded
//!   (zero factor columns contribute nothing to the boolean product).

use crate::bmf::algorithm1::{algorithm1, Algorithm1Config};
use crate::runtime::artifacts::GEOMETRY;
use crate::runtime::client::{literal_matrix, literal_vec, matrix_literal, Runtime};
use crate::serve::engine::MlpParams;
use crate::tensor::Matrix;
use crate::train::data::Dataset;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};

/// Training schedule (steps are scaled-down analogues of the paper's
/// 20K/40K/50K/60K MNIST iterations).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// SGD learning rate.
    pub lr: f32,
    /// Pre-training steps (paper: 20K).
    pub pretrain_steps: usize,
    /// Retraining steps after pruning (paper: 40K more).
    pub retrain_steps: usize,
    /// Record accuracy every this many steps.
    pub eval_every: usize,
    /// Batch size (must equal artifact batch for the PJRT path).
    pub batch: usize,
    /// Parameter init seed.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 0.1,
            pretrain_steps: 300,
            retrain_steps: 600,
            eval_every: 100,
            batch: GEOMETRY.batch,
            seed: 7,
        }
    }
}

/// Loss curve + accuracy checkpoints.
#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    /// (global step, training loss).
    pub losses: Vec<(usize, f32)>,
    /// (global step, test accuracy).
    pub accuracy: Vec<(usize, f64)>,
}

impl TrainLog {
    /// Last recorded accuracy.
    pub fn final_accuracy(&self) -> Option<f64> {
        self.accuracy.last().map(|&(_, a)| a)
    }
}

fn softmax_xent_grad(logits: &Matrix, y: &Matrix) -> (f32, Matrix) {
    let b = logits.rows();
    let mut dl = Matrix::zeros(b, logits.cols());
    let mut loss = 0.0f64;
    for i in 0..b {
        let row = logits.row(i);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let exps: Vec<f32> = row.iter().map(|&v| (v - max).exp()).collect();
        let z: f32 = exps.iter().sum();
        for j in 0..logits.cols() {
            let p = exps[j] / z;
            let t = y.get(i, j);
            if t > 0.0 {
                loss -= (p.max(1e-12)).ln() as f64;
            }
            dl.set(i, j, (p - t) / b as f32);
        }
    }
    (loss as f32 / b as f32, dl)
}

fn add_bias(m: &mut Matrix, b: &[f32]) {
    let cols = m.cols();
    for (idx, v) in m.data_mut().iter_mut().enumerate() {
        *v += b[idx % cols];
    }
}

/// Pure-Rust trainer (oracle + arbitrary-rank path).
pub struct NativeTrainer {
    /// Current parameters.
    pub params: MlpParams,
    /// FC1 keep-mask (all-ones before pruning).
    pub mask: BitMatrix,
    cfg: TrainConfig,
    step: usize,
}

impl NativeTrainer {
    /// Fresh trainer with He-initialised params and a dense mask.
    pub fn new(cfg: TrainConfig) -> Self {
        let g = GEOMETRY;
        NativeTrainer {
            params: MlpParams::init(cfg.seed),
            mask: BitMatrix::from_fn(g.hidden0, g.hidden1, |_, _| true),
            cfg,
            step: 0,
        }
    }

    /// Global step counter.
    pub fn step_count(&self) -> usize {
        self.step
    }

    fn masked_w1(&self) -> Matrix {
        let mut w = self.params.w1.clone();
        for i in 0..w.rows() {
            for j in 0..w.cols() {
                if !self.mask.get(i, j) {
                    w.set(i, j, 0.0);
                }
            }
        }
        w
    }

    /// One SGD step on a batch; returns the loss.
    pub fn train_step(&mut self, x: &Matrix, y: &Matrix) -> Result<f32> {
        let p = &self.params;
        let w1m = self.masked_w1();
        // forward
        let mut h0 = x.matmul(&p.w0)?;
        add_bias(&mut h0, &p.b0);
        let a0 = h0.map(|v| v.max(0.0));
        let mut h1 = a0.matmul(&w1m)?;
        add_bias(&mut h1, &p.b1);
        let a1 = h1.map(|v| v.max(0.0));
        let mut logits = a1.matmul(&p.w2)?;
        add_bias(&mut logits, &p.b2);
        let (loss, dlogits) = softmax_xent_grad(&logits, y);
        // backward
        let dw2 = a1.transpose().matmul(&dlogits)?;
        let db2: Vec<f32> = (0..dlogits.cols())
            .map(|j| (0..dlogits.rows()).map(|i| dlogits.get(i, j)).sum())
            .collect();
        let mut da1 = dlogits.matmul(&p.w2.transpose())?;
        for (v, &a) in da1.data_mut().iter_mut().zip(a1.data()) {
            if a <= 0.0 {
                *v = 0.0;
            }
        }
        let mut dw1 = a0.transpose().matmul(&da1)?;
        // gradient respects the mask
        for i in 0..dw1.rows() {
            for j in 0..dw1.cols() {
                if !self.mask.get(i, j) {
                    dw1.set(i, j, 0.0);
                }
            }
        }
        let db1: Vec<f32> = (0..da1.cols())
            .map(|j| (0..da1.rows()).map(|i| da1.get(i, j)).sum())
            .collect();
        let mut da0 = da1.matmul(&w1m.transpose())?;
        for (v, &a) in da0.data_mut().iter_mut().zip(a0.data()) {
            if a <= 0.0 {
                *v = 0.0;
            }
        }
        let dw0 = x.transpose().matmul(&da0)?;
        let db0: Vec<f32> = (0..da0.cols())
            .map(|j| (0..da0.rows()).map(|i| da0.get(i, j)).sum())
            .collect();
        // SGD
        let lr = self.cfg.lr;
        let p = &mut self.params;
        for (w, g) in [(&mut p.w0, &dw0), (&mut p.w1, &dw1), (&mut p.w2, &dw2)] {
            for (wv, &gv) in w.data_mut().iter_mut().zip(g.data()) {
                *wv -= lr * gv;
            }
        }
        for (b, g) in [(&mut p.b0, &db0), (&mut p.b1, &db1), (&mut p.b2, &db2)] {
            for (bv, &gv) in b.iter_mut().zip(g) {
                *bv -= lr * gv;
            }
        }
        self.step += 1;
        Ok(loss)
    }

    /// Run `steps` SGD steps over the dataset, logging losses and
    /// accuracy checkpoints against `test`.
    pub fn train(
        &mut self,
        train: &Dataset,
        test: &Dataset,
        steps: usize,
        log: &mut TrainLog,
    ) -> Result<()> {
        for s in 0..steps {
            let (x, y) = train.batch(s * self.cfg.batch, self.cfg.batch);
            let loss = self.train_step(&x, &y)?;
            if s % 20 == 0 || s + 1 == steps {
                log.losses.push((self.step, loss));
            }
            if self.step % self.cfg.eval_every == 0 || s + 1 == steps {
                log.accuracy.push((self.step, self.evaluate(test)?));
            }
        }
        Ok(())
    }

    /// Argmax accuracy on a dataset.
    pub fn evaluate(&self, data: &Dataset) -> Result<f64> {
        let w1m = self.masked_w1();
        let p = &self.params;
        let mut correct = 0usize;
        let n = data.len();
        let bsz = self.cfg.batch;
        let mut i = 0;
        while i < n {
            let take = bsz.min(n - i);
            let (x, _) = data.batch(i, take);
            let mut h0 = x.matmul(&p.w0)?;
            add_bias(&mut h0, &p.b0);
            h0.map_inplace(|v| v.max(0.0));
            let mut h1 = h0.matmul(&w1m)?;
            add_bias(&mut h1, &p.b1);
            h1.map_inplace(|v| v.max(0.0));
            let mut logits = h1.matmul(&p.w2)?;
            add_bias(&mut logits, &p.b2);
            for r in 0..take {
                let row = logits.row(r);
                let pred = (0..row.len())
                    .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                    .unwrap();
                if pred == data.y[i + r] {
                    correct += 1;
                }
            }
            i += take;
        }
        Ok(correct as f64 / n as f64)
    }

    /// Prune FC1 with Algorithm 1 and install the decoded mask.
    /// Returns the factorization (compression stats, factors).
    pub fn prune_fc1(&mut self, cfg: &Algorithm1Config) -> Result<crate::bmf::FactorizedIndex> {
        let f = algorithm1(&self.params.w1, cfg)?;
        self.mask = f.mask.clone();
        // zero pruned weights (paper keeps them zero during retrain)
        let mask = self.mask.clone();
        for i in 0..mask.rows() {
            for j in 0..mask.cols() {
                if !mask.get(i, j) {
                    self.params.w1.set(i, j, 0.0);
                }
            }
        }
        Ok(f)
    }
}

/// PJRT-backed trainer: every step executes the AOT artifact.
pub struct PjrtTrainer {
    runtime: Runtime,
    /// Current parameters (host copies; device literals rebuilt per step).
    pub params: MlpParams,
    /// FC1 factors as float {0,1} matrices (traced rank).
    pub ip: Matrix,
    /// Right factor.
    pub iz: Matrix,
    cfg: TrainConfig,
    step: usize,
}

impl PjrtTrainer {
    /// New trainer over a runtime. Mask starts dense (all-ones factors).
    pub fn new(runtime: Runtime, cfg: TrainConfig) -> Result<Self> {
        let g = GEOMETRY;
        if cfg.batch != g.batch {
            return Err(Error::invalid(format!(
                "PJRT path requires batch {} (artifact geometry)",
                g.batch
            )));
        }
        Ok(PjrtTrainer {
            runtime,
            params: MlpParams::init(cfg.seed),
            ip: Matrix::from_fn(g.hidden0, g.rank, |_, _| 1.0),
            iz: Matrix::from_fn(g.rank, g.hidden1, |_, _| 1.0),
            cfg,
            step: 0,
        })
    }

    /// Global step counter.
    pub fn step_count(&self) -> usize {
        self.step
    }

    /// One SGD step via the `train_step` artifact.
    pub fn train_step(&mut self, x: &Matrix, y: &Matrix) -> Result<f32> {
        let g = GEOMETRY;
        let p = &self.params;
        let inputs = vec![
            matrix_literal(&p.w0)?,
            xla::Literal::vec1(&p.b0),
            matrix_literal(&p.w1)?,
            xla::Literal::vec1(&p.b1),
            matrix_literal(&p.w2)?,
            xla::Literal::vec1(&p.b2),
            matrix_literal(&self.ip)?,
            matrix_literal(&self.iz)?,
            matrix_literal(x)?,
            matrix_literal(y)?,
            xla::Literal::vec1(&[self.cfg.lr]),
        ];
        let out = self.runtime.execute("train_step", &inputs)?;
        if out.len() != 7 {
            return Err(Error::Runtime(format!("train_step returned {} outputs", out.len())));
        }
        let loss = literal_vec(&out[0])?[0];
        self.params = MlpParams {
            w0: literal_matrix(&out[1], g.input_dim, g.hidden0)?,
            b0: literal_vec(&out[2])?,
            w1: literal_matrix(&out[3], g.hidden0, g.hidden1)?,
            b1: literal_vec(&out[4])?,
            w2: literal_matrix(&out[5], g.hidden1, g.classes)?,
            b2: literal_vec(&out[6])?,
        };
        self.step += 1;
        Ok(loss)
    }

    /// Run `steps` SGD steps, logging like the native trainer.
    pub fn train(
        &mut self,
        train: &Dataset,
        test: &Dataset,
        steps: usize,
        log: &mut TrainLog,
    ) -> Result<()> {
        for s in 0..steps {
            let (x, y) = train.batch(s * self.cfg.batch, self.cfg.batch);
            let loss = self.train_step(&x, &y)?;
            if s % 20 == 0 || s + 1 == steps {
                log.losses.push((self.step, loss));
            }
            if self.step % self.cfg.eval_every == 0 || s + 1 == steps {
                log.accuracy.push((self.step, self.evaluate(test)?));
            }
        }
        Ok(())
    }

    /// Argmax accuracy via the `predict` artifact.
    pub fn evaluate(&mut self, data: &Dataset) -> Result<f64> {
        let g = GEOMETRY;
        let mut correct = 0usize;
        let n = data.len();
        let mut i = 0;
        while i < n {
            let take = g.batch.min(n - i);
            let (x, _) = data.batch(i, g.batch); // pad by wrapping
            let p = &self.params;
            let inputs = vec![
                matrix_literal(&p.w0)?,
                xla::Literal::vec1(&p.b0),
                matrix_literal(&p.w1)?,
                xla::Literal::vec1(&p.b1),
                matrix_literal(&p.w2)?,
                xla::Literal::vec1(&p.b2),
                matrix_literal(&self.ip)?,
                matrix_literal(&self.iz)?,
                matrix_literal(&x)?,
            ];
            let out = self.runtime.execute("predict", &inputs)?;
            let logits = literal_matrix(&out[0], g.batch, g.classes)?;
            for r in 0..take {
                let row = logits.row(r);
                let pred = (0..row.len())
                    .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                    .unwrap();
                if pred == data.y[i + r] {
                    correct += 1;
                }
            }
            i += take;
        }
        Ok(correct as f64 / n as f64)
    }

    /// Prune FC1 with Algorithm 1 at a rank ≤ the traced rank; factors
    /// are zero-padded to the artifact geometry. Also zeroes pruned
    /// weights.
    pub fn prune_fc1(&mut self, cfg: &Algorithm1Config) -> Result<crate::bmf::FactorizedIndex> {
        let g = GEOMETRY;
        if cfg.rank > g.rank {
            return Err(Error::invalid(format!(
                "artifact traced at rank {}; got {} (use NativeTrainer for larger ranks)",
                g.rank, cfg.rank
            )));
        }
        let f = algorithm1(&self.params.w1, cfg)?;
        let mut ip = Matrix::zeros(g.hidden0, g.rank);
        for i in 0..g.hidden0 {
            for j in 0..cfg.rank {
                if f.ip.get(i, j) {
                    ip.set(i, j, 1.0);
                }
            }
        }
        let mut iz = Matrix::zeros(g.rank, g.hidden1);
        for i in 0..cfg.rank {
            for j in 0..g.hidden1 {
                if f.iz.get(i, j) {
                    iz.set(i, j, 1.0);
                }
            }
        }
        self.ip = ip;
        self.iz = iz;
        for i in 0..f.mask.rows() {
            for j in 0..f.mask.cols() {
                if !f.mask.get(i, j) {
                    self.params.w1.set(i, j, 0.0);
                }
            }
        }
        Ok(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::data::SyntheticDigits;

    fn small_cfg() -> TrainConfig {
        TrainConfig {
            lr: 0.1,
            pretrain_steps: 40,
            retrain_steps: 40,
            eval_every: 1000,
            batch: 32,
            seed: 1,
        }
    }

    #[test]
    fn native_loss_decreases() {
        let data = SyntheticDigits::default().generate(256);
        let mut t = NativeTrainer::new(small_cfg());
        let (x, y) = data.batch(0, 32);
        let first = t.train_step(&x, &y).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = t.train_step(&x, &y).unwrap();
        }
        assert!(last < first * 0.5, "no learning: {first} -> {last}");
    }

    #[test]
    fn native_learns_above_chance() {
        let train = SyntheticDigits::default().generate(640);
        let test = SyntheticDigits { seed: 99, ..Default::default() }.generate(200);
        let mut t = NativeTrainer::new(small_cfg());
        let mut log = TrainLog::default();
        t.train(&train, &test, 60, &mut log).unwrap();
        let acc = log.final_accuracy().unwrap();
        assert!(acc > 0.5, "accuracy {acc} should beat chance (0.1) clearly");
    }

    #[test]
    fn pruned_weights_stay_zero_during_retrain() {
        let train = SyntheticDigits::default().generate(320);
        let mut t = NativeTrainer::new(small_cfg());
        let (x, y) = train.batch(0, 32);
        for _ in 0..10 {
            t.train_step(&x, &y).unwrap();
        }
        let mut cfg = Algorithm1Config::new(8, 0.9);
        cfg.sp_grid = vec![0.3, 0.6];
        cfg.nmf.max_iters = 10;
        let f = t.prune_fc1(&cfg).unwrap();
        assert!((f.achieved_sparsity - 0.9).abs() < 0.03);
        for _ in 0..10 {
            t.train_step(&x, &y).unwrap();
        }
        for i in 0..40 {
            for j in 0..40 {
                if !t.mask.get(i, j) {
                    assert_eq!(t.params.w1.get(i, j), 0.0, "pruned weight moved at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn pruning_then_retraining_recovers_accuracy() {
        let train = SyntheticDigits::default().generate(640);
        let test = SyntheticDigits { seed: 5, ..Default::default() }.generate(200);
        let mut t = NativeTrainer::new(small_cfg());
        let mut log = TrainLog::default();
        t.train(&train, &test, 80, &mut log).unwrap();
        let before = t.evaluate(&test).unwrap();
        let mut cfg = Algorithm1Config::new(16, 0.9);
        cfg.sp_grid = vec![0.3, 0.6];
        cfg.nmf.max_iters = 10;
        t.prune_fc1(&cfg).unwrap();
        let right_after = t.evaluate(&test).unwrap();
        t.train(&train, &test, 80, &mut log).unwrap();
        let after = t.evaluate(&test).unwrap();
        // the paper's Table-1 pattern: prune hurts, retraining recovers
        assert!(after >= right_after, "retraining should not hurt: {right_after} -> {after}");
        assert!(
            after >= before - 0.15,
            "post-retrain accuracy {after} too far below pre-prune {before}"
        );
    }
}
