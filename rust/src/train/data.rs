//! Synthetic MNIST stand-in (docs/ARCHITECTURE.md §Substitutions): ten procedural
//! 16×16 glyph classes + Gaussian pixel noise + integer shifts.
//! Deterministic given a seed; linearly non-trivial (classes overlap
//! under noise) so pruning-induced accuracy loss is measurable.

use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Image side length (input dim = SIDE²).
pub const SIDE: usize = 16;
/// Number of classes.
pub const CLASSES: usize = 10;

/// A labelled dataset of flattened images.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// (n, SIDE²) feature matrix.
    pub x: Matrix,
    /// Labels in 0..CLASSES.
    pub y: Vec<usize>,
}

impl Dataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// Whether empty.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// One-hot label matrix (n, CLASSES).
    pub fn one_hot(&self) -> Matrix {
        let mut m = Matrix::zeros(self.len(), CLASSES);
        for (i, &c) in self.y.iter().enumerate() {
            m.set(i, c, 1.0);
        }
        m
    }

    /// Copy a batch `[start, start+n)` (wrapping) into (x, one-hot y).
    pub fn batch(&self, start: usize, n: usize) -> (Matrix, Matrix) {
        let len = self.len();
        let mut x = Matrix::zeros(n, self.x.cols());
        let mut y = Matrix::zeros(n, CLASSES);
        for i in 0..n {
            let src = (start + i) % len;
            for j in 0..self.x.cols() {
                x.set(i, j, self.x.get(src, j));
            }
            y.set(i, self.y[src], 1.0);
        }
        (x, y)
    }
}

/// Generator for the synthetic digit task.
#[derive(Debug, Clone, Copy)]
pub struct SyntheticDigits {
    /// RNG seed.
    pub seed: u64,
    /// Pixel noise standard deviation.
    pub noise: f32,
    /// Max |shift| in pixels applied per sample.
    pub max_shift: i32,
}

impl Default for SyntheticDigits {
    fn default() -> Self {
        SyntheticDigits { seed: 0xD1617, noise: 0.35, max_shift: 2 }
    }
}

/// Render the base glyph for a class on a SIDE×SIDE grid. Each class
/// is a distinct parametric stroke pattern (rings, bars, crosses,
/// diagonals ...) so the task needs non-linear features but stays
/// learnable by a 2-hidden-layer MLP.
fn glyph(class: usize, i: usize, j: usize) -> f32 {
    let c = (SIDE as f32 - 1.0) / 2.0;
    let x = j as f32 - c;
    let y = i as f32 - c;
    let r = (x * x + y * y).sqrt();
    let on = match class {
        0 => (r - 5.5).abs() < 1.2,                                  // ring
        1 => x.abs() < 1.3,                                          // vertical bar
        2 => y.abs() < 1.3,                                          // horizontal bar
        3 => (x - y).abs() < 1.6,                                    // main diagonal
        4 => (x + y).abs() < 1.6,                                    // anti-diagonal
        5 => x.abs() < 1.3 || y.abs() < 1.3,                         // cross
        6 => (r - 3.0).abs() < 1.1,                                  // small ring
        7 => y.abs() < 1.2 && x < 0.0 || x.abs() < 1.2 && y > 0.0,   // L-corner
        8 => (r - 5.5).abs() < 1.1 || (r - 2.0).abs() < 1.0,         // double ring
        _ => (x.abs() - 4.0).abs() < 1.1 && y.abs() < 5.0,           // two bars
    };
    if on {
        1.0
    } else {
        0.0
    }
}

impl SyntheticDigits {
    /// Generate `n` samples (classes balanced round-robin).
    pub fn generate(&self, n: usize) -> Dataset {
        let mut rng = Rng::new(self.seed);
        let dim = SIDE * SIDE;
        let mut x = Matrix::zeros(n, dim);
        let mut y = Vec::with_capacity(n);
        for s in 0..n {
            let class = s % CLASSES;
            let dx = rng.next_range(2 * self.max_shift as u64 + 1) as i32 - self.max_shift;
            let dy = rng.next_range(2 * self.max_shift as u64 + 1) as i32 - self.max_shift;
            for i in 0..SIDE {
                for j in 0..SIDE {
                    let si = i as i32 - dy;
                    let sj = j as i32 - dx;
                    let base = if (0..SIDE as i32).contains(&si) && (0..SIDE as i32).contains(&sj)
                    {
                        glyph(class, si as usize, sj as usize)
                    } else {
                        0.0
                    };
                    let v = base + rng.gaussian_f32(0.0, self.noise);
                    x.set(s, i * SIDE + j, v);
                }
            }
            y.push(class);
        }
        Dataset { x, y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let g = SyntheticDigits::default();
        let a = g.generate(50);
        let b = g.generate(50);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn classes_balanced() {
        let d = SyntheticDigits::default().generate(100);
        for c in 0..CLASSES {
            assert_eq!(d.y.iter().filter(|&&y| y == c).count(), 10);
        }
    }

    #[test]
    fn glyphs_are_distinct() {
        // pairwise pixel overlap of clean glyphs must be well below 1
        for a in 0..CLASSES {
            for b in (a + 1)..CLASSES {
                let mut inter = 0.0;
                let mut union = 0.0;
                for i in 0..SIDE {
                    for j in 0..SIDE {
                        let ga = glyph(a, i, j);
                        let gb = glyph(b, i, j);
                        inter += ga * gb;
                        union += (ga + gb).min(1.0);
                    }
                }
                let iou = inter / union.max(1.0);
                assert!(iou < 0.8, "classes {a},{b} overlap too much: {iou}");
            }
        }
    }

    #[test]
    fn one_hot_and_batch() {
        let d = SyntheticDigits::default().generate(20);
        let oh = d.one_hot();
        assert_eq!(oh.rows(), 20);
        for (i, &c) in d.y.iter().enumerate() {
            assert_eq!(oh.get(i, c), 1.0);
            assert_eq!(oh.row(i).iter().sum::<f32>(), 1.0);
        }
        let (bx, by) = d.batch(18, 4); // wraps
        assert_eq!(bx.rows(), 4);
        assert_eq!(by.get(0, d.y[18]), 1.0);
        assert_eq!(by.get(2, d.y[0]), 1.0);
    }

    #[test]
    fn noise_changes_samples_but_not_labels() {
        let mut gen = SyntheticDigits::default();
        gen.noise = 0.0;
        let clean = gen.generate(10);
        gen.noise = 0.5;
        let noisy = gen.generate(10);
        assert_eq!(clean.y, noisy.y);
        assert_ne!(clean.x.data(), noisy.x.data());
    }
}
