//! Compressed Sparse Row with 16-bit column indices (Figure 1's
//! "CSR Index Format"): `IA` row pointers + `JA` column indices.

use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};

/// CSR with u16 column indices and u32 row pointers.
#[derive(Debug, Clone)]
pub struct Csr16 {
    rows: usize,
    cols: usize,
    /// Row pointer array `IA` (len rows+1).
    pub ia: Vec<u32>,
    /// Column index array `JA` (len nnz).
    pub ja: Vec<u16>,
}

impl Csr16 {
    /// Bounds a mask must satisfy to be representable: column indices
    /// fit `JA`'s `u16` (cols ≤ 65536) and the non-zero count fits
    /// `IA`'s `u32`. Split out from [`Csr16::encode`] so the `nnz`
    /// bound — which would silently *wrap* `IA` into a corrupt but
    /// plausible-looking index — is unit-testable without allocating
    /// a four-billion-bit mask.
    pub fn encode_bounds(cols: usize, nnz: u64) -> Result<()> {
        if cols > u16::MAX as usize + 1 {
            return Err(Error::invalid(format!(
                "mask cols {cols} exceed the 16-bit CSR column range ({})",
                u16::MAX as usize + 1
            )));
        }
        if nnz > u32::MAX as u64 {
            return Err(Error::invalid(format!(
                "mask nnz {nnz} overflows the 32-bit CSR row pointers"
            )));
        }
        Ok(())
    }

    /// Encode a mask; rejects masks outside [`Csr16::encode_bounds`]
    /// with a typed error instead of wrapping the indices.
    pub fn encode(mask: &BitMatrix) -> Result<Self> {
        Self::encode_bounds(mask.cols(), mask.count_ones())?;
        let mut ia = Vec::with_capacity(mask.rows() + 1);
        let mut ja = Vec::new();
        ia.push(0u32);
        for i in 0..mask.rows() {
            for j in 0..mask.cols() {
                if mask.get(i, j) {
                    ja.push(j as u16);
                }
            }
            ia.push(ja.len() as u32);
        }
        Ok(Csr16 { rows: mask.rows(), cols: mask.cols(), ia, ja })
    }

    /// Recover the mask.
    pub fn decode(&self) -> Result<BitMatrix> {
        let mut mask = BitMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (a, b) = (self.ia[i] as usize, self.ia[i + 1] as usize);
            if b < a || b > self.ja.len() {
                return Err(Error::invalid(format!("corrupt IA at row {i}")));
            }
            for &j in &self.ja[a..b] {
                if (j as usize) >= self.cols {
                    return Err(Error::invalid(format!("JA out of range: {j}")));
                }
                mask.set(i, j as usize, true);
            }
        }
        Ok(mask)
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.ja.len()
    }

    /// Mask rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Mask cols.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Rebuild from raw `IA`/`JA` arrays (the store read path),
    /// validating the invariants `decode` relies on.
    pub fn from_parts(rows: usize, cols: usize, ia: Vec<u32>, ja: Vec<u16>) -> Result<Self> {
        if ia.len() != rows + 1 {
            return Err(Error::store(format!("IA has {} entries for {rows} rows", ia.len())));
        }
        if ia[0] != 0 || *ia.last().unwrap() as usize != ja.len() {
            return Err(Error::store("IA endpoints do not bracket JA"));
        }
        if ia.windows(2).any(|w| w[1] < w[0]) {
            return Err(Error::store("IA not monotonically non-decreasing"));
        }
        if ja.iter().any(|&j| j as usize >= cols) {
            return Err(Error::store("JA column out of range"));
        }
        Ok(Csr16 { rows, cols, ia, ja })
    }

    /// Size: 2 B per JA entry + 4 B per IA entry.
    pub fn index_bytes(&self) -> usize {
        self.ja.len() * 2 + self.ia.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn paper_figure1_example() {
        // Figure 1: the 4x4 pruned matrix has IA=[0 2 2 5 7],
        // JA=[0 3 0 1 3 0 1].
        let rows = [
            [1, 0, 0, 1],
            [0, 0, 0, 0],
            [1, 1, 0, 1],
            [1, 1, 0, 0],
        ];
        let mask = BitMatrix::from_fn(4, 4, |i, j| rows[i][j] == 1);
        let csr = Csr16::encode(&mask).unwrap();
        assert_eq!(csr.ia, vec![0, 2, 2, 5, 7]);
        assert_eq!(csr.ja, vec![0, 3, 0, 1, 3, 0, 1]);
    }

    #[test]
    fn roundtrip_random() {
        prop::check("csr16 roundtrip", 10, |rng| {
            let m = prop::dim(rng, 1, 30);
            let n = prop::dim(rng, 1, 60);
            let d = rng.next_f64();
            let mut r2 = Rng::new(rng.next_u64());
            let mask = BitMatrix::from_fn(m, n, |_, _| r2.bernoulli(d));
            let enc = Csr16::encode(&mask).unwrap();
            assert_eq!(enc.decode().unwrap(), mask);
            assert_eq!(enc.nnz() as u64, mask.count_ones());
        });
    }

    #[test]
    fn size_tracks_nnz() {
        let dense = BitMatrix::from_fn(10, 10, |_, _| true);
        let empty = BitMatrix::zeros(10, 10);
        assert!(
            Csr16::encode(&dense).unwrap().index_bytes()
                > Csr16::encode(&empty).unwrap().index_bytes()
        );
        assert_eq!(Csr16::encode(&empty).unwrap().index_bytes(), 11 * 4);
    }

    #[test]
    fn from_parts_roundtrip_and_validation() {
        let mut rng = Rng::new(11);
        let mask = BitMatrix::from_fn(9, 40, |_, _| rng.bernoulli(0.2));
        let enc = Csr16::encode(&mask).unwrap();
        let back = Csr16::from_parts(9, 40, enc.ia.clone(), enc.ja.clone()).unwrap();
        assert_eq!(back.decode().unwrap(), mask);
        // wrong IA length
        assert!(Csr16::from_parts(8, 40, enc.ia.clone(), enc.ja.clone()).is_err());
        // IA not ending at nnz
        let mut bad = enc.ia.clone();
        *bad.last_mut().unwrap() += 1;
        assert!(Csr16::from_parts(9, 40, bad, enc.ja.clone()).is_err());
        // JA out of range
        let mut badja = enc.ja.clone();
        if let Some(j) = badja.first_mut() {
            *j = 40;
        }
        assert!(Csr16::from_parts(9, 40, enc.ia.clone(), badja).is_err());
    }

    #[test]
    fn encode_bounds_reject_wide_and_overfull_masks() {
        // within bounds: exactly at both limits
        assert!(Csr16::encode_bounds(u16::MAX as usize + 1, u32::MAX as u64).is_ok());
        // cols one past the 16-bit column range
        let err = Csr16::encode_bounds(u16::MAX as usize + 2, 0).unwrap_err();
        assert!(err.to_string().contains("column range"), "{err}");
        assert!(matches!(err, Error::InvalidArg(_)), "typed invalid, not a panic");
        // nnz one past what IA's u32 row pointers can address
        let err = Csr16::encode_bounds(100, u32::MAX as u64 + 1).unwrap_err();
        assert!(err.to_string().contains("row pointers"), "{err}");
        assert!(matches!(err, Error::InvalidArg(_)));
    }

    #[test]
    fn encode_rejects_too_many_columns_end_to_end() {
        // 1 x 65537 is cheap to allocate (packed bits) but must be
        // refused: its last column index does not fit a u16.
        let wide = BitMatrix::zeros(1, u16::MAX as usize + 2);
        let err = Csr16::encode(&wide).unwrap_err();
        assert!(matches!(err, Error::InvalidArg(_)), "{err}");
    }

    #[test]
    fn corrupt_ja_detected() {
        let mask = BitMatrix::from_fn(2, 4, |i, j| i == 0 && j < 2);
        let mut enc = Csr16::encode(&mask).unwrap();
        enc.ja[0] = 99; // out of range
        assert!(enc.decode().is_err());
    }
}
