//! The proposed low-rank binary index as a storable format: packed
//! `I_p` and `I_z` (k(m+n) bits) + decode via boolean product.
//!
//! # Examples
//!
//! Factorize a layer's pruning index with Algorithm 1, serialize it,
//! and round-trip back to the exact mask:
//!
//! ```
//! use lrbi::bmf::algorithm1::{algorithm1, Algorithm1Config};
//! use lrbi::formats::lowrank::LowRankIndex;
//! use lrbi::tensor::Matrix;
//! use lrbi::util::rng::Rng;
//!
//! let mut rng = Rng::new(7);
//! let w = Matrix::gaussian(32, 24, 0.0, 0.1, &mut rng);
//! let mut cfg = Algorithm1Config::new(4, 0.8); // rank 4, S = 0.8
//! cfg.sp_grid = vec![0.4, 0.6];
//! cfg.nmf.max_iters = 10;
//! let f = algorithm1(&w, &cfg)?;
//!
//! let enc = LowRankIndex::encode(&f);           // pack I_p then I_z
//! assert_eq!(enc.index_bytes(), (4 * (32 + 24) + 7) / 8);
//! let (ip, iz) = enc.factors()?;                // unpack
//! assert_eq!((ip, iz), (f.ip.clone(), f.iz.clone()));
//! assert_eq!(enc.decode()?, f.mask);            // I_p ⊗ I_z == mask
//! # Ok::<(), lrbi::Error>(())
//! ```

use crate::bmf::algorithm1::FactorizedIndex;
use crate::util::bits::{bits_word_at, BitMatrix};
use crate::util::error::{Error, Result};

/// Serialized low-rank index: dims + packed factor bits.
#[derive(Debug, Clone, PartialEq)]
pub struct LowRankIndex {
    /// Mask rows.
    pub m: usize,
    /// Mask cols.
    pub n: usize,
    /// Rank.
    pub k: usize,
    /// Packed I_p then I_z, row-major, LSB-first.
    pub payload: Vec<u8>,
}

fn pack_into(bits: &BitMatrix, out: &mut Vec<u8>, cursor: &mut usize) {
    for i in 0..bits.rows() {
        for j in 0..bits.cols() {
            if bits.get(i, j) {
                let idx = *cursor;
                if idx / 8 >= out.len() {
                    out.resize(idx / 8 + 1, 0);
                }
                out[idx / 8] |= 1 << (idx % 8);
            }
            *cursor += 1;
        }
    }
}

impl LowRankIndex {
    /// Pack a factorized index.
    pub fn encode(f: &FactorizedIndex) -> Self {
        Self::from_factors(&f.ip, &f.iz).expect("FactorizedIndex factors are shape-consistent")
    }

    /// Pack a raw factor pair `(I_p, I_z)` — the store pack path for
    /// factors that did not come from Algorithm 1 (e.g. a served
    /// variant's in-memory factors).
    pub fn from_factors(ip: &BitMatrix, iz: &BitMatrix) -> Result<Self> {
        if ip.cols() != iz.rows() {
            return Err(Error::shape(format!(
                "factor ranks disagree: I_p {}x{}, I_z {}x{}",
                ip.rows(),
                ip.cols(),
                iz.rows(),
                iz.cols()
            )));
        }
        let (m, k) = (ip.rows(), ip.cols());
        let n = iz.cols();
        let total_bits = k * (m + n);
        let mut payload = vec![0u8; total_bits.div_ceil(8)];
        let mut cursor = 0usize;
        pack_into(ip, &mut payload, &mut cursor);
        pack_into(iz, &mut payload, &mut cursor);
        Ok(LowRankIndex { m, n, k, payload })
    }

    /// Probe one payload bit (flat LSB-first index) — the per-bit
    /// reference that the word-at-a-time unpack in
    /// [`LowRankIndex::factors`] must reproduce exactly.
    pub fn bit(&self, idx: usize) -> bool {
        self.payload[idx / 8] >> (idx % 8) & 1 == 1
    }

    /// Unpack to (I_p, I_z), assembling each factor row **64 bits at a
    /// time** from the payload (`bits_word_at`) instead of probing
    /// bit-by-bit — the same word-parallel discipline the serving
    /// kernels use, applied to the store decode path.
    ///
    /// The word-level reconstruction is exactly the per-bit one:
    ///
    /// ```
    /// use lrbi::formats::lowrank::LowRankIndex;
    /// use lrbi::util::bits::BitMatrix;
    ///
    /// let ip = BitMatrix::from_fn(5, 3, |i, j| (i + j) % 2 == 0);
    /// let iz = BitMatrix::from_fn(3, 70, |i, j| (i * j) % 5 == 1); // > 1 word per row
    /// let enc = LowRankIndex::from_factors(&ip, &iz)?;
    /// let (ip2, iz2) = enc.factors()?; // word-at-a-time unpack
    /// let ip_bits = BitMatrix::from_fn(5, 3, |i, j| enc.bit(i * 3 + j));
    /// let iz_bits = BitMatrix::from_fn(3, 70, |i, j| enc.bit(5 * 3 + i * 70 + j));
    /// assert_eq!((ip2, iz2), (ip_bits, iz_bits));
    /// assert_eq!(enc.decode()?, ip.bool_product(&iz));
    /// # Ok::<(), lrbi::Error>(())
    /// ```
    pub fn factors(&self) -> Result<(BitMatrix, BitMatrix)> {
        let need = (self.k * (self.m + self.n)).div_ceil(8);
        if self.payload.len() < need {
            return Err(Error::invalid(format!(
                "payload {} bytes, need {need}",
                self.payload.len()
            )));
        }
        let ip = unpack_rows(&self.payload, 0, self.m, self.k);
        let iz = unpack_rows(&self.payload, self.m * self.k, self.k, self.n);
        Ok((ip, iz))
    }

    /// Decode the mask (boolean product — the paper's decompressor).
    pub fn decode(&self) -> Result<BitMatrix> {
        let (ip, iz) = self.factors()?;
        Ok(ip.bool_product(&iz))
    }

    /// Payload size (the k(m+n)/8 the paper reports).
    pub fn index_bytes(&self) -> usize {
        self.payload.len()
    }
}

/// Unpack `rows × cols` bits starting at flat offset `base` of an
/// LSB-first payload into a [`BitMatrix`], one `u64` word per step
/// (the last word of each row masked to its remaining columns so row
/// padding stays clear).
fn unpack_rows(payload: &[u8], base: usize, rows: usize, cols: usize) -> BitMatrix {
    let mut out = BitMatrix::zeros(rows, cols);
    if cols == 0 {
        return out;
    }
    for i in 0..rows {
        let row_off = base + i * cols;
        let words = out.row_words_mut(i);
        let wpr = words.len();
        for (wi, w) in words.iter_mut().enumerate() {
            let nb = if wi + 1 == wpr { cols - wi * 64 } else { 64 };
            *w = bits_word_at(payload, row_off + wi * 64, nb);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bmf::algorithm1::{algorithm1, Algorithm1Config};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn factorize(seed: u64) -> FactorizedIndex {
        let mut rng = Rng::new(seed);
        let w = Matrix::gaussian(48, 36, 0.0, 0.1, &mut rng);
        let mut cfg = Algorithm1Config::new(6, 0.85);
        cfg.sp_grid = vec![0.3, 0.6];
        cfg.nmf.max_iters = 15;
        algorithm1(&w, &cfg).unwrap()
    }

    #[test]
    fn roundtrip_factors_and_mask() {
        let f = factorize(1);
        let enc = LowRankIndex::encode(&f);
        let (ip, iz) = enc.factors().unwrap();
        assert_eq!(ip, f.ip);
        assert_eq!(iz, f.iz);
        assert_eq!(enc.decode().unwrap(), f.mask);
    }

    #[test]
    fn payload_size_matches_formula() {
        let f = factorize(2);
        let enc = LowRankIndex::encode(&f);
        assert_eq!(enc.index_bytes(), (6usize * (48 + 36)).div_ceil(8));
    }

    #[test]
    fn word_unpack_matches_per_bit_probes_at_awkward_widths() {
        use crate::util::rng::Rng;
        // widths around the u64 boundary exercise every masking path
        for (m, k, n) in [(3usize, 1usize, 64usize), (5, 2, 65), (1, 7, 1), (4, 3, 130)] {
            let mut rng = Rng::new((m * 1000 + k * 100 + n) as u64);
            let ip = BitMatrix::from_fn(m, k, |_, _| rng.bernoulli(0.5));
            let iz = BitMatrix::from_fn(k, n, |_, _| rng.bernoulli(0.5));
            let enc = LowRankIndex::from_factors(&ip, &iz).unwrap();
            let (ip2, iz2) = enc.factors().unwrap();
            let ip_ref = BitMatrix::from_fn(m, k, |i, j| enc.bit(i * k + j));
            let iz_ref = BitMatrix::from_fn(k, n, |i, j| enc.bit(m * k + i * n + j));
            assert_eq!((ip2, iz2), (ip_ref, iz_ref), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn truncated_payload_rejected() {
        let f = factorize(3);
        let mut enc = LowRankIndex::encode(&f);
        enc.payload.truncate(enc.payload.len() - 1);
        assert!(enc.factors().is_err());
    }
}
