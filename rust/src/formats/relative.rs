//! CSR with 5-bit *relative* (gap) indexing — Deep Compression's
//! scheme [9]: store the column gap to the previous non-zero in 5
//! bits; when a gap exceeds 31, insert filler entries (gap 31 that do
//! not correspond to a weight) until the remainder fits.

use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};

/// 5-bit relative-index stream.
#[derive(Debug, Clone)]
pub struct Csr5Relative {
    rows: usize,
    cols: usize,
    /// Entries are (gap, is_real). Stored packed 5 bits each; fillers
    /// are entries with gap == MAX_GAP that carry no weight.
    entries: Vec<u8>,
    /// Real non-zero count (excludes fillers).
    nnz: usize,
}

/// Maximum representable gap (2^5 - 1).
pub const MAX_GAP: u32 = 31;

impl Csr5Relative {
    /// Encode a mask as a flat row-major gap stream. Entry values
    /// 0..=30 are real gaps; the sentinel 31 is a filler advancing the
    /// cursor 31 positions without emitting a weight (Deep Compression
    /// pads with an explicit zero weight instead — byte-for-byte the
    /// stream length is the same, and ours round-trips the mask
    /// exactly).
    pub fn encode(mask: &BitMatrix) -> Self {
        let (rows, cols) = (mask.rows(), mask.cols());
        let mut entries = Vec::new();
        let mut nnz = 0usize;
        let mut gap: u32 = 0;
        for i in 0..rows {
            for j in 0..cols {
                if mask.get(i, j) {
                    while gap >= MAX_GAP {
                        entries.push(MAX_GAP as u8);
                        gap -= MAX_GAP;
                    }
                    entries.push(gap as u8);
                    nnz += 1;
                    gap = 0;
                } else {
                    gap += 1;
                }
            }
        }
        Csr5Relative { rows, cols, entries, nnz }
    }

    /// Recover the mask: sentinel entries (31) accumulate skip
    /// distance; every other entry places one mask bit.
    pub fn decode(&self) -> BitMatrix {
        let mut mask = BitMatrix::zeros(self.rows, self.cols);
        let total = self.rows * self.cols;
        let mut pos: usize = 0; // next candidate position
        let mut pending: u32 = 0; // accumulated gap from fillers
        for &e in &self.entries {
            if e as u32 == MAX_GAP {
                pending += MAX_GAP;
                continue;
            }
            pos += (pending + e as u32) as usize;
            pending = 0;
            if pos < total {
                mask.set(pos / self.cols, pos % self.cols, true);
            }
            pos += 1;
        }
        mask
    }

    /// Real non-zeros represented.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// The raw gap stream (values `0..=30` are real gaps, `31` is a
    /// filler). Exposed so execution kernels can stream the entries
    /// without re-encoding — see `serve::kernels::RelativeKernel`.
    pub fn entries(&self) -> &[u8] {
        &self.entries
    }

    /// Total 5-bit entries including fillers.
    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Consume the stream, yielding the raw entry vector without a
    /// copy (used by the execution kernel when it owns the encode).
    pub fn into_entries(self) -> Vec<u8> {
        self.entries
    }

    /// Packed size: ceil(5 * entries / 8) bytes.
    pub fn index_bytes(&self) -> usize {
        (self.entries.len() * 5).div_ceil(8)
    }

    /// Mask rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Mask cols.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Pack the gap stream 5 bits per entry, LSB-first — the on-disk
    /// form, exactly `index_bytes()` long.
    pub fn to_packed_bytes(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.index_bytes()];
        for (idx, &e) in self.entries.iter().enumerate() {
            let bit = idx * 5;
            let v = (e as u16) << (bit % 8);
            out[bit / 8] |= (v & 0xFF) as u8;
            if v > 0xFF {
                out[bit / 8 + 1] |= (v >> 8) as u8;
            }
        }
        out
    }

    /// Rebuild from the packed on-disk form (the store read path).
    /// `entry_count` disambiguates trailing pad bits.
    pub fn from_packed_bytes(
        rows: usize,
        cols: usize,
        entry_count: usize,
        bytes: &[u8],
    ) -> Result<Self> {
        let need = (entry_count * 5).div_ceil(8);
        if bytes.len() != need {
            return Err(Error::store(format!(
                "relative index payload: {} bytes for {entry_count} entries, need {need}",
                bytes.len()
            )));
        }
        let mut entries = Vec::with_capacity(entry_count);
        let mut nnz = 0usize;
        let mut cursor = 0usize; // mask position the stream advances to
        for idx in 0..entry_count {
            let bit = idx * 5;
            let lo = bytes[bit / 8] as u16 >> (bit % 8);
            let hi = if bit % 8 > 3 && bit / 8 + 1 < bytes.len() {
                (bytes[bit / 8 + 1] as u16) << (8 - bit % 8)
            } else {
                0
            };
            let e = ((lo | hi) & 0x1F) as u8;
            if e as u32 == MAX_GAP {
                cursor += MAX_GAP as usize;
            } else {
                cursor += e as usize + 1;
                nnz += 1;
            }
            entries.push(e);
        }
        // Semantic validation: the stream must stay inside the mask.
        // Without this, a CRC-valid but mis-shaped section would load
        // cleanly and decode() would silently drop trailing bits.
        if cursor > rows * cols {
            return Err(Error::store(format!(
                "relative stream advances to position {cursor} of a {rows}x{cols} mask"
            )));
        }
        Ok(Csr5Relative { rows, cols, entries, nnz })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn encode_matches_gap_semantics() {
        // mask: positions 0, 2 in a 1x8 row -> gaps [0, 1]
        let mask = BitMatrix::from_fn(1, 8, |_, j| j == 0 || j == 2);
        let enc = Csr5Relative::encode(&mask);
        assert_eq!(enc.entries, vec![0, 1]);
        assert_eq!(enc.nnz(), 2);
    }

    #[test]
    fn long_gap_inserts_filler() {
        // single 1 at position 40: gap 40 = filler(31) + real gap 9
        let mask = BitMatrix::from_fn(1, 64, |_, j| j == 40);
        let enc = Csr5Relative::encode(&mask);
        assert_eq!(enc.entries, vec![31, 9]);
        assert_eq!(enc.nnz(), 1);
        assert_eq!(enc.decode(), mask);
    }

    #[test]
    fn gap_exactly_31_boundary() {
        // gap 31 must become filler(31) + real(0): real gaps are < 31.
        let mask = BitMatrix::from_fn(1, 64, |_, j| j == 31);
        let enc = Csr5Relative::encode(&mask);
        assert_eq!(enc.entries, vec![31, 0]);
        assert_eq!(enc.decode(), mask);
    }

    #[test]
    fn roundtrip_random_sparse() {
        prop::check("csr5 roundtrip", 12, |rng| {
            let m = prop::dim(rng, 1, 20);
            let n = prop::dim(rng, 1, 120);
            let d = rng.next_f64() * 0.3;
            let mut r2 = Rng::new(rng.next_u64());
            let mask = BitMatrix::from_fn(m, n, |_, _| r2.bernoulli(d));
            let enc = Csr5Relative::encode(&mask);
            assert_eq!(enc.decode(), mask);
        });
    }

    #[test]
    fn packed_bytes_roundtrip() {
        prop::check("csr5 packed roundtrip", 12, |rng| {
            let m = prop::dim(rng, 1, 16);
            let n = prop::dim(rng, 1, 150);
            let d = rng.next_f64() * 0.4;
            let mut r2 = Rng::new(rng.next_u64());
            let mask = BitMatrix::from_fn(m, n, |_, _| r2.bernoulli(d));
            let enc = Csr5Relative::encode(&mask);
            let packed = enc.to_packed_bytes();
            assert_eq!(packed.len(), enc.index_bytes());
            let back =
                Csr5Relative::from_packed_bytes(m, n, enc.entry_count(), &packed).unwrap();
            assert_eq!(back.decode(), mask);
            assert_eq!(back.nnz(), enc.nnz());
        });
        assert!(Csr5Relative::from_packed_bytes(1, 8, 9, &[0u8; 2]).is_err());
        // semantically invalid: 9 zero-gap entries walk past a 1x8 mask
        // even though the byte length (ceil(45/8) = 6) is consistent
        assert!(Csr5Relative::from_packed_bytes(1, 8, 9, &[0u8; 6]).is_err());
    }

    #[test]
    fn sparser_uses_more_fillers_but_fewer_bytes_than_csr16() {
        let mut rng = Rng::new(5);
        let mask = BitMatrix::from_fn(200, 200, |_, _| rng.bernoulli(0.05));
        let c5 = Csr5Relative::encode(&mask);
        let c16 = crate::formats::csr::Csr16::encode(&mask).unwrap();
        assert!(c5.index_bytes() < c16.index_bytes() / 2);
        assert!(c5.entry_count() >= c5.nnz());
    }
}
