//! CSR with 5-bit *relative* (gap) indexing — Deep Compression's
//! scheme [9]: store the column gap to the previous non-zero in 5
//! bits; when a gap exceeds 31, insert filler entries (gap 31 that do
//! not correspond to a weight) until the remainder fits.

use crate::util::bits::BitMatrix;

/// 5-bit relative-index stream.
#[derive(Debug, Clone)]
pub struct Csr5Relative {
    rows: usize,
    cols: usize,
    /// Entries are (gap, is_real). Stored packed 5 bits each; fillers
    /// are entries with gap == MAX_GAP that carry no weight.
    entries: Vec<u8>,
    /// Real non-zero count (excludes fillers).
    nnz: usize,
}

/// Maximum representable gap (2^5 - 1).
pub const MAX_GAP: u32 = 31;

impl Csr5Relative {
    /// Encode a mask as a flat row-major gap stream. Entry values
    /// 0..=30 are real gaps; the sentinel 31 is a filler advancing the
    /// cursor 31 positions without emitting a weight (Deep Compression
    /// pads with an explicit zero weight instead — byte-for-byte the
    /// stream length is the same, and ours round-trips the mask
    /// exactly).
    pub fn encode(mask: &BitMatrix) -> Self {
        let (rows, cols) = (mask.rows(), mask.cols());
        let mut entries = Vec::new();
        let mut nnz = 0usize;
        let mut gap: u32 = 0;
        for i in 0..rows {
            for j in 0..cols {
                if mask.get(i, j) {
                    while gap >= MAX_GAP {
                        entries.push(MAX_GAP as u8);
                        gap -= MAX_GAP;
                    }
                    entries.push(gap as u8);
                    nnz += 1;
                    gap = 0;
                } else {
                    gap += 1;
                }
            }
        }
        Csr5Relative { rows, cols, entries, nnz }
    }

    /// Recover the mask: sentinel entries (31) accumulate skip
    /// distance; every other entry places one mask bit.
    pub fn decode(&self) -> BitMatrix {
        let mut mask = BitMatrix::zeros(self.rows, self.cols);
        let total = self.rows * self.cols;
        let mut pos: usize = 0; // next candidate position
        let mut pending: u32 = 0; // accumulated gap from fillers
        for &e in &self.entries {
            if e as u32 == MAX_GAP {
                pending += MAX_GAP;
                continue;
            }
            pos += (pending + e as u32) as usize;
            pending = 0;
            if pos < total {
                mask.set(pos / self.cols, pos % self.cols, true);
            }
            pos += 1;
        }
        mask
    }

    /// Real non-zeros represented.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// The raw gap stream (values `0..=30` are real gaps, `31` is a
    /// filler). Exposed so execution kernels can stream the entries
    /// without re-encoding — see `serve::kernels::RelativeKernel`.
    pub fn entries(&self) -> &[u8] {
        &self.entries
    }

    /// Total 5-bit entries including fillers.
    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Packed size: ceil(5 * entries / 8) bytes.
    pub fn index_bytes(&self) -> usize {
        (self.entries.len() * 5).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn encode_matches_gap_semantics() {
        // mask: positions 0, 2 in a 1x8 row -> gaps [0, 1]
        let mask = BitMatrix::from_fn(1, 8, |_, j| j == 0 || j == 2);
        let enc = Csr5Relative::encode(&mask);
        assert_eq!(enc.entries, vec![0, 1]);
        assert_eq!(enc.nnz(), 2);
    }

    #[test]
    fn long_gap_inserts_filler() {
        // single 1 at position 40: gap 40 = filler(31) + real gap 9
        let mask = BitMatrix::from_fn(1, 64, |_, j| j == 40);
        let enc = Csr5Relative::encode(&mask);
        assert_eq!(enc.entries, vec![31, 9]);
        assert_eq!(enc.nnz(), 1);
        assert_eq!(enc.decode(), mask);
    }

    #[test]
    fn gap_exactly_31_boundary() {
        // gap 31 must become filler(31) + real(0): real gaps are < 31.
        let mask = BitMatrix::from_fn(1, 64, |_, j| j == 31);
        let enc = Csr5Relative::encode(&mask);
        assert_eq!(enc.entries, vec![31, 0]);
        assert_eq!(enc.decode(), mask);
    }

    #[test]
    fn roundtrip_random_sparse() {
        prop::check("csr5 roundtrip", 12, |rng| {
            let m = prop::dim(rng, 1, 20);
            let n = prop::dim(rng, 1, 120);
            let d = rng.next_f64() * 0.3;
            let mut r2 = Rng::new(rng.next_u64());
            let mask = BitMatrix::from_fn(m, n, |_, _| r2.bernoulli(d));
            let enc = Csr5Relative::encode(&mask);
            assert_eq!(enc.decode(), mask);
        });
    }

    #[test]
    fn sparser_uses_more_fillers_but_fewer_bytes_than_csr16() {
        let mut rng = Rng::new(5);
        let mask = BitMatrix::from_fn(200, 200, |_, _| rng.bernoulli(0.05));
        let c5 = Csr5Relative::encode(&mask);
        let c16 = crate::formats::csr::Csr16::encode(&mask);
        assert!(c5.index_bytes() < c16.index_bytes() / 2);
        assert!(c5.entry_count() >= c5.nnz());
    }
}
