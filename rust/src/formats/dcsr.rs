//! dCSR: delta-compressed CSR column indexing (Trommer et al. 2021).
//! The strongest published competitor to the 5-bit relative stream:
//! column indices are stored as 4-bit deltas to the previous non-zero
//! in a flat row-major stream, with an escape nibble for long gaps —
//! designed so embedded decoders can expand segments in parallel.
//!
//! Encoding here: nibble values `0..=14` are real gaps (advance
//! `gap + 1` positions and place a weight); the sentinel `15` is an
//! escape advancing 15 positions without emitting a weight. This is
//! structurally the [`Csr5Relative`](crate::formats::relative) scheme
//! at 4 bits, which keeps the two kernels head-to-head comparable:
//! same stream walk, half-width entries, more escapes at low density.

use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};

/// Largest nibble value — the escape sentinel. Real gaps are `0..=14`.
pub const ESCAPE: u32 = 15;

/// 4-bit delta-index stream.
#[derive(Debug, Clone)]
pub struct DcsrIndex {
    rows: usize,
    cols: usize,
    /// One byte per logical 4-bit entry in memory (nibble-packed only
    /// on disk). Values `0..=14` are real gaps; `15` is an escape.
    entries: Vec<u8>,
    /// Real non-zero count (excludes escapes).
    nnz: usize,
}

impl DcsrIndex {
    /// Encode a mask as a flat row-major 4-bit delta stream.
    pub fn encode(mask: &BitMatrix) -> Self {
        let (rows, cols) = (mask.rows(), mask.cols());
        let mut entries = Vec::new();
        let mut nnz = 0usize;
        let mut gap: u32 = 0;
        for i in 0..rows {
            for j in 0..cols {
                if mask.get(i, j) {
                    while gap >= ESCAPE {
                        entries.push(ESCAPE as u8);
                        gap -= ESCAPE;
                    }
                    entries.push(gap as u8);
                    nnz += 1;
                    gap = 0;
                } else {
                    gap += 1;
                }
            }
        }
        DcsrIndex { rows, cols, entries, nnz }
    }

    /// Recover the mask: escapes accumulate skip distance; every other
    /// entry places one mask bit.
    pub fn decode(&self) -> BitMatrix {
        let mut mask = BitMatrix::zeros(self.rows, self.cols);
        let total = self.rows * self.cols;
        let mut pos: usize = 0;
        let mut pending: u32 = 0;
        for &e in &self.entries {
            if e as u32 == ESCAPE {
                pending += ESCAPE;
                continue;
            }
            pos += (pending + e as u32) as usize;
            pending = 0;
            if pos < total {
                mask.set(pos / self.cols, pos % self.cols, true);
            }
            pos += 1;
        }
        mask
    }

    /// Real non-zeros represented.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// The raw delta stream (values `0..=14` are real gaps, `15` is an
    /// escape). Exposed so the execution kernel can stream the entries
    /// without re-encoding — see `serve::kernels::DcsrKernel`.
    pub fn entries(&self) -> &[u8] {
        &self.entries
    }

    /// Total 4-bit entries including escapes.
    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Consume the stream, yielding the raw entry vector without a
    /// copy.
    pub fn into_entries(self) -> Vec<u8> {
        self.entries
    }

    /// Packed size: ceil(4 * entries / 8) bytes (two nibbles a byte).
    pub fn index_bytes(&self) -> usize {
        (self.entries.len() * 4).div_ceil(8)
    }

    /// Mask rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Mask cols.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Pack the delta stream two nibbles per byte, low nibble first —
    /// the on-disk form, exactly `index_bytes()` long.
    pub fn to_packed_bytes(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.index_bytes()];
        for (idx, &e) in self.entries.iter().enumerate() {
            out[idx / 2] |= (e & 0x0F) << ((idx % 2) * 4);
        }
        out
    }

    /// Rebuild from the packed on-disk form (the store read path).
    /// `entry_count` disambiguates a trailing pad nibble.
    pub fn from_packed_bytes(
        rows: usize,
        cols: usize,
        entry_count: usize,
        bytes: &[u8],
    ) -> Result<Self> {
        let need = (entry_count * 4).div_ceil(8);
        if bytes.len() != need {
            return Err(Error::store(format!(
                "dcsr index payload: {} bytes for {entry_count} entries, need {need}",
                bytes.len()
            )));
        }
        let mut entries = Vec::with_capacity(entry_count);
        let mut nnz = 0usize;
        let mut cursor = 0usize; // mask position the stream advances to
        for idx in 0..entry_count {
            let e = (bytes[idx / 2] >> ((idx % 2) * 4)) & 0x0F;
            if e as u32 == ESCAPE {
                cursor += ESCAPE as usize;
            } else {
                cursor += e as usize + 1;
                nnz += 1;
            }
            entries.push(e);
        }
        // Semantic validation, mirroring Csr5Relative: a CRC-valid but
        // mis-shaped stream must not load and silently drop bits.
        if cursor > rows * cols {
            return Err(Error::store(format!(
                "dcsr stream advances to position {cursor} of a {rows}x{cols} mask"
            )));
        }
        Ok(DcsrIndex { rows, cols, entries, nnz })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn encode_matches_gap_semantics() {
        // mask: positions 0, 2 in a 1x8 row -> gaps [0, 1]
        let mask = BitMatrix::from_fn(1, 8, |_, j| j == 0 || j == 2);
        let enc = DcsrIndex::encode(&mask);
        assert_eq!(enc.entries, vec![0, 1]);
        assert_eq!(enc.nnz(), 2);
    }

    #[test]
    fn long_gap_inserts_escape() {
        // single 1 at position 40: gap 40 = escape(15)*2 + real gap 10
        let mask = BitMatrix::from_fn(1, 64, |_, j| j == 40);
        let enc = DcsrIndex::encode(&mask);
        assert_eq!(enc.entries, vec![15, 15, 10]);
        assert_eq!(enc.nnz(), 1);
        assert_eq!(enc.decode(), mask);
    }

    #[test]
    fn gap_exactly_15_boundary() {
        // gap 15 must become escape(15) + real(0): real gaps are < 15.
        let mask = BitMatrix::from_fn(1, 32, |_, j| j == 15);
        let enc = DcsrIndex::encode(&mask);
        assert_eq!(enc.entries, vec![15, 0]);
        assert_eq!(enc.decode(), mask);
    }

    #[test]
    fn roundtrip_random_sparse() {
        prop::check("dcsr roundtrip", 12, |rng| {
            let m = prop::dim(rng, 1, 20);
            let n = prop::dim(rng, 1, 120);
            let d = rng.next_f64() * 0.3;
            let mut r2 = Rng::new(rng.next_u64());
            let mask = BitMatrix::from_fn(m, n, |_, _| r2.bernoulli(d));
            let enc = DcsrIndex::encode(&mask);
            assert_eq!(enc.decode(), mask);
        });
    }

    #[test]
    fn packed_bytes_roundtrip() {
        prop::check("dcsr packed roundtrip", 12, |rng| {
            let m = prop::dim(rng, 1, 16);
            let n = prop::dim(rng, 1, 150);
            let d = rng.next_f64() * 0.4;
            let mut r2 = Rng::new(rng.next_u64());
            let mask = BitMatrix::from_fn(m, n, |_, _| r2.bernoulli(d));
            let enc = DcsrIndex::encode(&mask);
            let packed = enc.to_packed_bytes();
            assert_eq!(packed.len(), enc.index_bytes());
            let back = DcsrIndex::from_packed_bytes(m, n, enc.entry_count(), &packed).unwrap();
            assert_eq!(back.decode(), mask);
            assert_eq!(back.nnz(), enc.nnz());
        });
        assert!(DcsrIndex::from_packed_bytes(1, 8, 9, &[0u8; 2]).is_err());
        // semantically invalid: 9 zero-gap entries walk past a 1x8 mask
        // even though the byte length (ceil(36/8) = 5) is consistent
        assert!(DcsrIndex::from_packed_bytes(1, 8, 9, &[0u8; 5]).is_err());
    }

    #[test]
    fn denser_streams_beat_relative_at_moderate_sparsity() {
        // At moderate density the 4-bit stream undercuts the 5-bit
        // relative stream (few escapes); at extreme sparsity escapes
        // erode the advantage — both facts the bench tables report.
        let mut rng = Rng::new(5);
        let mask = BitMatrix::from_fn(200, 200, |_, _| rng.bernoulli(0.2));
        let d = DcsrIndex::encode(&mask);
        let r = crate::formats::relative::Csr5Relative::encode(&mask);
        assert!(d.index_bytes() < r.index_bytes());
        assert!(d.entry_count() >= d.nnz());
    }
}
