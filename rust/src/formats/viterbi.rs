//! Viterbi-based pruning-index compression — the [14] baseline.
//!
//! The scheme stores only the *input* bit-stream of a rate-1/R
//! convolutional encoder; the decompressor regenerates R mask bits per
//! input bit. Compression ratio is therefore fixed at R (the paper's
//! "5X Encoder"). Like our BMF format, the encoder cannot represent an
//! arbitrary mask: a trellis (Viterbi) search chooses the input stream
//! whose *output* mask keeps the largest weight magnitudes at the
//! target sparsity.
//!
//! Implementation: constraint-length-7 shift register; output bit `r`
//! of step `t` is `popcount(state & GEN[r]) & 1` xor-ed over taps —
//! the classic feed-forward convolutional code. Branch metric rewards
//! keeping large-|W| positions and penalises keeping positions the
//! magnitude-pruned mask discards, with a Lagrange weight λ bisected
//! until the output sparsity matches the target.

use crate::tensor::Matrix;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};

/// Outputs per input bit (the paper's 5×).
pub const RATE: usize = 5;
/// Shift-register length (constraint length 7 → 64 states).
const K: usize = 6;
const NSTATES: usize = 1 << K;
/// Generator taps (one per output), picked from standard odd-weight
/// polynomials so outputs are balanced and well-mixed.
const GEN: [u64; RATE] = [0b1011011, 0b1111001, 0b1100101, 0b1010111, 0b1101101];

/// Index size in bytes for an m×n mask. Each row stores
/// `ceil(n/RATE)` input bits (rows are padded to a whole step so the
/// hardware can decode them independently), so the total is
/// `ceil(m·ceil(n/RATE) / 8)` bytes — matching the packed layout
/// `compress` actually emits. (An earlier revision computed
/// `ceil(ceil(mn/RATE)/8)`, which under-reports whenever `n % RATE
/// != 0` because it amortises the per-row padding across rows.)
pub fn index_bytes(m: usize, n: usize) -> usize {
    (m * n.div_ceil(RATE)).div_ceil(8)
}

/// Encoder output for (state, input) — RATE mask bits.
#[inline]
fn emit(state: u64, input: u64) -> [bool; RATE] {
    let reg = (state << 1) | input; // K+1 bits of history
    let mut out = [false; RATE];
    for (r, g) in GEN.iter().enumerate() {
        out[r] = ((reg & g).count_ones() & 1) == 1;
    }
    out
}

/// A compressed Viterbi index: one input bit per RATE mask bits,
/// stored per row (the hardware decodes rows in parallel, paper §1).
#[derive(Debug, Clone)]
pub struct ViterbiIndex {
    rows: usize,
    cols: usize,
    /// Input bits, row-major, `ceil(cols/RATE)` per row.
    inputs: Vec<u8>,
}

/// Result of Viterbi mask search.
#[derive(Debug)]
pub struct ViterbiResult {
    /// The compressed index.
    pub index: ViterbiIndex,
    /// The (approximate) mask the decompressor will regenerate.
    pub mask: BitMatrix,
    /// Magnitude-sum of weights the magnitude-pruned reference keeps
    /// but this mask prunes (same Cost definition as Algorithm 1).
    pub cost: f64,
    /// Achieved sparsity.
    pub sparsity: f64,
}

impl ViterbiIndex {
    /// Input bits per row.
    fn steps(cols: usize) -> usize {
        cols.div_ceil(RATE)
    }

    /// Decode the full mask (what the on-chip decompressor does).
    pub fn decode(&self) -> BitMatrix {
        let mut mask = BitMatrix::zeros(self.rows, self.cols);
        let mut words = vec![0u64; self.cols.div_ceil(64)];
        for i in 0..self.rows {
            self.decode_row_words(i, &mut words);
            for (wi, &w) in words.iter().enumerate() {
                let mut bits = w;
                while bits != 0 {
                    let j = wi * 64 + bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    mask.set(i, j, true);
                }
            }
        }
        mask
    }

    /// Regenerate row `i`'s mask bits straight into packed 64-bit
    /// words (`words` must hold at least `ceil(cols/64)` — extra words
    /// are zeroed), without materializing the dense mask: each row's
    /// shift register restarts at state 0, which is exactly what lets
    /// the hardware (and the execution kernel's row shards) decode
    /// rows in parallel. Bits at columns `>= cols` in the truncated
    /// final step are dropped, so padding words stay clear.
    pub fn decode_row_words(&self, i: usize, words: &mut [u64]) {
        words.fill(0);
        let steps = Self::steps(self.cols);
        let mut state = 0u64;
        for t in 0..steps {
            let bit_idx = i * steps + t;
            let input = (self.inputs[bit_idx / 8] >> (bit_idx % 8)) as u64 & 1;
            let out = emit(state, input);
            for (r, &o) in out.iter().enumerate() {
                let j = t * RATE + r;
                if j < self.cols && o {
                    words[j / 64] |= 1u64 << (j % 64);
                }
            }
            state = ((state << 1) | input) & (NSTATES as u64 - 1);
        }
    }

    /// Exact non-zero count of the decoded mask, via the same per-row
    /// regeneration the execution kernel runs (used to size its row
    /// shards deterministically — no dense mask is built).
    pub fn nnz(&self) -> usize {
        let mut words = vec![0u64; self.cols.div_ceil(64)];
        let mut n = 0usize;
        for i in 0..self.rows {
            self.decode_row_words(i, &mut words);
            n += words.iter().map(|w| w.count_ones() as usize).sum::<usize>();
        }
        n
    }

    /// Stored bytes.
    pub fn index_bytes(&self) -> usize {
        self.inputs.len()
    }

    /// Mask rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Mask cols.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The packed input bit-stream (row-major, `ceil(cols/RATE)` bits
    /// per row, LSB-first) — the on-disk form, exactly
    /// `index_bytes()` long. Exposed so the execution kernel can walk
    /// the shift register straight off the stored bits.
    pub fn bytes(&self) -> &[u8] {
        &self.inputs
    }

    /// Rebuild from the packed on-disk form (the store read path).
    pub fn from_bytes(rows: usize, cols: usize, inputs: Vec<u8>) -> Result<Self> {
        let need = index_bytes(rows, cols);
        if inputs.len() != need {
            return Err(Error::store(format!(
                "viterbi index payload: {} bytes for {rows}x{cols}, need {need}",
                inputs.len()
            )));
        }
        Ok(ViterbiIndex { rows, cols, inputs })
    }

    /// Deterministically re-encode an already-chosen mask: per row,
    /// run the trellis with score +1 for mask-set positions and −1
    /// otherwise (no λ search), so the emitted stream is the encoder's
    /// best approximation of `mask`. Both kernel construction paths
    /// (from factors and from a stored artifact) route through this,
    /// which is what makes them bitwise identical.
    pub fn shape_mask(mask: &BitMatrix) -> ViterbiIndex {
        let (rows, cols) = (mask.rows(), mask.cols());
        let steps = Self::steps(cols);
        let mut packed = vec![0u8; (rows * steps).div_ceil(8)];
        let mut scores = vec![0.0f64; cols];
        for i in 0..rows {
            for (j, s) in scores.iter_mut().enumerate() {
                *s = if mask.get(i, j) { 1.0 } else { -1.0 };
            }
            let (inputs, _) = search_row(&scores, cols);
            for (t, &b) in inputs.iter().enumerate() {
                if b {
                    let idx = i * steps + t;
                    packed[idx / 8] |= 1 << (idx % 8);
                }
            }
        }
        ViterbiIndex { rows, cols, inputs: packed }
    }
}

/// Viterbi (max-sum trellis) search for the best input stream of one
/// row given per-position scores: score[j] is ADDED when mask bit j
/// is 1. Returns (input bits, emitted mask bits).
fn search_row(scores: &[f64], cols: usize) -> (Vec<bool>, Vec<bool>) {
    let steps = ViterbiIndex::steps(cols);
    // metric[state] plus backpointers per step
    let mut metric = vec![f64::NEG_INFINITY; NSTATES];
    metric[0] = 0.0;
    let mut bp: Vec<[u8; NSTATES]> = Vec::with_capacity(steps);
    for t in 0..steps {
        let mut next = vec![f64::NEG_INFINITY; NSTATES];
        let mut back = [0u8; NSTATES];
        for s in 0..NSTATES {
            if metric[s] == f64::NEG_INFINITY {
                continue;
            }
            for input in 0..2u64 {
                let out = emit(s as u64, input);
                let mut gain = 0.0;
                for (r, &o) in out.iter().enumerate() {
                    let j = t * RATE + r;
                    if o && j < cols {
                        gain += scores[j];
                    }
                }
                let ns = (((s as u64) << 1) | input) as usize & (NSTATES - 1);
                let cand = metric[s] + gain;
                if cand > next[ns] {
                    next[ns] = cand;
                    // pack (prev state, input) — prev state is
                    // recoverable from ns and input? ns low bit = input,
                    // prev = (ns >> 1) | (dropped bit << (K-1)): store
                    // the dropped bit.
                    back[ns] = ((s >> (K - 1)) as u8) << 1 | input as u8;
                }
            }
        }
        metric = next;
        bp.push(back);
    }
    // pick best terminal state, walk back
    let mut best = 0usize;
    for s in 1..NSTATES {
        if metric[s] > metric[best] {
            best = s;
        }
    }
    let mut inputs = vec![false; steps];
    let mut s = best;
    for t in (0..steps).rev() {
        let packed = bp[t][s];
        let input = packed & 1;
        let dropped = (packed >> 1) as usize;
        inputs[t] = input == 1;
        s = (s >> 1) | (dropped << (K - 1));
    }
    // re-emit mask bits forward
    let mut mask_bits = vec![false; cols];
    let mut state = 0u64;
    for (t, &inp) in inputs.iter().enumerate() {
        let out = emit(state, inp as u64);
        for (r, &o) in out.iter().enumerate() {
            let j = t * RATE + r;
            if j < cols {
                mask_bits[j] = o;
            }
        }
        state = ((state << 1) | inp as u64) & (NSTATES as u64 - 1);
    }
    (inputs, mask_bits)
}

/// Compress a weight matrix's pruning index with the Viterbi scheme at
/// target sparsity `s`. λ is bisected so the kept fraction matches.
pub fn compress(w: &Matrix, s: f64) -> Result<ViterbiResult> {
    if !(0.0..1.0).contains(&s) {
        return Err(Error::invalid("sparsity outside [0,1)"));
    }
    let (rows, cols) = (w.rows(), w.cols());
    let mags = w.abs();
    let max_mag = mags.max_abs() as f64;
    // score_j = |W_ij| - λ : keeping a weight is worth its magnitude
    // minus the sparsity price.
    let run = |lambda: f64| -> (Vec<Vec<bool>>, BitMatrix) {
        let mut inputs = Vec::with_capacity(rows);
        let mut mask = BitMatrix::zeros(rows, cols);
        for i in 0..rows {
            let scores: Vec<f64> =
                mags.row(i).iter().map(|&m| m as f64 - lambda).collect();
            let (inp, bits) = search_row(&scores, cols);
            for (j, &b) in bits.iter().enumerate() {
                if b {
                    mask.set(i, j, true);
                }
            }
            inputs.push(inp);
        }
        (inputs, mask)
    };
    let mut lo = 0.0f64;
    let mut hi = max_mag;
    let mut best = run(max_mag * s);
    for _ in 0..18 {
        let sp = best.1.sparsity();
        if (sp - s).abs() < 5e-3 {
            break;
        }
        let mid = 0.5 * (lo + hi);
        let cand = run(mid);
        if cand.1.sparsity() < s {
            lo = mid; // not sparse enough -> raise λ
        } else {
            hi = mid;
        }
        best = cand;
    }
    let (inputs, mask) = best;
    // pack input bits
    let steps = ViterbiIndex::steps(cols);
    let mut packed = vec![0u8; (rows * steps).div_ceil(8)];
    for (i, row) in inputs.iter().enumerate() {
        for (t, &b) in row.iter().enumerate() {
            if b {
                let idx = i * steps + t;
                packed[idx / 8] |= 1 << (idx % 8);
            }
        }
    }
    let index = ViterbiIndex { rows, cols, inputs: packed };
    // cost vs the magnitude-pruned reference
    let (reference, _) = crate::pruning::magnitude_mask(w, s);
    let mut cost = 0.0;
    for i in 0..rows {
        for j in 0..cols {
            if reference.get(i, j) && !mask.get(i, j) {
                cost += mags.get(i, j) as f64;
            }
        }
    }
    Ok(ViterbiResult { sparsity: mask.sparsity(), index, mask, cost })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn index_size_is_one_fifth_of_binary() {
        // Table 1R: 800x500 -> Viterbi 10.0KB vs Binary 50.0KB.
        assert_eq!(index_bytes(800, 500), 10_000);
        // Table 3: FC5 922KB (KB=1000): 9216*4096/5/8 = 943,718 B ≈ 921.6 KiB
        let fc5 = index_bytes(9216, 4096);
        assert!((fc5 as f64 / 1024.0 - 921.6).abs() < 1.0);
    }

    #[test]
    fn index_bytes_matches_stored_layout_on_odd_shapes() {
        // The per-row layout pads each row to a whole step, so the
        // free function must agree with what compress() actually
        // stores — in particular when cols % RATE != 0 (the old
        // double-div_ceil formula under-reported there).
        let mut rng = Rng::new(9);
        for (m, n) in [(3usize, 7usize), (5, 11), (13, 29), (7, 64), (1, 1), (9, 5)] {
            assert_eq!(index_bytes(m, n), (m * n.div_ceil(RATE)).div_ceil(8));
            let w = Matrix::gaussian(m, n, 0.0, 1.0, &mut rng);
            let res = compress(&w, 0.7).unwrap();
            assert_eq!(
                res.index.index_bytes(),
                index_bytes(m, n),
                "{m}x{n}: stored bytes disagree with index_bytes()"
            );
            // bytes → from_bytes round-trip decodes identically
            let back =
                ViterbiIndex::from_bytes(m, n, res.index.bytes().to_vec()).unwrap();
            assert_eq!(back.decode(), res.index.decode(), "{m}x{n}");
            // wrong length is a typed store error
            assert!(ViterbiIndex::from_bytes(m, n, vec![0; index_bytes(m, n) + 1]).is_err());
        }
    }

    #[test]
    fn shape_mask_is_deterministic_and_idempotent() {
        let mut rng = Rng::new(11);
        let w = Matrix::gaussian(10, 47, 0.0, 1.0, &mut rng);
        let res = compress(&w, 0.8).unwrap();
        // re-shaping a mask the encoder itself produced reproduces the
        // exact same input stream (the trellis has no reason to differ)
        let reshaped = ViterbiIndex::shape_mask(&res.mask);
        assert_eq!(reshaped.bytes(), res.index.bytes());
        assert_eq!(reshaped.decode(), res.mask);
        // and it is a pure function of the mask
        let again = ViterbiIndex::shape_mask(&res.mask);
        assert_eq!(again.bytes(), reshaped.bytes());
        // the all-zero mask is representable exactly
        let z = ViterbiIndex::shape_mask(&BitMatrix::zeros(4, 23));
        assert_eq!(z.decode(), BitMatrix::zeros(4, 23));
    }

    #[test]
    fn decode_reproduces_search_output() {
        let mut rng = Rng::new(1);
        let w = Matrix::gaussian(8, 50, 0.0, 1.0, &mut rng);
        let res = compress(&w, 0.8).unwrap();
        assert_eq!(res.index.decode(), res.mask, "decompressor must be exact");
    }

    #[test]
    fn achieves_target_sparsity_approximately() {
        let mut rng = Rng::new(2);
        let w = Matrix::gaussian(16, 100, 0.0, 1.0, &mut rng);
        for s in [0.6, 0.9] {
            let res = compress(&w, s).unwrap();
            assert!(
                (res.sparsity - s).abs() < 0.08,
                "target {s}, got {}",
                res.sparsity
            );
        }
    }

    #[test]
    fn keeps_heavier_weights_than_random() {
        // The trellis should prune mostly small weights: kept mean |w|
        // must clearly exceed the overall mean |w|.
        let mut rng = Rng::new(3);
        let w = Matrix::gaussian(12, 80, 0.0, 1.0, &mut rng);
        let res = compress(&w, 0.8).unwrap();
        let mags = w.abs();
        let mut kept_sum = 0.0;
        let mut kept_n = 0.0f64;
        for i in 0..12 {
            for j in 0..80 {
                if res.mask.get(i, j) {
                    kept_sum += mags.get(i, j) as f64;
                    kept_n += 1.0;
                }
            }
        }
        let kept_mean = kept_sum / kept_n.max(1.0);
        let overall = mags.mean();
        assert!(
            kept_mean > overall * 1.3,
            "kept mean {kept_mean} vs overall {overall}"
        );
    }

    #[test]
    fn emit_is_deterministic_and_balanced() {
        // across all (state, input), each output bit should be ~50/50
        let mut ones = [0u32; RATE];
        for s in 0..NSTATES as u64 {
            for i in 0..2 {
                let out = emit(s, i);
                for (r, &o) in out.iter().enumerate() {
                    if o {
                        ones[r] += 1;
                    }
                }
            }
        }
        let total = (NSTATES * 2) as u32;
        for (r, &c) in ones.iter().enumerate() {
            assert_eq!(c, total / 2, "output {r} unbalanced: {c}/{total}");
        }
    }
}
