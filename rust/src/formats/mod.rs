//! Sparse pruning-index representation formats (Figure 1, Tables 1R/3).
//!
//! Every format answers two questions: *how many bytes does the index
//! take* and *can the exact mask be recovered* (encode/decode
//! round-trip). Two of the formats — Viterbi and low-rank — are
//! *mask-shaping* formats: they do not store an arbitrary mask but
//! constrain which masks are representable, trading unintended prunes
//! (Cost) for a fixed compression ratio.

pub mod binary;
pub mod csr;
pub mod lowrank;
pub mod relative;
pub mod viterbi;

use crate::tensor::Matrix;

/// A row of the format-comparison tables.
#[derive(Debug, Clone)]
pub struct FormatRow {
    /// Format name as printed in the paper.
    pub name: String,
    /// Index size in bytes.
    pub bytes: usize,
    /// Paper-style comment column.
    pub comment: String,
}

impl FormatRow {
    /// Size in KB (paper uses KB = 1000 B for Table 1, KiB-ish for
    /// Table 3; we print KB = 1000 B and note the delta).
    pub fn kb(&self) -> f64 {
        self.bytes as f64 / 1000.0
    }
}

/// Compare all index formats on a mask derived from `w` at sparsity
/// `s`; `lowrank_bits` is the proposed format's index budget in bits
/// (k(m+n), possibly tiled). Produces the rows of Table 1 (right) /
/// Table 3.
pub fn format_comparison(
    w: &Matrix,
    s: f64,
    lowrank_bits: usize,
    lowrank_comment: &str,
) -> Vec<FormatRow> {
    let (mask, _) = crate::pruning::magnitude_mask(w, s);
    let bin = binary::BinaryIndex::encode(&mask);
    let c16 = csr::Csr16::encode(&mask);
    let c5 = relative::Csr5Relative::encode(&mask);
    let vit_bytes = viterbi::index_bytes(mask.rows(), mask.cols());
    vec![
        FormatRow {
            name: "Binary".into(),
            bytes: bin.index_bytes(),
            comment: "1bit/weight".into(),
        },
        FormatRow {
            name: "CSR(16bit)".into(),
            bytes: c16.index_bytes(),
            comment: String::new(),
        },
        FormatRow {
            name: "CSR(5bit)".into(),
            bytes: c5.index_bytes(),
            comment: "Relative Indexing".into(),
        },
        FormatRow {
            name: "Viterbi".into(),
            bytes: vit_bytes,
            comment: "5X Encoder".into(),
        },
        FormatRow {
            name: "Proposed".into(),
            bytes: lowrank_bits.div_ceil(8),
            comment: lowrank_comment.into(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn table1_right_shape_holds() {
        // FC1 800x500 at S=0.95, proposed k=16.
        let mut rng = Rng::new(1);
        let w = Matrix::gaussian(800, 500, 0.0, 0.1, &mut rng);
        let rows = format_comparison(&w, 0.95, 16 * (800 + 500), "k=16");
        let get = |n: &str| rows.iter().find(|r| r.name == n).unwrap().kb();
        // paper: Binary 50.0, CSR16 45.8, CSR5 14.3, Viterbi 10.0, ours 2.6
        assert_eq!(get("Binary"), 50.0);
        assert!((get("CSR(16bit)") - 45.8).abs() < 4.0, "csr16 {}", get("CSR(16bit)"));
        assert!((get("CSR(5bit)") - 14.3).abs() < 2.0, "csr5 {}", get("CSR(5bit)"));
        assert_eq!(get("Viterbi"), 10.0);
        assert_eq!(get("Proposed"), 2.6);
        // ordering must match the paper exactly
        let sizes: Vec<f64> = rows.iter().map(|r| r.kb()).collect();
        for pair in sizes.windows(2) {
            assert!(pair[0] > pair[1], "sizes must strictly decrease: {sizes:?}");
        }
    }
}
