//! Sparse pruning-index representation formats (Figure 1, Tables 1R/3).
//!
//! Every format answers two questions: *how many bytes does the index
//! take* and *can the exact mask be recovered* (encode/decode
//! round-trip). Two of the formats — Viterbi and low-rank — are
//! *mask-shaping* formats: they do not store an arbitrary mask but
//! constrain which masks are representable, trading unintended prunes
//! (Cost) for a fixed compression ratio.

pub mod binary;
pub mod csr;
pub mod dcsr;
pub mod lowrank;
pub mod relative;
pub mod viterbi;

use crate::tensor::Matrix;
use crate::tiling::TiledLowRankIndex;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};

/// A serialized pruning index in any storable representation — the
/// union the `.lrbi` artifact container reads and writes. Each variant
/// wraps the existing format struct unchanged, so a loaded artifact
/// decodes *straight into* the in-memory representation its execution
/// kernel consumes (see `serve::kernels::build_kernel_from_stored`) —
/// no dense-mask detour on the load path.
#[derive(Debug, Clone)]
pub enum StoredIndex {
    /// Dense bitmap, 1 bit/weight.
    Binary(binary::BinaryIndex),
    /// CSR with 16-bit column indices.
    Csr(csr::Csr16),
    /// 5-bit relative (gap) stream.
    Relative(relative::Csr5Relative),
    /// Packed low-rank factor pair `(I_p, I_z)`.
    LowRank(lowrank::LowRankIndex),
    /// Tiled low-rank: plan + per-tile factor pairs (per-tile ranks).
    Tiled(TiledLowRankIndex),
    /// Viterbi input bit-stream (rate-1/5 convolutional encoder).
    Viterbi(viterbi::ViterbiIndex),
    /// 4-bit delta (dCSR) stream.
    Dcsr(dcsr::DcsrIndex),
}

impl StoredIndex {
    /// Stable name used in CLI flags, artifact metadata, and reports.
    pub fn format_name(&self) -> &'static str {
        match self {
            StoredIndex::Binary(_) => "dense",
            StoredIndex::Csr(_) => "csr",
            StoredIndex::Relative(_) => "relative",
            StoredIndex::LowRank(_) => "lowrank",
            StoredIndex::Tiled(_) => "tiled",
            StoredIndex::Viterbi(_) => "viterbi",
            StoredIndex::Dcsr(_) => "dcsr",
        }
    }

    /// Mask shape `(rows, cols)` this index describes.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            StoredIndex::Binary(b) => (b.rows(), b.cols()),
            StoredIndex::Csr(c) => (c.rows(), c.cols()),
            StoredIndex::Relative(r) => (r.rows(), r.cols()),
            StoredIndex::LowRank(l) => (l.m, l.n),
            StoredIndex::Tiled(t) => (t.m, t.n),
            StoredIndex::Viterbi(v) => (v.rows(), v.cols()),
            StoredIndex::Dcsr(d) => (d.rows(), d.cols()),
        }
    }

    /// Index payload size in bytes — the quantity the paper's tables
    /// compare, and (within fixed per-section header overhead) the
    /// on-disk section size in a `.lrbi` container.
    pub fn index_bytes(&self) -> usize {
        match self {
            StoredIndex::Binary(b) => b.index_bytes(),
            StoredIndex::Csr(c) => c.index_bytes(),
            StoredIndex::Relative(r) => r.index_bytes(),
            StoredIndex::LowRank(l) => l.index_bytes(),
            StoredIndex::Tiled(t) => t.index_bytes(),
            StoredIndex::Viterbi(v) => v.index_bytes(),
            StoredIndex::Dcsr(d) => d.index_bytes(),
        }
    }

    /// Decode to the dense mask (validation/inspection path; serving
    /// goes through the per-format kernels instead).
    pub fn decode_mask(&self) -> Result<BitMatrix> {
        match self {
            StoredIndex::Binary(b) => Ok(b.decode()),
            StoredIndex::Csr(c) => c.decode(),
            StoredIndex::Relative(r) => Ok(r.decode()),
            StoredIndex::LowRank(l) => l.decode(),
            StoredIndex::Tiled(t) => t.decode_mask(),
            StoredIndex::Viterbi(v) => Ok(v.decode()),
            StoredIndex::Dcsr(d) => Ok(d.decode()),
        }
    }

    /// Build the stored form of `format_name` from a factor pair (the
    /// `lrbi pack` path): mask-storing formats encode `I_p ⊗ I_z`,
    /// the low-rank format packs the factors themselves. `"tiled"` is
    /// not constructible from a flat pair — use
    /// [`StoredIndex::Tiled`] with a [`TiledLowRankIndex`].
    pub fn from_factors(format_name: &str, ip: &BitMatrix, iz: &BitMatrix) -> Result<Self> {
        if ip.cols() != iz.rows() {
            return Err(Error::shape(format!(
                "factor ranks disagree: I_p {}x{}, I_z {}x{}",
                ip.rows(),
                ip.cols(),
                iz.rows(),
                iz.cols()
            )));
        }
        match format_name {
            "dense" | "binary" => {
                Ok(StoredIndex::Binary(binary::BinaryIndex::encode(&ip.bool_product(iz))))
            }
            "csr" => Ok(StoredIndex::Csr(csr::Csr16::encode(&ip.bool_product(iz))?)),
            "relative" | "csr5" => {
                Ok(StoredIndex::Relative(relative::Csr5Relative::encode(&ip.bool_product(iz))))
            }
            "lowrank" | "low-rank" => {
                Ok(StoredIndex::LowRank(lowrank::LowRankIndex::from_factors(ip, iz)?))
            }
            // Mask-shaping: the trellis re-encodes I_p ⊗ I_z as the
            // nearest emittable mask (deterministic, see `shape_mask`).
            "viterbi" => Ok(StoredIndex::Viterbi(viterbi::ViterbiIndex::shape_mask(
                &ip.bool_product(iz),
            ))),
            "dcsr" => Ok(StoredIndex::Dcsr(dcsr::DcsrIndex::encode(&ip.bool_product(iz)))),
            other => Err(Error::invalid(format!(
                "unknown storable format '{other}' (want dense|csr|relative|lowrank|viterbi|dcsr)"
            ))),
        }
    }
}

/// A row of the format-comparison tables.
#[derive(Debug, Clone)]
pub struct FormatRow {
    /// Format name as printed in the paper.
    pub name: String,
    /// Index size in bytes.
    pub bytes: usize,
    /// Paper-style comment column.
    pub comment: String,
}

impl FormatRow {
    /// Size in KB (paper uses KB = 1000 B for Table 1, KiB-ish for
    /// Table 3; we print KB = 1000 B and note the delta).
    pub fn kb(&self) -> f64 {
        self.bytes as f64 / 1000.0
    }
}

/// Compare all index formats on a mask derived from `w` at sparsity
/// `s`; `lowrank_bits` is the proposed format's index budget in bits
/// (k(m+n), possibly tiled). Produces the rows of Table 1 (right) /
/// Table 3. Errors if the mask exceeds 16-bit CSR's encodable bounds
/// (see [`csr::Csr16::encode_bounds`]).
pub fn format_comparison(
    w: &Matrix,
    s: f64,
    lowrank_bits: usize,
    lowrank_comment: &str,
) -> Result<Vec<FormatRow>> {
    let (mask, _) = crate::pruning::magnitude_mask(w, s);
    let bin = binary::BinaryIndex::encode(&mask);
    let c16 = csr::Csr16::encode(&mask)?;
    let c5 = relative::Csr5Relative::encode(&mask);
    let vit_bytes = viterbi::index_bytes(mask.rows(), mask.cols());
    Ok(vec![
        FormatRow {
            name: "Binary".into(),
            bytes: bin.index_bytes(),
            comment: "1bit/weight".into(),
        },
        FormatRow {
            name: "CSR(16bit)".into(),
            bytes: c16.index_bytes(),
            comment: String::new(),
        },
        FormatRow {
            name: "CSR(5bit)".into(),
            bytes: c5.index_bytes(),
            comment: "Relative Indexing".into(),
        },
        FormatRow {
            name: "Viterbi".into(),
            bytes: vit_bytes,
            comment: "5X Encoder".into(),
        },
        FormatRow {
            name: "Proposed".into(),
            bytes: lowrank_bits.div_ceil(8),
            comment: lowrank_comment.into(),
        },
    ])
}

/// [`format_comparison`] plus a dCSR row (Trommer 2021) — the
/// head-to-head the serving benches report. Kept separate so the
/// paper-pinned five-row table stays byte-for-byte what Table 1R/3
/// print.
pub fn format_comparison_extended(
    w: &Matrix,
    s: f64,
    lowrank_bits: usize,
    lowrank_comment: &str,
) -> Result<Vec<FormatRow>> {
    let mut rows = format_comparison(w, s, lowrank_bits, lowrank_comment)?;
    let (mask, _) = crate::pruning::magnitude_mask(w, s);
    let d = dcsr::DcsrIndex::encode(&mask);
    rows.push(FormatRow {
        name: "dCSR(4bit)".into(),
        bytes: d.index_bytes(),
        comment: "Delta Indexing".into(),
    });
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn stored_index_from_factors_decodes_same_mask() {
        let mut rng = Rng::new(21);
        let ip = BitMatrix::from_fn(40, 5, |_, _| rng.bernoulli(0.3));
        let iz = BitMatrix::from_fn(5, 70, |_, _| rng.bernoulli(0.3));
        let want = ip.bool_product(&iz);
        for name in ["dense", "csr", "relative", "lowrank", "dcsr"] {
            let s = StoredIndex::from_factors(name, &ip, &iz).unwrap();
            assert_eq!(s.format_name(), name);
            assert_eq!(s.shape(), (40, 70));
            assert_eq!(s.decode_mask().unwrap(), want, "{name}");
            assert!(s.index_bytes() > 0);
        }
        // viterbi is mask-shaping: it stores the trellis's nearest
        // emittable mask, so equality is against its own re-decode,
        // not against I_p ⊗ I_z.
        let v = StoredIndex::from_factors("viterbi", &ip, &iz).unwrap();
        assert_eq!(v.format_name(), "viterbi");
        assert_eq!(v.shape(), (40, 70));
        assert!(v.index_bytes() > 0);
        let shaped = viterbi::ViterbiIndex::shape_mask(&want);
        assert_eq!(v.decode_mask().unwrap(), shaped.decode());
        assert!(StoredIndex::from_factors("tiled", &ip, &iz).is_err());
        let bad_iz = BitMatrix::zeros(6, 70);
        assert!(StoredIndex::from_factors("csr", &ip, &bad_iz).is_err());
    }

    #[test]
    fn extended_comparison_appends_dcsr_row() {
        let mut rng = Rng::new(1);
        let w = Matrix::gaussian(200, 180, 0.0, 0.1, &mut rng);
        let base = format_comparison(&w, 0.9, 8 * (200 + 180), "k=8").unwrap();
        let ext = format_comparison_extended(&w, 0.9, 8 * (200 + 180), "k=8").unwrap();
        assert_eq!(ext.len(), base.len() + 1);
        for (a, b) in ext.iter().zip(&base) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.bytes, b.bytes);
        }
        let d = ext.last().unwrap();
        assert_eq!(d.name, "dCSR(4bit)");
        assert!(d.bytes > 0);
        // at S=0.9 the 4-bit deltas beat the dense bitmap
        assert!(d.bytes < base[0].bytes);
    }

    #[test]
    fn table1_right_shape_holds() {
        // FC1 800x500 at S=0.95, proposed k=16.
        let mut rng = Rng::new(1);
        let w = Matrix::gaussian(800, 500, 0.0, 0.1, &mut rng);
        let rows = format_comparison(&w, 0.95, 16 * (800 + 500), "k=16").unwrap();
        let get = |n: &str| rows.iter().find(|r| r.name == n).unwrap().kb();
        // paper: Binary 50.0, CSR16 45.8, CSR5 14.3, Viterbi 10.0, ours 2.6
        assert_eq!(get("Binary"), 50.0);
        assert!((get("CSR(16bit)") - 45.8).abs() < 4.0, "csr16 {}", get("CSR(16bit)"));
        assert!((get("CSR(5bit)") - 14.3).abs() < 2.0, "csr5 {}", get("CSR(5bit)"));
        assert_eq!(get("Viterbi"), 10.0);
        assert_eq!(get("Proposed"), 2.6);
        // ordering must match the paper exactly
        let sizes: Vec<f64> = rows.iter().map(|r| r.kb()).collect();
        for pair in sizes.windows(2) {
            assert!(pair[0] > pair[1], "sizes must strictly decrease: {sizes:?}");
        }
    }
}
