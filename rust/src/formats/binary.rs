//! Dense binary (bitmap) index: 1 bit per weight, fully regular.

use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};

/// The dense bitmap format of Figure 1.
#[derive(Debug, Clone)]
pub struct BinaryIndex {
    rows: usize,
    cols: usize,
    bytes: Vec<u8>,
}

impl BinaryIndex {
    /// Pack a mask row-major, MSB-first within each byte.
    pub fn encode(mask: &BitMatrix) -> Self {
        let (rows, cols) = (mask.rows(), mask.cols());
        let mut bytes = vec![0u8; (rows * cols).div_ceil(8)];
        for i in 0..rows {
            for j in 0..cols {
                if mask.get(i, j) {
                    let bit = i * cols + j;
                    bytes[bit / 8] |= 1 << (7 - bit % 8);
                }
            }
        }
        BinaryIndex { rows, cols, bytes }
    }

    /// Recover the mask. Byte-skipping fast path: at the paper's
    /// sparsity levels most bytes are zero, so scanning bytes and
    /// expanding only set bits is ~10x faster than per-bit reads
    /// (docs/ARCHITECTURE.md §Performance-notes).
    pub fn decode(&self) -> BitMatrix {
        let mut mask = BitMatrix::zeros(self.rows, self.cols);
        for (bi, &byte) in self.bytes.iter().enumerate() {
            if byte == 0 {
                continue;
            }
            let base = bi * 8;
            for b in 0..8 {
                if byte >> (7 - b) & 1 == 1 {
                    let bit = base + b;
                    if bit < self.rows * self.cols {
                        mask.set(bit / self.cols, bit % self.cols, true);
                    }
                }
            }
        }
        mask
    }

    /// Stored size (payload only).
    pub fn index_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Mask rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Mask cols.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The packed payload (row-major, MSB-first per byte) — what the
    /// `.lrbi` container stores verbatim.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Rebuild from a serialized payload (the store read path).
    pub fn from_bytes(rows: usize, cols: usize, bytes: Vec<u8>) -> Result<Self> {
        let need = (rows * cols).div_ceil(8);
        if bytes.len() != need {
            return Err(Error::store(format!(
                "binary index payload: {} bytes for {rows}x{cols}, need {need}",
                bytes.len()
            )));
        }
        Ok(BinaryIndex { rows, cols, bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_random_masks() {
        prop::check("binary roundtrip", 10, |rng| {
            let m = prop::dim(rng, 1, 40);
            let n = prop::dim(rng, 1, 70);
            let d = rng.next_f64();
            let mut r2 = Rng::new(rng.next_u64());
            let mask = BitMatrix::from_fn(m, n, |_, _| r2.bernoulli(d));
            let enc = BinaryIndex::encode(&mask);
            assert_eq!(enc.decode(), mask);
        });
    }

    #[test]
    fn size_is_mn_over_8() {
        let mask = BitMatrix::zeros(800, 500);
        assert_eq!(BinaryIndex::encode(&mask).index_bytes(), 50_000);
    }

    #[test]
    fn from_bytes_roundtrip_and_validation() {
        let mut rng = Rng::new(7);
        let mask = BitMatrix::from_fn(13, 29, |_, _| rng.bernoulli(0.4));
        let enc = BinaryIndex::encode(&mask);
        let back = BinaryIndex::from_bytes(13, 29, enc.bytes().to_vec()).unwrap();
        assert_eq!(back.decode(), mask);
        assert!(BinaryIndex::from_bytes(13, 29, vec![0u8; 3]).is_err());
    }
}
