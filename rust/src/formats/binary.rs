//! Dense binary (bitmap) index: 1 bit per weight, fully regular.

use crate::util::bits::{bits_word_at, BitMatrix};
use crate::util::error::{Error, Result};

/// The dense bitmap format of Figure 1.
#[derive(Debug, Clone)]
pub struct BinaryIndex {
    rows: usize,
    cols: usize,
    bytes: Vec<u8>,
}

impl BinaryIndex {
    /// Pack a mask row-major, MSB-first within each byte.
    pub fn encode(mask: &BitMatrix) -> Self {
        let (rows, cols) = (mask.rows(), mask.cols());
        let mut bytes = vec![0u8; (rows * cols).div_ceil(8)];
        for i in 0..rows {
            for j in 0..cols {
                if mask.get(i, j) {
                    let bit = i * cols + j;
                    bytes[bit / 8] |= 1 << (7 - bit % 8);
                }
            }
        }
        BinaryIndex { rows, cols, bytes }
    }

    /// Recover the mask, assembling each row **64 bits at a time**:
    /// the MSB-first payload is bit-reversed per byte once (one table
    /// op per byte) into an LSB-first stream, and every packed mask
    /// word is then two shifted `u64` loads (`bits_word_at`) instead
    /// of 64 per-bit probes — the word-parallel discipline of the
    /// serving kernels applied to the store decode path (supersedes
    /// the byte-skipping walk; see docs/ARCHITECTURE.md
    /// §Performance-notes).
    pub fn decode(&self) -> BitMatrix {
        let mut mask = BitMatrix::zeros(self.rows, self.cols);
        if self.rows * self.cols == 0 {
            return mask;
        }
        let rev: Vec<u8> = self.bytes.iter().map(|b| b.reverse_bits()).collect();
        for i in 0..self.rows {
            let row_off = i * self.cols;
            let words = mask.row_words_mut(i);
            let wpr = words.len();
            for (wi, w) in words.iter_mut().enumerate() {
                let nb = if wi + 1 == wpr { self.cols - wi * 64 } else { 64 };
                *w = bits_word_at(&rev, row_off + wi * 64, nb);
            }
        }
        mask
    }

    /// Stored size (payload only).
    pub fn index_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Mask rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Mask cols.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The packed payload (row-major, MSB-first per byte) — what the
    /// `.lrbi` container stores verbatim.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Rebuild from a serialized payload (the store read path).
    pub fn from_bytes(rows: usize, cols: usize, bytes: Vec<u8>) -> Result<Self> {
        let need = (rows * cols).div_ceil(8);
        if bytes.len() != need {
            return Err(Error::store(format!(
                "binary index payload: {} bytes for {rows}x{cols}, need {need}",
                bytes.len()
            )));
        }
        Ok(BinaryIndex { rows, cols, bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_random_masks() {
        prop::check("binary roundtrip", 10, |rng| {
            let m = prop::dim(rng, 1, 40);
            let n = prop::dim(rng, 1, 70);
            let d = rng.next_f64();
            let mut r2 = Rng::new(rng.next_u64());
            let mask = BitMatrix::from_fn(m, n, |_, _| r2.bernoulli(d));
            let enc = BinaryIndex::encode(&mask);
            assert_eq!(enc.decode(), mask);
        });
    }

    #[test]
    fn size_is_mn_over_8() {
        let mask = BitMatrix::zeros(800, 500);
        assert_eq!(BinaryIndex::encode(&mask).index_bytes(), 50_000);
    }

    #[test]
    fn from_bytes_roundtrip_and_validation() {
        let mut rng = Rng::new(7);
        let mask = BitMatrix::from_fn(13, 29, |_, _| rng.bernoulli(0.4));
        let enc = BinaryIndex::encode(&mask);
        let back = BinaryIndex::from_bytes(13, 29, enc.bytes().to_vec()).unwrap();
        assert_eq!(back.decode(), mask);
        assert!(BinaryIndex::from_bytes(13, 29, vec![0u8; 3]).is_err());
    }
}
