//! Tile-based binary matrix factorization (paper §3.1).
//!
//! A `(m × n)` index matrix is split into a grid of tiles; each tile
//! is factorized independently (possibly with its own rank). Benefits
//! demonstrated by Figures 4-6: bounded on-chip memory, faster NMF,
//! and larger factor-value variance (smaller sample size) which gives
//! the threshold conversion more room to optimise Cost.

use crate::bmf::algorithm1::{algorithm1, Algorithm1Config, FactorizedIndex};
use crate::tensor::Matrix;
use crate::util::bits::BitMatrix;
use crate::util::error::{Error, Result};

/// A rectangular tiling plan: `tiles_r × tiles_c` equal-ish tiles
/// (edge tiles absorb the remainder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilePlan {
    /// Number of tile rows.
    pub tiles_r: usize,
    /// Number of tile columns.
    pub tiles_c: usize,
}

/// One tile's coordinates within the parent matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileSpec {
    /// Tile index in row-major tile order.
    pub id: usize,
    /// Row range `[r0, r1)`.
    pub r0: usize,
    /// Row range end.
    pub r1: usize,
    /// Column range `[c0, c1)`.
    pub c0: usize,
    /// Column range end.
    pub c1: usize,
}

impl TileSpec {
    /// Tile height.
    pub fn rows(&self) -> usize {
        self.r1 - self.r0
    }
    /// Tile width.
    pub fn cols(&self) -> usize {
        self.c1 - self.c0
    }
}

impl TilePlan {
    /// Uniform plan.
    pub fn new(tiles_r: usize, tiles_c: usize) -> Self {
        TilePlan { tiles_r, tiles_c }
    }

    /// The identity plan (a single tile).
    pub fn single() -> Self {
        TilePlan { tiles_r: 1, tiles_c: 1 }
    }

    /// Total number of tiles.
    pub fn count(&self) -> usize {
        self.tiles_r * self.tiles_c
    }

    /// Enumerate tile coordinates for an `m × n` matrix. Every element
    /// belongs to exactly one tile; edge tiles absorb remainders.
    pub fn tiles(&self, m: usize, n: usize) -> Result<Vec<TileSpec>> {
        if self.tiles_r == 0 || self.tiles_c == 0 {
            return Err(Error::invalid("tile plan must have >= 1 tile per axis"));
        }
        if self.tiles_r > m || self.tiles_c > n {
            return Err(Error::invalid(format!(
                "plan {}x{} too fine for {}x{} matrix",
                self.tiles_r, self.tiles_c, m, n
            )));
        }
        let mut out = Vec::with_capacity(self.count());
        let th = m / self.tiles_r;
        let tw = n / self.tiles_c;
        let mut id = 0;
        for tr in 0..self.tiles_r {
            let r0 = tr * th;
            let r1 = if tr + 1 == self.tiles_r { m } else { r0 + th };
            for tc in 0..self.tiles_c {
                let c0 = tc * tw;
                let c1 = if tc + 1 == self.tiles_c { n } else { c0 + tw };
                out.push(TileSpec { id, r0, r1, c0, c1 });
                id += 1;
            }
        }
        Ok(out)
    }
}

/// Result of compressing a matrix tile-by-tile.
#[derive(Debug)]
pub struct TiledIndex {
    /// Plan used.
    pub plan: TilePlan,
    /// Per-tile factorization results, in tile id order.
    pub tiles: Vec<(TileSpec, FactorizedIndex)>,
    /// Assembled full-size mask.
    pub mask: BitMatrix,
}

impl TiledIndex {
    /// Total index bits: Σ kᵢ (mᵢ + nᵢ).
    pub fn index_bits(&self) -> usize {
        self.tiles.iter().map(|(_, f)| f.index_bits()).sum()
    }

    /// Total index bytes.
    pub fn index_bytes(&self) -> usize {
        self.index_bits().div_ceil(8)
    }

    /// Compression ratio vs a dense binary index of the full matrix.
    pub fn compression_ratio(&self) -> f64 {
        (self.mask.rows() * self.mask.cols()) as f64 / self.index_bits() as f64
    }

    /// Total Cost (Σ per-tile cost, manipulated magnitudes).
    pub fn cost(&self) -> f64 {
        self.tiles.iter().map(|(_, f)| f.cost).sum()
    }

    /// Achieved overall sparsity of the assembled mask.
    pub fn sparsity(&self) -> f64 {
        self.mask.sparsity()
    }
}

/// One tile's binary factor pair in the storable tiled index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileFactors {
    /// Tile rank `kᵢ`.
    pub rank: usize,
    /// Left factor (tile_rows × kᵢ).
    pub ip: BitMatrix,
    /// Right factor (kᵢ × tile_cols).
    pub iz: BitMatrix,
}

/// The storable form of a tiled low-rank index: parent dims, the
/// [`TilePlan`], and each tile's factor pair in tile-id order. This is
/// what the `.lrbi` artifact container serializes for tiled
/// compressions (per-tile ranks included), and what the tiled
/// execution kernel consumes without ever assembling the dense mask.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TiledLowRankIndex {
    /// Parent matrix rows.
    pub m: usize,
    /// Parent matrix cols.
    pub n: usize,
    /// Tiling plan (tile extents are derived via [`TilePlan::tiles`]).
    pub plan: TilePlan,
    /// Per-tile factors, tile-id order.
    pub tiles: Vec<TileFactors>,
}

impl TiledLowRankIndex {
    /// Build from parts, validating every tile's factor shapes against
    /// the plan's tile extents.
    pub fn new(m: usize, n: usize, plan: TilePlan, tiles: Vec<TileFactors>) -> Result<Self> {
        let idx = TiledLowRankIndex { m, n, plan, tiles };
        idx.validated_specs()?;
        Ok(idx)
    }

    /// Tile extents in tile-id order, with every tile's factor shapes
    /// checked against them — the single validation pass shared by
    /// [`TiledLowRankIndex::new`] and the tiled execution kernel.
    pub fn validated_specs(&self) -> Result<Vec<TileSpec>> {
        let specs = self.plan.tiles(self.m, self.n)?;
        if specs.len() != self.tiles.len() {
            return Err(Error::invalid(format!(
                "{} tile factor sets for a {}-tile plan",
                self.tiles.len(),
                specs.len()
            )));
        }
        for (spec, t) in specs.iter().zip(&self.tiles) {
            if t.ip.rows() != spec.rows()
                || t.ip.cols() != t.rank
                || t.iz.rows() != t.rank
                || t.iz.cols() != spec.cols()
            {
                return Err(Error::shape(format!(
                    "tile {}: factors {}x{} / {}x{} vs extent {}x{} rank {}",
                    spec.id,
                    t.ip.rows(),
                    t.ip.cols(),
                    t.iz.rows(),
                    t.iz.cols(),
                    spec.rows(),
                    spec.cols(),
                    t.rank
                )));
            }
        }
        Ok(specs)
    }

    /// Capture the factors of a [`TiledIndex`] produced by
    /// [`compress_tiled`].
    pub fn from_tiled(t: &TiledIndex) -> Self {
        TiledLowRankIndex {
            m: t.mask.rows(),
            n: t.mask.cols(),
            plan: t.plan,
            tiles: t
                .tiles
                .iter()
                .map(|(_, f)| TileFactors {
                    rank: f.rank,
                    ip: f.ip.clone(),
                    iz: f.iz.clone(),
                })
                .collect(),
        }
    }

    /// Tile extents in tile-id order.
    pub fn specs(&self) -> Result<Vec<TileSpec>> {
        self.plan.tiles(self.m, self.n)
    }

    /// Assemble the full mask from per-tile boolean products (the
    /// decompressor path; execution kernels avoid this).
    pub fn decode_mask(&self) -> Result<BitMatrix> {
        let mut mask = BitMatrix::zeros(self.m, self.n);
        for (spec, t) in self.specs()?.iter().zip(&self.tiles) {
            let sub = t.ip.bool_product(&t.iz);
            for i in 0..spec.rows() {
                for j in 0..spec.cols() {
                    if sub.get(i, j) {
                        mask.set(spec.r0 + i, spec.c0 + j, true);
                    }
                }
            }
        }
        Ok(mask)
    }

    /// Total index bits: Σ kᵢ (mᵢ + nᵢ) over actual tile extents.
    pub fn index_bits(&self) -> usize {
        self.tiles
            .iter()
            .map(|t| t.rank * (t.ip.rows() + t.iz.cols()))
            .sum()
    }

    /// Total index bytes.
    pub fn index_bytes(&self) -> usize {
        self.index_bits().div_ceil(8)
    }
}

/// Rank assignment for a tiling: same rank everywhere, or per-tile.
#[derive(Debug, Clone)]
pub enum RankPlan {
    /// All tiles use the same rank.
    Uniform(usize),
    /// Tile `id` uses `ranks[id]` (len must equal the tile count).
    PerTile(Vec<usize>),
}

impl RankPlan {
    fn rank_for(&self, id: usize) -> usize {
        match self {
            RankPlan::Uniform(k) => *k,
            RankPlan::PerTile(v) => v[id],
        }
    }
}

/// The rank giving a `(tiles_r × tiles_c)` plan the same total index
/// budget as a single-tile factorization at `rank_single` — the
/// "equal compression ratio" comparison of Figures 4 and 6.
///
/// Single: `k₁ (m + n)` bits. Tiled: `k_t · Σᵢ (mᵢ + nᵢ)` bits over
/// the actual [`TileSpec`] extents. Summing real extents matters for
/// non-divisible dims — e.g. a 3×4 plan over 10×9 has edge tiles
/// absorbing remainders, and the old `count · (m/tr + n/tc)` formula
/// under-counted their bits, inflating the returned rank.
pub fn equal_budget_rank(
    m: usize,
    n: usize,
    plan: TilePlan,
    rank_single: usize,
) -> Result<usize> {
    let single_bits = rank_single * (m + n);
    let per_rank_bits: usize =
        plan.tiles(m, n)?.iter().map(|t| t.rows() + t.cols()).sum();
    Ok((single_bits as f64 / per_rank_bits as f64).round().max(1.0) as usize)
}

/// Factorize a weight matrix tile-by-tile with Algorithm 1 applied
/// independently to each tile. `base` supplies everything except the
/// rank, which comes from `ranks`. Runs sequentially; the coordinator
/// offers the parallel path (`coordinator::sweep`).
pub fn compress_tiled(
    w: &Matrix,
    plan: TilePlan,
    ranks: &RankPlan,
    base: &Algorithm1Config,
) -> Result<TiledIndex> {
    let specs = plan.tiles(w.rows(), w.cols())?;
    if let RankPlan::PerTile(v) = ranks {
        if v.len() != specs.len() {
            return Err(Error::invalid(format!(
                "rank plan has {} entries for {} tiles",
                v.len(),
                specs.len()
            )));
        }
    }
    let mut tiles = Vec::with_capacity(specs.len());
    let mut mask = BitMatrix::zeros(w.rows(), w.cols());
    for spec in specs {
        let sub = w.submatrix(spec.r0, spec.r1, spec.c0, spec.c1)?;
        let mut cfg = base.clone();
        cfg.rank = ranks.rank_for(spec.id);
        cfg.nmf.rank = cfg.rank;
        // decorrelate per-tile NMF inits deterministically
        cfg.nmf.seed = base.nmf.seed.wrapping_add(spec.id as u64);
        let f = algorithm1(&sub, &cfg)?;
        for i in 0..spec.rows() {
            for j in 0..spec.cols() {
                if f.mask.get(i, j) {
                    mask.set(spec.r0 + i, spec.c0 + j, true);
                }
            }
        }
        tiles.push((spec, f));
    }
    Ok(TiledIndex { plan, tiles, mask })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::manip::ManipMethod;
    use crate::util::rng::Rng;

    fn w(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::gaussian(m, n, 0.0, 0.1, &mut rng)
    }

    fn fast_cfg(s: f64) -> Algorithm1Config {
        let mut c = Algorithm1Config::new(4, s);
        c.sp_grid = vec![0.3, 0.6];
        c.nmf.max_iters = 15;
        c
    }

    #[test]
    fn tiles_partition_exactly() {
        let plan = TilePlan::new(3, 4);
        let tiles = plan.tiles(10, 9).unwrap();
        assert_eq!(tiles.len(), 12);
        let mut covered = vec![vec![0u8; 9]; 10];
        for t in &tiles {
            for i in t.r0..t.r1 {
                for j in t.c0..t.c1 {
                    covered[i][j] += 1;
                }
            }
        }
        assert!(covered.iter().flatten().all(|&c| c == 1), "partition must be exact");
    }

    #[test]
    fn plan_validation() {
        assert!(TilePlan::new(0, 1).tiles(5, 5).is_err());
        assert!(TilePlan::new(6, 1).tiles(5, 5).is_err());
        assert!(TilePlan::new(5, 5).tiles(5, 5).is_ok());
    }

    #[test]
    fn equal_budget_rank_matches_paper_fig6() {
        // FC1 800x500: (1x1, k=128) == (2x2, k=64) == (4x4, k=32).
        assert_eq!(equal_budget_rank(800, 500, TilePlan::new(1, 1), 128).unwrap(), 128);
        assert_eq!(equal_budget_rank(800, 500, TilePlan::new(2, 2), 128).unwrap(), 64);
        assert_eq!(equal_budget_rank(800, 500, TilePlan::new(4, 4), 128).unwrap(), 32);
    }

    #[test]
    fn equal_budget_rank_uses_actual_tile_extents() {
        // 10x9 with a 3x4 plan: edge tiles absorb remainders, so
        // Σ(mᵢ+nᵢ) = 4·Σrows + 3·Σcols = 4·10 + 3·9 = 67 bits/rank,
        // not count·(m/tr + n/tc) = 12·(3+2) = 60. A single-tile
        // budget of k=67 must therefore map to exactly rank 19·... :
        // 67·(10+9)/67 = 19 per rank → k_t = round(k·19/67).
        let plan = TilePlan::new(3, 4);
        assert_eq!(equal_budget_rank(10, 9, plan, 67).unwrap(), 19);
        // The biased formula would have given round(67·19/60) = 21.
        assert_ne!(equal_budget_rank(10, 9, plan, 67).unwrap(), 21);
        // Invalid plans surface as errors instead of nonsense ranks.
        assert!(equal_budget_rank(5, 5, TilePlan::new(0, 1), 4).is_err());
        assert!(equal_budget_rank(5, 5, TilePlan::new(6, 1), 4).is_err());
    }

    #[test]
    fn tiled_compression_hits_sparsity_and_budget() {
        let w = w(60, 40, 1);
        let plan = TilePlan::new(2, 2);
        let res = compress_tiled(&w, plan, &RankPlan::Uniform(4), &fast_cfg(0.85)).unwrap();
        assert!((res.sparsity() - 0.85).abs() < 0.04, "sparsity {}", res.sparsity());
        // 4 tiles of 30x20 at k=4: 4 * 4*(30+20) = 800 bits
        assert_eq!(res.index_bits(), 800);
        assert_eq!(res.tiles.len(), 4);
    }

    #[test]
    fn per_tile_ranks_respected() {
        let w = w(40, 40, 2);
        let plan = TilePlan::new(2, 1);
        let ranks = RankPlan::PerTile(vec![2, 6]);
        let res = compress_tiled(&w, plan, &ranks, &fast_cfg(0.8)).unwrap();
        assert_eq!(res.tiles[0].1.rank, 2);
        assert_eq!(res.tiles[1].1.rank, 6);
        assert!(compress_tiled(&w, plan, &RankPlan::PerTile(vec![2]), &fast_cfg(0.8)).is_err());
    }

    #[test]
    fn assembled_mask_matches_tiles() {
        let w = w(30, 30, 3);
        let plan = TilePlan::new(3, 3);
        let res = compress_tiled(&w, plan, &RankPlan::Uniform(2), &fast_cfg(0.8)).unwrap();
        for (spec, f) in &res.tiles {
            for i in 0..spec.rows() {
                for j in 0..spec.cols() {
                    assert_eq!(res.mask.get(spec.r0 + i, spec.c0 + j), f.mask.get(i, j));
                }
            }
        }
    }

    #[test]
    fn stored_tiled_index_roundtrips_mask_and_bits() {
        let w = w(25, 22, 5);
        let plan = TilePlan::new(2, 3); // 25 and 22 don't divide: edge tiles differ
        let ranks = RankPlan::PerTile(vec![2, 3, 2, 4, 2, 3]);
        let res = compress_tiled(&w, plan, &ranks, &fast_cfg(0.8)).unwrap();
        let stored = TiledLowRankIndex::from_tiled(&res);
        assert_eq!(stored.decode_mask().unwrap(), res.mask);
        assert_eq!(stored.index_bits(), res.index_bits());
        // per-tile ranks preserved
        let ks: Vec<usize> = stored.tiles.iter().map(|t| t.rank).collect();
        assert_eq!(ks, vec![2, 3, 2, 4, 2, 3]);
        // shape validation: swapping two differently-shaped tiles fails
        let mut bad = stored.tiles.clone();
        bad.swap(0, 5);
        assert!(TiledLowRankIndex::new(25, 22, plan, bad).is_err());
        // count validation
        assert!(TiledLowRankIndex::new(25, 22, plan, stored.tiles[..3].to_vec()).is_err());
    }

    #[test]
    fn single_tile_equals_plain_algorithm1() {
        let w = w(24, 18, 4);
        let cfg = fast_cfg(0.8);
        let tiled = compress_tiled(&w, TilePlan::single(), &RankPlan::Uniform(4), &cfg).unwrap();
        let mut c = cfg.clone();
        c.rank = 4;
        c.nmf.rank = 4;
        c.nmf.seed = cfg.nmf.seed; // tile 0 adds 0
        let plain = algorithm1(&w, &c).unwrap();
        assert_eq!(tiled.mask, plain.mask);
        let _ = ManipMethod::all();
    }
}
