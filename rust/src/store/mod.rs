//! The `.lrbi` artifact store: a versioned binary container for
//! compressed models plus an on-disk model registry.
//!
//! The paper's claim is about the *stored* footprint of a pruning
//! index; this subsystem is where that footprint becomes real bytes.
//! An [`Artifact`] packages dense params (`MlpParams`), one
//! serialized index in any storable format (bitmap, 16-bit CSR, 5-bit
//! relative, low-rank factors, or tiled low-rank with per-tile
//! ranks), and provenance metadata into a CRC-checked container
//! ([`container`]); a [`Registry`] names artifacts in a directory so
//! a serving process can list, load, and hot-swap them
//! (`VariantServer::from_registry` / `hot_swap`).
//!
//! Load path: one file read → CRC validation → section slices decoded
//! straight into the `formats::StoredIndex` structs →
//! `serve::kernels::build_kernel_from_stored`. The dense mask is
//! never materialized for the CSR, relative, low-rank, or tiled
//! variants, and Algorithm 1 never re-runs: packaging happens once at
//! `lrbi pack` time, loading is milliseconds (`perf_store` measures
//! both artifact bytes and cold-load latency).
//!
//! See `docs/ARTIFACT_FORMAT.md` for the byte-level layout.

pub mod artifact;
pub mod atomic;
pub mod container;
pub mod registry;

pub use artifact::{Artifact, ArtifactMeta};
pub use container::{Container, ContainerWriter, SectionEntry, SectionKind};
pub use registry::{Registry, RegistryEntry};
