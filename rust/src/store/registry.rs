//! On-disk model registry: a directory of `.lrbi` artifacts plus a
//! plain-text manifest, the unit `lrbi serve --registry` and
//! `VariantServer::from_registry` operate on.
//!
//! Manifest (`manifest.txt`): one artifact per line,
//! `name<space>file<space>format`, in publish order. Re-publishing a
//! name replaces its entry (and file), which is what a hot-swap
//! deployment does: write the new artifact, then ask the running
//! server to reload the name.

use crate::store::artifact::Artifact;
use crate::store::atomic::write_atomic;
use crate::util::error::{Error, Result};
use std::path::{Path, PathBuf};

const MANIFEST: &str = "manifest.txt";

/// One manifest entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegistryEntry {
    /// Artifact name (registry-unique).
    pub name: String,
    /// File name inside the registry directory.
    pub file: String,
    /// Index format recorded at publish time.
    pub format: String,
}

/// A directory of artifacts + manifest.
#[derive(Debug)]
pub struct Registry {
    dir: PathBuf,
    entries: Vec<RegistryEntry>,
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
}

impl Registry {
    /// Create an empty registry (directory + empty manifest). Errors
    /// if a manifest already exists there.
    pub fn create(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let manifest = dir.join(MANIFEST);
        if manifest.exists() {
            return Err(Error::store(format!(
                "registry already exists at {}",
                dir.display()
            )));
        }
        write_atomic(&manifest, b"")?;
        Ok(Registry { dir, entries: Vec::new() })
    }

    /// Open an existing registry.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join(MANIFEST);
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::store(format!(
                "no registry manifest at {} — create one with `lrbi pack --registry` ({e})",
                manifest.display()
            ))
        })?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut tok = line.split_whitespace();
            match (tok.next(), tok.next(), tok.next()) {
                (Some(name), Some(file), Some(format)) => {
                    if !valid_name(name) {
                        return Err(Error::store(format!(
                            "manifest line {}: invalid artifact name '{name}'",
                            lineno + 1
                        )));
                    }
                    // publish() only ever writes `<name>.lrbi`, so any
                    // other file value is corruption — and accepting it
                    // would let a hand-edited manifest point outside
                    // the registry directory.
                    if file != format!("{name}.lrbi") {
                        return Err(Error::store(format!(
                            "manifest line {}: file '{file}' must be '{name}.lrbi'",
                            lineno + 1
                        )));
                    }
                    entries.push(RegistryEntry {
                        name: name.to_string(),
                        file: file.to_string(),
                        format: format.to_string(),
                    });
                }
                _ => {
                    return Err(Error::store(format!(
                        "malformed manifest line {}: '{line}'",
                        lineno + 1
                    )));
                }
            }
        }
        Ok(Registry { dir, entries })
    }

    /// Open if a manifest exists, otherwise create.
    pub fn open_or_create(dir: impl AsRef<Path>) -> Result<Self> {
        if dir.as_ref().join(MANIFEST).exists() {
            Self::open(dir)
        } else {
            Self::create(dir)
        }
    }

    /// Registry directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Manifest entries in publish order.
    pub fn entries(&self) -> &[RegistryEntry] {
        &self.entries
    }

    /// Artifact names in publish order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Number of published artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is published.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Full path of a published artifact.
    pub fn path_of(&self, name: &str) -> Option<PathBuf> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .map(|e| self.dir.join(&e.file))
    }

    /// Write `artifact` as `<name>.lrbi` and record it in the
    /// manifest; re-publishing a name replaces both. Returns the
    /// artifact path.
    ///
    /// Both writes are crash-atomic (temp file + fsync + rename +
    /// directory fsync) and ordered artifact-then-manifest, so a
    /// process killed mid-publish never leaves a manifest entry
    /// pointing at a torn or missing artifact.
    pub fn publish(&mut self, name: &str, artifact: &Artifact) -> Result<PathBuf> {
        if !valid_name(name) {
            return Err(Error::store(format!(
                "invalid artifact name '{name}' (want [A-Za-z0-9._-]{{1,64}})"
            )));
        }
        let file = format!("{name}.lrbi");
        let path = self.dir.join(&file);
        artifact.write(&path)?;
        let entry = RegistryEntry {
            name: name.to_string(),
            file,
            format: artifact.index.format_name().to_string(),
        };
        match self.entries.iter_mut().find(|e| e.name == name) {
            Some(e) => *e = entry,
            None => self.entries.push(entry),
        }
        self.write_manifest()?;
        Ok(path)
    }

    /// Load a published artifact by name.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        let path = self.path_of(name).ok_or_else(|| {
            Error::store(format!(
                "artifact '{name}' not in registry {} (have: {})",
                self.dir.display(),
                self.names().join(", ")
            ))
        })?;
        Artifact::read(path)
    }

    /// Rewrite the manifest crash-atomically: a publish interrupted
    /// at any point leaves either the old manifest or the new one on
    /// disk, never a prefix. The artifact file itself is written the
    /// same way (see [`Artifact::write`]), and the manifest is only
    /// updated *after* the artifact rename lands, so every state a
    /// crash can expose is openable.
    fn write_manifest(&self) -> Result<()> {
        let mut text = String::new();
        for e in &self.entries {
            text.push_str(&format!("{} {} {}\n", e.name, e.file, e.format));
        }
        write_atomic(self.dir.join(MANIFEST), text.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::MlpParams;
    use crate::util::bits::BitMatrix;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lrbi_registry_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn artifact(seed: u64, format: &str) -> Artifact {
        let params = MlpParams::init(seed);
        let (m, n) = (params.w1.rows(), params.w1.cols());
        let mut rng = Rng::new(seed + 100);
        let ip = BitMatrix::from_fn(m, 4, |_, _| rng.bernoulli(0.3));
        let iz = BitMatrix::from_fn(4, n, |_, _| rng.bernoulli(0.3));
        Artifact::pack_factors(params, format, &ip, &iz, "registry test").unwrap()
    }

    #[test]
    fn publish_open_load_roundtrip() {
        let dir = tmp("roundtrip");
        let mut reg = Registry::create(&dir).unwrap();
        reg.publish("v1", &artifact(1, "lowrank")).unwrap();
        reg.publish("v2", &artifact(2, "csr")).unwrap();
        assert_eq!(reg.names(), vec!["v1", "v2"]);

        let reopened = Registry::open(&dir).unwrap();
        assert_eq!(reopened.entries(), reg.entries());
        let a = reopened.load("v2").unwrap();
        assert_eq!(a.index.format_name(), "csr");
        assert!(reopened.load("nope").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn republish_replaces_entry() {
        let dir = tmp("republish");
        let mut reg = Registry::create(&dir).unwrap();
        reg.publish("v1", &artifact(1, "lowrank")).unwrap();
        reg.publish("v1", &artifact(3, "relative")).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.entries()[0].format, "relative");
        assert_eq!(
            Registry::open(&dir).unwrap().load("v1").unwrap().index.format_name(),
            "relative"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_names_and_missing_manifest_rejected() {
        let dir = tmp("badnames");
        let mut reg = Registry::create(&dir).unwrap();
        let too_long = "z".repeat(65);
        for bad in ["", "a b", "../evil", "x/y", too_long.as_str()] {
            assert!(reg.publish(bad, &artifact(1, "lowrank")).is_err(), "{bad:?}");
        }
        assert!(Registry::open(dir.join("nowhere")).is_err());
        assert!(Registry::create(&dir).is_err(), "double create must fail");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Simulate a process killed at each step of a publish and prove
    /// that no intermediate state is visible to `Registry::open` /
    /// `load`. The atomic-write protocol stages a temp file, fsyncs,
    /// renames, then fsyncs the directory; a kill therefore exposes
    /// exactly one of the on-disk states reconstructed here by hand.
    #[test]
    fn killed_publish_is_never_half_visible() {
        use crate::store::atomic::TMP_PREFIX;

        let dir = tmp("killsim");
        let mut reg = Registry::create(&dir).unwrap();
        reg.publish("v1", &artifact(1, "lowrank")).unwrap();
        let good_manifest = std::fs::read(dir.join(MANIFEST)).unwrap();
        let good_artifact = std::fs::read(dir.join("v1.lrbi")).unwrap();
        let new_artifact = artifact(9, "csr");
        let new_bytes = new_artifact.to_bytes();

        // Step 1: killed while the replacement artifact's temp file is
        // being written (any prefix of it may be on disk).
        for cut in [0, new_bytes.len() / 2, new_bytes.len()] {
            let tmp_file = dir.join(format!("{TMP_PREFIX}v1.lrbi.999"));
            std::fs::write(&tmp_file, &new_bytes[..cut]).unwrap();
            let r = Registry::open(&dir).unwrap();
            assert_eq!(r.names(), vec!["v1"]);
            assert_eq!(r.load("v1").unwrap().index.format_name(), "lowrank");
            std::fs::remove_file(&tmp_file).unwrap();
        }

        // Step 2: killed after the artifact rename landed but before
        // the manifest rewrite started. The manifest still names the
        // old entry; the file it points at is the complete new
        // artifact — fully openable, just not yet advertised as csr.
        std::fs::write(dir.join("v1.lrbi"), &new_bytes).unwrap();
        let r = Registry::open(&dir).unwrap();
        assert_eq!(r.load("v1").unwrap().index.format_name(), "csr");
        assert_eq!(r.entries()[0].format, "lowrank", "manifest not yet rewritten");

        // Step 3: killed while the new manifest's temp file is being
        // written — a torn manifest prefix sits beside the intact old
        // one; open still reads the old manifest verbatim.
        let torn = b"v1 v1.lrbi cs"; // mid-line prefix of the new manifest
        std::fs::write(dir.join(format!("{TMP_PREFIX}manifest.txt.999")), torn).unwrap();
        let r = Registry::open(&dir).unwrap();
        assert_eq!(r.entries()[0].format, "lowrank");
        assert!(r.load("v1").is_ok());

        // A torn manifest is never reachable at the real path: if the
        // rename had happened, the temp was complete by construction.
        // Re-running the publish from scratch converges to the final
        // state and ignores every stale temp file.
        let mut r = Registry::open(&dir).unwrap();
        r.publish("v1", &new_artifact).unwrap();
        let r = Registry::open(&dir).unwrap();
        assert_eq!(r.entries()[0].format, "csr");
        assert_eq!(r.load("v1").unwrap().index.format_name(), "csr");

        // Sanity: the untouched-publish baseline bytes were valid too.
        assert!(!good_manifest.is_empty() && !good_artifact.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_manifest_is_typed_error() {
        let dir = tmp("malformed");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "just-a-name\n").unwrap();
        let err = Registry::open(&dir).unwrap_err();
        assert!(matches!(err, Error::Store(_)), "{err}");
        // a file field pointing outside the registry dir is rejected
        std::fs::write(dir.join("manifest.txt"), "v1 ../../outside.lrbi lowrank\n").unwrap();
        let err = Registry::open(&dir).unwrap_err();
        assert!(err.to_string().contains("must be 'v1.lrbi'"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
