//! Crash-atomic file writes for the store layer.
//!
//! A publish that dies between "truncate the old file" and "finish
//! writing the new bytes" must never leave a torn file where a
//! manifest or artifact used to be. [`write_atomic`] gives the
//! all-or-nothing guarantee the registry's publish path builds on:
//!
//! 1. write the full payload to a hidden temp file in the same
//!    directory (same filesystem ⇒ `rename` cannot degrade to
//!    copy+delete),
//! 2. `fsync` the temp file (data is durable before it becomes
//!    reachable),
//! 3. atomically `rename` it over the destination,
//! 4. `fsync` the directory, so the rename itself survives a crash.
//!
//! A reader (e.g. `Registry::open`) therefore sees either the old
//! bytes or the new bytes, never a prefix — pinned by the
//! kill-between-steps simulation in `store/registry.rs` tests.

use crate::util::error::{Error, Result};
use std::io::Write;
use std::path::Path;

/// Prefix of the temp files [`write_atomic`] stages next to the
/// destination. Readers that scan directories (the registry) ignore
/// names starting with this, so an orphaned temp from a killed
/// process is invisible garbage, not a half-published artifact.
pub const TMP_PREFIX: &str = ".lrbi-tmp.";

/// Write `bytes` to `path` crash-atomically (temp file + fsync +
/// rename + directory fsync). On any error the destination is
/// untouched; a leftover temp file is cleaned up best-effort.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty()).unwrap_or(Path::new("."));
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| Error::store(format!("cannot write to {}: no file name", path.display())))?;
    // pid-suffixed so concurrent publishers in different processes
    // stage distinct temp files
    let tmp = dir.join(format!("{TMP_PREFIX}{name}.{}", std::process::id()));
    let res = (|| -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        sync_dir(dir)
    })();
    res.map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        Error::store(format!("atomic write of {} failed: {e}", path.display()))
    })
}

/// Fsync a directory so a just-renamed entry survives a crash. On
/// platforms where directories cannot be opened/synced this is a
/// no-op — the rename is still atomic, only its durability window
/// widens.
fn sync_dir(dir: &Path) -> std::io::Result<()> {
    match std::fs::File::open(dir) {
        Ok(d) => match d.sync_all() {
            Ok(()) => Ok(()),
            // e.g. EACCES/EINVAL on filesystems that refuse dir fsync
            Err(_) => Ok(()),
        },
        Err(_) => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("lrbi_atomic_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_then_overwrites() {
        let d = tmp_dir("basic");
        let p = d.join("file.bin");
        write_atomic(&p, b"one").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"one");
        write_atomic(&p, b"two-longer").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"two-longer");
        // no temp residue after a successful write
        let leftovers: Vec<_> = std::fs::read_dir(&d)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(TMP_PREFIX))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn failed_write_leaves_destination_untouched() {
        let d = tmp_dir("fail");
        let p = d.join("file.bin");
        write_atomic(&p, b"original").unwrap();
        // a destination whose parent vanished cannot be staged
        let gone = d.join("no_such_subdir").join("x.bin");
        assert!(write_atomic(&gone, b"data").is_err());
        assert_eq!(std::fs::read(&p).unwrap(), b"original");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn rejects_pathological_destination() {
        assert!(write_atomic(Path::new("/"), b"x").is_err());
    }
}
