//! The `.lrbi` binary container: magic + version header, a section
//! table, and CRC-32-checked section payloads.
//!
//! Byte-level layout (all integers little-endian; full spec in
//! `docs/ARTIFACT_FORMAT.md`):
//!
//! ```text
//! offset  size  field
//! 0       4     magic "LRBI"
//! 4       2     format version (currently 1)
//! 6       2     section count
//! 8       8     reserved (zero)
//! 16      24·N  section table: kind u32, offset u64, len u64, crc u32
//! ...           section payloads, in table order
//! ```
//!
//! The reader pulls the whole file into one buffer with a single read,
//! validates every section's CRC up front, and hands out *slices* of
//! that buffer — section decoding never re-reads the file or copies
//! through intermediate buffers, which is what makes artifact loads a
//! milliseconds-scale operation (`perf_store` measures it).

use crate::util::crc::crc32;
use crate::util::error::{Error, Result};
use std::path::Path;

/// Container magic bytes.
pub const MAGIC: [u8; 4] = *b"LRBI";
/// Current container format version.
pub const VERSION: u16 = 1;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 16;
/// Section-table entry length in bytes.
pub const ENTRY_LEN: usize = 24;

/// Known section kinds. Codes are stable wire values; unknown codes
/// are tolerated on read (skipped) so older readers survive newer
/// writers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SectionKind {
    /// Dense model parameters (`MlpParams`).
    Params,
    /// Artifact metadata (format, sparsity, cost, rank, provenance).
    Meta,
    /// Dense bitmap index payload.
    IndexBinary,
    /// 16-bit CSR index payload.
    IndexCsr,
    /// 5-bit relative (gap) index payload.
    IndexRelative,
    /// Packed low-rank factor payload.
    IndexLowRank,
    /// Tiled low-rank payload (plan + per-tile factors).
    IndexTiled,
    /// Viterbi input bit-stream payload.
    IndexViterbi,
    /// dCSR 4-bit delta index payload.
    IndexDcsr,
}

impl SectionKind {
    /// Every index-section kind, in wire-code order.
    pub const INDEX_KINDS: [SectionKind; 7] = [
        SectionKind::IndexBinary,
        SectionKind::IndexCsr,
        SectionKind::IndexRelative,
        SectionKind::IndexLowRank,
        SectionKind::IndexTiled,
        SectionKind::IndexViterbi,
        SectionKind::IndexDcsr,
    ];

    /// Stable wire code.
    pub fn code(self) -> u32 {
        match self {
            SectionKind::Params => 1,
            SectionKind::Meta => 2,
            SectionKind::IndexBinary => 16,
            SectionKind::IndexCsr => 17,
            SectionKind::IndexRelative => 18,
            SectionKind::IndexLowRank => 19,
            SectionKind::IndexTiled => 20,
            SectionKind::IndexViterbi => 21,
            SectionKind::IndexDcsr => 22,
        }
    }

    /// Parse a wire code.
    pub fn from_code(code: u32) -> Option<Self> {
        match code {
            1 => Some(SectionKind::Params),
            2 => Some(SectionKind::Meta),
            16 => Some(SectionKind::IndexBinary),
            17 => Some(SectionKind::IndexCsr),
            18 => Some(SectionKind::IndexRelative),
            19 => Some(SectionKind::IndexLowRank),
            20 => Some(SectionKind::IndexTiled),
            21 => Some(SectionKind::IndexViterbi),
            22 => Some(SectionKind::IndexDcsr),
            _ => None,
        }
    }

    /// Human-readable name (`lrbi inspect`).
    pub fn name(self) -> &'static str {
        match self {
            SectionKind::Params => "params",
            SectionKind::Meta => "meta",
            SectionKind::IndexBinary => "index/binary",
            SectionKind::IndexCsr => "index/csr",
            SectionKind::IndexRelative => "index/relative",
            SectionKind::IndexLowRank => "index/lowrank",
            SectionKind::IndexTiled => "index/tiled",
            SectionKind::IndexViterbi => "index/viterbi",
            SectionKind::IndexDcsr => "index/dcsr",
        }
    }
}

/// One parsed section-table entry.
#[derive(Debug, Clone, Copy)]
pub struct SectionEntry {
    /// Raw wire code (may be unknown to this reader).
    pub kind_code: u32,
    /// Payload offset from the start of the file.
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u64,
    /// CRC-32 of the payload.
    pub crc: u32,
}

impl SectionEntry {
    /// The kind, when this reader knows the code.
    pub fn kind(&self) -> Option<SectionKind> {
        SectionKind::from_code(self.kind_code)
    }
}

/// Builds a container file section by section.
#[derive(Debug, Default)]
pub struct ContainerWriter {
    sections: Vec<(u32, Vec<u8>)>,
}

impl ContainerWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a section (order is preserved on disk).
    pub fn add(&mut self, kind: SectionKind, payload: Vec<u8>) {
        self.sections.push((kind.code(), payload));
    }

    /// Serialize header + table + payloads.
    pub fn to_bytes(&self) -> Vec<u8> {
        let table_len = self.sections.len() * ENTRY_LEN;
        let mut offset = (HEADER_LEN + table_len) as u64;
        let total: usize =
            HEADER_LEN + table_len + self.sections.iter().map(|(_, p)| p.len()).sum::<usize>();
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u16).to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes());
        for (code, payload) in &self.sections {
            out.extend_from_slice(&code.to_le_bytes());
            out.extend_from_slice(&offset.to_le_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(&crc32(payload).to_le_bytes());
            offset += payload.len() as u64;
        }
        for (_, payload) in &self.sections {
            out.extend_from_slice(payload);
        }
        out
    }

    /// Write the container to a file crash-atomically (temp file +
    /// fsync + rename via [`crate::store::atomic::write_atomic`]).
    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        crate::store::atomic::write_atomic(path, &self.to_bytes())
    }
}

/// A validated, loaded container: one buffer + the parsed table.
#[derive(Debug)]
pub struct Container {
    buf: Vec<u8>,
    entries: Vec<SectionEntry>,
}

impl Container {
    /// Parse and validate a serialized container: magic, version,
    /// table bounds, and every section's CRC. All failures are typed
    /// [`Error::Store`] values — corrupt input never panics.
    pub fn from_bytes(buf: Vec<u8>) -> Result<Self> {
        if buf.len() < HEADER_LEN {
            return Err(Error::store(format!(
                "truncated container: {} bytes, header needs {HEADER_LEN}",
                buf.len()
            )));
        }
        if buf[0..4] != MAGIC {
            return Err(Error::store(format!(
                "bad magic {:02x?} (want \"LRBI\")",
                &buf[0..4]
            )));
        }
        let version = u16::from_le_bytes([buf[4], buf[5]]);
        if version != VERSION {
            return Err(Error::store(format!(
                "unsupported container version {version} (this reader speaks {VERSION})"
            )));
        }
        if buf[8..16] != [0u8; 8] {
            return Err(Error::store("reserved header bytes must be zero in v1"));
        }
        let count = u16::from_le_bytes([buf[6], buf[7]]) as usize;
        let table_end = HEADER_LEN + count * ENTRY_LEN;
        if buf.len() < table_end {
            return Err(Error::store(format!(
                "truncated container: {} bytes, section table needs {table_end}",
                buf.len()
            )));
        }
        let mut entries = Vec::with_capacity(count);
        for i in 0..count {
            let at = HEADER_LEN + i * ENTRY_LEN;
            let e = SectionEntry {
                kind_code: u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()),
                offset: u64::from_le_bytes(buf[at + 4..at + 12].try_into().unwrap()),
                len: u64::from_le_bytes(buf[at + 12..at + 20].try_into().unwrap()),
                crc: u32::from_le_bytes(buf[at + 20..at + 24].try_into().unwrap()),
            };
            let end = e.offset.checked_add(e.len).ok_or_else(|| {
                Error::store(format!("section {i}: offset+len overflows"))
            })?;
            if (e.offset as usize) < table_end || end as usize > buf.len() {
                return Err(Error::store(format!(
                    "section {i} [{}, {end}) outside file of {} bytes",
                    e.offset,
                    buf.len()
                )));
            }
            let payload = &buf[e.offset as usize..end as usize];
            let actual = crc32(payload);
            if actual != e.crc {
                return Err(Error::store(format!(
                    "section {i} ({}) crc mismatch: stored {:#010x}, computed {actual:#010x}",
                    e.kind().map(|k| k.name()).unwrap_or("unknown"),
                    e.crc
                )));
            }
            entries.push(e);
        }
        Ok(Container { buf, entries })
    }

    /// Read and validate a container file (single read syscall).
    pub fn read(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut buf = std::fs::read(path).map_err(|e| {
            Error::store(format!("cannot read artifact {}: {e}", path.display()))
        })?;
        // Chaos hooks (no-ops unless a fault plan is live): simulate a
        // torn read and silent media corruption. Both must surface as
        // typed store errors from the validation below, never a panic.
        {
            use crate::util::fault::{self, FaultPoint};
            if fault::fire(FaultPoint::ArtifactShortRead).is_some() {
                buf.truncate(buf.len() / 2);
            }
            if let Some(a) = fault::fire(FaultPoint::ArtifactBitflip) {
                if !buf.is_empty() {
                    // flip one seeded bit — whether it lands in the
                    // header, the table, or a payload, validation must
                    // reject it (the CRC sweep covers the payloads)
                    let bit = a.seed as usize % (buf.len() * 8);
                    buf[bit / 8] ^= 1 << (bit % 8);
                }
            }
        }
        Self::from_bytes(buf)
    }

    /// Parsed section table, in file order.
    pub fn entries(&self) -> &[SectionEntry] {
        &self.entries
    }

    /// Total container size in bytes.
    pub fn file_bytes(&self) -> usize {
        self.buf.len()
    }

    /// Borrow the payload of the first section of `kind`, if present.
    /// The slice points into the load buffer — no copy.
    pub fn section(&self, kind: SectionKind) -> Option<&[u8]> {
        self.entries
            .iter()
            .find(|e| e.kind_code == kind.code())
            .map(|e| &self.buf[e.offset as usize..(e.offset + e.len) as usize])
    }

    /// Like [`Container::section`] but a typed error when missing.
    pub fn require(&self, kind: SectionKind) -> Result<&[u8]> {
        self.section(kind)
            .ok_or_else(|| Error::store(format!("missing required section '{}'", kind.name())))
    }
}

/// Little-endian payload reader used by section decoders. Every
/// accessor bounds-checks and returns [`Error::Store`] on underrun.
#[derive(Debug)]
pub(crate) struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Rd { buf, pos: 0 }
    }

    pub(crate) fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::store(format!(
                "section payload underrun: want {n} bytes at {}, have {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    /// A `u32`-length-prefixed UTF-8 string.
    pub(crate) fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let s = self.bytes(len)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| Error::store("section string is not valid UTF-8"))
    }

    /// `count` little-endian `f32`s.
    pub(crate) fn f32s(&mut self, count: usize) -> Result<Vec<f32>> {
        let raw = self.bytes(count * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// `count` little-endian `u32`s.
    pub(crate) fn u32s(&mut self, count: usize) -> Result<Vec<u32>> {
        let raw = self.bytes(count * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// `count` little-endian `u16`s.
    pub(crate) fn u16s(&mut self, count: usize) -> Result<Vec<u16>> {
        let raw = self.bytes(count * 2)?;
        Ok(raw
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Error unless the payload was consumed exactly.
    pub(crate) fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::store(format!(
                "section payload has {} trailing bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Little-endian payload writer used by section encoders.
#[derive(Debug, Default)]
pub(crate) struct Wr {
    buf: Vec<u8>,
}

impl Wr {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub(crate) fn f32s(&mut self, vs: &[f32]) {
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub(crate) fn u32s(&mut self, vs: &[u32]) {
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub(crate) fn u16s(&mut self, vs: &[u16]) {
        self.buf.reserve(vs.len() * 2);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub(crate) fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub(crate) fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let mut w = ContainerWriter::new();
        w.add(SectionKind::Params, vec![1, 2, 3, 4, 5]);
        w.add(SectionKind::Meta, vec![9; 32]);
        w.add(SectionKind::IndexLowRank, vec![0xAB; 7]);
        w.to_bytes()
    }

    #[test]
    fn roundtrip_sections() {
        let c = Container::from_bytes(sample()).unwrap();
        assert_eq!(c.entries().len(), 3);
        assert_eq!(c.section(SectionKind::Params).unwrap(), &[1, 2, 3, 4, 5]);
        assert_eq!(c.section(SectionKind::Meta).unwrap().len(), 32);
        assert_eq!(c.section(SectionKind::IndexLowRank).unwrap(), &[0xAB; 7]);
        assert!(c.section(SectionKind::IndexCsr).is_none());
        assert!(c.require(SectionKind::IndexCsr).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample();
        bytes[0] = b'X';
        let err = Container::from_bytes(bytes).unwrap_err();
        assert!(matches!(err, Error::Store(_)), "{err}");
        assert!(err.to_string().contains("magic"));
    }

    #[test]
    fn bad_version_rejected() {
        let mut bytes = sample();
        bytes[4] = 0xFF;
        let err = Container::from_bytes(bytes).unwrap_err();
        assert!(err.to_string().contains("version"));
    }

    #[test]
    fn truncation_rejected_at_every_length() {
        let bytes = sample();
        for cut in 0..bytes.len() {
            let err = Container::from_bytes(bytes[..cut].to_vec()).unwrap_err();
            assert!(matches!(err, Error::Store(_)), "cut at {cut}: {err}");
        }
        assert!(Container::from_bytes(bytes).is_ok());
    }

    #[test]
    fn payload_corruption_caught_by_crc() {
        let bytes = sample();
        let start = HEADER_LEN + 3 * ENTRY_LEN;
        for i in start..bytes.len() {
            let mut b = bytes.clone();
            b[i] ^= 0x40;
            let err = Container::from_bytes(b).unwrap_err();
            assert!(err.to_string().contains("crc"), "flip at {i}: {err}");
        }
    }

    #[test]
    fn rd_wr_roundtrip_and_underrun() {
        let mut w = Wr::new();
        w.u32(7);
        w.f64(-1.5);
        w.string("hello");
        w.f32s(&[1.0, 2.5]);
        w.u32s(&[3, 4]);
        w.u16s(&[5, 6]);
        w.raw(&[0xFF]);
        let bytes = w.into_bytes();
        let mut r = Rd::new(&bytes);
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.f64().unwrap(), -1.5);
        assert_eq!(r.string().unwrap(), "hello");
        assert_eq!(r.f32s(2).unwrap(), vec![1.0, 2.5]);
        assert_eq!(r.u32s(2).unwrap(), vec![3, 4]);
        assert_eq!(r.u16s(2).unwrap(), vec![5, 6]);
        assert!(r.finish().is_err()); // 1 trailing byte
        assert_eq!(r.bytes(1).unwrap(), &[0xFF]);
        r.finish().unwrap();
        assert!(r.u32().is_err()); // underrun is an error, not a panic
    }
}
